"""Serving engine: completion, continuous batching, overload integration."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _engine(arch="llsc-100m", slots=2, max_seq=64):
    cfg = reduced_config(arch)
    params = init_params(cfg, KEY)
    return cfg, ServeEngine(cfg, params,
                            EngineConfig(slots=slots, max_seq_len=max_seq,
                                         monitor=True))


def _req(i, n=6, prompt_len=8, vocab=512):
    rng = np.random.default_rng(i)
    return Request(i, rng.integers(0, vocab, prompt_len).astype(np.int32),
                   max_new_tokens=n)


def test_completes_all_requests():
    cfg, eng = _engine(slots=2)
    for i in range(5):
        eng.submit(_req(i))
    stats = eng.run()
    assert stats["requests"] == 5
    ids = sorted(c.request_id for c in eng.completions)
    assert ids == list(range(5))
    for c in eng.completions:
        assert len(c.tokens) == 6
        assert all(0 <= t < cfg.vocab_size for t in c.tokens)


def test_deterministic_across_slot_counts():
    """Greedy generations are identical with 1 slot vs 4 slots."""
    _, e1 = _engine(slots=1)
    _, e4 = _engine(slots=4)
    for i in range(4):
        e1.submit(_req(i))
        e4.submit(_req(i))
    e1.run()
    e4.run()
    out1 = {c.request_id: c.tokens for c in e1.completions}
    out4 = {c.request_id: c.tokens for c in e4.completions}
    assert out1 == out4


def test_ssm_arch_serving():
    """State-carrying arch (mamba2) must decode correctly after prefill."""
    _, eng = _engine(arch="mamba2-370m", slots=2)
    for i in range(3):
        eng.submit(_req(i, n=4))
    stats = eng.run()
    assert stats["requests"] == 3


def test_overload_controller_sees_duty():
    _, eng = _engine(slots=2)
    for i in range(4):
        eng.submit(_req(i))
    stats = eng.run()
    assert stats["decision"].nppn in (1, 2, 4, 8)
    assert eng.controller.history, "controller should have observations"


def test_throughput_reported():
    _, eng = _engine(slots=2)
    eng.submit(_req(0))
    stats = eng.run()
    assert stats["tokens_per_s"] > 0
    assert stats["tokens"] >= stats["requests"]

"""MetricSource layer: protocol conformance, archive replay round-trip,
multi-cluster merge semantics, and the source registry."""
import random

import pytest

from repro.cluster.workloads import make_llsc_sim, paper_scenario
from repro.core.archive import SnapshotArchive
from repro.core.collector import SimCollector
from repro.core.metrics import ClusterSnapshot
from repro.monitor import (ArchiveSource, MetricSource, MultiClusterSource,
                           RegistrySource, SimSource, SourceRegistry,
                           build_source, default_registry, merge_snapshots)


def _sim(cluster="txgreen", n_cpu=6, n_gpu=4, until=1800.0):
    sim = make_llsc_sim(n_cpu, n_gpu, cluster=cluster)
    paper_scenario(sim, random.Random(0))
    sim.run_until(until)
    return sim


# ------------------------------------------------------------------ protocol


def test_all_sources_satisfy_protocol(tmp_path):
    sim = _sim()
    archive = SnapshotArchive(str(tmp_path))
    archive.append(sim.snapshot())
    sources = [
        SimSource(sim),
        RegistrySource(),
        ArchiveSource(archive.files()),
        MultiClusterSource([SimSource(sim)]),
    ]
    for src in sources:
        assert isinstance(src, MetricSource)
        assert isinstance(src.snapshot(), ClusterSnapshot)


def test_sim_source_matches_collector_and_advances():
    sim = _sim()
    src = sim.as_source()
    assert src.snapshot().to_tsv() == SimCollector(sim).snapshot().to_tsv()

    moving = _sim().as_source(advance_s=900.0)
    t0 = moving.snapshot().timestamp
    t1 = moving.snapshot().timestamp
    assert t1 == t0 + 900.0


# ------------------------------------------------------- archive round-trip


def test_archive_tsv_roundtrip(tmp_path):
    sim = _sim()
    orig = sim.snapshot()
    archive = SnapshotArchive(str(tmp_path), cluster="txgreen")
    archive.append(orig)

    src = archive.as_source()
    replay = src.snapshot()

    assert replay.cluster == orig.cluster
    assert replay.timestamp == orig.timestamp
    # archived rows only cover owned nodes
    owned = {h for j in orig.jobs if j.state == "R" for h in j.nodes}
    assert set(replay.nodes) == owned
    for host in owned:
        a, b = orig.nodes[host], replay.nodes[host]
        assert b.cores_total == a.cores_total
        assert b.cores_used == a.cores_used
        assert abs(b.load - a.load) < 1e-3
        assert b.gpus_total == a.gpus_total
        assert abs(b.gpu_load - a.gpu_load) < 1e-3
    # user -> nodes attribution survives the round trip (the TSV format
    # attributes each host to its single owning job, so users who only
    # share already-owned nodes are folded into the owner's rows)
    orig_by_user = orig.nodes_by_user()
    replay_by_user = replay.nodes_by_user()
    assert set(replay_by_user) <= set(orig_by_user)
    for user, hosts in replay_by_user.items():
        assert set(hosts) <= set(orig_by_user[user])


def test_archive_source_steps_through_frames(tmp_path):
    sim = _sim(until=900.0)
    archive = SnapshotArchive(str(tmp_path))
    for _ in range(3):
        archive.append(sim.snapshot())
        sim.run_until(sim.t + 900.0)

    src = archive.as_source()
    assert len(src) == 3
    stamps = [src.snapshot().timestamp for _ in range(5)]
    assert stamps[0] < stamps[1] < stamps[2]
    assert stamps[2] == stamps[3] == stamps[4]   # holds the last frame
    assert src.cadence_s == 900.0
    assert src.interval_hint is None   # replay pace is the poller's choice

    src.rewind()
    assert src.snapshot().timestamp == stamps[0]

    looping = archive.as_source(loop=True)
    seq = [looping.snapshot().timestamp for _ in range(4)]
    assert seq[3] == seq[0]


def test_archive_source_empty_raises(tmp_path):
    src = ArchiveSource(str(tmp_path))
    with pytest.raises(ValueError):
        src.snapshot()


def test_archive_source_multi_cluster_root_merges_not_corrupts(tmp_path):
    """An archive root holding several clusters (same hostnames, same
    timestamps) must merge frames with qualification, not overwrite."""
    for cname in ("east", "west"):
        sim = _sim(cname, until=900.0)
        SnapshotArchive(str(tmp_path), cluster=cname).append(sim.snapshot())

    src = ArchiveSource(str(tmp_path))
    assert len(src) == 1                      # one merged frame per stamp
    snap = src.snapshot()
    east = ArchiveSource(str(tmp_path), cluster="east").snapshot()
    # both clusters' nodes survive, qualified on collision
    assert len(snap.nodes) == 2 * len(east.nodes)
    assert {h.split(":")[0] for h in snap.nodes} == {"east", "west"}

    # cluster= still restricts to one
    assert set(east.nodes) == {h.split(":", 1)[1] for h in snap.nodes
                               if h.startswith("east:")}


# ------------------------------------------------------- multi-cluster merge


def test_multi_cluster_merges_and_qualifies_collisions():
    a, b = _sim("alpha"), _sim("beta")
    multi = MultiClusterSource([SimSource(a), SimSource(b)])
    snap = multi.snapshot()

    assert snap.cluster == "alpha+beta"
    # identical topologies => every hostname collides => all qualified
    assert len(snap.nodes) == len(a.snapshot().nodes) * 2
    assert all(":" in h for h in snap.nodes)
    assert {h.split(":")[0] for h in snap.nodes} == {"alpha", "beta"}
    # job node lists are renamed consistently with the node table
    for job in snap.jobs:
        for h in job.nodes:
            assert h in snap.nodes
    # NodeSnapshot.hostname matches its key after qualification
    for h, node in snap.nodes.items():
        assert node.hostname == h


def test_multi_cluster_keeps_unique_hostnames_short():
    a = _sim("alpha")
    b = _sim("beta")
    # rename beta's nodes so nothing collides
    bsnap = b.snapshot()

    class Renamed:
        name = "beta"
        interval_hint = None

        def snapshot(self):
            import dataclasses
            nodes = {f"b-{h}": dataclasses.replace(n, hostname=f"b-{h}")
                     for h, n in bsnap.nodes.items()}
            jobs = [dataclasses.replace(j, nodes=[f"b-{h}" for h in j.nodes])
                    for j in bsnap.jobs]
            return ClusterSnapshot("beta", bsnap.timestamp, nodes, jobs)

    snap = MultiClusterSource([SimSource(a), Renamed()]).snapshot()
    assert all(":" not in h for h in snap.nodes)


def test_multi_cluster_staleness_on_child_failure():
    a = _sim("alpha")

    class Flaky:
        name = "flaky"
        interval_hint = None

        def __init__(self):
            self.fail = False
            self._sim = _sim("flaky")

        def snapshot(self):
            if self.fail:
                raise RuntimeError("collection failed")
            return self._sim.snapshot()

    flaky = Flaky()
    multi = MultiClusterSource([SimSource(a), flaky])
    s1 = multi.snapshot()                      # both healthy
    n_nodes = len(s1.nodes)

    flaky.fail = True
    s2 = multi.snapshot()                      # flaky serves last-good
    assert len(s2.nodes) == n_nodes
    assert isinstance(multi.last_error("flaky"), RuntimeError)
    assert multi.last_error("alpha") is None
    assert set(multi.staleness()) == {"alpha", "flaky"}


def test_multi_cluster_hung_child_serves_last_good():
    """A child that exceeds the collection timeout must not break the
    merged snapshot — it serves its last good one and reports the miss."""
    import time as _time

    a = _sim("alpha")

    class Hanging:
        name = "slow"
        interval_hint = None

        def __init__(self):
            self.hang = False
            self._sim = _sim("slow")

        def snapshot(self):
            if self.hang:
                _time.sleep(1.0)
            return self._sim.snapshot()

    slow = Hanging()
    multi = MultiClusterSource([SimSource(a), slow], timeout_s=0.15)
    n_nodes = len(multi.snapshot().nodes)      # both healthy

    slow.hang = True
    snap = multi.snapshot()                    # returns before 1s sleep ends
    assert len(snap.nodes) == n_nodes
    assert isinstance(multi.last_error("slow"), TimeoutError)


def test_multi_cluster_max_staleness_cuts_only_failing_children():
    """Regression (unbounded staleness): with max_staleness_s set, a
    failing child serves its last good snapshot only within the window,
    then is cut from the merge and reported via stale_children(); a
    healthy child is never cut, and recovery restores the full fleet."""
    import time as _time

    a = _sim("alpha")

    class Flaky:
        name = "flaky"
        interval_hint = None

        def __init__(self):
            self.fail = False
            self._sim = _sim("flaky")

        def snapshot(self):
            if self.fail:
                raise RuntimeError("collection failed")
            return self._sim.snapshot()

    flaky = Flaky()
    multi = MultiClusterSource([SimSource(a), flaky], max_staleness_s=0.6)
    n_both = len(multi.snapshot().nodes)
    assert multi.stale_children() == {}

    flaky.fail = True
    s = multi.snapshot()                 # inside the window: last-good serves
    assert len(s.nodes) == n_both
    assert multi.stale_children() == {}

    _time.sleep(0.7)
    s = multi.snapshot()                 # beyond it: the stale child is cut
    assert len(s.nodes) == len(a.snapshot().nodes)
    stale = multi.stale_children()
    assert set(stale) == {"flaky"} and stale["flaky"] > 0.6

    flaky.fail = False                   # recovery rejoins the merge
    s = multi.snapshot()
    assert len(s.nodes) == n_both
    assert multi.stale_children() == {}


def test_multi_cluster_all_children_stale_raises():
    import time as _time

    class Mortal:
        name = "mortal"
        interval_hint = None

        def __init__(self):
            self.fail = False
            self._sim = _sim("mortal")

        def snapshot(self):
            if self.fail:
                raise RuntimeError("down")
            return self._sim.snapshot()

    mortal = Mortal()
    multi = MultiClusterSource([mortal], max_staleness_s=0.05)
    multi.snapshot()
    mortal.fail = True
    _time.sleep(0.1)
    with pytest.raises(RuntimeError):
        multi.snapshot()                 # stale fallback is not "working"
    assert set(multi.stale_children()) == {"mortal"}


def test_multi_cluster_all_failed_raises():
    class Dead:
        name = "dead"
        interval_hint = None

        def snapshot(self):
            raise RuntimeError("down")

    with pytest.raises(RuntimeError):
        MultiClusterSource([Dead()]).snapshot()


def test_merge_snapshots_single_passthrough():
    snap = _sim().snapshot()
    assert merge_snapshots([snap]) is snap


# --------------------------------------------------------------- registry


def test_default_registry_names():
    assert {"sim", "live", "jobs", "archive"} <= \
        set(default_registry().names())


def test_registry_unknown_source():
    with pytest.raises(KeyError):
        SourceRegistry().create("nope")


def test_build_source_fans_out_over_clusters():
    src = build_source("sim", clusters=["alpha", "beta"])
    assert isinstance(src, MultiClusterSource)
    assert src.name == "alpha+beta"
    single = build_source("sim", clusters=["gamma"])
    assert isinstance(single, SimSource)
    assert single.name == "gamma"

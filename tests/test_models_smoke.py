"""Per-arch smoke tests (assignment requirement): reduced config of the same
family -> one forward + one train step on CPU, assert shapes + no NaNs;
plus the decode==prefill consistency invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.archs import ASSIGNED
from repro.models import (decode_step, init_cache, init_params, lm_loss,
                          prefill)
from repro.train.train_step import default_opt_cfg, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
K1, K2, K3, K4 = jax.random.split(KEY, 4)


def _inputs(cfg, B=2, S=24):
    tokens = jax.random.randint(K2, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(K3, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend == "patch_stub":
        fe = jax.random.normal(K4, (B, cfg.frontend_len, cfg.d_model),
                               jnp.float32)
    elif cfg.frontend == "audio_stub":
        fe = jax.random.normal(K4, (B, cfg.encoder.source_len, cfg.d_model),
                               jnp.float32)
    return tokens, labels, fe


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    B, S = 2, 24
    tokens, labels, fe = _inputs(cfg, B, S)

    # forward (loss) — finite
    params = init_params(cfg, K1)
    loss = lm_loss(params, cfg, tokens, labels, fe)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"

    # one full train step — params update, loss finite, no NaN grads
    opt_cfg = default_opt_cfg(cfg, total_steps=10)
    state = init_train_state(cfg, K1, opt_cfg)
    batch = {"tokens": tokens, "labels": labels}
    if fe is not None:
        batch["frontend"] = fe
    step = make_train_step(cfg, opt_cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state.params, new_state.params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_prefill_decode_consistency(arch):
    cfg = reduced_config(arch)
    B, S = 2, 24
    tokens, _, fe = _inputs(cfg, B, S)
    params = init_params(cfg, K1)

    logits_full, _ = prefill(params, cfg, tokens, fe)
    assert logits_full.shape == (B, cfg.vocab_size)
    _, caches = prefill(params, cfg, tokens[:, : S - 1], fe)

    T = S + (cfg.frontend_len if cfg.frontend == "patch_stub" else 0)
    cap = init_cache(cfg, B, T)

    def grow(c, full):
        if c.shape == full.shape:
            return c
        pad = [(0, 0)] * c.ndim
        for ax, (a, b) in enumerate(zip(c.shape, full.shape)):
            if a != b:
                pad[ax] = (0, b - a)
        return jnp.pad(c, pad)

    caches = jax.tree.map(grow, caches, cap)
    pos = S - 1 + (cfg.frontend_len if cfg.frontend == "patch_stub" else 0)
    logits_dec, new_caches = decode_step(params, cfg, tokens[:, S - 1:],
                                         caches, jnp.int32(pos))
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    assert err < 2e-4, f"{arch}: decode/prefill mismatch {err}"
    # cache structure preserved
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


def test_param_counts_sane():
    # full configs: analytic counts in the right ballpark (catches config typos)
    expect = {
        "phi3-medium-14b": (12e9, 16e9),
        "qwen1.5-4b": (3e9, 5e9),
        "jamba-1.5-large-398b": (330e9, 430e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "granite-moe-1b-a400m": (1.0e9, 1.7e9),
        "mamba2-370m": (0.30e9, 0.45e9),
        "gemma3-1b": (0.8e9, 1.3e9),
        "whisper-base": (0.05e9, 0.11e9),
    }
    from repro.models import count_params, count_params_analytic
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
    # MoE active < total
    for arch in ("qwen3-moe-30b-a3b", "granite-moe-1b-a400m",
                 "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        assert count_params_analytic(cfg, True) < count_params(cfg) * 0.6

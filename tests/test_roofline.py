"""Roofline math + HLO collective parser."""
import pytest

from repro.roofline import hw
from repro.roofline.analysis import parse_collective_bytes, roofline

HLO = """
HloModule test
  %x = bf16[128,1024]{1,0} parameter(0)
  %ar = bf16[128,1024]{1,0} all-reduce(bf16[128,1024]{1,0} %x), replica_groups={}
  %ag = f32[256,512]{1,0} all-gather(f32[16,512]{1,0} %y), dimensions={0}
  %rs = f32[16,512]{1,0} reduce-scatter(f32[256,512]{1,0} %z), dimensions={0}
  %a2a = bf16[64,64]{1,0} all-to-all(bf16[64,64]{1,0} %w), dimensions={0}
  %cp = s32[8]{0} collective-permute(s32[8]{0} %v), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(f32[128,64]{1,0} %a, f32[64,128]{1,0} %b)
"""


def test_parse_collectives():
    out = parse_collective_bytes(HLO)
    assert out["all-reduce"] == pytest.approx(2 * 128 * 1024 * 2)
    assert out["all-gather"] == pytest.approx(256 * 512 * 4)
    assert out["reduce-scatter"] == pytest.approx(16 * 512 * 4)
    assert out["all-to-all"] == pytest.approx(64 * 64 * 2)
    assert out["collective-permute"] == pytest.approx(8 * 4)
    counts = out["_op_counts"]
    assert counts["all-reduce"] == 1 and counts["all-gather"] == 1


def test_parse_tuple_form_async():
    hlo = ('%ar = (bf16[4,8]{1,0}, bf16[4,8]{1,0}) all-reduce-start('
           'bf16[4,8]{1,0} %p), replica_groups={}')
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"] == pytest.approx(2 * 2 * 4 * 8 * 2)


def test_roofline_terms_and_dominant():
    cost = {"flops": 197e12, "bytes accessed": 819e9 / 2}
    t = roofline(cost, "", n_devices=256, model_flops_global=197e12 * 256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.dominant == "compute"
    assert t.useful_ratio == pytest.approx(1.0)
    assert t.roofline_fraction() == pytest.approx(1.0)


def test_collective_dominant():
    cost = {"flops": 1e9, "bytes accessed": 1e6}
    hlo = "%ar = f32[1000000]{0} all-reduce(f32[1000000]{0} %x)"
    t = roofline(cost, hlo, n_devices=4)
    assert t.dominant == "collective"
    assert t.collective_bytes == pytest.approx(8e6)


def test_hw_constants():
    assert hw.PEAK_FLOPS_BF16 == 197e12
    assert hw.HBM_BW == 819e9
    assert hw.ICI_BW_PER_LINK == 50e9

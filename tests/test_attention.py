"""Chunked attention vs full-softmax reference; windows; banded path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunked_attention

KEY = jax.random.PRNGKey(0)


def _ref(q, k, v, *, causal=True, window=None, q_offset=0, kv_valid_len=None,
         softcap=None):
    B, S, H, D = q.shape
    Hk, T = k.shape[2], k.shape[1]
    G = H // Hk
    qg = q.reshape(B, S, Hk, G, D)
    s = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * D ** -0.5
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = q_offset + jnp.arange(S)
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None]) < window
    if kv_valid_len is not None:
        mask &= kpos[None] < kv_valid_len
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgst,bthd->bshgd", w, v.astype(jnp.float32))
    return o.reshape(B, S, H, -1)


def _qkv(B=2, S=64, H=4, Hk=2, D=16, T=None, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    T = T or S
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, T, Hk, D))
    v = jax.random.normal(ks[2], (B, T, Hk, D))
    return q, k, v


@pytest.mark.parametrize("chunk", [16, 32, 64, 128])
def test_chunked_equals_full(chunk):
    q, k, v = _qkv()
    out = chunked_attention(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_ref(q, k, v)),
                               rtol=1e-4, atol=1e-5)


def test_non_divisible_seq_pads():
    q, k, v = _qkv(S=50, T=50)
    out = chunked_attention(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_ref(q, k, v)),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("window", [8, 16, 48])
def test_sliding_window_masked(window):
    q, k, v = _qkv()
    out = chunked_attention(q, k, v, causal=True, window=window, chunk=16)
    ref = _ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("window", [8, 16])
def test_banded_equals_masked(window):
    """The banded (KV-sliced) local path is exact for window <= band."""
    q, k, v = _qkv(S=96)
    a = chunked_attention(q, k, v, causal=True, window=window, chunk=16,
                          banded=False)
    b = chunked_attention(q, k, v, causal=True, window=window, chunk=16,
                          banded=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)


def test_softcap():
    q, k, v = _qkv()
    out = chunked_attention(q, k, v, causal=True, softcap=30.0, chunk=32)
    ref = _ref(q, k, v, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)


def test_decode_single_query_with_valid_len():
    q, k, v = _qkv(S=1, T=64)
    out = chunked_attention(q, k, v, causal=True, q_offset=40,
                            kv_valid_len=41)
    ref = _ref(q, k, v, q_offset=40, kv_valid_len=41)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)


def test_decode_vector_lengths():
    """Per-row cache lengths (continuous batching)."""
    B = 3
    q, k, v = _qkv(B=B, S=1, T=64)
    lens = jnp.asarray([10, 40, 63])
    out = chunked_attention(q, k, v, causal=True, q_offset=lens,
                            kv_valid_len=lens + 1)
    for i in range(B):
        ref = _ref(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                   q_offset=int(lens[i]), kv_valid_len=int(lens[i]) + 1)
        np.testing.assert_allclose(np.asarray(out[i:i + 1]),
                                   np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_mla_value_dim_differs():
    q, k, _ = _qkv(D=24)
    v = jax.random.normal(jax.random.PRNGKey(9), (2, 64, 2, 8))
    out = chunked_attention(q, k, v, causal=True, chunk=16)
    assert out.shape == (2, 64, 4, 8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_ref(q, k, v)),
                               rtol=1e-4, atol=1e-5)

"""MoE auxiliary losses, sampling, async checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import MoESpec
from repro.models import init_params, lm_loss
from repro.models.moe import (init_moe, load_balance_loss, moe_aux_losses,
                              router_z_loss, _route)
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.train import checkpoint as ck
from repro.train.train_step import default_opt_cfg, init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ aux losses ---

def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform router => loss == 1 (Switch normalization)."""
    T, E = 512, 8
    logits = jnp.zeros((T, E))
    idx = jnp.stack([jnp.arange(T) % E, (jnp.arange(T) + 1) % E], axis=1)
    spec = MoESpec(n_experts=E, top_k=2, d_ff_expert=8)
    assert float(load_balance_loss(logits, idx, spec)) == pytest.approx(1.0)


def test_load_balance_loss_collapse_is_high():
    T, E = 256, 8
    logits = jnp.full((T, E), -10.0).at[:, 0].set(10.0)
    idx = jnp.zeros((T, 2), jnp.int32)
    spec = MoESpec(n_experts=E, top_k=2, d_ff_expert=8)
    assert float(load_balance_loss(logits, idx, spec)) > 4.0


def test_router_z_loss_penalizes_scale():
    small = router_z_loss(jnp.ones((64, 8)))
    big = router_z_loss(100.0 * jnp.ones((64, 8)))
    assert float(big) > float(small)


def test_lm_loss_with_aux_weights_differs_and_trains():
    cfg = reduced_config("granite-moe-1b-a400m")
    params = init_params(cfg, KEY)
    k1, k2 = jax.random.split(KEY)
    tokens = jax.random.randint(k1, (2, 24), 0, cfg.vocab_size)
    labels = jax.random.randint(k2, (2, 24), 0, cfg.vocab_size)
    plain = float(lm_loss(params, cfg, tokens, labels))
    withaux = float(lm_loss(params, cfg, tokens, labels,
                            aux_weights=(0.01, 1e-3)))
    assert withaux > plain  # aux losses are non-negative, ~1.0 at init

    opt_cfg = default_opt_cfg(cfg, total_steps=5)
    state = init_train_state(cfg, KEY, opt_cfg)
    step = make_train_step(cfg, opt_cfg, aux_weights=(0.01, 1e-3))
    state2, metrics = step(state, {"tokens": tokens, "labels": labels})
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


def test_aux_weights_ignored_for_dense():
    cfg = reduced_config("qwen1.5-4b")
    params = init_params(cfg, KEY)
    k1, k2 = jax.random.split(KEY)
    tokens = jax.random.randint(k1, (2, 16), 0, cfg.vocab_size)
    labels = jax.random.randint(k2, (2, 16), 0, cfg.vocab_size)
    a = float(lm_loss(params, cfg, tokens, labels))
    b = float(lm_loss(params, cfg, tokens, labels, aux_weights=(0.01, 1e-3)))
    assert a == pytest.approx(b)


# -------------------------------------------------------------- sampling ---

def _engine(greedy, top_k=0, temp=1.0, seed=0):
    cfg = reduced_config("llsc-100m")
    params = init_params(cfg, KEY)
    return cfg, ServeEngine(cfg, params, EngineConfig(
        slots=2, max_seq_len=64, monitor=False, greedy=greedy,
        top_k=top_k, temperature=temp, seed=seed))


def test_sampling_deterministic_by_seed():
    outs = []
    for _ in range(2):
        cfg, eng = _engine(greedy=False, top_k=8, temp=1.0, seed=7)
        rng = np.random.default_rng(0)
        for i in range(3):
            eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 8)
                               .astype(np.int32), max_new_tokens=5))
        eng.run()
        outs.append({c.request_id: c.tokens for c in eng.completions})
    assert outs[0] == outs[1]


def test_sampling_differs_from_greedy():
    results = {}
    for greedy in (True, False):
        cfg, eng = _engine(greedy=greedy, top_k=0, temp=5.0, seed=3)
        rng = np.random.default_rng(0)
        for i in range(3):
            eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 8)
                               .astype(np.int32), max_new_tokens=6))
        eng.run()
        results[greedy] = {c.request_id: c.tokens for c in eng.completions}
    assert results[True] != results[False]


# --------------------------------------------------------- async ckpt ------

def test_async_checkpoint_trainer(tmp_path):
    cfg = reduced_config("llsc-100m")
    t = Trainer(cfg, TrainerConfig(steps=6, batch_size=2, seq_len=32,
                                   ckpt_dir=str(tmp_path), ckpt_every=2,
                                   async_ckpt=True, log_every=0,
                                   monitor_every=0))
    t.run(resume=False)
    ck.wait_pending_checkpoints()
    steps = ck.list_checkpoints(str(tmp_path))
    assert 6 in steps and len(steps) >= 2
    # resumable
    template = jax.eval_shape(t._init_state)
    state, meta = ck.restore_checkpoint(str(tmp_path), 6, template)
    assert meta["step"] == 6

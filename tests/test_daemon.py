"""LLload daemon: lifecycle, cached serving, wire round-trip, Prometheus
exposition, remote CLI byte-identity, cluster-of-clusters."""
import io
import contextlib
import json
import socket
import threading
import urllib.error
import urllib.request

import pytest

from repro.core import cli
from repro.daemon import (LLloadDaemon, RemoteSource, WireError,
                          decode_snapshot, encode_snapshot,
                          parse_prometheus, serve_background)
from repro.daemon import protocol
from repro.monitor import build_source


@pytest.fixture(scope="module")
def daemon_url():
    daemon = LLloadDaemon(build_source("sim"), ttl_s=3600.0)
    server, thread = serve_background(daemon)
    host, port = server.server_address[:2]
    yield f"http://{host}:{port}", daemon
    server.shutdown()
    server.server_close()
    daemon.close()
    thread.join(timeout=5)
    assert not thread.is_alive()


def _get(url, path):
    with urllib.request.urlopen(url + path, timeout=10) as rsp:
        return rsp.read()


# ---------------------------------------------------------------- lifecycle


def test_healthz(daemon_url):
    url, _ = daemon_url
    h = json.loads(_get(url, "/healthz"))
    assert h["status"] == "ok"
    assert h["wire_version"] == protocol.WIRE_VERSION
    assert h["source"] == "txgreen"


def test_graceful_shutdown_frees_port():
    daemon = LLloadDaemon(build_source("sim"), ttl_s=60.0)
    server, thread = serve_background(daemon)
    host, port = server.server_address[:2]
    assert json.loads(_get(f"http://{host}:{port}", "/healthz"))["status"] \
        == "ok"
    server.shutdown()
    server.server_close()
    daemon.close()
    thread.join(timeout=5)
    assert not thread.is_alive()
    # the socket is really gone: a fresh bind on the same port succeeds
    with socket.socket() as s:
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind((host, port))


# ------------------------------------------------------------- cached reads


def test_concurrent_readers_hit_cache(daemon_url):
    """N concurrent /snapshot readers cost one collection and one encode:
    the collections counter stays flat and every body is the same bytes."""
    url, daemon = daemon_url
    before = daemon.bus.stats("txgreen").collections
    _get(url, "/snapshot")                      # warm the byte-cache
    hits_before = daemon.counters()["http_cache_hits_total"]

    bodies = []
    lock = threading.Lock()

    def reader():
        body = _get(url, "/snapshot")
        with lock:
            bodies.append(body)

    threads = [threading.Thread(target=reader) for _ in range(12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert len(set(bodies)) == 1
    after = daemon.bus.stats("txgreen").collections
    assert after == max(before, 1), "cached reads must not re-collect"
    assert daemon.counters()["http_cache_hits_total"] >= hits_before + 12


# ------------------------------------------------------------- wire schema


def test_remote_source_roundtrips_byte_identically(daemon_url):
    """The snapshot that comes back over HTTP is indistinguishable from
    the local one — every node, job, email and float."""
    url, _ = daemon_url
    remote = RemoteSource(url).snapshot()
    local = build_source("sim").snapshot()     # deterministic sim
    assert remote == local
    assert remote.to_tsv() == local.to_tsv()


def test_wire_round_trip_exact():
    snap = build_source("sim").snapshot()
    again = decode_snapshot(json.loads(json.dumps(encode_snapshot(snap))))
    assert again == snap
    assert list(again.nodes) == list(snap.nodes)   # order preserved


def test_wire_rejects_newer_version():
    snap = build_source("sim").snapshot()
    wire = encode_snapshot(snap)
    wire["v"] = protocol.WIRE_VERSION + 1
    with pytest.raises(WireError, match="newer than supported"):
        decode_snapshot(wire)


def test_wire_ignores_unknown_fields():
    wire = encode_snapshot(build_source("sim").snapshot())
    wire["snapshot"]["future_field"] = {"x": 1}     # additive => no bump
    assert decode_snapshot(wire) == build_source("sim").snapshot()


# ------------------------------------------------------------- CLI remote


def _run_cli(argv):
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        rc = cli.main(argv)
    return rc, buf.getvalue()


@pytest.mark.parametrize("view", [
    ["-g", "--user", "va67890"],
    ["-t", "5"],
    ["--all", "-g", "--user", "admin"],
    ["--tsv"],
])
def test_cli_remote_byte_identical(daemon_url, view):
    url, _ = daemon_url
    rc_l, local = _run_cli(["--source", "sim"] + view)
    rc_r, remote = _run_cli(["--source", "remote", "--url", url] + view)
    assert rc_l == rc_r == 0
    assert remote == local


def test_cli_remote_requires_url():
    with pytest.raises(SystemExit):
        cli.main(["--source", "remote"])


def test_cli_remote_watch(daemon_url):
    url, _ = daemon_url
    rc, out = _run_cli(["--source", "remote", "--url", url,
                        "--watch", "--interval", "0.05", "--frames", "2",
                        "-t", "3"])
    assert rc == 0
    assert out.count("LLload watch") == 2


# ---------------------------------------------------------------- /metrics


def test_metrics_parses_as_prometheus(daemon_url):
    url, daemon = daemon_url
    text = _get(url, "/metrics").decode()
    families = parse_prometheus(text)
    snap = daemon.bus.read("txgreen")
    assert len(families["llload_node_norm_load"]) == len(snap.nodes)
    sample = next(iter(families["llload_node_norm_load"]))
    assert 'cluster="txgreen"' in sample and 'host="' in sample
    assert families["llload_cluster_nodes"][f'{{cluster="txgreen"}}'] \
        == len(snap.nodes)
    assert any(k.startswith("llload_user_nodes") for k in families)
    assert "# TYPE llload_node_norm_load gauge" in text
    assert "llload_daemon_bus_collections_total" in text


# --------------------------------------------------------- views + errors


def test_view_endpoints(daemon_url):
    url, _ = daemon_url
    top = _get(url, "/view/top?n=3").decode()
    assert "sorted by descending order" in top
    user = _get(url, "/view/user?user=va67890&gpu=1").decode()
    assert "va67890" in user and "GPUMEM" in user
    host = build_source("sim").snapshot().to_tsv().splitlines()[1] \
        .split("\t")[2]
    nodes = _get(url, f"/view/nodes?hosts={host}").decode()
    assert host in nodes


def test_query_endpoint_json_schema(daemon_url):
    url, _ = daemon_url
    obj = json.loads(_get(
        url, "/query?table=nodes&filter=gpus%3E0&columns=host,gpu_load"
             "&sort=-gpu_load&limit=3"))
    assert obj["v"] == 1 and obj["kind"] == "query_result"
    qr = obj["query_result"]
    assert qr["columns"] == ["host", "gpu_load"]
    assert len(qr["rows"]) == 3
    loads = [r[1] for r in qr["rows"]]
    assert loads == sorted(loads, reverse=True)


def test_query_endpoint_history_table(daemon_url):
    url, _ = daemon_url
    _get(url, "/snapshot")         # force >= 1 collection into the store
    obj = json.loads(_get(url, "/query?table=history&filter=tier%3D%3Draw"))
    rows = obj["query_result"]["rows"]
    assert rows, "raw tier should hold at least one summarized snapshot"


def test_query_endpoint_is_cached(daemon_url):
    url, daemon = daemon_url
    path = "/query?table=users&format=csv"
    first = _get(url, path)
    hits_before = daemon.counters()["http_cache_hits_total"]
    assert _get(url, path) == first
    assert daemon.counters()["http_cache_hits_total"] > hits_before


def test_query_endpoint_rejects_bad_queries(daemon_url):
    url, _ = daemon_url
    for path in ("/query?table=nope", "/query?columns=bogus",
                 "/query?limit=0", "/query?sort=-bogus",
                 "/query?format=xml", "/query?filter=cores%3E%3E1"):
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(url, path)
        assert ei.value.code == 400, path
        err = json.loads(ei.value.read())
        assert err["kind"] == "error"
    # unknown column error carries the vocabulary
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(url, "/query?columns=bogus")
    msg = json.loads(ei.value.read())["error"]["message"]
    assert "norm_load" in msg


def test_view_passthrough_query_params(daemon_url):
    url, _ = daemon_url
    # format passthrough: the same canned view as machine-readable rows
    obj = json.loads(_get(url, "/view/top?n=4&format=json"))
    assert len(obj["query_result"]["rows"]) == 4
    # filter passthrough narrows the text view
    text = _get(url, "/view/user?user=va67890&filter=norm_load%3E1e9") \
        .decode()
    assert "Nodes used: 0" in text


def test_errors_are_wire_envelopes(daemon_url):
    url, _ = daemon_url
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(url, "/nope")
    assert ei.value.code == 404
    err = json.loads(ei.value.read())
    assert err["kind"] == "error" and err["v"] == protocol.WIRE_VERSION
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(url, "/view/user")                # missing ?user
    assert ei.value.code == 400


def test_trend_and_weekly_endpoints(daemon_url):
    url, _ = daemon_url
    trend = json.loads(_get(url, "/trend"))
    assert trend["kind"] == "trend"
    pts = trend["trend"]["points"]
    assert pts and {"t", "count", "norm_load"} <= set(pts[0])
    assert pts[0]["norm_load"]["min"] <= pts[0]["norm_load"]["max"]
    weekly = json.loads(_get(url, "/weekly"))
    assert weekly["kind"] == "weekly"
    assert {"low_gpu", "low_cpu", "high_cpu"} <= set(weekly["weekly"])


# ------------------------------------------------------ cluster-of-clusters


def test_daemon_over_daemon(daemon_url):
    """A second daemon whose source is the first daemon serves the same
    snapshot — any daemon can fan out over other daemons."""
    url, _ = daemon_url
    upstream = RemoteSource(url, name="tier0")
    d2 = LLloadDaemon(upstream, ttl_s=3600.0)
    server, thread = serve_background(d2)
    try:
        host, port = server.server_address[:2]
        snap = RemoteSource(f"http://{host}:{port}").snapshot()
        assert snap == build_source("sim").snapshot()
    finally:
        server.shutdown()
        server.server_close()
        d2.close()
        thread.join(timeout=5)


def test_error_requests_do_not_leak_build_locks(daemon_url):
    """Distinct erroring cacheable queries must not grow the per-key
    build-lock table (it is only retained for successfully cached
    bodies)."""
    url, daemon = daemon_url
    for i in range(20):
        with pytest.raises(urllib.error.HTTPError):
            _get(url, f"/trend?tier=bogus{i}")
    assert not any("bogus" in k for k in daemon._build_locks)
    assert len(daemon._build_locks) <= len(daemon._cache) + 1


def test_cli_remote_cluster_name_matrix(daemon_url):
    url, _ = daemon_url
    # one URL + one name: child is renamed, output still renders
    rc, out = _run_cli(["--source", "remote", "--url", url,
                        "--cluster", "edge", "-t", "3"])
    assert rc == 0 and "sorted by descending order" in out
    # one URL + two names would silently double every node: rejected
    with pytest.raises(SystemExit):
        cli.main(["--source", "remote", "--url", url,
                  "--cluster", "a,b", "-t", "3"])


# ------------------------------------------------------- job report (/job)


JOB_ID = 26140000                  # the deterministic sim's first job
_NEW_JOB_FIELDS = ("submit_time", "gpu_duty", "cpu_load", "mem_used_gb",
                   "step_time_s")


def _golden_job_report():
    import os
    here = os.path.dirname(os.path.abspath(__file__))
    with open(os.path.join(here, "golden", "job_report.txt")) as f:
        return f.read()


def _run_cli_err(argv):
    buf, err = io.StringIO(), io.StringIO()
    with contextlib.redirect_stdout(buf), contextlib.redirect_stderr(err):
        rc = cli.main(argv)
    return rc, buf.getvalue(), err.getvalue()


def test_job_report_golden_local_remote_forwarded(daemon_url):
    """The MPCDF-style job report is byte-identical in every topology:
    local CLI, remote CLI against a daemon, and forwarded through a
    daemon-over-daemon tier."""
    url, _ = daemon_url
    golden = _golden_job_report()
    rc, local = _run_cli(["--source", "sim", "--job", str(JOB_ID)])
    assert rc == 0 and local == golden
    rc, remote = _run_cli(["--source", "remote", "--url", url,
                           "--job", str(JOB_ID)])
    assert rc == 0 and remote == golden
    upstream = RemoteSource(url, name="tier0")
    d2 = LLloadDaemon(upstream, ttl_s=3600.0)
    server, thread = serve_background(d2)
    try:
        host, port = server.server_address[:2]
        fwd = _get(f"http://{host}:{port}", f"/job/{JOB_ID}").decode()
        assert fwd == golden
    finally:
        server.shutdown()
        server.server_close()
        d2.close()
        thread.join(timeout=5)


def test_job_endpoint_is_cached(daemon_url):
    url, daemon = daemon_url
    first = _get(url, f"/job/{JOB_ID}")
    hits_before = daemon.counters()["http_cache_hits_total"]
    assert _get(url, f"/job/{JOB_ID}") == first
    assert daemon.counters()["http_cache_hits_total"] > hits_before


def test_job_endpoint_errors(daemon_url):
    url, _ = daemon_url
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(url, "/job/999999")
    assert ei.value.code == 404
    assert "unknown job" in json.loads(ei.value.read())["error"]["message"]
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(url, "/job/abc")
    assert ei.value.code == 400


# ----------------------------------------------- wire version negotiation


def test_old_client_ignores_new_job_fields(monkeypatch):
    """Old client vs new daemon: a decoder predating the per-job sample
    fields (same wire version, additive keys) must decode the new wire
    by ignoring the unknown keys."""
    wire = encode_snapshot(build_source("sim").snapshot())
    assert all(f in wire["snapshot"]["jobs"][0] for f in _NEW_JOB_FIELDS)
    old_fields = tuple(f for f in protocol._JOB_FIELDS
                       if f not in _NEW_JOB_FIELDS)
    monkeypatch.setattr(protocol, "_JOB_FIELDS", old_fields)
    snap = protocol.decode_snapshot(wire)
    job = snap.jobs[0]
    assert job.job_id == JOB_ID                 # identity intact
    assert job.gpu_duty == 0.0                  # new fields defaulted


def test_new_client_decodes_old_daemon_wire():
    """New client vs old daemon: wire missing the per-job sample fields
    decodes with zero defaults (the drop-in upgrade direction)."""
    wire = encode_snapshot(build_source("sim").snapshot())
    for jd in wire["snapshot"]["jobs"]:
        for f in _NEW_JOB_FIELDS:
            jd.pop(f, None)
    snap = decode_snapshot(wire)
    assert snap.jobs[0].job_id == JOB_ID
    assert snap.jobs[0].submit_time == 0.0
    assert snap.jobs[0].gpu_duty == 0.0


def test_cli_job_against_old_daemon_fails_gracefully():
    """--job against a daemon predating /job/{id} gets the daemon's
    404 envelope rendered as a one-line error, not a traceback."""
    import http.server

    class OldDaemonHandler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):            # an old daemon 404s unknown paths
            body = protocol.dumps(protocol.encode_error(
                f"unknown endpoint {self.path}", 404))
            self.send_response(404)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):
            pass

    server = http.server.ThreadingHTTPServer(("127.0.0.1", 0),
                                             OldDaemonHandler)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        host, port = server.server_address[:2]
        rc, out, err = _run_cli_err(["--source", "remote",
                                     "--url", f"http://{host}:{port}",
                                     "--job", str(JOB_ID)])
        assert rc == 1 and out == ""
        assert err.startswith("LLload: ")
        assert "unknown endpoint" in err and "Traceback" not in err
    finally:
        server.shutdown()
        server.server_close()
        thread.join(timeout=5)


def test_job_metrics_present_and_labeled(daemon_url):
    url, daemon = daemon_url
    text = _get(url, "/metrics").decode()
    families = parse_prometheus(text)
    snap = daemon.bus.read("txgreen")
    assert families["llload_jobs_tracked"][f'{{cluster="txgreen"}}'] \
        == len(snap.jobs)
    duty = families["llload_job_gpu_duty"]
    assert duty and all('job="' in k and 'user="' in k for k in duty)


def test_job_metric_family_is_bounded_at_10k_jobs():
    """Regression (PR 2 endpoint-label hardening, applied to jobs): a
    10k-job snapshot must not grow any per-job metric family past the
    label budget + the "other" bucket."""
    from repro.daemon.promtext import (JOB_LABEL_BUDGET,
                                       render_prometheus)
    from repro.daemon.store import JobSample

    snap = build_source("sim").snapshot()
    samples = [JobSample(t=0.0, job_id=i, username=f"u{i % 97}",
                         name="j", state="R", n_nodes=1,
                         gpu_duty=(i % 100) / 100.0, cpu_load=1.0,
                         mem_used_gb=8.0, mem_total_gb=384.0,
                         gpu_mem_used_gb=2.0, gpu_mem_total_gb=32.0,
                         queue_wait_s=60.0, step_time_s=0.0)
               for i in range(10_000)]
    families = parse_prometheus(render_prometheus(snap,
                                                  job_samples=samples))
    job_families = [k for k in families if k.startswith("llload_job_")]
    assert job_families
    for name in job_families:
        assert len(families[name]) <= JOB_LABEL_BUDGET + 1, name
        assert any('job="other"' in k for k in families[name]), name
    assert families["llload_jobs_tracked"][f'{{cluster="txgreen"}}'] \
        == 10_000

"""Race hammer for the streaming fan-out: 32 concurrent /stream
subscribers decode bounded subscriptions from a churning short-TTL
daemon while a pump forces collections.  Every line must parse (no torn
frames), every delta must apply contiguously (no gaps inside a healthy
subscription), no handler may 500 — and afterwards the hub's /stats
ledger must reconcile **exactly** against the client-side counts, the
same lost-update detector as test_daemon_race.py."""
import json
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro.daemon import LLloadDaemon, StreamDecoder, serve_background
from repro.monitor import build_source

N_CLIENTS = 32
FRAMES_EACH = 6


@pytest.fixture()
def churning_daemon():
    # advance_s makes every forced collection a different snapshot, so
    # the stream carries real deltas, not just timestamp ticks
    daemon = LLloadDaemon(build_source("sim", advance_s=60.0), ttl_s=0.05)
    server, thread = serve_background(daemon)
    host, port = server.server_address[:2]
    yield f"http://{host}:{port}", daemon
    server.shutdown()
    server.server_close()
    daemon.close()
    thread.join(timeout=5)
    assert not thread.is_alive()


def test_concurrent_subscribers_exact_ledger(churning_daemon):
    url, daemon = churning_daemon
    daemon.bus.poll(daemon.source.name)      # hub is primed before anyone joins

    stop = threading.Event()

    def pump():
        while not stop.is_set():
            daemon.bus.poll(daemon.source.name)
            time.sleep(0.002)

    pump_thread = threading.Thread(target=pump, daemon=True)
    pump_thread.start()

    ledger_lock = threading.Lock()
    received = []
    errors = []
    barrier = threading.Barrier(N_CLIENTS)

    def worker(i):
        barrier.wait()
        dec = StreamDecoder()
        try:
            rsp = urllib.request.urlopen(
                f"{url}/stream?frames={FRAMES_EACH}", timeout=30)
            with rsp:
                assert rsp.status == 200
                assert "ndjson" in rsp.headers.get("Content-Type", "")
                frames = 0
                for line in rsp:
                    line = line.strip()
                    if not line:
                        continue
                    obj = json.loads(line)   # a torn frame dies here
                    snap = dec.feed(obj)     # a gap/corruption dies here
                    assert snap.nodes
                    frames += 1
            with ledger_lock:
                received.append(frames)
        except Exception as exc:  # noqa: BLE001 — surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(N_CLIENTS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    stop.set()
    pump_thread.join(timeout=5)
    assert errors == []
    assert not any(t.is_alive() for t in threads)

    # every bounded subscription delivered exactly its ?frames budget
    assert received == [FRAMES_EACH] * N_CLIENTS

    with urllib.request.urlopen(url + "/stats", timeout=30) as rsp:
        stats = json.loads(rsp.read())

    # the hub ledger reconciles exactly: ?frames is enforced at enqueue
    # time, so with no evictions frames_sent == frames received
    stream = stats["stream"]
    assert stream["evicted"] == 0.0
    assert stream["subscribed_total"] == float(N_CLIENTS)
    assert stream["resyncs"] == float(N_CLIENTS)   # one keyframe per join
    assert stream["frames_sent"] == float(sum(received))
    assert stream["subscribers"] == 0.0            # everyone drained out

    # and the HTTP side agrees: 32 /stream requests, zero handler errors
    http = stats["http"]
    assert http['requests_total{endpoint="/stream"}'] == float(N_CLIENTS)
    assert http["http_errors_total"] == 0.0


def test_stream_rejects_bad_frames_param_with_400(churning_daemon):
    url, daemon = churning_daemon
    for bad in ("0", "-3", "abc"):
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{url}/stream?frames={bad}", timeout=30)
        assert ei.value.code == 400
        err = json.loads(ei.value.read())
        assert err["kind"] == "error"
        assert "frames" in err["error"]["message"]

"""All-to-all (shard_map) MoE vs dense reference — runs on 8 fake devices.

XLA locks the device count at first jax init, so this test runs in a
subprocess with XLA_FLAGS set (the main pytest process keeps 1 device).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import MoESpec
from repro.models.moe import init_moe, moe_ffn_dense_reference
from repro.models.moe_a2a import moe_ffn_a2a

spec = MoESpec(n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=8.0)
params = init_moe(jax.random.PRNGKey(0), 16, spec)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
ref = moe_ffn_dense_reference(params, x, spec)

for shape, axes in [((2, 4), ("data", "model")), ((1, 8), ("data", "model"))]:
    mesh = jax.make_mesh(shape, axes)
    with mesh:
        out = moe_ffn_a2a(params, x, spec, "swiglu", mesh, fsdp_axes=("data",))
    err = float(jnp.max(jnp.abs(np.asarray(out) - np.asarray(ref))))
    assert err < 2e-4, (shape, err)

# gradients match the dense reference
mesh = jax.make_mesh((2, 4), ("data", "model"))
def loss_a2a(p):
    with mesh:
        return jnp.sum(moe_ffn_a2a(p, x, spec, "swiglu", mesh,
                                   fsdp_axes=("data",)) ** 2)
g = jax.grad(loss_a2a)(params)
gref = jax.grad(lambda p: jnp.sum(moe_ffn_dense_reference(p, x, spec) ** 2))(params)
for k in g:
    e = float(jnp.max(jnp.abs(g[k] - gref[k])))
    assert e < 5e-4, (k, e)
print("A2A_MOE_OK")
"""


@pytest.mark.slow
def test_moe_a2a_subprocess():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "A2A_MOE_OK" in out.stdout

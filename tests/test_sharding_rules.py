"""Sharding rule engine: specs valid (divisible or replicated) per arch."""
import types

import pytest

from repro.configs import get_config
from repro.configs.archs import ASSIGNED
from repro.launch.sharding import _param_spec_leaf


class FakeMesh:
    """Duck-typed mesh for spec-rule tests (no devices needed)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.size = 1
        for v in shape.values():
            self.size *= v


SP = FakeMesh({"data": 16, "model": 16})
MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _norm(entry):
    """PartitionSpec normalizes 1-tuples to bare strings."""
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry) if entry is not None else None


@pytest.mark.parametrize("mesh", [SP, MP], ids=["single-pod", "multi-pod"])
@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_divisible(mesh, arch):
    """Every sharded dim must divide by its axis product."""
    import jax
    from repro.models import init_params_shape

    cfg = get_config(arch)
    tree = init_params_shape(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    n_sharded = 0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", p)) for p in path]
        name = keys[-1]
        stacked = any(k in ("blocks", "enc_blocks") for k in keys[:-1])
        spec = _param_spec_leaf(mesh, name, leaf.shape, stacked)
        assert len(spec) <= len(leaf.shape)
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is not None:
                n_sharded += 1
                assert dim % _axis_size(mesh, axes) == 0, \
                    f"{arch} {name} {leaf.shape} spec={spec}"
    assert n_sharded > 0, f"{arch}: nothing sharded at all"


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "qwen3-moe-30b-a3b",
                                  "jamba-1.5-large-398b"])
def test_big_matrices_are_2d_sharded(arch):
    """The large weights must shard on two axes (FSDP x TP) on single pod."""
    import jax
    from repro.models import init_params_shape

    cfg = get_config(arch)
    tree = init_params_shape(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    found_2d = 0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", p)) for p in path]
        stacked = any(k in ("blocks", "enc_blocks") for k in keys[:-1])
        spec = _param_spec_leaf(SP, keys[-1], leaf.shape, stacked)
        n_axes = sum(1 for s in tuple(spec) if s is not None)
        if n_axes >= 2:
            found_2d += 1
    assert found_2d >= 3, f"{arch}: expected 2D-sharded weights"


def test_moe_experts_on_model_axis():
    spec = _param_spec_leaf(SP, "w1", (128, 2048, 768), False)
    assert _norm(tuple(spec)[0]) == ("model",)   # expert parallelism
    assert _norm(tuple(spec)[1]) == ("data",)    # fsdp on d_model


def test_nondivisible_replicates():
    spec = _param_spec_leaf(SP, "wq", (2560, 1234), False)
    assert tuple(spec)[1] is None  # 1234 % 16 != 0 -> replicated
    assert _norm(tuple(spec)[0]) == ("data",)

"""Durable segment storage (DESIGN.md §12): record format round-trip,
torn-tail crash recovery, WAL roll/seal/prune, sharding, and restart
byte-identity of /trend, /weekly and /job/{id} via the store backends."""
import dataclasses
import math
import os

import pytest
from hypothesis import given, strategies as st

from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot
from repro.daemon import protocol
from repro.daemon.store import HistoryStore, JobHistoryStore, TierSpec
from repro.storage import (SegmentLog, SegmentWriter, open_storage,
                           safe_key, scan_segment, unsafe_key)
from repro.storage.segment import FRAME, frame_record, header_bytes


def _snap(ts, load_a=10.0, load_b=40.0, gpu=0.5, cluster="tx"):
    nodes = {
        "a": NodeSnapshot("a", cores_total=48, cores_used=48, load=load_a,
                          mem_total_gb=192.0, mem_used_gb=50.0),
        "b": NodeSnapshot("b", cores_total=48, cores_used=48, load=load_b,
                          mem_total_gb=192.0, mem_used_gb=60.0,
                          gpus_total=2, gpus_used=2, gpu_load=gpu,
                          gpu_mem_total_gb=64.0, gpu_mem_used_gb=8.0),
    }
    jobs = [JobRecord(1, "ua", "ja", ["a"], cores_per_node=48),
            JobRecord(2, "ub", "jb", ["b"], cores_per_node=48,
                      gpus_per_node=2)]
    return ClusterSnapshot(cluster, ts, nodes, jobs)


def _snaps(n, t0=1_700_000_000.0, step=300.0):
    return [_snap(t0 + step * i, load_a=5.0 + (i % 7) * 3.0,
                  load_b=20.0 + (i % 5) * 8.0, gpu=0.1 * (i % 9))
            for i in range(n)]


# ------------------------------------------------------------ record format


@given(st.lists(st.tuples(
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    st.binary(min_size=0, max_size=200)), min_size=0, max_size=30))
def test_segment_roundtrip_property(records, tmp_path_factory):
    """Any (timestamp, payload) sequence survives the write → scan round
    trip exactly, in order, with no torn tail."""
    path = str(tmp_path_factory.mktemp("seg") / "seg-00000000.log")
    w = SegmentWriter(path)
    for t, payload in records:
        w.append(t, payload)
    w.close()
    scan = scan_segment(path)
    assert not scan.torn
    assert scan.records == records
    assert scan.valid_bytes == os.path.getsize(path)


@given(st.binary(min_size=1, max_size=64),
       st.integers(min_value=1, max_value=20))
def test_torn_tail_truncation_property(payload, cut, tmp_path_factory):
    """Cutting any number of bytes off the final frame loses only that
    frame: every earlier record scans back intact."""
    path = str(tmp_path_factory.mktemp("seg") / "seg-00000000.log")
    frames = [frame_record(float(i), payload + bytes([i]))
              for i in range(3)]
    with open(path, "wb") as f:
        f.write(header_bytes() + b"".join(frames))
    size = os.path.getsize(path)
    torn_size = size - min(cut, len(frames[-1]) - 1)
    with open(path, "r+b") as f:
        f.truncate(torn_size)
    scan = scan_segment(path)
    assert scan.torn
    assert [p for _, p in scan.records] == \
        [payload + bytes([0]), payload + bytes([1])]
    # a writer reopening the torn segment truncates to the last valid
    # boundary and appends cleanly after it
    w = SegmentWriter(path)
    assert w.torn_dropped == 1
    w.append(9.0, b"after")
    w.close()
    scan2 = scan_segment(path)
    assert not scan2.torn
    assert [p for _, p in scan2.records][-1] == b"after"
    assert len(scan2.records) == 3


def test_corrupt_middle_record_stops_scan(tmp_path):
    path = str(tmp_path / "seg-00000000.log")
    w = SegmentWriter(path)
    for i in range(4):
        w.append(float(i), b"rec%d" % i)
    w.close()
    # flip one payload byte of the second record: CRC catches it and the
    # scan keeps everything before it
    with open(path, "r+b") as f:
        data = bytearray(f.read())
        first_end = len(header_bytes()) + FRAME.size + 4
        data[first_end + FRAME.size] ^= 0xFF
        f.seek(0)
        f.write(data)
    scan = scan_segment(path)
    assert scan.torn
    assert [p for _, p in scan.records] == [b"rec0"]


# ------------------------------------------------------------- segment log


def test_segment_log_rolls_seals_replays(tmp_path):
    log = SegmentLog(str(tmp_path), max_records=4)
    for i in range(10):
        log.append(float(i), b"p%d" % i)
    infos = log.segments()
    assert [s.sealed for s in infos] == [True, True, False]
    assert [s.count for s in infos] == [4, 4, 2]
    assert infos[0].t_min == 0.0 and infos[0].t_max == 3.0
    assert [p for _, p in log.replay()] == [b"p%d" % i for i in range(10)]
    assert [s for s, _, _ in log.replay(with_seq=True)] == \
        [0] * 4 + [1] * 4 + [2] * 2
    log.close()
    # reopen resumes the tail; sealed segments are untouched
    log2 = SegmentLog(str(tmp_path), max_records=4)
    log2.append(10.0, b"p10")
    assert [p for _, p in log2.replay()][-1] == b"p10"
    log2.close()


def test_segment_log_prune_keeps_tail_and_ring(tmp_path):
    log = SegmentLog(str(tmp_path), max_records=4)
    for i in range(20):
        log.append(float(i), b"x")
    # prune everything older than t=100 but keep >= 6 trailing records
    removed = log.prune_before(100.0, keep_records=6)
    assert removed > 0
    assert sum(s.count for s in log.segments()) >= 6
    # the unsealed tail is never deleted even with no keep floor
    log.prune_before(math.inf)
    assert any(not s.sealed for s in log.segments())
    # max_seq fences pruning at the compaction cursor
    log2 = SegmentLog(str(tmp_path / "fence"), max_records=2)
    for i in range(8):
        log2.append(float(i), b"x")
    assert log2.prune_before(math.inf, max_seq=0) == 1
    log2.close()
    log.close()


# ---------------------------------------------------------------- sharding


@given(st.text(min_size=0, max_size=40))
def test_safe_key_roundtrip_property(key):
    safe = safe_key(key)
    assert unsafe_key(safe) == key
    assert "/" not in safe and safe not in ("..", ".")


def test_shard_layout_is_traversal_safe(tmp_path):
    rt = open_storage(str(tmp_path / "data"))
    log = rt.jobs.raw.log_for("../../etc/passwd")
    assert os.path.realpath(log.root).startswith(
        os.path.realpath(str(tmp_path)))
    rt.close()


# ------------------------------------------------- history restart identity


def _history_pair(tmp_path, n=40, segment_records=8):
    data = str(tmp_path / "data")
    rt = open_storage(data, segment_records=segment_records,
                      compact_interval_s=9999.0)
    store = HistoryStore(backend=rt.history)
    for snap in _snaps(n):
        store.append(snap)
    rt.compact_once()
    return data, rt, store


def test_history_restart_is_byte_identical(tmp_path):
    data, rt, store = _history_pair(tmp_path)
    before = {
        tier: protocol.dumps(store.trend_wire(tier))
        for tier in ("raw", "15min", "hourly")}
    weekly_before = store.weekly_report()
    sizes_before = store.sizes()
    rt.close()

    rt2 = open_storage(data, compact_interval_s=9999.0)
    store2 = HistoryStore(backend=rt2.history)
    counts = store2.recover()
    assert counts["checkpoint"] == 1
    for tier, body in before.items():
        assert protocol.dumps(store2.trend_wire(tier)) == body
    assert store2.weekly_report() == weekly_before
    assert store2.sizes() == sizes_before
    # appends continue seamlessly after recovery
    store2.append(_snap(1_700_000_000.0 + 300.0 * 41))
    assert store2.sizes()["appended"] == sizes_before["appended"] + 1
    rt2.close()


def test_history_recovery_tolerates_torn_tail(tmp_path):
    """Truncate the tail raw segment mid-record: recovery keeps every
    record before the tear and /trend tier selection is unchanged."""
    data, rt, store = _history_pair(tmp_path)
    tier_sel = store.select_tier(3600.0)
    n_appended = store.sizes()["appended"]
    rt.close()

    raw_dir = os.path.join(data, "history", "raw")
    tails = sorted(f for f in os.listdir(raw_dir) if f.endswith(".log")
                   and not os.path.exists(os.path.join(raw_dir, f + ".idx")))
    tail = os.path.join(raw_dir, tails[-1])
    with open(tail, "r+b") as f:
        f.truncate(os.path.getsize(tail) - 3)   # mid final record

    rt2 = open_storage(data, compact_interval_s=9999.0)
    store2 = HistoryStore(backend=rt2.history)
    store2.recover()
    # exactly the torn final record is gone; everything before survives
    assert store2.sizes()["appended"] == n_appended - 1
    times = [s.timestamp for s in store2.raw()]
    assert times == [1_700_000_000.0 + 300.0 * i
                     for i in range(len(times))]
    assert store2.select_tier(3600.0) == tier_sel
    # the reopened writer truncated the tear: new appends are clean
    store2.append(_snap(1_700_000_000.0 + 300.0 * 60))
    rt2.close()
    rt3 = open_storage(data, compact_interval_s=9999.0)
    store3 = HistoryStore(backend=rt3.history)
    store3.recover()
    # 40 originals - 1 torn + 1 post-recovery append
    assert store3.sizes()["appended"] == n_appended
    rt3.close()


def test_history_compaction_survives_raw_pruning(tmp_path):
    """Once compacted, tier history no longer depends on raw segments:
    aggressive raw retention cannot lose downsampled points."""
    data = str(tmp_path / "data")
    rt = open_storage(data, segment_records=8, compact_interval_s=9999.0,
                      retain_raw_s=600.0)       # keep only 2 raw steps
    store = HistoryStore(backend=rt.history, raw_capacity=4)
    for snap in _snaps(64):
        store.append(snap)
    rt.compact_once()
    before_15 = protocol.dumps(store.trend_wire("15min"))
    before_h = protocol.dumps(store.trend_wire("hourly"))
    stats = rt.history.stats()
    assert stats["raw"]["pruned_segments"] > 0
    rt.close()

    rt2 = open_storage(data, compact_interval_s=9999.0)
    store2 = HistoryStore(backend=rt2.history, raw_capacity=4)
    store2.recover()
    assert protocol.dumps(store2.trend_wire("15min")) == before_15
    assert protocol.dumps(store2.trend_wire("hourly")) == before_h
    # the ring refilled from the retained raw tail despite pruning
    assert len(store2.raw()) == 4
    rt2.close()


def test_duplicate_timestamps_dropped_entirely(tmp_path):
    """An exact repeat of the previous timestamp (frozen-clock source,
    re-delivered snapshot) is dropped before the ring and the WAL."""
    data = str(tmp_path / "data")
    rt = open_storage(data, compact_interval_s=9999.0)
    store = HistoryStore(backend=rt.history)
    snap = _snap(1_700_000_000.0)
    for _ in range(5):
        store.append(snap)
    sizes = store.sizes()
    assert sizes["appended"] == 1
    assert sizes["duplicate_dropped"] == 4
    assert rt.history.raw_log.stats()["appended"] == 1
    rt.close()


def test_weekly_window_answers_from_disk_after_memory_ages_out(tmp_path):
    """A /weekly window older than the in-memory finest tier is served
    from the user-keyed flag shards compaction wrote."""
    t0 = 1_700_000_000.0
    data = str(tmp_path / "data")
    rt = open_storage(data, segment_records=8, compact_interval_s=9999.0)
    # finest tier retains only 4 buckets in memory; ingest 16 buckets
    tiers = [TierSpec("15min", 900.0, capacity=4)]
    store = HistoryStore(backend=rt.history, tiers=tiers)
    for snap in _snaps(64, t0=t0, step=225.0):  # 4 samples per bucket
        store.append(snap)
    rt.compact_once()

    full = store.weekly_report(start=t0, end=t0 + 225.0 * 64)
    # the same flags replayed through a memory-only store with room for
    # every bucket give the ground truth
    ref = HistoryStore(tiers=[TierSpec("15min", 900.0, capacity=64)])
    for snap in _snaps(64, t0=t0, step=225.0):
        ref.append(snap)
    expected = ref.weekly_report(start=t0, end=t0 + 225.0 * 64)
    assert full == expected
    rt.close()


# ----------------------------------------------------- job shards + reload


def test_jobstore_restart_and_cold_reload(tmp_path):
    data = str(tmp_path / "data")
    rt = open_storage(data, compact_interval_s=9999.0)
    jobs = JobHistoryStore(backend=rt.jobs)
    for snap in _snaps(30):
        jobs.observe(snap)
    before_raw = {jid: jobs.raw_points(jid) for jid in jobs.job_ids()}
    before_life = {jid: jobs.lifetime(jid) for jid in jobs.job_ids()}
    rt.compact_once()
    rt.close()

    rt2 = open_storage(data, compact_interval_s=9999.0)
    jobs2 = JobHistoryStore(backend=rt2.jobs)
    rec = jobs2.recover()
    assert rec["jobs"] == len(before_raw)
    for jid, samples in before_raw.items():
        assert jobs2.raw_points(jid) == samples
        assert jobs2.lifetime(jid) == before_life[jid]
    rt2.close()


def test_jobstore_eviction_reloads_from_disk(tmp_path):
    """max_jobs=2 with 3 jobs: the evicted job's history answers from
    its shard on the next read, and counts as a reload."""
    data = str(tmp_path / "data")
    rt = open_storage(data, compact_interval_s=9999.0)
    jobs = JobHistoryStore(backend=rt.jobs, max_jobs=2)
    t0 = 1_700_000_000.0
    for i in range(6):
        snap = _snap(t0 + 300.0 * i)
        # jobs 1 and 2 come from _snap; add job 3 on node a
        snap.jobs.append(JobRecord(3, "uc", "jc", ["a"],
                                   cores_per_node=48))
        jobs.observe(snap)
    assert jobs.sizes()["evicted"] > 0
    assert len(jobs.job_ids()) == 2
    evicted_id = next(jid for jid in (1, 2, 3)
                      if jid not in jobs.job_ids())
    reloads_before = jobs.sizes()["reloaded"]
    samples = jobs.raw_points(evicted_id)
    assert len(samples) == 6                    # reloaded from its shard
    assert jobs.sizes()["reloaded"] == reloads_before + 1
    assert len(jobs.job_ids()) == 2             # population stays bounded
    rt.close()


def test_jobstore_without_backend_unchanged(tmp_path):
    jobs = JobHistoryStore(max_jobs=2)
    for snap in _snaps(4):
        jobs.observe(snap)
    assert jobs.raw_points(999) == []
    sizes = jobs.sizes()
    assert sizes["reloaded"] == 0 and sizes["jobs"] == 2


# ------------------------------------------------------------ daemon level


def test_daemon_stats_reports_storage_and_jobstore_counters(tmp_path):
    from repro.daemon.server import LLloadDaemon
    from repro.monitor import build_source

    rt = open_storage(str(tmp_path / "data"), compact_interval_s=9999.0)
    daemon = LLloadDaemon(build_source("sim"), ttl_s=3600.0, storage=rt)
    try:
        daemon.backfill(_snaps(10))
        rt.compact_once()
        status, _, body = daemon.handle("/stats")
        assert status == 200
        stats = protocol.loads(body)
        assert stats["storage"]["history"]["raw"]["records"] == 10
        assert stats["storage"]["compactor"]["cycles"] == 1
        assert "segments" in stats["storage"]["history"]["raw"]
        js = stats["jobstore"]
        for key in ("jobs", "raw_samples", "buckets", "evicted",
                    "reloaded"):
            assert key in js
        assert stats["store"]["duplicate_dropped"] == 0
    finally:
        daemon.close()


def test_daemon_without_data_dir_has_no_storage_section():
    from repro.daemon.server import LLloadDaemon
    from repro.monitor import build_source

    daemon = LLloadDaemon(build_source("sim"), ttl_s=3600.0)
    try:
        status, _, body = daemon.handle("/stats")
        assert status == 200
        assert "storage" not in protocol.loads(body)
    finally:
        daemon.close()


def test_backfill_sources_accepts_file_and_directory(tmp_path):
    from repro.core.archive import SnapshotArchive
    from repro.daemon.server import backfill_sources

    archive = SnapshotArchive(str(tmp_path), cluster="tx")
    for snap in _snaps(6):
        archive.append(snap)
    files = archive.files()
    assert files

    # a single TSV file replays exactly its rows
    pairs = backfill_sources(files[0])
    assert len(pairs) == 1 and pairs[0][0] == files[0]
    store = HistoryStore()
    n_file = store.backfill(pairs[0][1])
    assert n_file > 0

    # the archive root (one subdir per cluster) replays everything
    pairs = backfill_sources(str(tmp_path))
    labels = [label for label, _ in pairs]
    assert labels == [os.path.join(str(tmp_path), "tx")]
    store2 = HistoryStore()
    total = sum(store2.backfill(replayable) for _, replayable in pairs)
    assert total == 6

"""DeltaCodec / StreamDecoder contract (DESIGN.md §14): applying a delta
reproduces the producer's snapshot **byte-identically** under
``dumps(encode_snapshot(...))``, dropped frames surface as
:class:`StreamGapError` (never a silently corrupted view), and a
keyframe repairs the gap.  Property-tested with hypothesis where
installed, with an always-running seeded-random fuzz twin; plus the
StreamHub fan-out ledger (keyframes, eviction, frame limits, close)."""
import dataclasses
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot
from repro.daemon import protocol
from repro.daemon.stream import StreamHub


def _wire(snap: ClusterSnapshot) -> bytes:
    return protocol.dumps(protocol.encode_snapshot(snap))


# ----------------------------------------------------- snapshot generators

def _node(rng: random.Random, host: str) -> NodeSnapshot:
    gpus = rng.choice([0, 0, 2, 4])
    return NodeSnapshot(
        hostname=host,
        cores_total=rng.choice([48, 64]),
        cores_used=rng.randrange(0, 65),
        load=round(rng.uniform(0.0, 64.0), 3),
        mem_total_gb=192.0,
        mem_used_gb=round(rng.uniform(0.0, 192.0), 3),
        gpus_total=gpus,
        gpus_used=rng.randrange(0, gpus + 1),
        gpu_load=round(rng.uniform(0.0, gpus), 3),
        gpu_mem_total_gb=float(gpus * 40),
        gpu_mem_used_gb=round(rng.uniform(0.0, gpus * 40), 3))


def _job(rng: random.Random, job_id: int, hosts: list) -> JobRecord:
    return JobRecord(
        job_id=job_id,
        username=f"u{rng.randrange(6)}",
        name=f"job-{job_id}",
        nodes=rng.sample(hosts, min(len(hosts), 1 + rng.randrange(2))),
        cores_per_node=rng.choice([1, 16, 48]),
        state=rng.choice(["R", "R", "PD"]),
        gpus_per_node=rng.choice([0, 0, 2]),
        start_time=round(rng.uniform(0.0, 1e5), 3),
        cpu_load=round(rng.uniform(0.0, 48.0), 3),
        gpu_duty=round(rng.uniform(0.0, 1.0), 3))


def _rand_snapshot(rng: random.Random, t: float = 0.0) -> ClusterSnapshot:
    hosts = [f"n{i}" for i in range(1 + rng.randrange(7))]
    nodes = {h: _node(rng, h) for h in hosts}
    jobs = [_job(rng, 1000 + i, hosts) for i in range(rng.randrange(5))]
    emails = {f"u{i}": f"u{i}@example.org" for i in range(rng.randrange(3))}
    return ClusterSnapshot("txgreen", t, nodes, jobs, emails)


def _mutate(rng: random.Random, snap: ClusterSnapshot) -> ClusterSnapshot:
    """One random structural or value mutation (never mutates ``snap``).

    Covers every delta field: node upsert/add/remove/reorder, job
    upsert/add/remove/reorder, email churn — and the timestamp always
    moves, so a draw that hits a no-op branch still yields the
    smallest-possible (timestamp-only) delta."""
    nodes = dict(snap.nodes)
    jobs = list(snap.jobs)
    emails = dict(snap.user_emails)
    op = rng.randrange(9)
    if op == 0 and nodes:                          # touch a node in place
        h = rng.choice(list(nodes))
        nodes[h] = _node(rng, h)
    elif op == 1:                                  # a node joins the fleet
        h = f"x{rng.randrange(10_000)}"
        nodes[h] = _node(rng, h)
    elif op == 2 and len(nodes) > 1:               # a node leaves
        del nodes[rng.choice(list(nodes))]
    elif op == 3 and len(nodes) > 1:               # fleet order changes
        order = list(nodes)
        rng.shuffle(order)
        nodes = {h: nodes[h] for h in order}
    elif op == 4:                                  # a job starts
        jid = max((j.job_id for j in jobs), default=1000) + 1
        jobs.append(_job(rng, jid, list(nodes)))
    elif op == 5 and jobs:                         # a job ends
        jobs.pop(rng.randrange(len(jobs)))
    elif op == 6 and jobs:                         # a job's samples move
        i = rng.randrange(len(jobs))
        jobs[i] = dataclasses.replace(
            jobs[i], state=rng.choice(["R", "PD", "CG"]),
            cpu_load=round(rng.uniform(0.0, 48.0), 3))
    elif op == 7 and len(jobs) > 1:                # queue order changes
        rng.shuffle(jobs)
    elif op == 8:                                  # email table churns
        if emails and rng.random() < 0.5:
            del emails[rng.choice(list(emails))]
        else:
            u = f"u{rng.randrange(100)}"
            emails[u] = f"{u}@example.org"
    return ClusterSnapshot(snap.cluster,
                           round(snap.timestamp + rng.uniform(0.1, 60.0), 3),
                           nodes, jobs, emails)


# ------------------------------------------------- round-trip (fuzz twin)

@pytest.mark.parametrize("seed", range(8))
def test_fuzz_stream_roundtrip_byte_identical(seed):
    """40 random mutations through encode -> real bytes -> decode: every
    decoded snapshot must be byte-identical to the producer's."""
    rng = random.Random(seed)
    codec = protocol.DeltaCodec(keyframe_every=5)
    dec = protocol.StreamDecoder()
    cur = _rand_snapshot(rng)
    kinds = []
    for _ in range(40):
        frame = protocol.loads(protocol.dumps(codec.encode(cur)))
        kinds.append(frame["frame"]["type"])
        assert _wire(dec.feed(frame)) == _wire(cur)
        cur = _mutate(rng, cur)
    assert kinds[0] == "full" and "delta" in kinds


@given(st.integers(min_value=0, max_value=2**32 - 1),
       st.lists(st.integers(min_value=0, max_value=2**32 - 1),
                min_size=1, max_size=25))
@settings(max_examples=50)
def test_property_stream_roundtrip_byte_identical(seed, steps):
    """Hypothesis twin of the fuzz test: arbitrary mutation chains keep
    the diff -> apply round trip exact at every step."""
    rng = random.Random(seed)
    codec = protocol.DeltaCodec(keyframe_every=4)
    dec = protocol.StreamDecoder()
    cur = _rand_snapshot(rng)
    assert _wire(dec.feed(codec.encode(cur))) == _wire(cur)
    for s in steps:
        cur = _mutate(random.Random(s), cur)
        frame = protocol.loads(protocol.dumps(codec.encode(cur)))
        assert _wire(dec.feed(frame)) == _wire(cur)


def _advance(rng, codec, cur):
    cur = _mutate(rng, cur)
    return cur, codec.encode(cur)


def test_dropped_delta_is_a_gap_and_keyframe_repairs_it():
    rng = random.Random(1)
    codec = protocol.DeltaCodec(keyframe_every=10_000)
    dec = protocol.StreamDecoder()
    cur = _rand_snapshot(rng)
    dec.feed(codec.encode(cur))
    cur, frame = _advance(rng, codec, cur)
    dec.feed(frame)
    cur, dropped = _advance(rng, codec, cur)       # lost in transit
    assert dropped["frame"]["type"] == "delta"
    cur, nxt = _advance(rng, codec, cur)
    with pytest.raises(protocol.StreamGapError):
        dec.feed(nxt)                              # gap detected, not applied
    dec.reset()
    with pytest.raises(protocol.StreamGapError):
        dec.feed(nxt)                              # delta before any keyframe
    repaired = dec.feed(codec.keyframe())          # the resync protocol
    assert _wire(repaired) == _wire(cur)
    cur, frame = _advance(rng, codec, cur)         # deltas continue after it
    assert _wire(dec.feed(frame)) == _wire(cur)


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25)
def test_property_gap_detection_and_keyframe_repair(seed):
    rng = random.Random(seed)
    codec = protocol.DeltaCodec(keyframe_every=10_000)
    dec = protocol.StreamDecoder()
    cur = _rand_snapshot(rng)
    dec.feed(codec.encode(cur))
    cur, _dropped = _advance(rng, codec, cur)
    cur, nxt = _advance(rng, codec, cur)
    with pytest.raises(protocol.StreamGapError):
        dec.feed(nxt)
    assert _wire(dec.feed(codec.keyframe())) == _wire(cur)


# -------------------------------------------------------- codec behaviour

def test_keyframe_cadence():
    rng = random.Random(2)
    codec = protocol.DeltaCodec(keyframe_every=4)
    cur = _rand_snapshot(rng)
    kinds = []
    for _ in range(9):
        kinds.append(codec.encode(cur)["frame"]["type"])
        cur = _mutate(rng, cur)
    assert kinds == ["full", "delta", "delta", "delta",
                     "full", "delta", "delta", "delta", "full"]


def test_idle_delta_omits_empty_fields_and_stays_tiny():
    """Nothing changed but the clock: the delta carries only
    type/seq/cluster/timestamp — omitting empty upsert/remove sets is
    where the low-churn byte reduction comes from."""
    rng = random.Random(3)
    codec = protocol.DeltaCodec()
    nodes = {f"n{i}": _node(rng, f"n{i}") for i in range(50)}
    cur = ClusterSnapshot("txgreen", 0.0, nodes,
                          [_job(rng, 1000 + i, list(nodes))
                           for i in range(10)], {"u0": "u0@example.org"})
    full = codec.encode(cur)
    idle = dataclasses.replace(cur, timestamp=cur.timestamp + 15.0)
    frame = codec.encode(idle)
    payload = frame["frame"]
    assert payload["type"] == "delta"
    assert set(payload) == {"type", "seq", "cluster", "timestamp"}
    assert len(protocol.dumps(frame)) < len(protocol.dumps(full)) / 10


def test_duplicate_job_ids_force_a_keyframe():
    """Merged multi-cluster snapshots may repeat a job id; a keyed
    upsert would corrupt them, so the pair is not delta-representable
    and the codec falls back to a full frame."""
    rng = random.Random(4)
    cur = _rand_snapshot(rng)
    job = _job(rng, 7777, list(cur.nodes))
    dup = ClusterSnapshot(cur.cluster, cur.timestamp + 1.0,
                          dict(cur.nodes), [job, dataclasses.replace(job)],
                          dict(cur.user_emails))
    assert protocol.diff_snapshot(cur, dup) is None
    codec = protocol.DeltaCodec()
    assert codec.encode(cur)["frame"]["type"] == "full"
    assert codec.encode(dup)["frame"]["type"] == "full"   # fallback
    with pytest.raises(protocol.WireError):
        protocol.apply_delta(dup, {"cluster": "c", "timestamp": 2.0})


def test_apply_delta_rejects_unknown_references():
    rng = random.Random(5)
    cur = _rand_snapshot(rng)
    with pytest.raises(protocol.WireError):
        protocol.apply_delta(cur, {"cluster": "c", "timestamp": 1.0,
                                   "node_order": ["no-such-host"]})
    with pytest.raises(protocol.WireError):
        protocol.apply_delta(cur, {"cluster": "c", "timestamp": 1.0,
                                   "job_order": [999_999]})
    with pytest.raises(protocol.WireError):
        protocol.apply_delta(cur, {"timestamp": 1.0})      # malformed


def test_decoder_rejects_garbage_frames():
    dec = protocol.StreamDecoder()
    with pytest.raises(protocol.WireError):
        dec.feed({"v": 1, "kind": "frame",
                  "frame": {"type": "full", "seq": "one"}})
    with pytest.raises(protocol.WireError):
        dec.feed({"v": 1, "kind": "frame",
                  "frame": {"type": "mystery", "seq": 1}})
    with pytest.raises(protocol.WireError):
        dec.feed({"v": 1, "kind": "frame", "frame": {"type": "full",
                                                     "seq": 1}})


# ------------------------------------------------------------- StreamHub

def _snap(i: int) -> ClusterSnapshot:
    base = _rand_snapshot(random.Random(0))
    return dataclasses.replace(base, timestamp=float(i))


def test_hub_fans_out_one_encode_and_keyframes_joiners():
    hub = StreamHub(keyframe_every=4)
    early = hub.subscribe()               # before any publish: no keyframe
    assert early.get(timeout=0.01) == b""
    hub.publish("sim", _snap(1))
    first = protocol.loads(early.get(timeout=1.0))["frame"]
    assert first["type"] == "full" and first["seq"] == 1
    late = hub.subscribe()                # joins mid-stream
    kf = protocol.loads(late.get(timeout=1.0))["frame"]
    assert kf["type"] == "full" and kf["seq"] == 1
    hub.publish("sim", _snap(2))
    a = protocol.loads(early.get(timeout=1.0))["frame"]
    b = protocol.loads(late.get(timeout=1.0))["frame"]
    assert a == b                         # one encode, byte-equal fan-out
    assert a["type"] == "delta" and a["seq"] == 2
    stats = hub.stats()
    assert stats["resyncs"] == 1.0        # only the late join resynced
    assert stats["frames_sent"] == 4.0
    assert stats["subscribers"] == 2.0
    hub.close()


def test_hub_prime_seeds_exactly_once():
    hub = StreamHub()
    assert hub.empty()
    hub.prime(_snap(1))
    assert not hub.empty()
    sub = hub.subscribe()
    kf = protocol.loads(sub.get(timeout=1.0))["frame"]
    assert kf["type"] == "full" and kf["seq"] == 1
    hub.prime(_snap(2))                   # no-op: already primed
    assert sub.get(timeout=0.05) == b""
    hub.close()


def test_hub_evicts_slow_consumer_instead_of_blocking():
    hub = StreamHub(queue_max=2)
    hub.publish("sim", _snap(1))
    sub = hub.subscribe()                 # queue: [keyframe]
    hub.publish("sim", _snap(2))          # queue: [keyframe, delta]
    hub.publish("sim", _snap(3))          # full -> evict, never block
    assert sub.get(timeout=0.5) != b""
    assert sub.get(timeout=0.5) is None   # stream ended by eviction
    assert sub.evicted
    stats = hub.stats()
    assert stats["evicted"] == 1.0
    assert stats["subscribers"] == 0.0
    assert stats["frames_sent"] == 2.0    # enqueued before the overflow
    hub.close()


def test_hub_frames_limit_ends_subscription_exactly():
    hub = StreamHub()
    hub.publish("sim", _snap(1))
    sub = hub.subscribe(frames=2)         # frame 1: the keyframe
    hub.publish("sim", _snap(2))          # frame 2: limit reached
    hub.publish("sim", _snap(3))          # never delivered
    got = []
    while True:
        item = sub.get(timeout=0.5)
        if item is None:
            break
        assert item != b""
        got.append(protocol.loads(item)["frame"])
    assert [f["type"] for f in got] == ["full", "delta"]
    assert hub.stats()["subscribers"] == 0.0
    with pytest.raises(ValueError):
        hub.subscribe(frames=0)
    hub.close()


def test_hub_close_wakes_subscribers_and_rejects_new_ones():
    hub = StreamHub()
    hub.publish("sim", _snap(1))
    sub = hub.subscribe()
    assert sub.get(timeout=1.0) != b""
    hub.close()
    assert sub.get(timeout=1.0) is None   # sentinel, not a poll timeout
    with pytest.raises(RuntimeError):
        hub.subscribe()
    hub.publish("sim", _snap(2))          # no-op after close
    hub.close()                           # idempotent
    hub.unsubscribe(sub)                  # idempotent too

"""End-to-end paper pipeline: sim cluster -> 15-min archive -> weekly
analysis -> report + emails (Fig 1)."""
import random

import pytest

from repro.cluster.workloads import make_llsc_sim, paper_scenario
from repro.core.archive import PeriodicArchiver, SnapshotArchive
from repro.core.analysis import weekly_analysis
from repro.core.collector import SimCollector
from repro.core.report import format_weekly_report, notification_email


def test_pipeline_end_to_end(tmp_path):
    sim = make_llsc_sim()
    paper_scenario(sim, random.Random(0))
    archive = SnapshotArchive(str(tmp_path), cluster="txgreen")
    archiver = PeriodicArchiver(archive, SimCollector(sim))

    # one simulated day at the paper's 15-minute cadence
    captured = 0
    for _ in range(24 * 4):
        sim.step(900.0)
        captured += archiver.maybe_capture(sim.t)
    assert captured == 96

    rows = archive.rows()
    assert rows
    rep = weekly_analysis(rows, emails=sim.user_emails)
    # the paper-scenario pathological users surface in the right buckets
    low_gpu_users = [r.username for r in rep.low_gpu]
    high_cpu_users = [r.username for r in rep.high_cpu]
    assert "va67890" in low_gpu_users or "rs12345" in low_gpu_users
    assert "user02" in high_cpu_users  # io storm

    text = format_weekly_report(rep)
    assert "node-hours" in text
    mail = notification_email(rep.high_cpu[0], "high_cpu")
    assert mail.to.endswith("@ll.mit.edu")


def test_interval_gating(tmp_path):
    sim = make_llsc_sim(n_cpu=2, n_gpu=0)
    archive = SnapshotArchive(str(tmp_path))
    archiver = PeriodicArchiver(archive, SimCollector(sim), interval_s=900)
    assert archiver.maybe_capture(0.0)
    assert not archiver.maybe_capture(100.0)
    assert archiver.maybe_capture(901.0)


def test_time_window_filter(tmp_path):
    from repro.cluster.workloads import low_gpu_job

    sim = make_llsc_sim(n_cpu=6, n_gpu=4)
    sim.submit(low_gpu_job("u", tasks=1))
    sim.run_until(600.0)
    archive = SnapshotArchive(str(tmp_path))
    archive.append(sim.snapshot())
    sim.run_until(7200.0)
    archive.append(sim.snapshot())
    assert 0 < len(archive.rows(start=3600.0)) < len(archive.rows())


# ---------------------------------------------------- header-race hardening


def test_concurrent_appends_write_one_header(tmp_path):
    """Two writers racing on a fresh daily file (bus subscriber +
    periodic archiver) must not both decide to write the header row."""
    import threading

    sim = make_llsc_sim(n_cpu=4, n_gpu=2)
    paper_scenario(sim, random.Random(0))
    sim.run_until(1800.0)
    snap = sim.snapshot()
    archive = SnapshotArchive(str(tmp_path), cluster="txgreen")

    barrier = threading.Barrier(8)

    def writer():
        barrier.wait()
        for _ in range(5):
            archive.append(snap)

    threads = [threading.Thread(target=writer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    path = archive.files()[0]
    with open(path) as f:
        lines = f.read().splitlines()
    headers = [ln for ln in lines if ln.startswith("timestamp\t")]
    assert len(headers) == 1, "exactly one header row"
    body_rows = len(snap.to_tsv().splitlines()) - 1
    assert len(lines) == 1 + 40 * body_rows    # nothing torn or dropped


def test_replay_tolerates_duplicate_headers(tmp_path):
    """Cross-process writers can still double-write the header; replay
    (rows_from_tsv) must skip mid-file header lines instead of crashing."""
    from repro.core.metrics import rows_from_tsv

    sim = make_llsc_sim(n_cpu=4, n_gpu=2)
    paper_scenario(sim, random.Random(0))
    sim.run_until(1800.0)
    text = sim.snapshot().to_tsv()
    header, body = text.split("\n", 1)
    doubled = header + "\n" + body + header + "\n" + body

    rows = rows_from_tsv(doubled)
    assert len(rows) == 2 * len(rows_from_tsv(text))
    assert all(isinstance(r["timestamp"], float) for r in rows)

    # and an ArchiveSource replay over such a file keeps working
    path = tmp_path / "txgreen"
    path.mkdir(exist_ok=True)
    (path / "llload-doubled.tsv").write_text(doubled)
    from repro.monitor import ArchiveSource

    src = ArchiveSource(str(tmp_path))
    assert len(src.snapshot().nodes) > 0

"""End-to-end paper pipeline: sim cluster -> 15-min archive -> weekly
analysis -> report + emails (Fig 1)."""
import random

import pytest

from repro.cluster.workloads import make_llsc_sim, paper_scenario
from repro.core.archive import PeriodicArchiver, SnapshotArchive
from repro.core.analysis import weekly_analysis
from repro.core.collector import SimCollector
from repro.core.report import format_weekly_report, notification_email


def test_pipeline_end_to_end(tmp_path):
    sim = make_llsc_sim()
    paper_scenario(sim, random.Random(0))
    archive = SnapshotArchive(str(tmp_path), cluster="txgreen")
    archiver = PeriodicArchiver(archive, SimCollector(sim))

    # one simulated day at the paper's 15-minute cadence
    captured = 0
    for _ in range(24 * 4):
        sim.step(900.0)
        captured += archiver.maybe_capture(sim.t)
    assert captured == 96

    rows = archive.rows()
    assert rows
    rep = weekly_analysis(rows, emails=sim.user_emails)
    # the paper-scenario pathological users surface in the right buckets
    low_gpu_users = [r.username for r in rep.low_gpu]
    high_cpu_users = [r.username for r in rep.high_cpu]
    assert "va67890" in low_gpu_users or "rs12345" in low_gpu_users
    assert "user02" in high_cpu_users  # io storm

    text = format_weekly_report(rep)
    assert "node-hours" in text
    mail = notification_email(rep.high_cpu[0], "high_cpu")
    assert mail.to.endswith("@ll.mit.edu")


def test_interval_gating(tmp_path):
    sim = make_llsc_sim(n_cpu=2, n_gpu=0)
    archive = SnapshotArchive(str(tmp_path))
    archiver = PeriodicArchiver(archive, SimCollector(sim), interval_s=900)
    assert archiver.maybe_capture(0.0)
    assert not archiver.maybe_capture(100.0)
    assert archiver.maybe_capture(901.0)


def test_time_window_filter(tmp_path):
    from repro.cluster.workloads import low_gpu_job

    sim = make_llsc_sim(n_cpu=6, n_gpu=4)
    sim.submit(low_gpu_job("u", tasks=1))
    sim.run_until(600.0)
    archive = SnapshotArchive(str(tmp_path))
    archive.append(sim.snapshot())
    sim.run_until(7200.0)
    archive.append(sim.snapshot())
    assert 0 < len(archive.rows(start=3600.0)) < len(archive.rows())

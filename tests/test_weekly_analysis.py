"""Weekly node-hours analysis (paper §V-A, Fig 6) + property tests."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.analysis import (HIGH_THRESHOLD, LOW_THRESHOLD,
                                 weekly_analysis)
from repro.core.report import format_weekly_report, notification_email


def _row(user, load, cores, gpu_load, gpus, ts=0.0):
    return {"timestamp": ts, "cluster": "tx", "hostname": f"n-{user}",
            "username": user, "jobtype": "batch", "cores_total": cores,
            "cores_used": cores, "load": load, "mem_total_gb": 192.0,
            "mem_used_gb": 10.0, "gpus_total": gpus, "gpus_used": gpus,
            "gpu_load": gpu_load, "gpu_mem_total_gb": 64.0 * gpus,
            "gpu_mem_used_gb": 1.0}


def test_thresholds_match_paper():
    assert LOW_THRESHOLD == 0.45
    assert HIGH_THRESHOLD == pytest.approx(1.55)


def test_categories():
    rows = [
        _row("lowgpu", load=30.0, cores=48, gpu_load=0.2, gpus=2),
        _row("lowcpu", load=5.0, cores=48, gpu_load=0.9, gpus=2),
        _row("highcpu", load=100.0, cores=48, gpu_load=0.0, gpus=0),
        _row("fine", load=40.0, cores=48, gpu_load=0.9, gpus=2),
    ]
    rep = weekly_analysis(rows)
    assert [r.username for r in rep.low_gpu] == ["lowgpu"]
    assert "lowcpu" in [r.username for r in rep.low_cpu]
    assert [r.username for r in rep.high_cpu] == ["highcpu"]
    # 1 snapshot = 0.25 node-hours (15-minute cadence)
    assert rep.low_gpu[0].node_hours == pytest.approx(0.25)


def test_cpu_only_nodes_never_low_gpu():
    rows = [_row("u", load=1.0, cores=48, gpu_load=0.0, gpus=0)]
    rep = weekly_analysis(rows)
    assert rep.low_gpu == []


def test_top10_cap_and_order():
    rows = []
    for i in range(15):
        for _ in range(i + 1):  # user i accrues (i+1) snapshots
            rows.append(_row(f"u{i:02d}", load=1.0, cores=48, gpu_load=0.1,
                             gpus=2))
    rep = weekly_analysis(rows)
    assert len(rep.low_gpu) == 10
    hours = [r.node_hours for r in rep.low_gpu]
    assert hours == sorted(hours, reverse=True)
    assert rep.low_gpu[0].username == "u14"


def test_report_rendering_fig6():
    rows = [_row("user01", load=30.0, cores=48, gpu_load=0.2, gpus=2)]
    rep = weekly_analysis(rows)
    text = format_weekly_report(rep, anonymize=True)
    assert "Most Low GPULOAD node-hours:" in text
    assert "user01@ll.mit.edu" in text
    assert "This report covers activity between" in text


def test_anonymize_aliases_stable_across_sections():
    """Regression: aliases were assigned per-section, so one real user
    read as different pseudonyms in low_gpu vs high_cpu (and 'user01'
    meant different people per section)."""
    from repro.core.analysis import ReportRow, WeeklyReport
    from repro.core.report import _anonymized

    rep = WeeklyReport(
        start=0.0, end=7 * 86400.0,
        # alice leads low_gpu but trails high_cpu; bob only in low_cpu
        low_gpu=[ReportRow("alice", "alice@x", 40.0),
                 ReportRow("carol", "carol@x", 10.0)],
        low_cpu=[ReportRow("bob", "bob@x", 30.0)],
        high_cpu=[ReportRow("carol", "carol@x", 25.0),
                  ReportRow("alice", "alice@x", 5.0)])
    anon = _anonymized(rep)
    alias = {}
    for section in ("low_gpu", "low_cpu", "high_cpu"):
        for real, row in zip(getattr(rep, section), getattr(anon, section)):
            alias.setdefault(real.username, set()).add(row.username)
            assert row.email == f"{row.username}@ll.mit.edu"
            assert row.node_hours == real.node_hours
    # one pseudonym per real user, one real user per pseudonym
    assert all(len(v) == 1 for v in alias.values())
    names = [next(iter(v)) for v in alias.values()]
    assert len(set(names)) == len(names) == 3
    # carol appears in two sections under one alias; alice (first seen)
    # is user01 everywhere, even where she trails the section
    assert anon.low_gpu[1].username == anon.high_cpu[0].username
    assert anon.low_gpu[0].username == anon.high_cpu[1].username == "user01"


def test_notification_email():
    rows = [_row("user01", load=30.0, cores=48, gpu_load=0.2, gpus=2)]
    rep = weekly_analysis(rows)
    mail = notification_email(rep.low_gpu[0], "low_gpu", advice="overload")
    assert mail.to == "user01@ll.mit.edu"
    assert "every 15 minutes" in mail.body
    assert "overload" in mail.body


@given(st.lists(st.tuples(
    st.sampled_from(["u1", "u2", "u3"]),
    st.floats(0.0, 200.0),          # load
    st.floats(0.0, 1.0),            # gpu load
    st.booleans(),                  # has gpu
), min_size=1, max_size=60))
def test_node_hours_conservation(entries):
    """Every snapshot row lands in at most one CPU bucket; totals add up."""
    rows = [_row(u, load=l, cores=48, gpu_load=g, gpus=2 if has else 0)
            for (u, l, g, has) in entries]
    rep = weekly_analysis(rows)
    low = sum(r.node_hours for r in rep.low_cpu)
    high = sum(r.node_hours for r in rep.high_cpu)
    n_low = sum(1 for r in rows if r["load"] / 48 < LOW_THRESHOLD)
    n_high = sum(1 for r in rows if r["load"] / 48 > HIGH_THRESHOLD)
    # <=: top-10 truncation can only drop hours (3 users -> never drops)
    assert low == pytest.approx(0.25 * n_low)
    assert high == pytest.approx(0.25 * n_high)


@given(st.floats(0.0, 0.44), st.floats(1.56, 50.0))
def test_threshold_boundaries(low_gpu, high_norm):
    rows = [_row("a", load=low_gpu * 48, cores=48, gpu_load=low_gpu, gpus=2),
            _row("b", load=high_norm * 48, cores=48, gpu_load=0.9, gpus=2)]
    rep = weekly_analysis(rows)
    assert any(r.username == "a" for r in rep.low_gpu)
    assert any(r.username == "b" for r in rep.high_cpu)


# ----------------------------------------------- columnarize vectorization


def _columnarize_reference(rows):
    """The pre-vectorization per-row loop, kept as the oracle."""
    users = sorted({r["username"] for r in rows})
    uidx = {u: i for i, u in enumerate(users)}
    n = len(rows)
    codes = np.empty(n, np.int32)
    norm_cpu = np.empty(n, np.float64)
    gpu_load = np.empty(n, np.float64)
    has_gpu = np.empty(n, bool)
    ts = np.empty(n, np.float64)
    for i, r in enumerate(rows):
        codes[i] = uidx[r["username"]]
        norm_cpu[i] = r["load"] / max(r["cores_total"], 1)
        gpu_load[i] = r["gpu_load"]
        has_gpu[i] = r["gpus_total"] > 0
        ts[i] = r["timestamp"]
    return codes, users, norm_cpu, gpu_load, has_gpu, ts


def _week_rows(n_users=50, n_nodes=40, n_snaps=7 * 24 * 4, seed=0):
    rng = np.random.default_rng(seed)
    rows = []
    for s in range(n_snaps):
        for node in range(n_nodes):
            u = f"u{rng.integers(n_users):03d}"
            rows.append(_row(u, load=float(rng.uniform(0, 96)), cores=48,
                             gpu_load=float(rng.uniform(0, 1)),
                             gpus=int(rng.integers(0, 2)) * 2,
                             ts=900.0 * s))
    return rows


def test_columnarize_matches_reference_on_week_archive():
    from repro.core.analysis import columnarize

    rows = _week_rows(n_snaps=48)              # half a day is plenty here
    col = columnarize(rows)
    codes, users, norm_cpu, gpu_load, has_gpu, ts = \
        _columnarize_reference(rows)
    assert col.user_list == users
    np.testing.assert_array_equal(col.usernames, codes)
    np.testing.assert_allclose(col.norm_cpu, norm_cpu)
    np.testing.assert_allclose(col.gpu_load, gpu_load)
    np.testing.assert_array_equal(col.has_gpu, has_gpu)
    np.testing.assert_array_equal(col.timestamps, ts)


def test_columnarize_empty_and_zero_cores():
    from repro.core.analysis import columnarize

    assert columnarize([]).norm_cpu.size == 0
    col = columnarize([_row("u", load=5.0, cores=0, gpu_load=0.0, gpus=0)])
    assert col.norm_cpu[0] == 5.0              # max(cores, 1) guard


def test_columnarize_week_scale_microbench():
    """Week-scale synthetic archive (~270k rows) columnarizes fast enough
    to stay interactive: well under 10us/row even on a loaded CI box (the
    numpy path runs ~0.5us/row; the old per-row loop was the bottleneck)."""
    import time

    from repro.core.analysis import columnarize

    rows = _week_rows()
    assert len(rows) == 7 * 24 * 4 * 40
    t0 = time.perf_counter()
    col = columnarize(rows)
    dt = time.perf_counter() - t0
    assert col.norm_cpu.size == len(rows)
    assert dt / len(rows) < 1e-5, f"{dt / len(rows) * 1e6:.2f}us/row"

"""TelemetryBus: cache TTL, ring buffer, deltas, subscribers, sampler,
and the watch loop's cached-read property."""
import io
import random
import threading
import time

import pytest

from repro.cluster.workloads import make_llsc_sim, paper_scenario
from repro.core.archive import ArchiveSubscriber, SnapshotArchive
from repro.core.metrics import ClusterSnapshot, NodeSnapshot
from repro.monitor import TelemetryBus, publish_step_utilization, watch
from repro.core.collector import JaxJobRegistry


def _sim(cluster="txgreen", until=1800.0):
    sim = make_llsc_sim(6, 4, cluster=cluster)
    paper_scenario(sim, random.Random(0))
    sim.run_until(until)
    return sim


class CountingSource:
    """Wraps a source, counting snapshot() calls (the collection cost)."""

    def __init__(self, inner):
        self.inner = inner
        self.name = inner.name
        self.interval_hint = None
        self.calls = 0

    def snapshot(self):
        self.calls += 1
        return self.inner.snapshot()


# ----------------------------------------------------------------- caching


def test_cached_reads_within_ttl():
    src = CountingSource(_sim().as_source())
    bus = TelemetryBus(ttl_s=60.0)
    bus.register(src)

    snaps = [bus.read() for _ in range(10)]
    assert src.calls == 1, "nine of ten reads must be served from cache"
    assert all(s is snaps[0] for s in snaps)
    st = bus.stats()
    assert st.reads == 10 and st.cache_hits == 9 and st.collections == 1


def test_ttl_expiry_forces_recollection():
    src = CountingSource(_sim().as_source(advance_s=900.0))
    bus = TelemetryBus(ttl_s=0.0)          # nothing is ever fresh
    bus.register(src)
    t0 = bus.read().timestamp
    t1 = bus.read().timestamp
    assert src.calls == 2
    assert t1 > t0


def test_max_age_overrides_ttl():
    src = CountingSource(_sim().as_source())
    bus = TelemetryBus(ttl_s=1e9)
    bus.register(src)
    bus.read()
    bus.read(max_age_s=0.0)
    assert src.calls == 2


def test_multi_source_read_requires_name():
    bus = TelemetryBus()
    bus.register(_sim("a").as_source())
    bus.register(_sim("b").as_source())
    with pytest.raises(ValueError):
        bus.read()
    assert bus.read("a").cluster == "a"
    assert bus.sources() == ["a", "b"]


def test_duplicate_registration_rejected():
    bus = TelemetryBus()
    bus.register(_sim("a").as_source())
    with pytest.raises(ValueError):
        bus.register(_sim("a").as_source())


def test_concurrent_cold_reads_collect_once():
    """Readers racing on an expired cache must not double-collect (a
    stateful source would skip frames / double-advance sim time)."""
    inner = _sim().as_source(advance_s=60.0)

    class Slow(CountingSource):
        def snapshot(self):
            time.sleep(0.05)
            return super().snapshot()

    src = Slow(inner)
    bus = TelemetryBus(ttl_s=60.0)
    bus.register(src)
    threads = [threading.Thread(target=bus.read) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert src.calls == 1, "racing readers must serialize on one collection"
    st = bus.stats()
    assert st.reads == 8 and st.collections == 1 and st.cache_hits == 7


def test_watch_stats_are_per_run_not_cumulative():
    bus = TelemetryBus(ttl_s=60.0)
    bus.register(_sim().as_source())
    for _ in range(5):                 # pre-watch bus activity
        bus.read()
    ws = watch(bus, lambda s: "", interval_s=0.01, max_frames=2,
               out=io.StringIO(), sleep=lambda s: None)
    assert ws.frames == 2
    assert ws.reads == 2               # not 7
    assert ws.collections <= 1


def test_multi_cluster_hung_child_does_not_stack_threads():
    """Repeated polls while a child is hung must reuse the in-flight
    future instead of spawning a new worker each poll."""
    import time as _time
    from repro.monitor import MultiClusterSource, SimSource

    class Hang:
        name = "hang"
        interval_hint = None
        concurrent_calls = 0
        max_concurrent = 0

        def snapshot(self):
            Hang.concurrent_calls += 1
            Hang.max_concurrent = max(Hang.max_concurrent,
                                      Hang.concurrent_calls)
            try:
                _time.sleep(0.5)
                raise RuntimeError("always failing after hang")
            finally:
                Hang.concurrent_calls -= 1

    multi = MultiClusterSource(
        [SimSource(_sim("ok")), Hang()], timeout_s=0.05)
    for _ in range(4):                 # polls arrive faster than the hang
        snap = multi.snapshot()
        assert "ok" in snap.cluster or snap.cluster == "ok"
    assert Hang.max_concurrent == 1
    assert isinstance(multi.last_error("hang"), TimeoutError)


def test_watch_restores_bus_ttl():
    bus = TelemetryBus(ttl_s=0.5)
    bus.register(_sim().as_source())
    watch(bus, lambda s: "", interval_s=5.0, max_frames=1,
          out=io.StringIO(), sleep=lambda s: None)
    assert bus.ttl_s == 0.5


# ------------------------------------------------------- ring buffer/deltas


def test_ring_buffer_and_load_trend():
    bus = TelemetryBus(ttl_s=0.0, history=4)
    bus.register(_sim().as_source(advance_s=900.0))
    for _ in range(6):
        bus.poll()
    ring = bus.history_of()
    assert len(ring) == 4                       # bounded
    assert ring[-1].timestamp - ring[0].timestamp == 3 * 900.0
    # trend is finite and computed over the ring window
    trend = bus.load_trend()
    assert isinstance(trend, float)


def test_gpu_duty_ewma_tracks_users():
    bus = TelemetryBus(ttl_s=0.0, ewma_alpha=0.5)
    bus.register(_sim().as_source(advance_s=900.0))
    bus.poll()
    ewma1 = bus.gpu_duty_ewma()
    assert ewma1, "scenario has GPU users"
    assert all(0.0 <= v <= 1.5 for v in ewma1.values())
    bus.poll()
    ewma2 = bus.gpu_duty_ewma()
    assert set(ewma2) >= set(ewma1)


# ------------------------------------------------------------- subscribers


def test_subscribers_see_every_collection():
    bus = TelemetryBus(ttl_s=0.0)
    bus.register(_sim().as_source())
    got = []
    bus.subscribe(lambda name, snap: got.append((name, snap.timestamp)))
    bus.poll()
    bus.poll()
    assert len(got) == 2
    assert got[0][0] == "txgreen"
    bus.unsubscribe(bus._subscribers[0])


def test_archive_subscriber_respects_cadence(tmp_path):
    bus = TelemetryBus(ttl_s=0.0)
    bus.register(_sim().as_source(advance_s=300.0))   # 5 sim-min per poll
    archive = SnapshotArchive(str(tmp_path), cluster="txgreen")
    sub = ArchiveSubscriber(archive, interval_s=900.0)
    bus.subscribe(sub)
    for _ in range(7):                                # 30 sim-minutes
        bus.poll()
    rows = archive.rows()
    stamps = sorted({r["timestamp"] for r in rows})
    assert len(stamps) == 3                           # t0, +15min, +30min
    assert stamps[1] - stamps[0] >= 900.0


# ------------------------------------------------------------ sampler/watch


def test_background_sampler_collects_without_readers():
    src = CountingSource(_sim().as_source(advance_s=60.0))
    src.interval_hint = 0.02
    bus = TelemetryBus(ttl_s=10.0)
    bus.register(src)
    bus.start()
    try:
        deadline = time.monotonic() + 5.0
        while src.calls < 3 and time.monotonic() < deadline:
            time.sleep(0.01)
    finally:
        bus.stop()
    assert src.calls >= 3


def test_watch_serves_cached_reads_between_polls():
    """Acceptance: >= 3 refreshed frames; the underlying source is
    snapshotted fewer times than the bus is read."""
    src = CountingSource(_sim().as_source(advance_s=60.0))
    bus = TelemetryBus(ttl_s=10.0)
    bus.register(src)
    out = io.StringIO()
    ws = watch(bus, lambda s: f"cluster={s.cluster}", interval_s=0.01,
               max_frames=5, out=out)
    assert ws.frames >= 3
    assert ws.reads >= 5
    assert src.calls < ws.reads, (src.calls, ws.reads)
    text = out.getvalue()
    assert text.count("LLload watch | frame") == ws.frames
    assert "cluster=txgreen" in text


def test_watch_cli_end_to_end(capsys):
    from repro.core import cli

    rc = cli.main(["--watch", "--interval", "0.05", "--frames", "3",
                   "--source", "sim", "-t", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    frames = [l for l in out.splitlines() if "LLload watch | frame" in l]
    assert len(frames) == 3
    summary = [l for l in out.splitlines() if l.startswith("watch:")][0]
    # "watch: F frames, R reads, C collections" — cached reads between polls
    parts = summary.replace(",", "").split()
    n_reads, n_collections = int(parts[3]), int(parts[5])
    assert n_collections < n_reads


# ---------------------------------------------------------------- publish


def test_publish_hook_feeds_registry():
    reg = JaxJobRegistry()
    publish_step_utilization("job-a", model_flops_per_step=1e9,
                             step_time_s=0.01, peak_flops=1e12,
                             n_devices=2, registry=reg)
    agg = reg.aggregate()
    assert agg.n_devices == 2
    assert agg.duty_cycle == pytest.approx(1e9 / 0.01 / (1e12 * 2))

"""The §V-B experiment campaign subsystem (DESIGN.md §9): spec/TOML
loading, deterministic runs, closed-loop convergence, the experiments
query table, and the CLI/daemon surfaces (golden + remote identity)."""
import dataclasses
import json
import os

import pytest

from repro.cluster.job import JobSpec, TaskProfile
from repro.cluster.node import make_nodes
from repro.cluster.scheduler import Scheduler
from repro.core import cli
from repro.experiments import (JOB_RULE_CAMPAIGNS, Campaign, CampaignError,
                               Scenario, arrival_times, campaign_from_dict,
                               load_campaign, loads_toml, run_campaign,
                               render_result, starvation_campaign)
from repro.insights.rules import recommend_nppn
from repro.query import Query, QueryError, run_query

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN = os.path.join(HERE, "golden")
CAMPAIGN_TOML = os.path.join(HERE, os.pardir, "examples",
                             "overload_campaign.toml")
SMOKE_CELLS = "low_duty/8g/nppn1,low_duty/8g/controller"


@pytest.fixture(scope="module")
def campaign():
    return load_campaign(CAMPAIGN_TOML)


@pytest.fixture(scope="module")
def low_duty_result(campaign):
    """The low_duty 8-node fleet group (ladder + controller), run once."""
    return run_campaign(campaign, cells="low_duty/8g/*")


# ----------------------------------------------------------------- spec/TOML


def test_example_campaign_grid(campaign):
    names = [c.name for c in campaign.cells()]
    assert "low_duty/8g/nppn1" in names
    assert "low_duty/8g/controller" in names
    assert "mixed/4g/nppn4" in names
    # grid size: mixes x fleets x (ladder + controller)
    assert len(names) == 2 * 2 * (3 + 1)
    assert len(set(names)) == len(names)


def test_select_cells_glob_and_errors(campaign):
    cells = campaign.select_cells("low_duty/8g/*")
    assert [c.name for c in cells] == [
        "low_duty/8g/nppn1", "low_duty/8g/nppn2", "low_duty/8g/nppn4",
        "low_duty/8g/controller"]
    # exact names, deduplicated, grid order regardless of pattern order
    cells = campaign.select_cells(
        "low_duty/8g/controller,low_duty/8g/nppn1,low_duty/8g/nppn1")
    assert [c.name for c in cells] == ["low_duty/8g/nppn1",
                                       "low_duty/8g/controller"]
    with pytest.raises(CampaignError, match="matches no cell"):
        campaign.select_cells("bogus/*")


def test_toml_subset_values():
    data = loads_toml('a = 1\n[s]\nb = "x"  # comment\nc = [1, 2]\n'
                      'd = true\ne = 1.5\n')
    assert data == {"a": 1, "s": {"b": "x", "c": [1, 2], "d": True,
                                  "e": 1.5}}


@pytest.mark.parametrize("text", [
    "a\n",                       # no '='
    "[bad\n",                    # malformed section
    "[a.b]\n",                   # nesting is outside the subset
    'a = "x\\n"\n',              # escapes are outside the subset
    "a = {x = 1}\n",             # inline tables are outside the subset
])
def test_toml_subset_rejects(text):
    with pytest.raises(CampaignError):
        loads_toml(text)


def test_campaign_dict_roundtrip(campaign):
    again = campaign_from_dict(json.loads(campaign.spec_json()))
    assert again == campaign


@pytest.mark.parametrize("mutate,match", [
    ({"sweep": {"mixes": ["nope"]}}, "unknown workload mix"),
    ({"sweep": {"nppn": [0]}}, "nppn"),
    ({"scenario": {"duration_s": -1.0}}, "duration_s"),
    ({"scenario": {"bogus": 1}}, "unknown scenario key"),
    ({"bogus": {}}, "unknown campaign section"),
    # resource ceilings: campaign specs reach the daemon from remote
    # clients, so a spec may not demand unbounded compute/memory
    ({"scenario": {"duration_s": 1e12}}, "cap"),
    ({"sweep": {"fleets": [10**6]}}, "cap"),
    ({"scenario": {"n_jobs": 10**6}}, "cap"),
    ({"scenario": {"tasks_per_job": 10**6}}, "cap"),
    ({"sweep": {"nppn": [1024]}}, "nppn"),
    ({"sweep": {"fleets": list(range(1, 200))}}, "cells"),
])
def test_campaign_validation_errors(campaign, mutate, match):
    data = campaign.to_dict()
    for section, kv in mutate.items():
        data.setdefault(section, {}).update(kv)
    with pytest.raises(CampaignError, match=match):
        campaign_from_dict(data)


# ------------------------------------------------------------------- runner


def test_same_seed_identical_results_table(campaign):
    outs = [render_result(run_campaign(campaign, cells=SMOKE_CELLS),
                          fmt="json") for _ in range(2)]
    assert outs[0] == outs[1]


def test_different_seed_still_runs():
    c = Campaign(name="s", scenario=Scenario(duration_s=3600.0),
                 mixes=("low_duty",), nppn=(1,), fleets=(4,),
                 controller=False, seed=7).validate()
    rows = run_campaign(c).rows()
    assert rows[0]["seed"] == 7 and rows[0]["tasks_done"] >= 0


def test_fixed_ladder_monotonic_throughput(low_duty_result):
    thr = {r["cell"]: r["throughput"] for r in low_duty_result.rows()}
    assert thr["low_duty/8g/nppn1"] < thr["low_duty/8g/nppn2"] \
        <= thr["low_duty/8g/nppn4"]


def test_controller_converges_to_recommended_nppn(low_duty_result):
    """The closed loop must land on the level the Fig-7 rule recommends
    for a 0.35-duty, 2GB-per-task job on a 32GB device — and stay."""
    ctl = low_duty_result.cell_row("low_duty/8g/controller")
    assert ctl["nppn"] == recommend_nppn(0.35, 2.0, 32.0)
    # it acted on live diagnoses (some insight-active snapshots), then
    # the diagnosis cleared (far fewer than the fixed nppn1 cell's)
    fixed = low_duty_result.cell_row("low_duty/8g/nppn1")
    assert 0 < ctl["insights"] < fixed["insights"]


def test_closed_loop_speedup_acceptance(low_duty_result):
    """Acceptance: >= 1.2x throughput for the closed-loop cell on the
    low-duty workload mix (paper §V-B, Figs 5-7)."""
    ctl = low_duty_result.cell_row("low_duty/8g/controller")
    assert ctl["speedup"] >= 1.2
    # and it shortens the queue: overloading frees capacity
    fixed = low_duty_result.cell_row("low_duty/8g/nppn1")
    assert ctl["queue_wait_s"] < fixed["queue_wait_s"]


def test_high_duty_mix_gains_nothing():
    """Control: the controller must NOT overload a well-utilized mix."""
    c = Campaign(name="ctl", scenario=Scenario(duration_s=7200.0),
                 mixes=("high_duty",), nppn=(1,), fleets=(8,),
                 controller=True).validate()
    rows = run_campaign(c).rows()
    ctl = [r for r in rows if r["mode"] == "controller"][0]
    assert ctl["nppn"] == 1
    assert ctl["speedup"] == pytest.approx(1.0)


def test_scheduler_cancel_frees_slots():
    sched = Scheduler(make_nodes("c", 2, cores=40, gpus=2, gpu_mem_gb=32.0))
    spec = JobSpec("u", "j", n_tasks=2, cores_per_task=5, gpus_per_task=1,
                   duration_s=1e6, profile=TaskProfile(gpu_frac=0.3,
                                                       gpu_mem_gb=2.0))
    job = sched.submit(spec, 0.0)
    sched.tick(60.0)
    assert job.state == "R"
    assert sum(len(ns.tasks) for ns in sched.nodes.values()) == 2
    cancelled = sched.cancel(job.job_id)
    assert cancelled is job and job.state == "CA"
    assert sum(len(ns.tasks) for ns in sched.nodes.values()) == 0
    assert job not in sched.running and job not in sched.completed
    assert sched.cancel(job.job_id) is None          # already gone
    pending = sched.submit(dataclasses.replace(spec, n_tasks=999), 1.0)
    sched.tick(61.0)
    assert pending.state == "PD"
    assert sched.cancel(pending.job_id) is pending
    assert not sched.pending


# -------------------------------------------------------------- query table


def test_experiments_table_through_query_engine(low_duty_result):
    q = Query.from_params(table="experiments", filter="speedup>=1.2",
                          sort="-speedup", columns="cell,speedup")
    rs = run_query(None, q, experiments=low_duty_result)
    assert rs.columns == ["cell", "speedup"]
    assert all(r["speedup"] >= 1.2 for r in rs.rows)
    speedups = [r["speedup"] for r in rs.rows]
    assert speedups == sorted(speedups, reverse=True)


def test_experiments_table_needs_results():
    with pytest.raises(QueryError, match="experiments"):
        run_query(None, Query(table="experiments"))


def test_experiments_rows_accept_plain_dicts(low_duty_result):
    rows = low_duty_result.rows()
    rs = run_query(None, Query(table="experiments"), experiments=rows)
    assert len(rs.rows) == len(rows)


def test_speedup_none_without_baseline(campaign):
    result = run_campaign(campaign, cells="low_duty/8g/controller")
    row = result.rows()[0]
    assert row["speedup"] is None
    # None speedups sort after values in both directions (§7 contract)
    out = render_result(result, sort="-speedup", fmt="json")
    assert json.loads(out)["query_result"]["rows"]


# ------------------------------------------------------------- CLI + daemon


def _golden(name):
    with open(os.path.join(GOLDEN, name)) as f:
        return f.read()


def test_cli_golden_experiments_table(capsys):
    assert cli.main(["--experiment", CAMPAIGN_TOML,
                     "--cells", SMOKE_CELLS]) == 0
    assert capsys.readouterr().out == _golden("experiments.txt")


def test_cli_watch_streams_progress_frames(capsys):
    assert cli.main(["--experiment", CAMPAIGN_TOML, "--watch",
                     "--cells", SMOKE_CELLS,
                     "--columns", "cell,nppn,speedup"]) == 0
    out = capsys.readouterr().out
    headers = [ln for ln in out.splitlines()
               if ln.startswith("=== LLload campaign overload-sweep")]
    assert len(headers) == 2
    assert "cell 1/2" in headers[0] and "cell 2/2" in headers[1]
    # the final frame carries the full (partial-complete) table
    assert "low_duty/8g/controller" in out.splitlines()[-2]


@pytest.mark.parametrize("argv,needle", [
    (["--experiment", "no-such.toml"], "cannot read campaign"),
    (["--experiment", CAMPAIGN_TOML, "--cells", "bogus/*"],
     "matches no cell"),
    (["--cells", "low_duty/*"], "--experiment"),
    (["--experiment", CAMPAIGN_TOML, "--advise"], "--experiment"),
    (["--experiment", CAMPAIGN_TOML, "--table", "nodes"], "--experiment"),
    (["--experiment", CAMPAIGN_TOML, "--tsv"], "--experiment"),
    (["--experiment", CAMPAIGN_TOML, "--columns", "bogus"],
     "unknown column"),
    (["--experiment", CAMPAIGN_TOML, "--source", "remote"],
     "one --url"),
    (["--experiment", CAMPAIGN_TOML, "--source", "remote",
      "--url", "http://localhost:1", "--watch"], "--watch"),
])
def test_cli_experiment_errors_exit_1(capsys, argv, needle):
    assert cli.main(argv) == 1
    assert needle in capsys.readouterr().err


@pytest.fixture(scope="module")
def daemon_box():
    from repro.daemon import LLloadDaemon, serve_background
    from repro.monitor import build_source

    daemon = LLloadDaemon(build_source("sim"), ttl_s=3600.0)
    server, thread = serve_background(daemon)
    host, port = server.server_address[:2]
    yield daemon, f"http://{host}:{port}"
    server.shutdown()
    server.server_close()
    daemon.close()
    thread.join(timeout=5)


@pytest.mark.parametrize("extra", [
    [], ["--format", "json"], ["--filter", "mode == controller"],
    ["--columns", "cell,throughput,speedup"], ["--sort", "-speedup"]],
    ids=["flagless", "json", "filter", "columns", "sort"])
def test_remote_experiments_byte_identical(capsys, daemon_box, extra):
    """--experiment --source remote is answered by GET /experiments and
    must be byte-identical to the local run (acceptance)."""
    _, url = daemon_box
    args = ["--experiment", CAMPAIGN_TOML, "--cells", SMOKE_CELLS] + extra
    assert cli.main(args) == 0
    local = capsys.readouterr().out
    assert cli.main(args + ["--source", "remote", "--url", url]) == 0
    assert capsys.readouterr().out == local


def test_remote_experiments_memoized(daemon_box, campaign):
    """A repeated spec must not re-run the sweep (results are
    deterministic): the memo answers, only the render differs."""
    daemon, _ = daemon_box
    params = {"spec": campaign.spec_json(), "cells": SMOKE_CELLS}
    status, _, body1 = daemon.handle("/experiments", dict(params))
    assert status == 200
    memo_size = len(daemon._experiment_memo)
    status, _, body2 = daemon.handle(
        "/experiments", {**params, "format": "csv"})
    assert status == 200 and body2 != body1
    assert len(daemon._experiment_memo) == memo_size


@pytest.mark.parametrize("params,needle", [
    ({}, "spec"),
    ({"spec": "{"}, "bad campaign spec"),
    ({"spec": '{"bogus": {}}'}, "bad campaign spec"),
])
def test_daemon_experiments_rejects_bad_specs(daemon_box, params, needle):
    daemon, _ = daemon_box
    status, _, body = daemon.handle("/experiments", params)
    assert status == 400
    assert needle in json.loads(body)["error"]["message"]


# ------------------------------------------------- job-level rule campaigns


RULES_TOML = os.path.join(HERE, os.pardir, "examples",
                          "job_rules_campaign.toml")


@pytest.fixture(scope="module")
def rule_results():
    """Each job-level rule's demo campaign (library.py), run once."""
    return {kind: run_campaign(factory())
            for kind, factory in JOB_RULE_CAMPAIGNS.items()}


def test_arrival_pattern_validation():
    with pytest.raises(CampaignError, match="arrival_pattern"):
        Scenario(arrival_pattern="weekly").validate()


def test_arrival_times_traces():
    sc = Scenario(n_jobs=16, arrival_s=300.0, duration_s=9600.0)
    assert arrival_times(sc) == [i * 300.0 for i in range(16)]
    diurnal = arrival_times(dataclasses.replace(sc,
                                                arrival_pattern="diurnal"))
    assert diurnal == sorted(diurnal)
    assert 0.0 <= diurnal[0] and diurnal[-1] <= sc.duration_s
    # bunched around the first "day" peak (t = D/4): the quarter-window
    # around it holds clearly more than a uniform quarter of the jobs
    d = sc.duration_s
    peak = [t for t in diurnal if d / 8 <= t <= 3 * d / 8]
    assert len(peak) > sc.n_jobs // 4
    bursty = arrival_times(dataclasses.replace(sc,
                                               arrival_pattern="bursty"))
    assert bursty[:8] == [0.0] * 8 and bursty[8:] == [2400.0] * 8
    elastic = arrival_times(dataclasses.replace(sc,
                                                arrival_pattern="elastic"),
                            n_streams=2)
    assert all(t < d / 3 for t in elastic[0::2])       # dominant tenant
    assert all(t >= d / 3 for t in elastic[1::2])      # late arrivals


def test_arrival_pattern_spec_roundtrip():
    """arrival_pattern survives the spec_json wire form (str field)."""
    camp = starvation_campaign()
    assert campaign_from_dict(json.loads(camp.spec_json())) == camp
    assert camp.scenario.arrival_pattern == "diurnal"


def test_job_rules_campaign_toml_matches_library():
    assert load_campaign(RULES_TOML) == starvation_campaign()


@pytest.mark.parametrize("kind", sorted(JOB_RULE_CAMPAIGNS))
def test_rule_fires_in_its_campaign_cells(rule_results, kind):
    """Every job-level rule's campaign makes that rule fire — in the
    pathology cell AND (before remediation kicks in) the controller
    cell."""
    for r in rule_results[kind].results:
        assert r.kinds.get(kind, 0) > 0, (r.cell, r.kinds)


@pytest.mark.parametrize("kind", sorted(JOB_RULE_CAMPAIGNS))
def test_rule_campaign_closed_loop_remediates(rule_results, kind):
    """The controller cell beats the fixed cell on throughput and queue
    wait, and quiets the diagnosis it actuates on."""
    by_mode = {r.mode: r for r in rule_results[kind].results}
    fixed, ctl = by_mode["fixed"], by_mode["controller"]
    assert ctl.throughput > fixed.throughput
    assert ctl.queue_wait_s < fixed.queue_wait_s
    assert ctl.kinds[kind] < fixed.kinds[kind]

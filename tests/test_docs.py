"""Documentation hygiene (ISSUE 5 satellites): every public class and
function in the query/insights/daemon/experiments packages carries a
docstring, every module renders cleanly under pydoc, and the doc-snippet
runner that CI executes over README.md / docs/*.md can find and classify
fenced blocks."""
import importlib
import inspect
import os
import pkgutil
import pydoc
import sys

import pytest

AUDITED_PACKAGES = ("repro.query", "repro.insights", "repro.daemon",
                    "repro.experiments")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _modules():
    out = []
    for pkg_name in AUDITED_PACKAGES:
        pkg = importlib.import_module(pkg_name)
        out.append(pkg_name)
        for info in pkgutil.iter_modules(pkg.__path__):
            out.append(f"{pkg_name}.{info.name}")
    return out


MODULES = _modules()


def _public_objects(module):
    """(qualname, obj) for every public class/function/method defined in
    ``module`` (not re-exported from elsewhere, not dataclass/typing
    machinery)."""
    out = []
    for name, obj in vars(module).items():
        if name.startswith("_"):
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        if getattr(obj, "__module__", None) != module.__name__:
            continue
        out.append((name, obj))
        if inspect.isclass(obj):
            for mname, member in vars(obj).items():
                if mname.startswith("_"):
                    continue
                if inspect.isfunction(member):
                    out.append((f"{name}.{mname}", member))
    return out


@pytest.mark.parametrize("mod_name", MODULES)
def test_module_has_docstring(mod_name):
    mod = importlib.import_module(mod_name)
    assert (mod.__doc__ or "").strip(), f"{mod_name} has no module docstring"


@pytest.mark.parametrize("mod_name", MODULES)
def test_public_api_has_docstrings(mod_name):
    mod = importlib.import_module(mod_name)
    missing = [qual for qual, obj in _public_objects(mod)
               if not (inspect.getdoc(obj) or "").strip()]
    assert not missing, (f"{mod_name}: public API without docstrings: "
                         + ", ".join(sorted(missing)))


@pytest.mark.parametrize("mod_name", MODULES)
def test_pydoc_renders_clean(mod_name):
    """``python -m pydoc <module>`` must work for every audited module:
    render the same document in-process and require non-trivial output."""
    text = pydoc.render_doc(mod_name, renderer=pydoc.plaintext)
    assert mod_name.rsplit(".", 1)[-1] in text
    assert len(text.splitlines()) > 5


# ------------------------------------------------------- doc-snippet runner


def test_check_docs_extracts_fenced_blocks(tmp_path):
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    md = tmp_path / "sample.md"
    md.write_text(
        "# t\n```bash\necho hi\n```\ntext\n```python\nx = 1\n```\n"
        "```text\nnot runnable output\n```\n"
        "```bash\n# docs: skip\nexit 1\n```\n")
    blocks = check_docs.extract_blocks(str(md))
    langs = [b.lang for b in blocks]
    assert langs == ["bash", "python", "text", "bash"]
    runnable = [b for b in blocks if check_docs.is_runnable(b)]
    assert [b.lang for b in runnable] == ["bash", "python"]
    assert runnable[0].code == "echo hi\n"


def test_check_docs_runs_and_fails_on_bad_snippet(tmp_path):
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    good = tmp_path / "good.md"
    good.write_text("```bash\ntrue\n```\n```python\nprint(1)\n```\n")
    assert check_docs.main([str(good)]) == 0
    bad = tmp_path / "bad.md"
    bad.write_text("```bash\nfalse\n```\n")
    assert check_docs.main([str(bad)]) == 1


def test_repo_docs_have_runnable_snippets():
    """README.md and both guides must carry executable blocks — the CI
    docs job is only meaningful if there is something to run."""
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    for rel in ("README.md", os.path.join("docs", "user-guide.md"),
                os.path.join("docs", "operator-guide.md")):
        blocks = check_docs.extract_blocks(os.path.join(REPO, rel))
        runnable = [b for b in blocks if check_docs.is_runnable(b)]
        assert runnable, f"{rel} has no runnable fenced blocks"

"""Insights subsystem (DESIGN.md §8): rules, incremental engine
(persistence / hysteresis / first-seen), the insights query table, the
daemon's /insights endpoint, Prometheus gauges, and the overload
controller as a rule consumer."""
import json

import pytest

from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot
from repro.core.overload import (DeviceObservation, OverloadController,
                                 nearest_level)
from hypothesis import given, strategies as st
from repro.insights import (SEVERITIES, Insight, InsightEngine, Severity,
                            evaluate_snapshots, get_rule, recommend_nppn,
                            rule_names)
from repro.query import Query, QueryError, run_query


# ------------------------------------------------------------- fixtures ----


def _gpu_node(host="g-1", gpu_load=0.3, mem_used=2.0, mem_total=32.0,
              gpus=1, load=20.0, cores=40):
    return NodeSnapshot(host, cores_total=cores, cores_used=cores,
                        load=load, mem_total_gb=192.0, mem_used_gb=50.0,
                        gpus_total=gpus, gpus_used=gpus, gpu_load=gpu_load,
                        gpu_mem_total_gb=mem_total, gpu_mem_used_gb=mem_used)


def _snap(nodes, user="u1", ts=0.0):
    return ClusterSnapshot(
        cluster="t", timestamp=ts,
        nodes={n.hostname: n for n in nodes},
        jobs=[JobRecord(1, user, "job", [n.hostname for n in nodes], 40)])


def _low_gpu_snap(ts=0.0, firing=True):
    return _snap([_gpu_node(gpu_load=0.3 if firing else 0.9)], ts=ts)


# ----------------------------------------------------------------- rules ----


def test_registry_has_the_builtin_rules():
    assert rule_names() == ["fleet_fragmentation", "io_storm", "low_gpu",
                            "missubmission", "multi_tenant_fairness",
                            "overload", "queue_starvation"]
    assert get_rule("low_gpu").kind == "low_gpu"
    with pytest.raises(KeyError):
        get_rule("bogus")


def test_cli_advise_flag_interactions(capsys):
    """--advise never consults -n (no unknown-host exit 1), and --tsv
    rejects it loudly like every other query-shaping flag."""
    from repro.core import cli
    assert cli.main(["--source", "sim", "--advise", "-n", "bogus"]) == 0
    assert "Active insights:" in capsys.readouterr().out
    assert cli.main(["--source", "sim", "--advise", "--tsv"]) == 1
    assert "--advise" in capsys.readouterr().err


def test_custom_rule_bad_severity_fails_at_the_rule():
    """A custom rule minting an unknown severity errors where the record
    is built, not as a daemon 500 on the first /insights read."""
    with pytest.raises(ValueError) as ei:
        Insight(kind="x", severity="notice", username="u", hostnames=[],
                message="")
    assert "info, warn, critical" in str(ei.value)


def test_severity_orders_by_rank_not_lexically():
    assert Severity("critical") > "warn" > Severity("info")
    assert not (Severity("critical") < "info")
    assert Severity("warn") == "warn"
    assert sorted([Severity("warn"), Severity("critical"),
                   Severity("info")]) == ["info", "warn", "critical"]
    with pytest.raises(ValueError):
        Severity("bogus")


def test_fig7_heterogeneous_nodes_use_one_node_for_nppn():
    """Satellite fix: NPPN memory numerator/denominator must come from
    the same node.  Node a: 2GB used of 16GB; node b: 10GB used of
    64GB.  The old code paired b's 10GB with a's 16GB total -> NPPN 1;
    pairing 10GB with b's own 64GB leaves room for NPPN 4."""
    a = _gpu_node("g-a", gpu_load=0.2, mem_used=2.0, mem_total=16.0)
    b = _gpu_node("g-b", gpu_load=0.2, mem_used=10.0, mem_total=64.0)
    engine = InsightEngine()
    engine.observe(_snap([a, b]))
    (ins,) = engine.active()
    assert ins.kind == "low_gpu"
    assert ins.suggested_nppn == 4
    assert ins.evidence["gpu_mem_used_gb"] == 10.0
    assert ins.evidence["gpu_mem_total_gb"] == 64.0


# ---------------------------------------------------------------- engine ----


def test_engine_persistence_is_hit_fraction_since_first_seen():
    engine = InsightEngine(clear_after=3)
    for ts, firing in enumerate([True, True, False, True]):
        engine.observe(_low_gpu_snap(ts=float(ts), firing=firing))
    (ins,) = [i for i in engine.active() if i.kind == "low_gpu"]
    assert ins.persistence == pytest.approx(3 / 4)
    assert ins.first_seen == 0.0 and ins.last_seen == 3.0
    assert ins.streak == 1                   # reset by the miss at ts=2


def test_engine_min_streak_gates_activation():
    engine = InsightEngine(min_streak=2)
    engine.observe(_low_gpu_snap(ts=0.0))
    assert engine.active() == []             # one hit is not enough
    engine.observe(_low_gpu_snap(ts=1.0))
    (ins,) = engine.active()
    assert ins.streak == 2 and ins.first_seen == 0.0


def test_engine_clear_after_hysteresis():
    engine = InsightEngine(clear_after=2)
    engine.observe(_low_gpu_snap(ts=0.0))
    engine.observe(_low_gpu_snap(ts=1.0, firing=False))
    assert len(engine.active()) == 1         # lingers through one miss
    (ins,) = engine.active()
    assert ins.streak == 0 and ins.persistence == pytest.approx(0.5)
    engine.observe(_low_gpu_snap(ts=2.0, firing=False))
    assert engine.active() == []             # second miss clears it


def test_engine_new_episode_resets_first_seen():
    engine = InsightEngine(clear_after=1)
    engine.observe(_low_gpu_snap(ts=0.0))
    engine.observe(_low_gpu_snap(ts=1.0, firing=False))   # episode over
    engine.observe(_low_gpu_snap(ts=2.0))
    (ins,) = engine.active()
    assert ins.first_seen == 2.0 and ins.persistence == 1.0


def test_evaluate_snapshots_matches_streaming():
    snaps = [_low_gpu_snap(ts=float(t)) for t in range(4)]
    engine = InsightEngine()
    for s in snaps:
        engine.observe(s)
    assert evaluate_snapshots(snaps) == engine.active()


def test_engine_subscriber_filters_by_source_name():
    engine = InsightEngine()
    fn = engine.subscriber("a")
    fn("b", _low_gpu_snap())
    assert engine.active() == [] and engine.observations == 0
    fn("a", _low_gpu_snap())
    assert len(engine.active()) == 1


# ----------------------------------------------------------- query table ----


def test_insights_table_filters_by_severity_rank():
    crit = _snap([_gpu_node("c-1", gpu_load=0.0, load=720.0, cores=48)],
                 user="u2")
    info = _low_gpu_snap()
    engine = InsightEngine()
    engine.observe(_snap(list(info.nodes.values())
                         + list(crit.nodes.values())))
    # one user owning both nodes: low_gpu (info) + io_storm (critical)
    q = Query.from_params(table="insights", filter="severity>=warn")
    rs = run_query(info, q, insights=engine)
    assert [r["kind"] for r in rs.rows] == ["io_storm"]
    q2 = Query.from_params(table="insights", filter="severity<warn")
    assert [r["kind"] for r in run_query(info, q2, insights=engine).rows] \
        == ["low_gpu"]


def test_unknown_severity_literal_is_a_query_error():
    with pytest.raises(QueryError) as ei:
        Query.from_params(table="insights", filter="severity>=wrn")
    assert "info, warn, critical" in str(ei.value)


def test_insights_table_requires_engine():
    with pytest.raises(QueryError) as ei:
        run_query(_low_gpu_snap(), Query(table="insights"))
    assert "insights" in str(ei.value)


def test_sort_tolerates_none_cells():
    """nppn is None outside the low_gpu rule; sorting on it must not
    TypeError (Nones group after values)."""
    engine = InsightEngine()
    snap = _snap([_gpu_node(), _gpu_node("c-1", gpu_load=0.0,
                                         load=720.0, cores=48)])
    engine.observe(snap)
    q = Query.from_params(table="insights", sort="nppn")
    rows = run_query(snap, q, insights=engine).rows
    assert rows[0]["nppn"] is not None and rows[-1]["nppn"] is None
    # Nones stay last on DESCENDING sorts too (reverse=True must not
    # float the None marker to the top)
    q_desc = Query.from_params(table="insights", sort="-nppn")
    rows = run_query(snap, q_desc, insights=engine).rows
    assert rows[0]["nppn"] is not None and rows[-1]["nppn"] is None


# --------------------------------------------------- overload controller ----


def test_nearest_level_clamps_off_ladder_values():
    assert nearest_level(3) == 2
    assert nearest_level(16) == 8
    assert nearest_level(0) == 1
    assert nearest_level(16, max_nppn=4) == 4


def test_decide_accepts_off_ladder_nppn():
    """Satellite fix: decide(3) used to raise ValueError from
    NPPN_LEVELS.index(3)."""
    c = OverloadController()
    for _ in range(4):
        c.observe(DeviceObservation(0.3, 2.0, 32.0))
    assert c.decide(3).nppn == 4             # clamp to 2, step up one level
    sat = OverloadController()
    for _ in range(8):
        sat.observe(DeviceObservation(0.99, 2.0, 32.0))
    d = sat.decide(3)
    assert d.nppn == 2 and "saturated" in d.reason
    # clamping an over-max value to the ladder IS the back-off step
    assert sat.decide(16).nppn == 8
    assert sat.decide(8).nppn == 4           # on-ladder: step down one


def test_controller_consumes_low_gpu_insight():
    engine = InsightEngine()
    engine.observe(_snap([_gpu_node(gpu_load=0.35, mem_used=2.0)]))
    (ins,) = engine.active()
    c = OverloadController()
    d = c.consume(ins, current_nppn=1)
    assert d.nppn == 2                       # the paper's Fig-7 step
    other = OverloadController()
    kept = other.consume(Insight(
        kind="io_storm", severity=Severity("critical"), username="u",
        hostnames=[], message=""), current_nppn=2)
    assert kept.nppn == 2 and other.history == []


# ----------------------------------------------------- recommend_nppn ------


@given(st.floats(0.0, 2.0), st.floats(0.001, 100.0),
       st.floats(0.5, 100.0))
def test_recommend_nppn_always_an_llsub_level(load, mem_used, mem_total):
    assert recommend_nppn(load, mem_used, mem_total) in (1, 2, 4, 8)


@given(st.floats(0.0, 2.0), st.floats(0.001, 100.0),
       st.floats(0.5, 100.0))
def test_recommend_nppn_respects_memory_headroom(load, mem_used, mem_total):
    n = recommend_nppn(load, mem_used, mem_total)
    assert n == 1 or n * mem_used <= mem_total * 0.9 + 1e-6


@given(st.integers(2, 32))
def test_recommend_nppn_honors_max_cap(max_nppn):
    n = recommend_nppn(0.01, 0.01, 100.0, max_nppn=max_nppn)
    assert n <= max_nppn and n in (1, 2, 4, 8)


# ------------------------------------------------------- daemon surface ----


@pytest.fixture()
def daemon():
    from repro.daemon import LLloadDaemon
    from repro.monitor import build_source
    d = LLloadDaemon(build_source("sim"), ttl_s=3600.0)
    yield d
    d.close()


def test_daemon_insights_endpoint_text_and_json(daemon):
    status, ct, body = daemon.handle("/insights")
    assert status == 200 and "text/plain" in ct
    assert b"Active insights:" in body
    status, ct, body = daemon.handle("/insights", {"format": "json"})
    assert status == 200
    obj = json.loads(body)
    assert obj["kind"] == "query_result"
    assert obj["query_result"]["table"] == "insights"
    # the canned advise sort: most severe first
    sev = [r[0] for r in obj["query_result"]["rows"]]
    ranks = [SEVERITIES.index(s) for s in sev]
    assert ranks == sorted(ranks, reverse=True)


def test_daemon_insights_bad_filter_is_400(daemon):
    status, _, body = daemon.handle("/insights",
                                    {"filter": "severity>=bogus"})
    assert status == 400 and b"severity" in body


def test_daemon_query_table_insights(daemon):
    status, _, body = daemon.handle(
        "/query", {"table": "insights", "format": "json",
                   "filter": "severity>=warn"})
    assert status == 200
    rows = json.loads(body)["query_result"]["rows"]
    assert rows and all(r[0] in ("warn", "critical") for r in rows)


def test_daemon_metrics_exposes_insight_gauges(daemon):
    from repro.daemon.promtext import parse_prometheus
    status, _, body = daemon.handle("/metrics")
    assert status == 200
    metrics = parse_prometheus(body.decode("utf-8"))
    assert "llload_active_insights" in metrics
    per_kind = metrics["llload_insights_active"]
    total = sum(per_kind.values())
    (total_val,) = metrics["llload_active_insights"].values()
    assert total == total_val > 0
    assert any('kind="low_gpu"' in labels for labels in per_kind)


def test_daemon_backfill_feeds_insight_engine(daemon):
    """Restart recovery: backfilled snapshots reach the insight engine,
    so /insights wakes up with persistence/first-seen history instead
    of starting cold."""
    snaps = [_low_gpu_snap(ts=float(t)) for t in range(3)]
    assert daemon.backfill(snaps) == 3
    assert daemon.insights.observations == 3
    (ins,) = [i for i in daemon.insights.active() if i.kind == "low_gpu"
              and i.username == "u1"]
    assert ins.first_seen == 0.0 and ins.streak == 3


def test_daemon_insights_persistence_across_collections(daemon):
    """The daemon engine streams: repeated collections of the frozen sim
    keep persistence at 1.0 (which is what makes remote byte-identical
    to a one-shot local evaluation)."""
    daemon.handle("/insights")
    daemon.bus.poll(daemon.source.name)      # force a second collection
    assert daemon.insights.observations >= 2
    assert all(i.persistence == 1.0 for i in daemon.insights.active())

"""Regression tests for the races llcheck (LL001) flagged and this tree
fixed: stats() paths reading counters unlocked, and the multi-cluster
fan-out mutating its in-flight table outside the lock.

These are behavioural pins, not schedulers: each drives the fixed path
from many threads and asserts the *exact* final counter values — a torn
or lost update shows up as an off-by-N, a re-introduced unlocked access
shows up under `python -m llcheck`.
"""
import concurrent.futures
import threading
import time

from repro.daemon.store import HistoryStore
from repro.monitor import build_source
from repro.monitor.source import MultiClusterSource
from repro.storage import SegmentLog, open_storage
from repro.storage.shards import ShardManager


def _hammer(n_threads, fn):
    barrier = threading.Barrier(n_threads)
    errors = []

    def run(i):
        barrier.wait()
        try:
            fn(i)
        except Exception as exc:  # noqa: BLE001 — surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=run, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []


# ----------------------------------------------------------- wal.stats()


def test_wal_stats_exact_under_concurrent_appends(tmp_path):
    log = SegmentLog(str(tmp_path), max_records=32)
    per_thread, n_threads = 200, 4

    def work(i):
        if i == 0:                       # one thread polls stats
            for _ in range(100):
                st = log.stats()
                assert 0 <= st["appended"] <= per_thread * (n_threads - 1)
        else:
            for j in range(per_thread):
                log.append(float(j), b"x")

    _hammer(n_threads, work)
    st = log.stats()
    assert st["appended"] == per_thread * (n_threads - 1)
    assert st["records"] == per_thread * (n_threads - 1)
    log.close()


# --------------------------------------------------------- shards.stats()


def test_shard_stats_exact_under_concurrent_opens(tmp_path):
    mgr = ShardManager(str(tmp_path), max_open=8)
    keys = [f"user{i}" for i in range(32)]

    def work(i):
        for key in keys[i * 8:(i + 1) * 8]:
            mgr.log_for(key).append(1.0, b"x")
        for _ in range(50):
            st = mgr.stats()
            assert st["open"] <= 8

    _hammer(4, work)
    st = mgr.stats()
    assert st["opened"] == len(keys)     # each key opened exactly once
    assert st["opened"] - st["evicted"] == st["open"]
    mgr.close()


# -------------------------------------------------------- backend.stats()


def test_history_backend_stats_while_appending_and_compacting(tmp_path):
    rt = open_storage(str(tmp_path / "data"), compact_interval_s=9999.0)
    try:
        store = HistoryStore(backend=rt.history)
        from tests.test_storage import _snaps
        snaps = _snaps(40)

        def work(i):
            if i == 0:
                for snap in snaps:
                    store.append(snap)
            elif i == 1:
                rt.compact_once()
            else:
                for _ in range(50):
                    st = rt.stats()
                    assert st["history"]["raw"]["records"] >= 0

        _hammer(4, work)
        rt.compact_once()                # fold whatever the race left
        st = rt.stats()
        assert st["history"]["raw"]["records"] == len(snaps)
    finally:
        rt.close()


# ---------------------------------------------- multi-cluster fan-out


class _SlowChild:
    """A child whose collection blocks until released."""
    interval_hint = None

    def __init__(self, name, snap, hold):
        self.name = name
        self.snap = snap
        self.hold = hold
        self.calls = 0
        self._calls_lock = threading.Lock()

    def snapshot(self):
        with self._calls_lock:
            self.calls += 1
        assert self.hold.wait(timeout=10)
        return self.snap


def test_fanout_concurrent_snapshots_never_stack_collections():
    """N racing snapshot() callers reuse ONE in-flight collection per
    child (the _inflight table is read-modify-write under the lock)."""
    base = build_source("sim").snapshot()
    hold = threading.Event()
    child = _SlowChild("slow", base, hold)
    ms = MultiClusterSource([child], timeout_s=10.0)
    results = []

    def work(_):
        results.append(ms.snapshot())

    threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    time.sleep(0.3)                      # let every caller hit the table
    hold.set()
    for t in threads:
        t.join()
    assert child.calls == 1
    assert len(results) == 8
    assert all(set(r.nodes) == set(base.nodes) for r in results)
    ms._pool.shutdown(wait=False)


class _FailingChild:
    interval_hint = None

    def __init__(self, name):
        self.name = name

    def snapshot(self):
        raise ValueError(f"boom from {self.name}")


def test_fanout_all_failed_reports_errors_consistently():
    ms = MultiClusterSource([_FailingChild("a"), _FailingChild("b")],
                            timeout_s=5.0)
    with concurrent.futures.ThreadPoolExecutor(4) as pool:
        futs = [pool.submit(ms.snapshot) for _ in range(4)]
        for fut in futs:
            try:
                fut.result()
                raise AssertionError("expected RuntimeError")
            except RuntimeError as exc:
                msg = str(exc)
                assert "all 2 child sources failed" in msg
                assert "boom from a" in msg and "boom from b" in msg
    assert isinstance(ms.last_error("a"), ValueError)
    ms._pool.shutdown(wait=False)

"""Golden tests: default (flagless) view output is byte-identical to the
pre-engine fixtures in tests/golden/, locally and via --source remote —
the api_redesign acceptance bar — plus the CLI's query-flag surface."""
import json
import os

import pytest

from repro.core import cli
from repro.daemon import LLloadDaemon, serve_background
from repro.monitor import build_source

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden")

CASES = [
    ("user_default.txt", []),
    ("user_gpu.txt", ["-g", "--user", "va67890"]),
    ("top5.txt", ["-t", "5"]),
    ("all_admin_gpu.txt", ["--all", "-g", "--user", "admin"]),
    ("nodes.txt", ["-n", "c-1-1-1"]),
    ("advise.txt", ["--advise"]),
]


def _golden(name: str) -> str:
    with open(os.path.join(GOLDEN, name)) as f:
        return f.read()


@pytest.fixture(scope="module")
def daemon_url():
    daemon = LLloadDaemon(build_source("sim"), ttl_s=3600.0)
    server, thread = serve_background(daemon)
    host, port = server.server_address[:2]
    yield f"http://{host}:{port}"
    server.shutdown()
    server.server_close()
    daemon.close()
    thread.join(timeout=5)


@pytest.mark.parametrize("fixture,argv", CASES,
                         ids=[c[0].split(".")[0] for c in CASES])
def test_default_views_byte_identical_local(fixture, argv, capsys):
    assert cli.main(["--source", "sim"] + argv) == 0
    assert capsys.readouterr().out == _golden(fixture)


@pytest.mark.parametrize("fixture,argv",
                         [c for c in CASES if "--all" not in c[1]],
                         ids=[c[0].split(".")[0] for c in CASES
                              if "--all" not in c[1]])
def test_default_views_byte_identical_remote(fixture, argv, capsys,
                                             daemon_url):
    assert cli.main(["--source", "remote", "--url", daemon_url]
                    + argv) == 0
    assert capsys.readouterr().out == _golden(fixture)


def test_view_flags_reproduce_top_view(capsys):
    """The -t view is reproducible from raw query flags (acceptance)."""
    assert cli.main(["--source", "sim", "-t", "5", "--format", "json"]) == 0
    via_view = capsys.readouterr().out
    assert cli.main(["--source", "sim", "--table", "nodes",
                     "--sort", "-norm_load", "--limit", "5",
                     "--format", "json"]) == 0
    via_table = capsys.readouterr().out
    a = json.loads(via_view)["query_result"]
    b = json.loads(via_table)["query_result"]
    assert a["rows"] == b["rows"] and a["columns"] == b["columns"]


@pytest.mark.parametrize("fmt", ["json", "table", "csv"])
def test_remote_output_identical_to_local(capsys, daemon_url, fmt):
    args = ["--table", "nodes", "--filter", "gpus>0",
            "--columns", "host,user,gpu_load", "--sort", "-gpu_load",
            "--format", fmt]
    assert cli.main(["--source", "sim"] + args) == 0
    local = capsys.readouterr().out
    assert cli.main(["--source", "remote", "--url", daemon_url]
                    + args) == 0
    remote = capsys.readouterr().out
    assert local == remote


def test_advise_forwarded_identical_to_local(capsys, daemon_url):
    """--advise against one daemon URL is answered by GET /insights;
    the body must be byte-identical to the local render (acceptance)."""
    for extra in ([], ["--format", "json"],
                  ["--filter", "severity>=warn"],
                  ["--columns", "severity,kind,user,persistence"]):
        assert cli.main(["--source", "sim", "--advise"] + extra) == 0
        local = capsys.readouterr().out
        assert cli.main(["--source", "remote", "--url", daemon_url,
                         "--advise"] + extra) == 0
        assert capsys.readouterr().out == local


def test_remote_nodes_view_keeps_unknown_host_exit_code(capsys,
                                                        daemon_url):
    """-n is never forwarded: the all-hosts-unknown exit-1 contract
    must hold against a daemon too."""
    assert cli.main(["--source", "remote", "--url", daemon_url,
                     "-n", "nope", "--format", "json"]) == 1
    assert cli.main(["--source", "remote", "--url", daemon_url,
                     "-n", "c-1-1-1", "--filter", "gpus>=0"]) == 0
    assert "c-1-1-1" in capsys.readouterr().out


def test_watch_frames_accept_query_flags(capsys):
    assert cli.main(["--watch", "--interval", "0.01", "--frames", "2",
                     "--source", "sim", "-q", "-t", "3",
                     "--format", "json"]) == 0
    out = capsys.readouterr().out
    frames = [ln for ln in out.splitlines()
              if ln.startswith('{"v":1,"kind":"query_result"')]
    assert len(frames) == 2
    assert len(json.loads(frames[0])["query_result"]["rows"]) == 3
    # a machine-format frame's bytes match one-shot output: no blank
    # separator line from newline doubling
    assert "" not in out.splitlines()


def test_tsv_rejects_query_flags(capsys):
    assert cli.main(["--source", "sim", "--tsv",
                     "--filter", "gpus>0"]) == 1
    assert "--tsv" in capsys.readouterr().err


def test_watch_filter_narrows_text_view(capsys):
    assert cli.main(["--watch", "--interval", "0.01", "--frames", "1",
                     "--source", "sim", "-q", "--user", "cd67890",
                     "--filter", "norm_load>100"]) == 0
    out = capsys.readouterr().out
    assert "Nodes used: 0" in out


def test_filtered_out_host_is_not_reported_unknown(capsys):
    """Regression: -n with a --filter that excludes an existing host
    must omit it, not claim 'no such host in this snapshot'."""
    assert cli.main(["--source", "sim", "-n", "c-1-1-1",
                     "--filter", "cores>10000"]) == 0
    out = capsys.readouterr().out
    assert "Unknown node(s)" not in out
    assert cli.main(["--source", "sim", "-n", "c-1-1-1,nope"]) == 0
    assert "Unknown node(s): nope" in capsys.readouterr().out


def test_group_by_upgrades_text_to_table_renderer(capsys):
    """Regression: --group-by on a text view was computed then dropped."""
    assert cli.main(["--source", "sim", "--all", "--user", "admin",
                     "--group-by", "user"]) == 0
    out = capsys.readouterr().out
    assert "-- user = " in out and "rows)" in out


def test_unknown_column_exits_1_with_vocabulary(capsys):
    assert cli.main(["--source", "sim", "--columns", "host,bogus"]) == 1
    err = capsys.readouterr().err
    assert "bogus" in err and "norm_load" in err and "host" in err
    assert cli.main(["--source", "sim", "--sort", "-bogus"]) == 1
    assert "bogus" in capsys.readouterr().err


def test_limit_zero_rejected_like_other_nonpositive_flags(capsys):
    with pytest.raises(SystemExit) as ei:
        cli.main(["--source", "sim", "--limit", "0"])
    assert ei.value.code == 2
    assert "must be > 0" in capsys.readouterr().err


def test_bad_filter_exits_1(capsys):
    assert cli.main(["--source", "sim", "--filter", "cores >"]) == 1
    assert "filter" in capsys.readouterr().err


def test_history_table_needs_daemon_locally(capsys):
    assert cli.main(["--source", "sim", "--table", "history"]) == 1
    assert "history" in capsys.readouterr().err


def test_streaming_watch_byte_identical_to_polling_one_shot(capsys):
    """--watch against a daemon subscribes to /stream; with the daemon
    frozen (huge TTL), every streamed frame must render byte-identically
    to the polling one-shot — and the one-shot itself must stay on the
    polling path (no subscription for a single read)."""
    daemon = LLloadDaemon(build_source("sim"), ttl_s=3600.0)
    server, thread = serve_background(daemon)
    host, port = server.server_address[:2]
    args = ["--source", "remote", f"--url=http://{host}:{port}",
            "-q", "-t", "3", "--format", "json"]
    try:
        assert cli.main(args) == 0
        one_shot = capsys.readouterr().out
        assert daemon.hub.stats()["subscribed_total"] == 0.0  # stayed polling

        assert cli.main(["--watch", "--interval", "0.01",
                         "--frames", "3"] + args) == 0
        out = capsys.readouterr().out
        # frame headers carry timing-dependent reads/collections counts;
        # everything else must match the polling render byte-for-byte
        body = "".join(ln + "\n" for ln in out.splitlines()
                       if not ln.startswith("=== LLload watch"))
        assert body == one_shot * 3
        stats = daemon.hub.stats()
        assert stats["subscribed_total"] >= 1.0               # watch streamed
        assert stats["frames_sent"] >= 1.0
    finally:
        server.shutdown()
        server.server_close()
        daemon.close()
        thread.join(timeout=5)


def test_history_table_via_remote(capsys, daemon_url):
    assert cli.main(["--source", "remote", "--url", daemon_url,
                     "--table", "history", "--format", "json"]) == 0
    obj = json.loads(capsys.readouterr().out)
    tiers = {row[0] for row in obj["query_result"]["rows"]}
    assert "raw" in tiers

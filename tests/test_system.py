"""End-to-end system behaviour: the full paper loop against a REAL JAX job.

Story (paper Fig 1 + §V-B):
  1. a JAX training job runs with LLload self-reporting hooks,
  2. LLload observes its utilization through the collector,
  3. the weekly-style analysis flags low device duty,
  4. the advisor recommends overloading (NPPN analog),
  5. the serving engine applies it (more concurrent streams) and
     aggregate throughput improves.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.collector import (JaxJobRegistry, LocalHostCollector,
                                  publish_step_utilization)
from repro.core.overload import OverloadController, DeviceObservation
from repro.models import init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


def test_training_job_visible_to_llload():
    JaxJobRegistry.global_registry().remove("e2e")
    cfg = reduced_config("llsc-100m")
    t = Trainer(cfg, TrainerConfig(steps=4, batch_size=2, seq_len=32,
                                   log_every=0, job_name="e2e"))
    t.run(resume=False)
    agg = JaxJobRegistry.global_registry().aggregate()
    assert agg.n_devices >= 1
    assert agg.duty_cycle >= 0.0
    assert agg.step_time_s > 0

    snap = LocalHostCollector(username="tester").snapshot()
    node = list(snap.nodes.values())[0]
    assert node.cores_total >= 1
    assert node.load >= 0.0
    JaxJobRegistry.global_registry().remove("e2e")


def test_loss_decreases_on_copy_task():
    cfg = reduced_config("llsc-100m")
    t = Trainer(cfg, TrainerConfig(steps=40, batch_size=4, seq_len=64,
                                   log_every=0, monitor_every=0))
    out = t.run(resume=False)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, f"no learning: {first:.3f} -> {last:.3f}"


def test_overloading_improves_throughput():
    """The paper's central claim, measured on real decode workloads:
    co-scheduling more low-duty request streams raises aggregate tok/s."""
    cfg = reduced_config("llsc-100m")
    params = init_params(cfg, jax.random.PRNGKey(0))

    def run_with_slots(slots, n_req=8):
        eng = ServeEngine(cfg, params, EngineConfig(
            slots=slots, max_seq_len=64, monitor=False))
        rng = np.random.default_rng(0)
        for i in range(n_req):
            eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 8)
                               .astype(np.int32), max_new_tokens=8))
        stats = eng.run()
        return stats

    s1 = run_with_slots(1)
    s4 = run_with_slots(4)
    assert s4["steps"] < s1["steps"], "packing must cut decode steps"
    # per-token work is batched: fewer steps for the same tokens
    assert s4["tokens"] == s1["tokens"]


def test_controller_converges_to_saturation():
    """Closed loop: simulated device with per-task duty 0.3 under the
    controller reaches NPPN that saturates near target without exceeding."""
    ctl = OverloadController()
    nppn = 1
    per_task = 0.3
    for _ in range(6):
        duty = min(1.0, per_task * nppn)
        for _ in range(4):
            ctl.observe(DeviceObservation(duty_cycle=duty, mem_used_gb=0.5,
                                          mem_total_gb=32.0))
        nppn = ctl.decide(nppn).nppn
    assert nppn == 2  # 0.3 * 2 = 0.6; stepping to 4 would exceed 0.9 target

"""Known-bad exit codes: nonzero pipe exit, swallowed env error,
out-of-convention codes, stray sys.exit in a helper."""
import sys


def main(argv=None):
    try:
        work()
    except BrokenPipeError:
        return 1
    except OSError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 0
    return 64


def work():
    sys.exit(7)

"""Known-good metric emission: names resolve statically, keys in vocab."""

_GAUGES = [
    ("hosts", "number of hosts"),
    ("users", "number of users"),
]


class _Writer:
    def __init__(self):
        self.lines = []

    def header(self, name, help_text, kind):
        self.lines.append(name)

    def sample(self, name, labels, value):
        self.lines.append(name)


def render(snapshot, prefix="llload_"):
    w = _Writer()
    for name, help_text in _GAUGES:
        w.header(f"{prefix}{name}", help_text, "gauge")
        w.sample(f"{prefix}{name}", [("cluster", "main")], 1.0)
    w.sample(prefix + "up", [("cluster", "main"), ("kind", "gauge")], 1.0)
    return w.lines

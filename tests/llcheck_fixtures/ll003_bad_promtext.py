"""Known-bad metric emission: unresolvable names, off-vocabulary keys,
f-string values, non-literal label lists, raw label injection."""


class _Writer:
    def __init__(self):
        self.lines = []

    def header(self, name, help_text, kind):
        self.lines.append(name)

    def sample(self, name, labels, value):
        self.lines.append(name)


def render(snapshot, metric_name):
    w = _Writer()
    w.header(metric_name, "dynamic name", "gauge")
    w.sample("nodes_total", [("cluster", "main")], 1.0)
    w.sample("llload_hosts", [("hostname", "h1")], 1.0)
    w.sample("llload_users", [("user", f"{snapshot.user}")], 1.0)
    w.sample("llload_flat", snapshot.pairs, 1.0)
    line = f'cluster="{snapshot.name}"'
    return w.lines + [line]

"""Known-good exit codes: pipe exits 0, env errors exit 1, helpers may
return sentinel ints that are not process exit codes."""
import sys


def main(argv=None):
    try:
        print("ok")
    except BrokenPipeError:
        return 0
    except OSError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
    return 0


def parse_retries(raw):
    try:
        return int(raw)
    except ValueError:
        return 124

"""Known-bad lock discipline: unclassified state and unlocked access."""
import threading


class Bad:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []              # guarded-by: _lock
        self.pending = {}

    def add(self, x):
        self._items.append(x)

    def drain(self):
        with self._lock:
            out = list(self._items)
        self._items.clear()
        return out

    def nested_resets(self):
        with self._lock:
            def inner():
                return len(self._items)
            return inner()

"""Known-good lock discipline: every guarded access is under the lock."""
import threading


class Good:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []              # guarded-by: _lock
        self.count = 0                # guarded-by: _lock
        # llcheck: ignore[LL001] fixed after construction, read-only later
        self.config = {}

    def add(self, x):
        with self._lock:
            self._items.append(x)
            self.count += 1

    # guarded-by: _lock
    def _locked_len(self):
        return len(self._items)

    def snapshot(self):
        with self._lock:
            return list(self._items)

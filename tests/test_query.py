"""The unified query engine (DESIGN.md §7): expression parser, Query
execution, renderer registry, edge cases, and CSV/TSV escaping."""
import json

import pytest
from hypothesis import given, strategies as st

from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot
from repro.query import (Query, QueryError, ResultSet, apply_modifiers,
                         get_renderer, parse_delimited, parse_filter,
                         render_csv, render_tsv, run_query, top_query,
                         user_query, view_query, vocabulary)


def _snap():
    nodes = {
        "a-1": NodeSnapshot("a-1", 40, 40, 38.0, 384.0, 120.0,
                            gpus_total=2, gpus_used=2, gpu_load=0.8,
                            gpu_mem_total_gb=64.0, gpu_mem_used_gb=30.0),
        "a-2": NodeSnapshot("a-2", 40, 10, 4.0, 384.0, 30.0,
                            gpus_total=2, gpus_used=1, gpu_load=0.1,
                            gpu_mem_total_gb=64.0, gpu_mem_used_gb=2.0),
        "b-1": NodeSnapshot("b-1", 48, 48, 96.0, 192.0, 150.0),
        "b-2": NodeSnapshot("b-2", 48, 0, 0.1, 192.0, 5.0),
    }
    jobs = [
        JobRecord(1, "alice", "train", ["a-1"], 40, gpus_per_node=2),
        JobRecord(2, "bob", "sweep", ["a-2", "b-1"], 10),
        JobRecord(3, "alice", "old", ["b-1"], 4, state="PD"),
        JobRecord(4, "carol", "nb", ["a-2"], 2, job_type="jupyter",
                  gpu_request="gres:gpu:volta:1"),
    ]
    return ClusterSnapshot("test", 1000.0, nodes, jobs)


def _empty_snap():
    return ClusterSnapshot("empty", 0.0, {}, [])


# ------------------------------------------------------------------- expr


def test_filter_parses_comparisons_and_booleans():
    vocab = vocabulary("nodes")
    e = parse_filter("gpu_load<0.2 and gpus>0", vocab)
    rows = run_query(_snap(), Query(table="nodes", where=e)).rows
    assert [r["host"] for r in rows] == ["a-2"]


def test_filter_or_not_parens():
    vocab = vocabulary("nodes")
    e = parse_filter("not (cores_used>0) or host == b-1", vocab)
    rows = run_query(_snap(), Query(where=e)).rows
    assert [r["host"] for r in rows] == ["b-1", "b-2"]


def test_filter_glob_and_has():
    vocab = vocabulary("nodes")
    rows = run_query(_snap(), Query(
        where=parse_filter('host =~ "a-*"', vocab))).rows
    assert [r["host"] for r in rows] == ["a-1", "a-2"]
    rows = run_query(_snap(), Query(
        where=parse_filter("users has bob", vocab))).rows
    assert [r["host"] for r in rows] == ["a-2", "b-1"]


def test_filter_unknown_column_reports_vocabulary():
    with pytest.raises(QueryError) as ei:
        parse_filter("bogus > 1", vocabulary("nodes"))
    msg = str(ei.value)
    assert "bogus" in msg and "gpu_load" in msg and "host" in msg


def test_filter_syntax_errors():
    vocab = vocabulary("nodes")
    for bad in ("cores >", "cores ! 3", "(cores>1", "cores>1 extra",
                "and", "cores has"):
        with pytest.raises(QueryError):
            parse_filter(bad, vocab)


def test_filter_type_mismatch_matches_nothing():
    vocab = vocabulary("nodes")
    e = parse_filter('cores == "forty"', vocab)
    assert run_query(_snap(), Query(where=e)).rows == []


def test_filter_type_mismatch_neq_is_negation_of_eq():
    # regression: != must stay `not ==` even across a type mismatch
    vocab = vocabulary("nodes")
    e = parse_filter('cores != "forty"', vocab)
    assert len(run_query(_snap(), Query(where=e)).rows) == 4
    e = parse_filter('cores < "forty"', vocab)      # orderings: no match
    assert run_query(_snap(), Query(where=e)).rows == []


def test_numeric_literal_matches_string_column_as_written():
    # regression: `users has 42` compared "42.0" against the list and
    # could never match a numeric username; same for `host == 123`
    vocab = vocabulary("nodes")
    assert parse_filter("users has 42", vocab) \
        .evaluate({"users": "42, bob"})
    assert parse_filter("host == 123", vocab).evaluate({"host": "123"})
    assert not parse_filter("host == 123", vocab).evaluate({"host": "12"})


# ------------------------------------------------------------------ engine


def test_sort_desc_and_multi_key():
    rows = run_query(_snap(), Query(sort=("-gpus", "host"))).rows
    assert [r["host"] for r in rows] == ["a-1", "a-2", "b-1", "b-2"]
    rows = run_query(_snap(), Query(sort=("-norm_load",))).rows
    assert [r["host"] for r in rows] == ["b-1", "a-1", "a-2", "b-2"]


def test_limit_and_columns():
    rs = run_query(_snap(), Query(columns=("host", "cpu_load"),
                                  sort=("-cpu_load",), limit=2))
    assert rs.columns == ["host", "cpu_load"]
    assert [r["host"] for r in rs.rows] == ["b-1", "a-1"]
    # rows keep the full vocabulary; renderers project onto columns
    assert "gpu_load" in rs.rows[0]


def test_group_by_partitions_in_first_seen_order():
    rs = run_query(_snap(), Query(sort=("host",), group_by="user"))
    keys = [k for k, _ in rs.groups]
    assert keys == ["alice", "bob", ""]        # a-1, a-2/b-1, b-2 idle
    assert [r["host"] for r in dict(rs.groups)["bob"]] == ["a-2", "b-1"]


def test_users_table_counts_shared_nodes_for_each_owner():
    rows = run_query(_snap(), Query(table="users")).rows
    by_user = {r["user"]: r for r in rows}
    # carol shares a-2 with bob; both count it
    assert by_user["carol"]["nodes"] == 1
    assert by_user["bob"]["nodes"] == 2
    assert "alice" in by_user
    assert by_user["alice"]["gpus_used"] == 2


def test_jobs_table():
    rows = run_query(_snap(), Query(
        table="jobs", where=parse_filter("state == R",
                                         vocabulary("jobs")))).rows
    assert {r["job_id"] for r in rows} == {1, 2, 4}
    nb = [r for r in rows if r["jobtype"] == "jupyter"][0]
    assert nb["user"] == "carol" and nb["gpu_request"]


def test_query_validate_rejects_bad_specs():
    with pytest.raises(QueryError):
        Query(table="nope").validate()
    with pytest.raises(QueryError):
        Query(columns=("host", "bogus")).validate()
    with pytest.raises(QueryError):
        Query(sort=("-bogus",)).validate()
    with pytest.raises(QueryError):
        Query(group_by="bogus").validate()
    with pytest.raises(QueryError):
        Query(limit=0).validate()
    with pytest.raises(QueryError):
        Query.from_params(limit="three")
    # the descending prefix is only meaningful in --sort
    with pytest.raises(QueryError):
        Query.from_params(columns="-host")
    with pytest.raises(QueryError):
        Query.from_params(group_by="-user")


def test_unknown_sort_column_message_lists_vocabulary():
    with pytest.raises(QueryError) as ei:
        Query.from_params(sort="-nope")
    assert "norm_load" in str(ei.value) and "'nope'" in str(ei.value)


# -------------------------------------------------------------- edge cases


def test_empty_snapshot_every_table_and_renderer():
    snap = _empty_snap()
    for table in ("nodes", "users", "jobs"):
        rs = run_query(snap, Query(table=table))
        assert rs.rows == []
        for fmt in ("table", "json", "csv", "tsv", "prom"):
            out = get_renderer(fmt).render(rs)
            assert isinstance(out, str)
    payload = json.loads(get_renderer("json").render(
        run_query(snap, Query())))
    assert payload["query_result"]["rows"] == []


def test_filter_matching_zero_rows():
    rs = run_query(_snap(), Query(
        where=parse_filter("cores > 1000", vocabulary("nodes"))))
    assert rs.rows == []
    assert "(0 rows)" in get_renderer("table").render(rs)


def test_history_table_requires_store():
    with pytest.raises(QueryError) as ei:
        run_query(_snap(), Query(table="history"))
    assert "history" in str(ei.value)


def test_history_table_from_store():
    from repro.daemon.store import HistoryStore
    store = HistoryStore()
    store.append(_snap())
    rs = run_query(None, Query(table="history"), store=store)
    tiers = {r["tier"] for r in rs.rows}
    assert {"raw", "15min", "hourly"} <= tiers
    raw = [r for r in rs.rows if r["tier"] == "raw"][0]
    assert raw["count"] == 1 and raw["nodes_mean"] == 4.0


# --------------------------------------------------------------- canned views


def test_user_query_includes_shared_nodes():
    rs = run_query(_snap(), user_query("carol"))
    assert [r["host"] for r in rs.rows] == ["a-2"]
    rs = run_query(_snap(), user_query("bob"))
    assert [r["host"] for r in rs.rows] == ["a-2", "b-1"]


def test_top_query_matches_legacy_top_loaded():
    from repro.core.llload import LLload
    snap = _snap()
    legacy = LLload(snap).top_loaded(3)
    rs = run_query(snap, top_query(3))
    assert [r["host"] for r in rs.rows] == [t.hostname for t in legacy]
    assert [r["norm_load"] for r in rs.rows] == \
        [t.avg_load for t in legacy]


def test_view_query_unknown_kind():
    with pytest.raises(QueryError):
        view_query("bogus")


def test_apply_modifiers_ands_filter_and_overrides_rest():
    q = apply_modifiers(user_query("bob"), filter="gpus > 0",
                        sort="-cpu_load", limit=1)
    rs = run_query(_snap(), q)
    assert [r["host"] for r in rs.rows] == ["a-2"]   # b-1 has no gpus


# ---------------------------------------------------- csv/tsv escaping


def _hostile_resultset(cells):
    rows = [{"host": h, "user": u} for h, u in cells]
    return ResultSet(table="nodes", columns=["host", "user"], rows=rows,
                     cluster="x", timestamp=0.0)


def test_csv_escapes_delimiters_quotes_newlines():
    rs = _hostile_resultset([('evil,"host"', 'a\nb'), ("plain", "u,v")])
    out = render_csv(rs)
    parsed = parse_delimited(out, "csv")
    assert parsed[0] == ["host", "user"]
    assert parsed[1] == ['evil,"host"', "a\nb"]
    assert parsed[2] == ["plain", "u,v"]


def test_tsv_escapes_tabs_and_newlines():
    rs = _hostile_resultset([("h\tx", "u\r\nv")])
    out = render_tsv(rs)
    parsed = parse_delimited(out, "tsv")
    assert parsed[1] == ["h\tx", "u\r\nv"]


_cell = st.text(
    alphabet=st.sampled_from(list('abc,"\t\n\r ;x')), max_size=8)


@given(st.lists(st.tuples(_cell, _cell), min_size=1, max_size=6))
def test_csv_tsv_roundtrip_property(cells):
    for fmt, render in (("csv", render_csv), ("tsv", render_tsv)):
        out = render(_hostile_resultset(cells))
        parsed = parse_delimited(out, fmt)
        assert parsed[0] == ["host", "user"]
        assert [tuple(r) for r in parsed[1:]] == list(cells)


def test_json_schema_is_stable():
    rs = run_query(_snap(), Query(columns=("host", "gpus"),
                                  sort=("host",), limit=1))
    obj = json.loads(get_renderer("json").render(rs))
    assert obj["v"] == 1 and obj["kind"] == "query_result"
    qr = obj["query_result"]
    assert qr["table"] == "nodes" and qr["cluster"] == "test"
    assert qr["columns"] == ["host", "gpus"]
    assert qr["rows"] == [["a-1", 2]]


def test_prom_renderer_escapes_labels():
    rs = _hostile_resultset([('h"x\n', "u")])
    rs.rows[0]["cpu_load"] = 1.5
    rs.columns = ["host", "cpu_load"]
    out = get_renderer("prom").render(rs)
    assert r'host="h\"x\n"' in out
    assert "llload_query_nodes_cpu_load" in out


def test_prom_rejects_duplicate_label_sets():
    # two samples with identical labels are invalid exposition format
    rs = _hostile_resultset([("h", "alice"), ("h2", "alice")])
    rs.columns = ["user", "cpu_load"]
    for r, load in zip(rs.rows, (1.0, 2.0)):
        r["cpu_load"] = load
    with pytest.raises(QueryError) as ei:
        get_renderer("prom").render(rs)
    assert "uniquely" in str(ei.value)
    rs.columns = ["host", "user", "cpu_load"]     # host disambiguates
    assert get_renderer("prom").render(rs).count("cpu_load{") == 2


def test_every_renderer_ends_with_newline():
    rs = run_query(_snap(), Query(limit=1))
    for fmt in ("table", "json", "csv", "tsv", "prom"):
        assert get_renderer(fmt).render(rs).endswith("\n"), fmt


def test_unknown_renderer_lists_names():
    with pytest.raises(QueryError) as ei:
        get_renderer("xml")
    assert "json" in str(ei.value) and "csv" in str(ei.value)

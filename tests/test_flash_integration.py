"""Model-level flash-kernel integration: routing global causal attention
through the Pallas kernel (interpret mode on CPU) must reproduce the
chunked-attention path exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import init_params
from repro.models.perf_flags import PerfFlags, perf_flags
from repro.models.transformer import forward_hidden

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["llsc-100m", "phi3-medium-14b"])
def test_flash_flag_matches_chunked(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                                cfg.vocab_size)  # S % block == 0
    base, _ = forward_hidden(params, cfg, tokens)
    with perf_flags(PerfFlags(flash_kernel=True)):
        flash, _ = forward_hidden(params, cfg, tokens)
    err = float(jnp.max(jnp.abs(base - flash)))
    assert err < 5e-5, err


def test_flash_flag_skips_local_and_softcap():
    """gemma3 has sliding-window layers; the flag must leave them on the
    (banded/masked) chunked path and still produce correct output."""
    cfg = reduced_config("gemma3-1b")
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 128), 0,
                                cfg.vocab_size)
    base, _ = forward_hidden(params, cfg, tokens)
    with perf_flags(PerfFlags(flash_kernel=True)):
        flash, _ = forward_hidden(params, cfg, tokens)
    err = float(jnp.max(jnp.abs(base - flash)))
    assert err < 5e-5, err


def test_flash_flag_gradients():
    cfg = reduced_config("llsc-100m")
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 128), 0,
                                cfg.vocab_size)

    def loss(p, flag):
        ctx = perf_flags(PerfFlags(flash_kernel=flag))
        with ctx:
            h, _ = forward_hidden(p, cfg, tokens)
        return jnp.sum(h.astype(jnp.float32) ** 2)

    g_base = jax.grad(lambda p: loss(p, False))(params)
    g_flash = jax.grad(lambda p: loss(p, True))(params)
    errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                        g_base, g_flash)
    assert max(jax.tree.leaves(errs)) < 5e-3

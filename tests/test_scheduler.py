"""Whole-node scheduling policy invariants (paper §III)."""
import random

from hypothesis import given, settings, strategies as st

from repro.cluster.job import JobSpec, TaskProfile
from repro.cluster.node import make_nodes
from repro.cluster.scheduler import Scheduler
from repro.cluster.workloads import make_llsc_sim, jupyter_job


def _sched(n=8, gpus=0):
    return Scheduler(make_nodes("d", n, cores=48, gpus=gpus,
                                gpu_mem_gb=32.0 if gpus else 0.0))


def _job(user, tasks=1, cores=8, gpus=0, tpg=1, mem=4.0, excl=False):
    return JobSpec(user, "j", n_tasks=tasks, cores_per_task=cores,
                   gpus_per_task=gpus, tasks_per_gpu=tpg, exclusive=excl,
                   profile=TaskProfile(mem_gb=mem))


def test_whole_node_isolation():
    s = _sched(2)
    s.submit(_job("alice", tasks=1, cores=8), 0.0)
    s.submit(_job("bob", tasks=1, cores=8), 0.0)
    s.tick(1.0)
    assert s.check_whole_node_invariant() == []
    nodes_a = {h for j in s.running if j.spec.username == "alice"
               for h in j.hostnames}
    nodes_b = {h for j in s.running if j.spec.username == "bob"
               for h in j.hostnames}
    assert nodes_a.isdisjoint(nodes_b)


def test_same_user_packs_same_node():
    s = _sched(4)
    s.submit(_job("alice", tasks=1, cores=8), 0.0)
    s.tick(1.0)
    s.submit(_job("alice", tasks=1, cores=8), 1.0)
    s.tick(2.0)
    hosts = {h for j in s.running for h in j.hostnames}
    assert len(hosts) == 1, "second job of same user should co-locate"


def test_pending_when_no_capacity():
    s = _sched(1)
    s.submit(_job("a", tasks=1, cores=48), 0.0)
    s.submit(_job("b", tasks=1, cores=1), 0.0)
    s.tick(1.0)
    assert len(s.running) == 1 and len(s.pending) == 1
    # completion frees the node
    s.tick(1e9)
    assert any(j.spec.username == "b" for j in s.running)


def test_exclusive_job():
    s = _sched(2)
    s.submit(_job("a", tasks=1, cores=1, excl=True), 0.0)
    s.submit(_job("a", tasks=1, cores=1), 0.0)
    s.tick(1.0)
    excl_host = next(j for j in s.running if j.spec.exclusive).hostnames[0]
    other_host = next(j for j in s.running
                      if not j.spec.exclusive).hostnames[0]
    assert excl_host != other_host


def test_gpu_overloading_slots():
    s = _sched(1, gpus=2)
    # NPPN=4: 8 tasks over 2 GPUs on one node
    s.submit(_job("a", tasks=8, cores=4, gpus=1, tpg=4), 0.0)
    s.tick(1.0)
    assert len(s.running) == 1
    ns = list(s.nodes.values())[0]
    occ = ns.gpu_occupancy()
    assert sum(occ.values()) == 8 and max(occ.values()) == 4


def test_shared_partition_allows_multiuser():
    sim = make_llsc_sim(n_cpu=4, n_gpu=2)
    # both need the single GPU jupyter host -> must co-reside (shared policy)
    sim.submit(jupyter_job("u1", gpu=True))
    sim.submit(jupyter_job("u2", gpu=True))
    sim.run_until(120.0)
    snap = sim.snapshot()
    hosts_u1 = set(snap.nodes_by_user().get("u1", []))
    hosts_u2 = set(snap.nodes_by_user().get("u2", []))
    assert hosts_u1 & hosts_u2, "jupyter partition should share nodes"
    assert sim.sched.check_whole_node_invariant() == []


@settings(max_examples=20)
@given(st.lists(st.tuples(
    st.sampled_from(["u1", "u2", "u3", "u4"]),
    st.integers(1, 4),     # tasks
    st.integers(1, 48),    # cores per task
    st.floats(1.0, 64.0),  # mem
), min_size=1, max_size=20))
def test_whole_node_invariant_random_streams(jobs):
    s = _sched(6)
    t = 0.0
    for (u, tasks, cores, mem) in jobs:
        s.submit(_job(u, tasks=tasks, cores=cores, mem=mem), t)
        t += 60.0
        s.tick(t)
        assert s.check_whole_node_invariant() == []
        # resource caps hold
        for ns in s.nodes.values():
            assert ns.cores_used <= ns.spec.cores
            assert ns.mem_used() <= ns.spec.mem_gb + 1e-6

"""MoE sort/scatter dispatch vs dense reference + capacity semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoESpec
from repro.models.moe import (capacity, init_moe, moe_ffn,
                              moe_ffn_dense_reference)

KEY = jax.random.PRNGKey(0)


def _setup(E=8, k=2, d=16, f=32, cf=8.0, norm=True):
    spec = MoESpec(n_experts=E, top_k=k, d_ff_expert=f, capacity_factor=cf,
                   norm_topk_prob=norm)
    params = init_moe(KEY, d, spec)
    return spec, params


@pytest.mark.parametrize("norm_topk", [True, False])
@pytest.mark.parametrize("B,S", [(2, 16), (4, 1), (1, 64)])
def test_matches_dense_reference_no_drops(B, S, norm_topk):
    spec, params = _setup(cf=8.0, norm=norm_topk)  # cf=E/k*2 -> no drops
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 16))
    out = moe_ffn(params, x, spec)
    ref = moe_ffn_dense_reference(params, x, spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_capacity_drops_reduce_output():
    """With tiny capacity some tokens are dropped (zero contribution)."""
    spec_hi, params = _setup(cf=8.0)
    spec_lo = MoESpec(n_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=0.1)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 16))
    out_hi = moe_ffn(params, x, spec_hi)
    out_lo = moe_ffn(params, x, spec_lo)
    # dropped tokens produce strictly smaller output energy
    assert float(jnp.sum(out_lo ** 2)) < float(jnp.sum(out_hi ** 2))


def test_capacity_formula():
    spec, _ = _setup(E=8, k=2, cf=1.25)
    assert capacity(64, spec) == int(np.ceil(64 * 2 * 1.25 / 8))
    assert capacity(1, spec) >= 1


def test_grouping_invariance_without_drops():
    """Group count must not change results when capacity is ample."""
    spec, params = _setup(cf=8.0)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 16))
    a = moe_ffn(params, x, spec, n_groups=1)
    b = moe_ffn(params, x, spec, n_groups=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-5)


def test_differentiable():
    spec, params = _setup(cf=8.0)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 16))

    def loss(p):
        return jnp.sum(moe_ffn(p, x, spec) ** 2)

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@settings(max_examples=15)
@given(st.integers(1, 6), st.integers(1, 32), st.integers(0, 2 ** 31 - 1))
def test_router_weights_sum_to_one(B, S, seed):
    spec, params = _setup(norm=True)
    x = jax.random.normal(jax.random.PRNGKey(seed % 1000), (B, S, 16))
    from repro.models.moe import _route
    logits = x.reshape(-1, 16).astype(jnp.float32) @ params["router"]
    w, idx = _route(logits, spec)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert int(jnp.max(idx)) < spec.n_experts
    # top-k indices are distinct per token
    assert all(len(set(row)) == spec.top_k for row in np.asarray(idx)[:16])

"""HistoryStore: multi-resolution downsampling invariants (property
test), tier-based weekly analysis vs. the archive pipeline, backfill."""
import math

import pytest
from hypothesis import given, strategies as st

from repro.core.analysis import weekly_analysis
from repro.core.archive import SnapshotArchive
from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot
from repro.daemon.store import HistoryStore, TierSpec


def _snap(ts, load_a=10.0, load_b=40.0, gpu=0.5, cluster="tx"):
    nodes = {
        "a": NodeSnapshot("a", cores_total=48, cores_used=48, load=load_a,
                          mem_total_gb=192.0, mem_used_gb=50.0),
        "b": NodeSnapshot("b", cores_total=48, cores_used=48, load=load_b,
                          mem_total_gb=192.0, mem_used_gb=60.0,
                          gpus_total=2, gpus_used=2, gpu_load=gpu,
                          gpu_mem_total_gb=64.0, gpu_mem_used_gb=8.0),
    }
    jobs = [JobRecord(1, "ua", "ja", ["a"], cores_per_node=48),
            JobRecord(2, "ub", "jb", ["b"], cores_per_node=48,
                      gpus_per_node=2)]
    return ClusterSnapshot(cluster, ts, nodes, jobs)


# ------------------------------------------------------------- properties


@given(st.lists(st.tuples(st.floats(0.0, 100.0), st.floats(0.0, 1.5)),
                min_size=1, max_size=60))
def test_downsampling_invariants(samples):
    """For monotonically spaced snapshots folded into any tier:
    counts conserve appends, min <= mean <= max, bucket starts are
    aligned, and the per-bucket mean matches a direct recomputation."""
    store = HistoryStore(raw_capacity=1024,
                         tiers=[TierSpec("t60", 60.0, capacity=1024),
                                TierSpec("t300", 300.0, capacity=1024)])
    per_bucket = {}
    for i, (load, gpu) in enumerate(samples):
        ts = 17.0 + 13.0 * i                    # deliberately unaligned
        snap = _snap(ts, load_a=load, load_b=load, gpu=gpu)
        store.append(snap)
        norm = load / 48.0
        per_bucket.setdefault(math.floor(ts / 60.0) * 60.0,
                              []).append(norm)

    for tier, bucket_s in (("t60", 60.0), ("t300", 300.0)):
        pts = store.points(tier)
        assert sum(p.count for p in pts) == len(samples)
        assert [p.bucket_start for p in pts] == \
            sorted({math.floor((17.0 + 13.0 * i) / bucket_s) * bucket_s
                    for i in range(len(samples))})
        for p in pts:
            assert p.norm_load.min <= p.norm_load.mean <= p.norm_load.max \
                or math.isclose(p.norm_load.min, p.norm_load.max)
            assert p.bucket_start % bucket_s == 0
            assert p.gpu_load.min >= 0.0

    for p in store.points("t60"):
        vals = per_bucket[p.bucket_start]
        assert p.count == len(vals)
        assert math.isclose(p.norm_load.mean, sum(vals) / len(vals),
                            rel_tol=1e-9, abs_tol=1e-12)
        assert math.isclose(p.norm_load.min, min(vals), rel_tol=1e-9)
        assert math.isclose(p.norm_load.max, max(vals), rel_tol=1e-9)


# ------------------------------------------------------- weekly from tiers


def test_weekly_from_tiers_matches_archive_pipeline(tmp_path):
    """Cadence-aligned snapshots: the store's tier-based weekly report
    reproduces weekly_analysis over the replayed TSV archive."""
    archive = SnapshotArchive(str(tmp_path), cluster="tx")
    store = HistoryStore()
    for i in range(4 * 24 * 2):                 # two days, 15-min cadence
        gpu = 0.2 if i % 3 else 0.9             # ub dips below 0.45 often
        load = 10.0 if i % 2 else 90.0          # ua alternates low/high
        snap = _snap(900.0 * i, load_a=load, load_b=load, gpu=gpu)
        archive.append(snap)
        store.append(snap)

    ref = weekly_analysis(archive.rows())
    got = store.weekly_report()
    for cat in ("low_gpu", "low_cpu", "high_cpu"):
        ref_rows = [(r.username, r.node_hours) for r in getattr(ref, cat)]
        got_rows = [(r.username, r.node_hours) for r in getattr(got, cat)]
        assert got_rows == ref_rows, cat


def test_backfill_from_archive(tmp_path):
    archive = SnapshotArchive(str(tmp_path), cluster="tx")
    for i in range(10):
        archive.append(_snap(900.0 * i))
    store = HistoryStore()
    assert store.backfill(archive) == 10
    assert store.sizes()["raw"] == 10
    assert sum(p.count for p in store.points("15min")) == 10


# ------------------------------------------------------------ tier queries


def test_raw_ring_ages_out_but_tiers_remember():
    store = HistoryStore(raw_capacity=4,
                         tiers=[TierSpec("15min", 900.0, capacity=1000)])
    for i in range(50):
        store.append(_snap(900.0 * i))
    assert store.sizes()["raw"] == 4
    assert sum(p.count for p in store.points("15min")) == 50


def test_select_tier_prefers_finest_covering_window():
    store = HistoryStore(raw_capacity=4,
                         tiers=[TierSpec("15min", 900.0, capacity=1000),
                                TierSpec("hourly", 3600.0, capacity=1000)])
    for i in range(100):
        store.append(_snap(900.0 * i))
    assert store.select_tier(900.0) == "raw"        # 4 raw snaps span 45min
    assert store.select_tier(7200.0) == "15min"
    assert store.select_tier(100 * 900.0 * 2) == "hourly"


def test_points_window_and_unknown_tier():
    store = HistoryStore()
    for i in range(20):
        store.append(_snap(900.0 * i))
    recent = store.points("15min", window_s=3 * 900.0)
    assert 3 <= len(recent) <= 4
    with pytest.raises(KeyError):
        store.points("nope")


def test_trend_wire_shapes():
    store = HistoryStore()
    for i in range(8):
        store.append(_snap(900.0 * i, load_a=float(i)))
    for tier in ("raw", "15min"):
        wire = store.trend_wire(tier)
        assert wire["tier"] == tier
        assert len(wire["points"]) == 8
        p = wire["points"][0]
        assert p["norm_load"]["min"] <= p["norm_load"]["max"]


def test_out_of_order_snapshots_drop_instead_of_corrupting():
    """A snapshot older than the bucket being filled (mixed clocks, e.g.
    epoch-stamped backfill then a sim-clock source) must not fold into
    the open later bucket — it is dropped from tiers and counted."""
    store = HistoryStore(tiers=[TierSpec("15min", 900.0, capacity=100)])
    store.append(_snap(1.7e9, load_a=48.0))
    store.append(_snap(3600.0, load_a=480.0))       # older clock
    assert store.sizes()["out_of_order_dropped"] == 1
    assert store.sizes()["raw"] == 2                 # ring keeps both
    pts = store.points("15min")
    assert sum(p.count for p in pts) == 1
    assert pts[-1].norm_load.max <= 1.01             # 480-load never folded


def test_weekly_report_defaults_to_finest_custom_tier():
    store = HistoryStore(tiers=[TierSpec("5min", 300.0, capacity=100)])
    for i in range(6):
        store.append(_snap(300.0 * i, gpu=0.1))      # ub low-gpu
    rep = store.weekly_report()
    assert any(r.username == "ub" for r in rep.low_gpu)
    hours = [r.node_hours for r in rep.low_gpu if r.username == "ub"][0]
    assert hours == pytest.approx(6 * 300.0 / 3600.0)


def test_shared_node_attribution_matches_archive_rules():
    """Two users with running jobs on one node: to_tsv credits the first
    job's owner only, and so must the store's weekly flags (no
    double-counted node-hours on shared nodes)."""
    node = NodeSnapshot("n0", cores_total=48, cores_used=48, load=1.0,
                        mem_total_gb=192.0, mem_used_gb=10.0)
    jobs = [JobRecord(1, "alice", "j1", ["n0"], cores_per_node=24),
            JobRecord(2, "bob", "j2", ["n0"], cores_per_node=24)]
    snap = ClusterSnapshot("tx", 900.0, {"n0": node}, jobs)

    from repro.core.metrics import rows_from_tsv

    store = HistoryStore()
    store.append(snap)
    rep = store.weekly_report()
    ref = weekly_analysis(rows_from_tsv(snap.to_tsv()))
    assert [(r.username, r.node_hours) for r in rep.low_cpu] == \
        [(r.username, r.node_hours) for r in ref.low_cpu]
    assert [r.username for r in rep.low_cpu] == ["alice"]

"""Checkpoint/restart, retention, crash injection, straggler detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.fault import CrashInjector, StragglerDetector, resume_latest
from repro.train import checkpoint as ck
from repro.train.trainer import Trainer, TrainerConfig


def test_roundtrip(tmp_path):
    state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
             "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ck.save_checkpoint(str(tmp_path), 5, state)
    template = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, meta = ck.restore_checkpoint(str(tmp_path), 5, template)
    assert meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_retention(tmp_path):
    state = {"a": jnp.zeros((2,))}
    for step in range(6):
        ck.save_checkpoint(str(tmp_path), step, state, keep=3)
    assert ck.list_checkpoints(str(tmp_path)) == [3, 4, 5]


def test_latest_ignores_torn_tmp(tmp_path):
    state = {"a": jnp.zeros((2,))}
    ck.save_checkpoint(str(tmp_path), 1, state)
    os.makedirs(tmp_path / ".tmp-step-2")  # simulated torn write
    assert ck.latest_step(str(tmp_path)) == 1


def test_shape_mismatch_rejected(tmp_path):
    ck.save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        ck.restore_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((3,))})


def test_crash_restart_resumes_and_matches(tmp_path):
    """Deterministic data + restart => same final loss as uninterrupted."""
    cfg = reduced_config("llsc-100m")
    tc = dict(steps=8, batch_size=2, seq_len=32, ckpt_every=2, log_every=0,
              monitor_every=0)

    # uninterrupted run
    t_ref = Trainer(cfg, TrainerConfig(**tc))
    ref = t_ref.run(resume=False)

    # crash at step 5, then restart from checkpoint (step 4)
    ckpt_dir = str(tmp_path / "ck")
    t1 = Trainer(cfg, TrainerConfig(ckpt_dir=ckpt_dir, **tc),
                 crash=CrashInjector(5))
    with pytest.raises(RuntimeError, match="injected node failure"):
        t1.run(resume=False)
    assert ck.latest_step(ckpt_dir) == 4

    t2 = Trainer(cfg, TrainerConfig(ckpt_dir=ckpt_dir, **tc))
    out = t2.run(resume=True)
    assert out["start_step"] == 4
    assert out["final_loss"] == pytest.approx(ref["final_loss"], rel=1e-4)


def test_straggler_detection():
    det = StragglerDetector(slow_factor=1.5)
    for step in range(10):
        for host in ("host-0", "host-1", "host-2", "host-3"):
            det.record(host, 1.0)
        det.record("host-slow", 2.5)
    reports = det.stragglers()
    assert [r.host for r in reports] == ["host-slow"]
    assert reports[0].factor == pytest.approx(2.5, rel=0.05)


def test_no_false_stragglers():
    det = StragglerDetector(slow_factor=1.5)
    for step in range(10):
        for i in range(4):
            det.record(f"h{i}", 1.0 + 0.05 * i)
    assert det.stragglers() == []


def test_resume_latest_empty(tmp_path):
    state, step = resume_latest(str(tmp_path / "none"), {"a": jnp.zeros(2)})
    assert state is None and step == 0

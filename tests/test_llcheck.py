"""llcheck: the AST invariant checker (DESIGN.md §13).

Each checker is proven twice: it *fires* on a known-bad fixture at the
exact codes/lines, and it is *silent* on the known-good twin.  A final
repo-wide run pins the tree clean (zero unbaselined findings) and under
the 2-second budget that keeps it a pre-commit-grade gate.
"""
import json
import os
import sys
import time

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_TOOLS = os.path.join(REPO_ROOT, "tools")
if _TOOLS not in sys.path:
    sys.path.insert(0, _TOOLS)

import llcheck                                             # noqa: E402
from llcheck import cli, core, wire_schema                 # noqa: E402

FIXTURES = os.path.join(REPO_ROOT, "tests", "llcheck_fixtures")


def run_on(*names):
    paths = [os.path.join(FIXTURES, n) for n in names]
    findings, _ = llcheck.run(paths, FIXTURES)
    return findings


def keys(findings):
    return [(f.code, f.line) for f in findings]


# ------------------------------------------------------------ LL001 corpus


def test_ll001_good_is_silent():
    assert run_on("ll001_good.py") == []


def test_ll001_bad_exact_codes_and_lines():
    findings = run_on("ll001_bad.py")
    assert keys(findings) == [
        ("LL001", 9),    # self.pending: mutable container, unclassified
        ("LL001", 12),   # write path touches _items outside the lock
        ("LL001", 17),   # .clear() after the with-block ended
        ("LL001", 23),   # nested def does not inherit the held lock
    ]
    assert all(f.path == "ll001_bad.py" for f in findings)
    assert "not classified" in findings[0].message
    assert "outside 'with self._lock:'" in findings[1].message


# ------------------------------------------------------------ LL003 corpus


def test_ll003_good_is_silent():
    """Names built from the prefix default + a module-level literal table
    resolve statically; vocabulary keys and plain values pass."""
    assert run_on("ll003_good_promtext.py") == []


def test_ll003_bad_exact_codes_and_lines():
    findings = run_on("ll003_bad_promtext.py")
    assert keys(findings) == [
        ("LL003", 18),   # metric name from an unresolvable parameter
        ("LL003", 19),   # resolves, but outside the llload_* family
        ("LL003", 20),   # label key off the fixed vocabulary
        ("LL003", 21),   # f-string label value (unbounded cardinality)
        ("LL003", 22),   # labels not a literal (key, value) list
        ("LL003", 23),   # raw …="{value}" injection skeleton
    ]


def test_ll003_scope_is_basename_matched():
    """The same bad code outside a promtext.py/server.py basename is out
    of scope — LL003 polices the emitters, not arbitrary code."""
    bad = open(os.path.join(FIXTURES, "ll003_bad_promtext.py"),
               encoding="utf-8").read()
    mod = core.SourceModule(os.path.join(FIXTURES, "other.py"),
                            FIXTURES, text=bad)
    ctx = core.Context(repo_root=FIXTURES, modules=[mod])
    from llcheck import prom_labels
    assert list(prom_labels.check(ctx)) == []


# ------------------------------------------------------------ LL004 corpus


def test_ll004_good_is_silent():
    """Pipe→0, env→1 pass; a helper's sentinel return (124) is not an
    exit code and must not be flagged."""
    assert run_on("ll004_good.py") == []


def test_ll004_bad_exact_codes_and_lines():
    findings = run_on("ll004_bad.py")
    assert keys(findings) == [
        ("LL004", 10),   # BrokenPipeError path exits nonzero
        ("LL004", 13),   # env-error handler swallows the failure (0)
        ("LL004", 14),   # 64 is outside the 0/1/2 convention
        ("LL004", 18),   # sys.exit(7) anywhere in the module
    ]


# -------------------------------------------------- annotation grammars


def _mod(text, name="frag.py"):
    return core.SourceModule(os.path.join(FIXTURES, name), FIXTURES,
                             text=text)


def test_guard_grammar_trailing_and_own_line():
    mod = _mod("x = 1  # guarded-by: _lock\n"
               "# guarded-by: _mu\n"
               "y = 2\n")
    assert mod.guards == {1: "_lock", 3: "_mu"}


def test_ignore_requires_reason_to_suppress():
    mod = _mod("a = 1  # llcheck: ignore[LL001] config, set once\n"
               "b = 2  # llcheck: ignore[LL001]\n"
               "c = 3  # llcheck: ignore[]\n")
    assert mod.ignored(1, "LL001")
    assert not mod.ignored(1, "LL002")     # only the named codes
    assert not mod.ignored(2, "LL001")     # reasonless does not suppress
    lls = core.suppression_findings([mod])
    assert [(f.code, f.line) for f in lls] == [("LL000", 2), ("LL000", 3)]


def test_reasonless_ignore_leaves_underlying_finding():
    text = open(os.path.join(FIXTURES, "ll001_bad.py"),
                encoding="utf-8").read()
    # slap a reasonless ignore on the unlocked access: both the LL000
    # (bad suppression) and the LL001 (still unsuppressed) must fire
    text = text.replace("self._items.append(x)",
                        "self._items.append(x)  # llcheck: ignore[LL001]")
    mod = _mod(text, name="ll001_bad_variant.py")
    ctx = core.Context(repo_root=FIXTURES, modules=[mod])
    from llcheck import lock_discipline
    codes = {f.code for f in core.suppression_findings([mod])}
    codes |= {f.code for f in lock_discipline.check(ctx)
              if f.line == 12}
    assert codes == {"LL000", "LL001"}


# ----------------------------------------------------------------- LL002


_PROTOCOL = """\
WIRE_VERSION = 1
_NODE_FIELDS = ["hostname", "load"]
_JOB_FIELDS = ["job_id", "username"]
"""

_METRICS = """\
import dataclasses


@dataclasses.dataclass
class JobRecord:
    job_id: str
    username: str = ""
    nodes: int = 1
    state: str = "R"
"""


def _schema(protocol=_PROTOCOL, metrics=_METRICS):
    p = core.SourceModule(os.path.join(FIXTURES, "daemon/protocol.py"),
                          FIXTURES, text=protocol)
    m = core.SourceModule(os.path.join(FIXTURES, "core/metrics.py"),
                          FIXTURES, text=metrics)
    return wire_schema.extract_schema(p, m)


def test_ll002_extract_schema():
    schema = _schema()
    assert schema["wire_version"] == 1
    assert schema["node_fields"] == ["hostname", "load"]
    assert schema["job_fields"] == ["job_id", "username"]
    assert schema["job_record"]["username"] == {"type": "str",
                                               "default": "''"}


def test_ll002_clean_round_trip():
    schema = _schema()
    lock = wire_schema.build_lock(schema)
    assert wire_schema.diff_schema(schema, lock, "p.py", "lock.json") == []


def test_ll002_v1_removal_is_always_an_error():
    lock = wire_schema.build_lock(_schema())
    removed = _schema(protocol=_PROTOCOL.replace(', "username"', ""))
    msgs = [f.message for f in
            wire_schema.diff_schema(removed, lock, "p.py", "lock.json")]
    assert any("'username'" in m and "never be dropped" in m for m in msgs)


def test_ll002_regenerating_cannot_launder_a_v1_removal():
    """frozen_v1 is copied verbatim: even a freshly regenerated lock
    still flags the removal of a field that shipped in v1."""
    lock = wire_schema.build_lock(_schema())
    removed = _schema(protocol=_PROTOCOL.replace(', "username"', ""))
    regenerated = wire_schema.build_lock(removed, previous=lock)
    assert regenerated["frozen_v1"] == lock["frozen_v1"]
    msgs = [f.message for f in wire_schema.diff_schema(
        removed, regenerated, "p.py", "lock.json")]
    assert any("never be dropped" in m for m in msgs)


def test_ll002_addition_requires_lock_regen():
    lock = wire_schema.build_lock(_schema())
    grown = _schema(protocol=_PROTOCOL.replace(
        '"username"]', '"username", "state"]'))
    msgs = [f.message for f in
            wire_schema.diff_schema(grown, lock, "p.py", "lock.json")]
    assert any("'state'" in m and "--update-schema-lock" in m for m in msgs)
    # ...and regenerating resolves it (additive change, deliberate act)
    regenerated = wire_schema.build_lock(grown, previous=lock)
    assert wire_schema.diff_schema(grown, regenerated,
                                   "p.py", "lock.json") == []


def test_ll002_v1_retype_is_always_an_error():
    lock = wire_schema.build_lock(_schema())
    retyped = _schema(metrics=_METRICS.replace("nodes: int = 1",
                                               "nodes: float = 1"))
    msgs = [f.message for f in
            wire_schema.diff_schema(retyped, lock, "p.py", "lock.json")]
    assert any("JobRecord.nodes" in m for m in msgs)


def test_ll002_version_downgrade():
    lock = wire_schema.build_lock(_schema())
    old = _schema(protocol=_PROTOCOL.replace("WIRE_VERSION = 1",
                                             "WIRE_VERSION = 0"))
    msgs = [f.message for f in
            wire_schema.diff_schema(old, lock, "p.py", "lock.json")]
    assert any("backwards" in m for m in msgs)


def test_ll002_job_fields_must_exist_on_job_record():
    schema = _schema(protocol=_PROTOCOL.replace(
        '"username"]', '"username", "ghost"]'))
    lock = wire_schema.build_lock(schema)
    msgs = [f.message for f in
            wire_schema.diff_schema(schema, lock, "p.py", "lock.json")]
    assert any("ghost" in m and "AttributeError" in m for m in msgs)


def test_deleting_a_job_record_wire_field_fails_ci(tmp_path):
    """The acceptance drill: drop 'gpu_duty' from the real protocol's
    _JOB_FIELDS and the real checked-in schema lock must flag it."""
    for rel in ("daemon/protocol.py", "core/metrics.py"):
        src = os.path.join(REPO_ROOT, "src", "repro", rel)
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        text = open(src, encoding="utf-8").read()
        if rel.endswith("protocol.py"):
            assert '"gpu_duty", ' in text
            text = text.replace('"gpu_duty", ', "")
        dst.write_text(text, encoding="utf-8")
    findings, _ = llcheck.run([str(tmp_path)], str(tmp_path),
                              schema_lock_path=cli.DEFAULT_LOCK)
    ll002 = [f for f in findings if f.code == "LL002"]
    assert any("gpu_duty" in f.message and "never be dropped" in f.message
               for f in ll002)


def test_checked_in_lock_matches_the_code():
    """CI's regen check, as a unit test: regenerating the lock from the
    current tree must be a byte-identical no-op."""
    assert cli._check_lock_regen(cli.DEFAULT_LOCK)


# --------------------------------------------------------------- full tree


def test_repo_is_clean_and_fast():
    """Zero unbaselined findings over src/ + tools/, in under 2 seconds
    (the pre-commit budget from DESIGN.md §13)."""
    started = time.monotonic()
    findings, n_modules = llcheck.run(
        [os.path.join(REPO_ROOT, "src"), os.path.join(REPO_ROOT, "tools")],
        REPO_ROOT, schema_lock_path=cli.DEFAULT_LOCK)
    elapsed = time.monotonic() - started
    baseline = core.load_baseline(cli.DEFAULT_BASELINE)
    fresh, _ = core.apply_baseline(findings, baseline)
    assert fresh == [], "\n" + core.render_findings_table(fresh)
    assert n_modules > 50          # it really scanned the tree
    assert elapsed < 2.0, f"llcheck took {elapsed:.2f}s (budget: 2s)"


# --------------------------------------------------------------------- CLI


def test_cli_exit_codes(capsys):
    assert cli.main([os.path.join(FIXTURES, "ll001_good.py")]) == 0
    assert cli.main([os.path.join(FIXTURES, "ll001_bad.py")]) == 1
    assert cli.main([os.path.join(FIXTURES, "nope.py")]) == 1
    capsys.readouterr()


def test_cli_table_output(capsys):
    rc = cli.main([os.path.join(FIXTURES, "ll004_bad.py")])
    out = capsys.readouterr().out
    assert rc == 1
    assert out.splitlines()[0].split() == ["code", "location", "message"]
    assert "(4 findings)" in out
    assert "llcheck: 4 findings" in out


def test_cli_json_output(capsys):
    rc = cli.main(["--format", "json",
                   os.path.join(FIXTURES, "ll004_bad.py")])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert [f["line"] for f in payload["findings"]] == [10, 13, 14, 18]
    assert all(f["code"] == "LL004" for f in payload["findings"])
    assert payload["modules"] == 1


def test_cli_baseline_suppresses(tmp_path, capsys):
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps(
        [{"code": "LL004", "path": "tests/llcheck_fixtures/ll004_bad.py"}]))
    rc = cli.main(["--baseline", str(baseline),
                   os.path.join(FIXTURES, "ll004_bad.py")])
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 findings (4 baselined)" in out


def test_cli_update_schema_lock_round_trip(tmp_path, capsys):
    lock = tmp_path / "schema_lock.json"
    assert cli.main(["--update-schema-lock",
                     "--schema-lock", str(lock)]) == 0
    out = capsys.readouterr().out
    assert "wire version 1" in out
    fresh = json.loads(lock.read_text())
    checked_in = json.loads(open(cli.DEFAULT_LOCK).read())
    assert fresh == checked_in

"""LLload query engine + formatting (paper Figs 2-5, 10, 11)."""
import random

import pytest

from repro.cluster.workloads import (make_llsc_sim, paper_scenario,
                                     low_gpu_job, io_storm_job)
from repro.core import formatting
from repro.core.llload import LLload
from repro.core.metrics import rows_from_tsv


@pytest.fixture(scope="module")
def snap():
    sim = make_llsc_sim()
    paper_scenario(sim, random.Random(0))
    sim.run_until(3600.0)
    return sim.snapshot()


def test_user_view_lists_only_that_users_nodes(snap):
    ll = LLload(snap)
    blk = ll.user_view("va67890")
    assert blk.nodes, "user should hold nodes"
    owners = snap.nodes_by_user()
    for n in blk.nodes:
        assert n.hostname in owners["va67890"]


def test_default_output_format_fig2(snap):
    ll = LLload(snap)
    out = formatting.format_user_view(snap.cluster, ll.user_view("va67890"))
    assert out.startswith("Cluster name: txgreen")
    assert "Username: va67890" in out
    assert "HOSTNAME" in out and "LOAD" in out and "MEMORY" in out
    # no GPU columns without -g
    assert "GPUMEM" not in out


def test_gpu_option_adds_gpu_columns_fig3(snap):
    ll = LLload(snap)
    out = formatting.format_user_view(snap.cluster, ll.user_view("va67890"),
                                      gpu=True)
    assert "GPUS" in out and "GPUMEM" in out


def test_all_view_requires_privilege(snap):
    ll = LLload(snap, privileged_users={"admin"})
    view = ll.all_view("va67890")  # not privileged: scoped to self
    assert len(view.users) == 1
    assert view.users[0].username == "va67890"
    assert view.jupyter == []

    full = ll.all_view("admin")
    assert len(full.users) > 1
    assert full.jupyter, "jupyter summary expected (Fig 4)"
    assert all("@" in b.email for b in full.users)


def test_all_view_gpu_request_tags(snap):
    ll = LLload(snap, privileged_users={"admin"})
    view = ll.all_view("admin")
    tags = [u for e in view.jupyter for u in e.users]
    assert any("gres:gpu" in t for t in tags), "Fig 4 GPU gres tag"


def test_top_loaded_sorted_and_normalized(snap):
    ll = LLload(snap)
    rows = ll.top_loaded(5)
    assert len(rows) == 5
    loads = [r.avg_load for r in rows]
    assert loads == sorted(loads, reverse=True)
    # io storm nodes dominate, normalized load >> 1 (Fig 10)
    assert loads[0] > 5.0
    out = formatting.format_top(rows, 5)
    assert "AVG_LOAD" in out and "CPUS(A/I/O/T)" in out


def test_node_detail_shows_jobs_fig11(snap):
    ll = LLload(snap)
    top = ll.top_loaded(2)
    details = ll.node_detail([t.hostname for t in top])
    assert details
    out = formatting.format_node_detail(details)
    assert "JOBID" in out and "START_TIME" in out
    assert any(d.jobs for d in details)


def test_tsv_roundtrip(snap):
    text = snap.to_tsv()
    rows = rows_from_tsv(text)
    assert rows
    hosts_with_jobs = {h for j in snap.jobs for h in j.nodes}
    assert {r["hostname"] for r in rows} == hosts_with_jobs
    for r in rows:
        n = snap.nodes[r["hostname"]]
        assert r["cores_total"] == n.cores_total
        assert abs(r["load"] - n.load) < 1e-3

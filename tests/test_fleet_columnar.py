"""Columnar FleetState engine vs the object-path oracle (DESIGN.md §10).

Three layers of equivalence evidence:

* golden replay — the two ``tests/golden/sim_snapshots*.tsv`` fixtures
  were captured from the pre-columnar implementation; the columnar
  engine must reproduce them byte-for-byte;
* property tests — random fleets / submission sequences / cancels run
  through both the columnar :class:`ClusterSim` and the preserved
  :class:`ObjectClusterSim`, comparing snapshots, TSV bytes, job queues
  and the whole-node invariant after every operation;
* the multi-GPU *distinct devices* regression (the old fit counted free
  slots, so one GPU with 2 free slots could satisfy a 2-GPU task).
"""
import dataclasses
import json
import random

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.cluster.baseline import (NodeState, ObjectClusterSim,
                                    ObjectScheduler, gpu_fit_distinct)
from repro.cluster.fleet import FleetState, gpu_task_capacity
from repro.cluster.job import JobSpec, RunningTask, TaskProfile
from repro.cluster.node import NodeSpec, make_nodes
from repro.cluster.scheduler import Scheduler
from repro.cluster.workloads import (jupyter_job, low_gpu_job,
                                     make_llsc_sim, ml_training_job,
                                     overloaded_gpu_job, paper_scenario)
from repro.core.metrics import ColumnarNodeMap


# ------------------------------------------------------------- golden replay

def _read_golden(name):
    with open(f"tests/golden/{name}") as f:
        return f.read()


def test_golden_paper_scenario_byte_identical():
    out = []
    sim = make_llsc_sim(n_cpu=12, n_gpu=6)
    paper_scenario(sim, random.Random(0))
    for t in (900.0, 1800.0, 86400.0 + 900.0):
        sim.run_until(t)
        out.append(f"# t={t}\n" + sim.snapshot().to_tsv())
    assert "".join(out) == _read_golden("sim_snapshots.tsv")


def test_golden_churn_byte_identical():
    """Overloading + cancel + resubmission + completions, pinned to the
    pre-columnar engine's exact output."""
    sim = make_llsc_sim(n_cpu=6, n_gpu=6)
    ids = [
        sim.submit(dataclasses.replace(
            overloaded_gpu_job("ov1", tasks=12, tasks_per_gpu=4),
            duration_s=3000.0)),
        sim.submit(dataclasses.replace(
            low_gpu_job("lg2", tasks=4), duration_s=5000.0)),
        sim.submit(dataclasses.replace(
            ml_training_job("ml3", tasks=4), duration_s=9000.0)),
        sim.submit(jupyter_job("ju4", gpu=True)),
        sim.submit(jupyter_job("ju5", gpu=True)),
    ]
    out = []
    for t in (600.0, 1200.0):
        sim.run_until(t)
        out.append(f"# t={t}\n" + sim.snapshot().to_tsv())
    sim.sched.cancel(ids[0])
    sim.submit(dataclasses.replace(
        overloaded_gpu_job("ov1", tasks=8, tasks_per_gpu=2),
        duration_s=3000.0))
    for t in (1800.0, 3600.0, 6000.0, 9600.0):
        sim.run_until(t)
        out.append(f"# t={t}\n" + sim.snapshot().to_tsv())
    assert "".join(out) == _read_golden("sim_snapshots_churn.tsv")


# -------------------------------------------------------- paired-sim helpers

def _fleet(n_cpu, n_gpu, gpus=2):
    cpu = make_nodes("d", n_cpu, cores=24, mem_gb=96.0)
    gpu = make_nodes("c", n_gpu, cores=16, mem_gb=64.0, gpus=gpus,
                     gpu_mem_gb=16.0)
    nodes = cpu + gpu
    hosts = [n.hostname for n in nodes]
    shared = hosts[n_cpu:n_cpu + 1]            # first GPU node is shared
    partitions = {
        "normal": {"hosts": [h for h in hosts if h not in shared],
                   "policy": "whole-node"},
        "shared": {"hosts": shared, "policy": "shared"},
    }
    return nodes, partitions


def _assert_equiv(col, obj):
    """Columnar and object sims agree on every externally visible fact."""
    a, b = col.snapshot(), obj.snapshot()
    assert a.timestamp == b.timestamp
    assert a.to_tsv() == b.to_tsv()
    assert list(a.nodes) == list(b.nodes)
    for host in b.nodes:
        assert a.nodes[host] == b.nodes[host], host
    assert a.jobs == b.jobs
    for attr in ("pending", "running", "completed"):
        aj = [(j.job_id, j.state, j.start_time, j.end_time,
               list(j.hostnames)) for j in getattr(col.sched, attr)]
        bj = [(j.job_id, j.state, j.start_time, j.end_time,
               list(j.hostnames)) for j in getattr(obj.sched, attr)]
        assert aj == bj, attr
    assert (col.sched.check_whole_node_invariant()
            == obj.sched.check_whole_node_invariant())
    # NodeState-shaped views match the real object state
    for host, ns in obj.sched.nodes.items():
        view = col.sched.nodes[host]
        assert view.cores_used == ns.cores_used
        assert view.mem_used() == ns.mem_used()
        assert view.users == ns.users
        assert view.user == ns.user
        assert view.exclusive_job == ns.exclusive_job
        assert view.gpu_occupancy() == ns.gpu_occupancy()
        av = [(t.job_id, t.username, t.cores, set(t.gpu_slots))
              for t in view.tasks]
        bv = [(t.job_id, t.username, t.cores, set(t.gpu_slots))
              for t in ns.tasks]
        assert av == bv, host


_MEMS = (0.0, 4.0, 25.5, 63.0)
_DURS = (120.0, 600.0, 3600.0)

_submit_op = st.tuples(
    st.just("submit"), st.integers(0, 3), st.integers(1, 6),
    st.integers(1, 20), st.integers(0, 2), st.integers(1, 3),
    st.integers(0, len(_MEMS) - 1), st.integers(0, len(_DURS) - 1),
    st.booleans(), st.sampled_from(["normal", "shared", "nosuch"]))
_step_op = st.tuples(st.just("step"), st.sampled_from([60.0, 300.0, 1200.0]))
_cancel_op = st.tuples(st.just("cancel"), st.integers(0, 30))


def _run_ops(n_cpu, n_gpu, gpus, ops):
    from repro.cluster.simulator import ClusterSim

    nodes, partitions = _fleet(n_cpu, n_gpu, gpus=gpus)
    col = ClusterSim(nodes, cluster="eq", partitions=partitions)
    obj = ObjectClusterSim(nodes, cluster="eq", partitions=partitions)
    submitted = []
    for op in ops:
        if op[0] == "submit":
            (_, u, tasks, cores, gpt, tpg, mi, di, excl, part) = op
            spec = JobSpec(
                f"u{u}", "j", n_tasks=tasks, cores_per_task=cores,
                gpus_per_task=gpt, tasks_per_gpu=tpg, exclusive=excl,
                duration_s=_DURS[di], partition=part,
                profile=TaskProfile(threads=2, cpu_activity=0.7,
                                    mem_gb=_MEMS[mi], gpu_frac=0.3,
                                    gpu_mem_gb=1.5 if gpt else 0.0))
            ja, jb = col.submit(spec), obj.submit(spec)
            assert ja == jb
            submitted.append(ja)
        elif op[0] == "step":
            col.step(op[1])
            obj.step(op[1])
        elif submitted:
            jid = submitted[op[1] % len(submitted)]
            ra = col.sched.cancel(jid)
            rb = obj.sched.cancel(jid)
            assert (ra is None) == (rb is None)
        _assert_equiv(col, obj)


@settings(max_examples=30)
@given(n_cpu=st.integers(0, 3), n_gpu=st.integers(1, 3),
       gpus=st.integers(1, 3),
       ops=st.lists(st.one_of(_submit_op, _step_op, _cancel_op),
                    min_size=1, max_size=25))
def test_columnar_matches_object_engine(n_cpu, n_gpu, gpus, ops):
    _run_ops(n_cpu, n_gpu, gpus, ops)


def test_columnar_matches_object_engine_seeded():
    """Hypothesis-free fuzz of the same property, so environments without
    hypothesis (the tier1-no-hypothesis CI job, bare dev boxes) still
    exercise random fleets/sequences rather than skipping."""
    for seed in range(8):
        rng = random.Random(seed)
        ops = []
        for _ in range(rng.randint(5, 25)):
            k = rng.random()
            if k < 0.55:
                ops.append(("submit", rng.randint(0, 3), rng.randint(1, 6),
                            rng.randint(1, 20), rng.randint(0, 2),
                            rng.randint(1, 3), rng.randrange(len(_MEMS)),
                            rng.randrange(len(_DURS)), rng.random() < 0.2,
                            rng.choice(["normal", "shared", "nosuch"])))
            elif k < 0.85:
                ops.append(("step", rng.choice([60.0, 300.0, 1200.0])))
            else:
                ops.append(("cancel", rng.randint(0, 30)))
        _run_ops(rng.randint(0, 3), rng.randint(1, 3), rng.randint(1, 3),
                 ops)


# --------------------------------------------------- distinct-GPU regression

def test_gpu_capacity_requires_distinct_devices():
    """The old fit counted total free slots: caps (2, 0) and a 2-GPU task
    gave ``4 // 2 = ... 1`` task, placed on a single device."""
    assert gpu_task_capacity(np.array([[2, 0]]), 2).tolist() == [0]
    assert gpu_fit_distinct({0: 0, 1: 2}, tpg=2, gpt=2, cap=9) == 0
    # with the slots on distinct devices the same totals do fit
    assert gpu_task_capacity(np.array([[1, 1]]), 2).tolist() == [1]
    assert gpu_fit_distinct({0: 1, 1: 1}, tpg=2, gpt=2, cap=9) == 1


def test_scheduler_fit_rejects_concentrated_slots():
    """End to end on both engines: free slots concentrated on one device
    must not satisfy a multi-GPU task."""
    spec = NodeSpec("g-1", cores=16, mem_gb=64.0, gpus=2, gpu_mem_gb=16.0)
    want = JobSpec("u0", "j", n_tasks=1, cores_per_task=1,
                   gpus_per_task=2, tasks_per_gpu=2, duration_s=60.0,
                   profile=TaskProfile(mem_gb=1.0))
    busy = TaskProfile(mem_gb=1.0)

    sched = Scheduler([spec])
    sched.fleet.place(0, sched.submit(JobSpec(
        "u0", "seed", n_tasks=1, cores_per_task=1, duration_s=1e6,
        profile=busy), 0.0), 1)
    sched.fleet.occ[0, 1] = 2          # device 1 fully occupied, 0 free
    assert sched._fits(want).tolist() == [0]
    sched.fleet.occ[0] = (1, 1)        # one free slot on EACH device
    assert sched._fits(want).tolist() == [1]

    osched = ObjectScheduler([spec])
    ns = osched.nodes["g-1"]
    ns.tasks.append(RunningTask(1, "u0", "g-1", busy, 1, (1,)))
    ns.tasks.append(RunningTask(1, "u0", "g-1", busy, 1, (1,)))
    job = osched.submit(want, 0.0)
    assert osched._node_fits(ns, job, 1) == 0
    ns.tasks[1] = RunningTask(1, "u0", "g-1", busy, 1, (0,))
    assert osched._node_fits(ns, job, 1) == 1


@settings(max_examples=40)
@given(st.integers(1, 4),
       st.lists(st.lists(st.integers(0, 5), min_size=1, max_size=6),
                min_size=1, max_size=8))
def test_gpu_capacity_matches_greedy(gpt, rows):
    """The closed-form Gale-Ryser capacity equals the greedy
    least-occupied assignment the scheduler actually performs."""
    width = max(len(r) for r in rows)
    caps = np.array([r + [0] * (width - len(r)) for r in rows], np.int64)
    got = gpu_task_capacity(caps, gpt)
    for i, row in enumerate(caps):
        tpg = int(row.max())
        occ = {g: tpg - int(c) for g, c in enumerate(row)}
        assert got[i] == gpu_fit_distinct(occ, tpg, gpt, cap=10**6), row


# ----------------------------------------------------------- scale + shapes

def test_whole_node_invariant_sweep_4096():
    sim = make_llsc_sim(n_cpu=3584, n_gpu=512)
    paper_scenario(sim, random.Random(0))
    for i in range(16):
        sim.submit(ml_training_job(f"sw{i % 5}", tasks=4))
    sim.run_until(1800.0)
    assert len(sim.sched.nodes) == 4096
    assert sim.sched.check_whole_node_invariant() == []
    assert len(sim.sched.running) > 0


def test_columnar_node_map_is_dict_shaped():
    sim = make_llsc_sim(n_cpu=4, n_gpu=2)
    paper_scenario(sim, random.Random(0))
    sim.run_until(600.0)
    snap = sim.snapshot()
    assert isinstance(snap.nodes, ColumnarNodeMap)
    hosts = list(snap.nodes)
    assert hosts == snap.nodes.keys()
    assert len(snap.nodes.values()) == len(hosts) == len(snap.nodes)
    first = hosts[0]
    assert first in snap.nodes
    assert snap.nodes.get("nope") is None
    node = snap.nodes[first]
    assert snap.nodes.items()[0] == (first, node)
    # materialized snapshots carry native scalars (JSON paths depend on it)
    json.dumps(dataclasses.asdict(node))
    # dict equality both ways (wire-decoded snapshots hold plain dicts)
    as_dict = {h: snap.nodes[h] for h in snap.nodes}
    assert snap.nodes == as_dict and as_dict == snap.nodes
    assert snap.nodes != {**as_dict, "extra": node}


def test_fleet_free_jobs_batch():
    nodes, partitions = _fleet(2, 2)
    fs = FleetState(nodes, partitions)
    job_a = type("J", (), {"job_id": 1, "hostnames": [],
                           "spec": ml_training_job("a", tasks=1)})()
    job_b = type("J", (), {"job_id": 2, "hostnames": [],
                           "spec": ml_training_job("b", tasks=1)})()
    fs.place(2, job_a, 1)
    fs.place(3, job_b, 1)
    assert fs.n_tasks_total == 2
    freed = fs.free_jobs([1, 2], job_a.hostnames + job_b.hostnames)
    assert freed == 2 and fs.n_tasks_total == 0
    assert fs.cores_used.sum() == 0 and fs.occ.sum() == 0


def test_node_state_reexport():
    assert NodeState is not None  # compat import path kept alive

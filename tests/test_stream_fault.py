"""Fault injection for the streaming path (DESIGN.md §14): a daemon
killed and restarted mid-stream, and a child daemon severed while a
fan-in is subscribed to it.  The consumer must (a) keep serving the last
good frame only while it is fresh, (b) report staleness instead of a
silently frozen view, (c) resync after the restart to state
byte-identical to a fresh poll, and (d) never crash."""
import json
import time
import urllib.request

import pytest

from repro.daemon import (LLloadDaemon, RemoteError, RemoteSource, protocol,
                          serve_background)
from repro.monitor import MultiClusterSource, build_source


def _wire(snap) -> bytes:
    return protocol.dumps(protocol.encode_snapshot(snap))


def _serve(source, *, port=0, ttl_s=3600.0):
    daemon = LLloadDaemon(source, ttl_s=ttl_s)
    server, thread = serve_background(daemon, port=port)
    return daemon, server, thread


def _stop(daemon, server, thread):
    server.shutdown()
    server.server_close()
    daemon.close()
    thread.join(timeout=5)
    assert not thread.is_alive()


def _wait_until(fn, timeout=10.0, interval=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if fn():
            return True
        time.sleep(interval)
    return False


def _stale_raises(src) -> bool:
    try:
        src.snapshot()
        return False
    except RemoteError:
        return True


def test_daemon_kill_and_restart_mid_stream(tmp_path):
    daemon, server, thread = _serve(build_source("sim", advance_s=60.0))
    host, port = server.server_address[:2]
    url = f"http://{host}:{port}"
    src = RemoteSource(url, name="a", stream=True, timeout_s=5.0,
                       stale_after_s=0.4)
    try:
        # streaming state is byte-identical to the daemon's own snapshot
        first = src.snapshot()
        assert _wire(first) == _wire(daemon.bus.read(daemon.source.name))

        _stop(daemon, server, thread)            # kill mid-stream

        # the source must not freeze: once the last frame ages past
        # stale_after_s with the connection down, snapshot() raises
        # instead of serving stale data as current
        assert _wait_until(lambda: _stale_raises(src))
        with pytest.raises(RemoteError, match="stale_after_s"):
            src.snapshot()

        # restart on the same port with fresh (different) state: the
        # reader resubscribes, resyncs from the keyframe, and converges
        # byte-identically to what fresh polling now returns
        daemon2, server2, thread2 = _serve(
            build_source("sim", advance_s=60.0), port=port)
        try:
            def converged():
                try:
                    streamed = src.snapshot()
                except RemoteError:
                    return False
                polled = RemoteSource(url, stream=False).snapshot()
                return _wire(streamed) == _wire(polled)

            assert _wait_until(converged)
            assert src.resyncs >= 1
        finally:
            _stop(daemon2, server2, thread2)
    finally:
        src.close()


def test_child_severed_mid_fanin_is_cut_and_reported():
    # distinct cluster names: identically-named sims would merge into
    # the same qualified hostnames and mask the child being cut
    da, sa, ta = _serve(build_source("sim", clusters=["alpha"],
                                     advance_s=60.0))
    db, sb, tb = _serve(build_source("sim", clusters=["beta"],
                                     advance_s=60.0))
    url_a = "http://%s:%d" % sa.server_address[:2]
    url_b = "http://%s:%d" % sb.server_address[:2]
    port_b = sb.server_address[1]
    child_a = RemoteSource(url_a, name="a", stream=True, timeout_s=5.0,
                           stale_after_s=0.2)
    child_b = RemoteSource(url_b, name="b", stream=True, timeout_s=5.0,
                           stale_after_s=0.2)
    multi = MultiClusterSource([child_a, child_b], max_staleness_s=0.5)
    # a parent daemon over the fan-in: /stats must surface the severed
    # child (ttl short so every read re-collects the children)
    dp, sp, tp = _serve(multi, ttl_s=0.05)
    url_p = "http://%s:%d" % sp.server_address[:2]

    def parent_stats():
        with urllib.request.urlopen(url_p + "/stats", timeout=30) as rsp:
            return json.loads(rsp.read())

    try:
        both = multi.snapshot()
        n_both = len(both.nodes)
        assert multi.stale_children() == {}

        _stop(db, sb, tb)                        # sever child b

        # b's last-good serves briefly, then ages out of the merge; the
        # fleet view never crashes and never freezes — it narrows to a
        def b_cut():
            urllib.request.urlopen(url_p + "/snapshot", timeout=30).close()
            snap = multi.snapshot()
            return (set(multi.stale_children()) == {"b"}
                    and len(snap.nodes) < n_both)

        assert _wait_until(b_cut)
        snap = multi.snapshot()
        assert set(snap.nodes) == set(child_a.snapshot().nodes)
        assert multi.stale_children()["b"] > 0.5
        assert isinstance(multi.last_error("b"), RemoteError)

        fanin = parent_stats()["fanin"]
        assert fanin["stale_children"] == 1
        assert "b" in fanin["stale"]

        # restart b on its old port: the child resubscribes and the
        # merge converges back to the full fleet with no intervention
        db2, sb2, tb2 = _serve(build_source("sim", clusters=["beta"],
                                            advance_s=60.0), port=port_b)
        try:
            def b_back():
                snap = multi.snapshot()
                return (multi.stale_children() == {}
                        and len(snap.nodes) == n_both)

            assert _wait_until(b_back)
            assert parent_stats()["fanin"]["stale_children"] == 0
        finally:
            _stop(db2, sb2, tb2)
    finally:
        _stop(dp, sp, tp)
        for child in (child_a, child_b):
            child.close()
        _stop(da, sa, ta)

"""The LLload CLI (paper's command surface)."""
import sys

import pytest

from repro.core import cli


def test_default_view(capsys):
    assert cli.main(["--user", "va67890"]) == 0
    out = capsys.readouterr().out
    assert "Cluster name: txgreen" in out
    assert "va67890" in out and "HOSTNAME" in out


def test_gpu_flag(capsys):
    cli.main(["-g", "--user", "va67890"])
    assert "GPUMEM" in capsys.readouterr().out


def test_all_privileged(capsys):
    cli.main(["--all", "-g", "--user", "admin"])
    out = capsys.readouterr().out
    assert "Jupyter notebook jobs:" in out
    assert "@ll.mit.edu" in out


def test_all_unprivileged_scoped(capsys):
    cli.main(["--all", "--user", "va67890"])
    out = capsys.readouterr().out
    assert "Jupyter notebook jobs:" not in out
    assert "va67890" in out


def test_topn(capsys):
    cli.main(["-t", "3"])
    out = capsys.readouterr().out
    assert "sorted by descending order" in out
    assert len([l for l in out.splitlines() if l.strip()]) >= 4


def test_nodelist(capsys):
    # find a real host via tsv first
    cli.main(["--tsv"])
    host = capsys.readouterr().out.splitlines()[1].split("\t")[2]
    cli.main(["-n", host])
    out = capsys.readouterr().out
    assert "Node Information:" in out and host in out


def test_tsv(capsys):
    cli.main(["--tsv"])
    out = capsys.readouterr().out
    header = out.splitlines()[0].split("\t")
    assert header[:3] == ["timestamp", "cluster", "hostname"]


def test_live_source(capsys):
    assert cli.main(["--source", "live", "--user", "nobody"]) == 0


# ------------------------------------------------------- flag validation


@pytest.mark.parametrize("argv", [
    ["-t", "0"], ["-t", "-3"],
    ["--interval", "0"], ["--interval", "-1.5"],
    ["--frames", "0"], ["--frames", "-2"],
])
def test_nonpositive_numeric_flags_rejected(argv, capsys):
    with pytest.raises(SystemExit) as ei:
        cli.main(argv)
    assert ei.value.code == 2                  # argparse usage error
    assert "must be > 0" in capsys.readouterr().err


def test_non_numeric_flags_rejected(capsys):
    with pytest.raises(SystemExit) as ei:
        cli.main(["--interval", "fast"])
    assert ei.value.code == 2


# -------------------------------------------------- broken pipe (one-shot)


class _ClosedPipe:
    """A stdout whose consumer (e.g. `| head`) already went away."""

    def write(self, _):
        raise BrokenPipeError

    def flush(self):
        raise BrokenPipeError


@pytest.mark.parametrize("argv", [
    [], ["--tsv"], ["-t", "3"], ["-n", "c-1-1-1"]])
def test_one_shot_broken_pipe_exits_zero(argv, monkeypatch):
    monkeypatch.setattr(sys, "stdout", _ClosedPipe())
    assert cli.main(["--source", "sim"] + argv) == 0


def test_watch_broken_pipe_exits_zero(monkeypatch):
    monkeypatch.setattr(sys, "stdout", _ClosedPipe())
    assert cli.main(["--watch", "--frames", "2", "--interval", "0.05"]) == 0

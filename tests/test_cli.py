"""The LLload CLI (paper's command surface)."""
import pytest

from repro.core import cli


def test_default_view(capsys):
    assert cli.main(["--user", "va67890"]) == 0
    out = capsys.readouterr().out
    assert "Cluster name: txgreen" in out
    assert "va67890" in out and "HOSTNAME" in out


def test_gpu_flag(capsys):
    cli.main(["-g", "--user", "va67890"])
    assert "GPUMEM" in capsys.readouterr().out


def test_all_privileged(capsys):
    cli.main(["--all", "-g", "--user", "admin"])
    out = capsys.readouterr().out
    assert "Jupyter notebook jobs:" in out
    assert "@ll.mit.edu" in out


def test_all_unprivileged_scoped(capsys):
    cli.main(["--all", "--user", "va67890"])
    out = capsys.readouterr().out
    assert "Jupyter notebook jobs:" not in out
    assert "va67890" in out


def test_topn(capsys):
    cli.main(["-t", "3"])
    out = capsys.readouterr().out
    assert "sorted by descending order" in out
    assert len([l for l in out.splitlines() if l.strip()]) >= 4


def test_nodelist(capsys):
    # find a real host via tsv first
    cli.main(["--tsv"])
    host = capsys.readouterr().out.splitlines()[1].split("\t")[2]
    cli.main(["-n", host])
    out = capsys.readouterr().out
    assert "Node Information:" in out and host in out


def test_tsv(capsys):
    cli.main(["--tsv"])
    out = capsys.readouterr().out
    header = out.splitlines()[0].split("\t")
    assert header[:3] == ["timestamp", "cluster", "hostname"]


def test_live_source(capsys):
    assert cli.main(["--source", "live", "--user", "nobody"]) == 0

"""CLI regressions for the telemetry refactor: deterministic sim output,
node-detail miss reporting, and multi-cluster selection."""
import random

import pytest

from repro.cluster.workloads import make_llsc_sim, paper_scenario
from repro.core import cli
from repro.core.llload import LLload


def _legacy_snapshot():
    """The pre-refactor build path, inlined: sim + scenario + 1h warmup."""
    sim = make_llsc_sim()
    paper_scenario(sim, random.Random(0))
    sim.run_until(3600.0)
    return sim.snapshot()


def test_sim_output_matches_legacy_build_path(capsys):
    """--source sim must render exactly what the old if/else construction
    produced (the registry is plumbing, not behaviour)."""
    from repro.core import formatting

    assert cli.main(["--source", "sim"]) == 0
    out = capsys.readouterr().out

    snap = _legacy_snapshot()
    ll = LLload(snap, privileged_users=cli.PRIVILEGED)
    legacy = formatting.format_user_view(
        snap.cluster, ll.user_view("ab12345"), False) + "\n"
    assert out == legacy


def test_sim_output_deterministic_across_builds(capsys):
    cli.main(["--source", "sim", "--tsv"])
    first = capsys.readouterr().out
    cli.main(["--source", "sim", "--tsv"])
    second = capsys.readouterr().out
    assert first == second


# ------------------------------------------------------------- node misses


def test_unknown_node_reported_and_nonzero_exit(capsys):
    rc = cli.main(["-n", "no-such-host"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "Unknown node(s): no-such-host" in out


def test_mixed_known_unknown_nodes(capsys):
    cli.main(["--tsv"])
    host = capsys.readouterr().out.splitlines()[1].split("\t")[2]
    rc = cli.main(["-n", f"{host},badhost"])
    out = capsys.readouterr().out
    assert rc == 0                      # something useful was shown
    assert host in out
    assert "Unknown node(s): badhost" in out


def test_t_takes_precedence_over_n_as_in_legacy_cli(capsys):
    """The pre-refactor CLI checked -t before -n; both the one-shot and
    watch paths must keep that order."""
    rc = cli.main(["-t", "3", "-n", "badhost"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "sorted by descending order" in out       # top view rendered
    assert "Unknown node" not in out


def test_node_detail_report_api():
    snap = _legacy_snapshot()
    ll = LLload(snap)
    some = next(iter(snap.nodes))
    rep = ll.node_detail_report([some, "ghost"])
    assert [d.node.hostname for d in rep.details] == [some]
    assert rep.missing == ["ghost"]
    # legacy shape unchanged
    assert [d.node.hostname for d in ll.node_detail([some, "ghost"])] \
        == [some]


# ------------------------------------------------------------ multi-cluster


def test_cluster_flag_single_rename(capsys):
    assert cli.main(["--source", "sim", "--cluster", "west",
                     "--user", "ab12345"]) == 0
    out = capsys.readouterr().out
    assert out.startswith("Cluster name: west")


def test_cluster_flag_fans_out_and_merges(capsys):
    assert cli.main(["--source", "sim", "--cluster", "east,west",
                     "--tsv"]) == 0
    out = capsys.readouterr().out
    hosts = {ln.split("\t")[2] for ln in out.splitlines()[1:] if ln}
    assert any(h.startswith("east:") for h in hosts)
    assert any(h.startswith("west:") for h in hosts)


def test_archive_source_requires_dir():
    with pytest.raises(SystemExit):
        cli.main(["--source", "archive"])


def test_archive_source_replays(tmp_path, capsys):
    from repro.core.archive import SnapshotArchive

    archive = SnapshotArchive(str(tmp_path), cluster="txgreen")
    archive.append(_legacy_snapshot())
    rc = cli.main(["--source", "archive", "--archive-dir", str(tmp_path),
                   "--user", "ab12345"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "Cluster name: txgreen" in out
    assert "ab12345" in out

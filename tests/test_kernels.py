"""Pallas kernels vs ref.py oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (attention_ref, gated_rmsnorm_ref, rmsnorm_ref,
                               ssd_intra_chunk_ref)
from repro.kernels.rmsnorm import gated_rmsnorm, rmsnorm
from repro.kernels.ssd import ssd_intra_chunk

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hk,S,D,bq,bk", [
    (1, 2, 1, 128, 64, 64, 64),
    (2, 4, 2, 128, 32, 32, 64),
    (1, 4, 4, 256, 64, 128, 128),
    (2, 8, 2, 64, 128, 64, 64),
])
def test_flash_attention_sweep(B, H, Hk, S, D, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hk, S, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hk, S, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,d", [(32, 128), (33, 256), (7, 64)])
def test_rmsnorm_sweep(rows, d, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (rows, d)).astype(dtype)
    s = (jax.random.normal(ks[1], (d,)) * 0.1 + 1.0).astype(dtype)
    out = rmsnorm(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gated_rmsnorm(dtype):
    ks = jax.random.split(KEY, 3)
    y = jax.random.normal(ks[0], (4, 16, 128)).astype(dtype)
    z = jax.random.normal(ks[1], (4, 16, 128)).astype(dtype)
    s = (jax.random.normal(ks[2], (128,)) * 0.1 + 1.0).astype(dtype)
    out = gated_rmsnorm(y, z, s, interpret=True)
    ref = gated_rmsnorm_ref(y, z, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,l,h,p,g,n", [
    (1, 32, 4, 16, 1, 8),
    (2, 64, 8, 32, 2, 16),
    (1, 16, 2, 8, 2, 4),
])
def test_ssd_intra_chunk_sweep(b, l, h, p, g, n):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, g, n))
    C = jax.random.normal(ks[4], (b, l, g, n))
    out = ssd_intra_chunk(x, dt, A, B, C, interpret=True)
    ref = ssd_intra_chunk_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_ssd_kernel_matches_model_path():
    """Kernel y_diag == the y_diag term inside ssd_chunked (chunk == S)."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, g, n = 1, 32, 4, 16, 1, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    # one chunk == whole sequence: chunked output = intra-chunk only
    y_model, _ = ssd_chunked(x, dt, A, B, C, chunk=s)
    y_kernel = ssd_intra_chunk(x, dt, A, B, C, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=2e-4, atol=2e-4)

"""JaxJobRegistry.aggregate(): device-weighted duty combination capped at
the true oversubscription bound (number of co-resident jobs)."""
import pytest

from repro.core.collector import DeviceUtilization, JaxJobRegistry


def _util(duty, n_devices=1, **kw):
    return DeviceUtilization(n_devices=n_devices, n_active=n_devices,
                             duty_cycle=duty, **kw)


def test_empty_registry_aggregates_to_zero():
    assert JaxJobRegistry().aggregate() == DeviceUtilization()


def test_single_job_passthrough():
    reg = JaxJobRegistry()
    reg.publish("a", _util(0.4, n_devices=2, hbm_used_gb=1.0,
                           hbm_total_gb=16.0))
    agg = reg.aggregate()
    assert agg.duty_cycle == pytest.approx(0.4)
    assert agg.n_devices == 2


def test_co_resident_jobs_duties_add():
    """Two jobs sharing the same device: duty sums (the overloading
    payoff), and is NOT clamped at the old magic 1.5."""
    reg = JaxJobRegistry()
    reg.publish("a", _util(0.9))
    reg.publish("b", _util(0.9))
    assert reg.aggregate().duty_cycle == pytest.approx(1.8)

    reg.publish("c", _util(0.9))
    # three jobs: 2.7 <= bound of 3
    assert reg.aggregate().duty_cycle == pytest.approx(2.7)


def test_device_weighted_mean_for_mixed_device_counts():
    """duty = sum(duty_j * n_j) / max_j(n_j): a 1-device job cannot claim
    the same absolute load as a 4-device job at equal duty."""
    reg = JaxJobRegistry()
    reg.publish("big", _util(1.0, n_devices=4))
    reg.publish("small", _util(1.0, n_devices=1))
    assert reg.aggregate().duty_cycle == pytest.approx((4 + 1) / 4)


def test_cap_at_oversubscription_bound():
    """Self-report noise (duty > 1 from a miscalibrated peak) cannot push
    the aggregate past the number of co-resident jobs."""
    reg = JaxJobRegistry()
    reg.publish("noisy", _util(7.5))
    assert reg.aggregate().duty_cycle == pytest.approx(1.0)

    reg.publish("other", _util(0.2))
    agg = reg.aggregate()
    assert agg.duty_cycle == pytest.approx(2.0)     # capped at k=2


def test_memory_and_flops_aggregation_unchanged():
    reg = JaxJobRegistry()
    reg.publish("a", _util(0.1, hbm_used_gb=2.0, hbm_total_gb=16.0,
                           achieved_flops=1e9))
    reg.publish("b", _util(0.2, hbm_used_gb=3.0, hbm_total_gb=16.0,
                           achieved_flops=2e9))
    agg = reg.aggregate()
    assert agg.hbm_used_gb == pytest.approx(5.0)    # sums (shared HBM pool)
    assert agg.hbm_total_gb == pytest.approx(16.0)  # same physical devices
    assert agg.achieved_flops == pytest.approx(3e9)

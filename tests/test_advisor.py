"""Usage characterization (paper §V-B, Figs 7-9) + NPPN recommendation."""
import random

import pytest
from hypothesis import given, strategies as st

from repro.cluster.workloads import (fixed_gpu_job, io_storm_job, low_gpu_job,
                                     make_llsc_sim, missubmitted_gpu_job,
                                     thread_oversubscribed_job)
from repro.core.advisor import characterize_user, recommend_nppn


def _sim_with(*jobs):
    sim = make_llsc_sim()
    for j in jobs:
        sim.submit(j)
    sim.run_until(1800.0)
    return sim


def test_low_gpu_detected_fig7():
    sim = _sim_with(low_gpu_job("va67890", tasks=4, gpu_frac=0.35))
    advice = characterize_user(sim.snapshot(), "va67890")
    kinds = {a.kind for a in advice}
    assert "low_gpu" in kinds
    a = next(a for a in advice if a.kind == "low_gpu")
    assert a.suggested_nppn and a.suggested_nppn >= 2
    assert "overloading" in a.message


def test_missubmission_detected_fig8():
    sim = _sim_with(missubmitted_gpu_job("rs12345", tasks=3))
    advice = characterize_user(sim.snapshot(), "rs12345")
    a = next(a for a in advice if a.kind == "missubmission")
    # 40-core 2-GPU nodes: fair request is 20 cores/task (the paper's fix)
    assert a.suggested_cores_per_task == 20


def test_fix_improves_packing_fig9():
    """After the advisor's fix, tasks pack 2/node instead of 1/node."""
    sim_bad = _sim_with(missubmitted_gpu_job("u", tasks=4))
    sim_good = _sim_with(fixed_gpu_job("u", tasks=4))
    bad_nodes = len(sim_bad.snapshot().nodes_by_user().get("u", []))
    good_nodes = len(sim_good.snapshot().nodes_by_user().get("u", []))
    assert good_nodes < bad_nodes
    assert good_nodes == 2 and bad_nodes == 4


def test_thread_oversubscription_fig10():
    sim = _sim_with(thread_oversubscribed_job("user01", tasks=2))
    advice = characterize_user(sim.snapshot(), "user01")
    a = next(a for a in advice if a.kind in ("overload", "io_storm"))
    assert a.kind == "overload"
    assert "threads" in a.message


def test_io_storm_fig11():
    sim = _sim_with(io_storm_job("user02", tasks=2))
    advice = characterize_user(sim.snapshot(), "user02")
    a = next(a for a in advice if a.kind == "io_storm")
    assert "I/O" in a.message


def test_healthy_job_no_advice():
    from repro.cluster.workloads import ml_training_job
    sim = _sim_with(ml_training_job("ok", tasks=4, gpu_frac=0.85))
    advice = characterize_user(sim.snapshot(), "ok")
    assert advice == []


# ----------------------------------------------------------------- NPPN ----

def test_recommend_nppn_paper_case():
    # Fig 7: gpu load ~0.4, 2GB of 32GB -> load allows 2, memory allows 8+
    assert recommend_nppn(0.4, 2.0, 32.0) == 2
    # very low duty -> memory-capped at 8 (LLsub levels)
    assert recommend_nppn(0.1, 2.0, 32.0) == 8
    # memory-bound: 20GB of 32GB -> 1
    assert recommend_nppn(0.4, 20.0, 32.0) == 1


@given(st.floats(0.01, 1.0), st.floats(0.1, 32.0))
def test_recommend_nppn_properties(load, mem):
    n = recommend_nppn(load, mem, 32.0)
    assert n in (1, 2, 4, 8)
    # projected duty cycle stays under ~target
    assert n * load <= 0.91 or n == 1
    # projected memory stays under headroom
    assert n * mem <= 32.0 * 0.9 or n == 1


@given(st.floats(0.01, 0.5), st.floats(0.01, 0.5))
def test_recommend_nppn_monotone_in_load(l1, l2):
    lo, hi = sorted([l1, l2])
    assert recommend_nppn(hi, 1.0, 32.0) <= recommend_nppn(lo, 1.0, 32.0)

"""Overloading controller: the paper's NPPN 1->2->4->8 policy."""
import pytest
from hypothesis import given, strategies as st

from repro.core.overload import (DeviceObservation, OverloadController,
                                 packed_throughput_model, NPPN_LEVELS)


def _obs(duty, mem=2.0, total=32.0):
    return DeviceObservation(duty_cycle=duty, mem_used_gb=mem,
                             mem_total_gb=total)


def test_steps_up_one_level_at_a_time():
    c = OverloadController()
    for _ in range(4):
        c.observe(_obs(0.3))
    d = c.decide(1)
    assert d.nppn == 2, d.reason
    # simulate running at 2 with same per-task duty
    c2 = OverloadController()
    for _ in range(4):
        c2.observe(_obs(0.6))
    assert c2.decide(2).nppn == 4 - 2 or c2.decide(2).nppn in (2, 4)


def test_saturation_backs_off():
    c = OverloadController()
    for _ in range(8):
        c.observe(_obs(0.99))
    d = c.decide(4)
    assert d.nppn == 2
    assert "saturated" in d.reason


def test_memory_caps_packing():
    c = OverloadController()
    for _ in range(4):
        c.observe(_obs(0.1, mem=20.0, total=32.0))
    assert c.decide(1).nppn == 1


def test_no_observations_keeps_level():
    c = OverloadController()
    assert c.decide(4).nppn == 4


@given(st.floats(0.05, 1.0), st.sampled_from(NPPN_LEVELS))
def test_packed_throughput_model_properties(duty, nppn):
    t1 = packed_throughput_model(duty, 1)
    tn = packed_throughput_model(duty, nppn)
    assert tn <= nppn * t1 + 1e-9          # no superlinear speedup
    assert tn <= 1.0                       # device duty saturates
    if duty * nppn <= 1.0 and nppn <= 2:
        assert tn >= t1 - 1e-9             # packing low-duty work helps


def test_paper_fig7_scenario_gain():
    """GPU duty 0.35 job: NPPN=2 nearly doubles throughput (paper claim)."""
    t1 = packed_throughput_model(0.35, 1)
    t2 = packed_throughput_model(0.35, 2)
    assert t2 / t1 > 1.8

"""Runtime twin of llcheck's LL001: hammer a live daemon from 32 threads
while the short TTL keeps snapshots ingesting, then reconcile the
/stats request counters against a client-side ledger — a lost or torn
counter update shows up as an exact-count mismatch, a race in the
cache/build-lock path shows up as a 500.
"""
import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.daemon import LLloadDaemon, decode_snapshot, serve_background
from repro.monitor import build_source

N_THREADS = 32
ROUNDS = 6


@pytest.fixture()
def racing_daemon():
    # TTL shorter than the run: reads keep triggering fresh collections,
    # so ingestion (store/jobstore/insight folds) races the serving path
    daemon = LLloadDaemon(build_source("sim"), ttl_s=0.05)
    server, thread = serve_background(daemon)
    host, port = server.server_address[:2]
    yield f"http://{host}:{port}", daemon
    server.shutdown()
    server.server_close()
    daemon.close()
    thread.join(timeout=5)
    assert not thread.is_alive()


def test_concurrent_mixed_endpoints_exact_counters(racing_daemon):
    url, daemon = racing_daemon

    ledger_lock = threading.Lock()
    sent = {"/snapshot": 0, "/query": 0, "/job": 0, "/stats": 0}
    statuses = []

    def get(path, endpoint):
        with ledger_lock:
            sent[endpoint] += 1
        try:
            with urllib.request.urlopen(url + path, timeout=30) as rsp:
                body, status = rsp.read(), rsp.status
        except urllib.error.HTTPError as exc:
            body, status = exc.read(), exc.code
        with ledger_lock:
            statuses.append((path, status))
        return status, body

    # job ids that exist in the snapshot *and* the job history tier
    # (the store folds each collection, so after one read they're there)
    _, body = get("/snapshot", "/snapshot")
    snap = decode_snapshot(json.loads(body))
    job_ids = [j.job_id for j in snap.jobs[:4]]
    assert job_ids, "sim source must expose jobs"

    barrier = threading.Barrier(N_THREADS)
    errors = []

    def worker(i):
        barrier.wait()
        try:
            for r in range(ROUNDS):
                get("/snapshot", "/snapshot")
                get("/query?table=nodes&limit=5", "/query")
                get(f"/job/{job_ids[(i + r) % len(job_ids)]}", "/job")
                get("/stats", "/stats")
        except Exception as exc:  # noqa: BLE001 — surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []

    # no handler may 500 under concurrency (the handle() contract);
    # /job of a just-rotated id may legitimately 404 — nothing else may
    fine = {s for p, s in statuses if s < 400}
    assert fine <= {200}
    client_errors = [(p, s) for p, s in statuses if s >= 400]
    assert all(p.startswith("/job/") and s == 404
               for p, s in client_errors), client_errors

    # the final /stats read counts itself: increment-then-serve
    status, body = get("/stats", "/stats")
    assert status == 200
    http = json.loads(body)["http"]
    for endpoint, n in sent.items():
        assert http[f'requests_total{{endpoint="{endpoint}"}}'] == float(n)
    assert http["http_errors_total"] == float(len(client_errors))
    # every request we sent is accounted for — none lost, none doubled
    total = sum(v for k, v in http.items()
                if k.startswith("requests_total"))
    assert total == float(sum(sent.values()))

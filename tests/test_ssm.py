"""Mamba-2 SSD: chunked form vs sequential oracle + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.ssm import (causal_conv, conv_decode_step, ssd_chunked,
                              ssd_decode_step, ssd_reference)

KEY = jax.random.PRNGKey(0)


def _data(b=2, s=32, h=4, p=8, g=2, n=4, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    return x, dt, A, B, C


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_chunked_matches_sequential(chunk):
    x, dt, A, B, C = _data()
    y_c, st_c = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y_r, st_r = ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r),
                               rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance():
    x, dt, A, B, C = _data(s=24)
    y1, s1 = ssd_chunked(x, dt, A, B, C, chunk=6)
    y2, s2 = ssd_chunked(x, dt, A, B, C, chunk=24)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


def test_padding_path():
    # s not divisible by chunk exercises the pad branch
    x, dt, A, B, C = _data(s=21)
    y_c, _ = ssd_chunked(x, dt, A, B, C, chunk=8)
    y_r, _ = ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), rtol=2e-4,
                               atol=2e-4)


def test_initial_state_continuation():
    """SSD over [0:s1] then [s1:] with carried state == full sequence."""
    x, dt, A, B, C = _data(s=32)
    s1 = 16
    y_a, state = ssd_chunked(x[:, :s1], dt[:, :s1], A, B[:, :s1], C[:, :s1],
                             chunk=8)
    y_b, _ = ssd_chunked(x[:, s1:], dt[:, s1:], A, B[:, s1:], C[:, s1:],
                         chunk=8, initial_state=state)
    y_full, _ = ssd_chunked(x, dt, A, B, C, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)


def test_decode_step_matches_chunked_tail():
    x, dt, A, B, C = _data(s=16)
    y_full, st_full = ssd_chunked(x, dt, A, B, C, chunk=8)
    _, st_prefix = ssd_chunked(x[:, :-1], dt[:, :-1], A, B[:, :-1],
                               C[:, :-1], chunk=8)
    y_t, st_t = ssd_decode_step(st_prefix, x[:, -1], dt[:, -1], A,
                                B[:, -1], C[:, -1])
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, -1]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_t), np.asarray(st_full),
                               rtol=2e-4, atol=2e-4)


def test_conv_decode_matches_full():
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (2, 10, 6))
    w = jax.random.normal(ks[1], (4, 6))
    b = jax.random.normal(ks[2], (6,))
    full = causal_conv(x, w, b)
    state = jnp.zeros((2, 3, 6))
    outs = []
    for t in range(10):
        y, state = conv_decode_step(state, x[:, t], w, b)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)


@settings(max_examples=10)
@given(st.integers(1, 3), st.integers(4, 40), st.integers(0, 10 ** 6))
def test_ssd_property_random_shapes(b, s, seed):
    x, dt, A, B, C = _data(b=b, s=s, seed=seed)
    y_c, st_c = ssd_chunked(x, dt, A, B, C, chunk=8)
    y_r, st_r = ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), rtol=5e-4,
                               atol=5e-4)

"""Continuous batching with ragged (unequal) prompt lengths.

The engine keeps a per-slot cache length vector; generations must be
identical to running each request alone (greedy decoding is order- and
batching-invariant when slots don't interact).
"""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _requests(vocab, lens=(5, 9, 13, 7), n_new=6):
    rng = np.random.default_rng(42)
    return [Request(i, rng.integers(0, vocab, L).astype(np.int32),
                    max_new_tokens=n_new)
            for i, L in enumerate(lens)]


@pytest.mark.parametrize("arch", ["llsc-100m", "gemma3-1b", "mamba2-370m"])
def test_ragged_batch_matches_solo(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, KEY)

    def run(slots, reqs):
        eng = ServeEngine(cfg, params, EngineConfig(
            slots=slots, max_seq_len=64, monitor=False))
        for r in reqs:
            eng.submit(Request(r.request_id, r.prompt.copy(),
                               r.max_new_tokens))
        eng.run()
        return {c.request_id: c.tokens for c in eng.completions}

    reqs = _requests(cfg.vocab_size)
    batched = run(4, reqs)       # all four in flight with ragged lengths
    solo = run(1, reqs)          # one at a time
    assert batched == solo


def test_slot_refill_midstream():
    """More requests than slots: finished slots refill with new prompts at
    different positions than their neighbours."""
    cfg = reduced_config("llsc-100m")
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, EngineConfig(slots=2, max_seq_len=64,
                                                monitor=False))
    rng = np.random.default_rng(7)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 4 + 3 * i)
                    .astype(np.int32), max_new_tokens=3 + i)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    assert stats["requests"] == 5
    for c in eng.completions:
        assert len(c.tokens) == 3 + c.request_id

"""Elastic re-scaling: a checkpoint written under one mesh restores onto a
different mesh (different device organization), with identical values.

Runs in a subprocess with 8 fake devices (device count locks at jax init).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import reduced_config
from repro.models import init_params
from repro.train.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch.sharding import param_shardings

cfg = reduced_config("qwen1.5-4b")
params = init_params(cfg, jax.random.PRNGKey(0))

mesh_a = jax.make_mesh((4, 2), ("data", "model"))
mesh_b = jax.make_mesh((2, 4), ("data", "model"))

sh_a = param_shardings(mesh_a, params)
placed = jax.device_put(params, sh_a)

d = tempfile.mkdtemp()
save_checkpoint(d, 7, placed)

template = jax.eval_shape(lambda: params)
sh_b = param_shardings(mesh_b, template)
restored, meta = restore_checkpoint(d, 7, template, shardings=sh_b)
assert meta["step"] == 7

flat_o = jax.tree.leaves(params)
flat_r = jax.tree.leaves(restored)
for o, r in zip(flat_o, flat_r):
    np.testing.assert_array_equal(np.asarray(o, np.float32),
                                  np.asarray(r, np.float32))
# restored arrays actually live on the new mesh
some = [x for x in flat_r if x.ndim >= 2][0]
assert some.sharding.mesh.shape == mesh_b.shape
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_elastic_remesh_subprocess():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ELASTIC_OK" in out.stdout

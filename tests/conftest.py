import os
import sys

# Tests run with PYTHONPATH=src, but make it robust when invoked otherwise.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from hypothesis import settings

settings.register_profile("repro", max_examples=25, deadline=None)
settings.load_profile("repro")

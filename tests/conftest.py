import os
import sys
import types

# Tests run with PYTHONPATH=src, but make it robust when invoked otherwise.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    from hypothesis import settings

    settings.register_profile("repro", max_examples=25, deadline=None)
    settings.load_profile("repro")
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    # hypothesis is optional: property-based tests are skipped (not errored)
    # when it is absent.  Install a minimal stub so `from hypothesis import
    # given, settings, strategies as st` keeps importing; @given marks the
    # test skipped and strategy constructors return inert placeholders.
    HAVE_HYPOTHESIS = False
    import pytest

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    class _Settings:
        def __init__(self, *_a, **_k):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*_a, **_k):
            pass

        @staticmethod
        def load_profile(*_a, **_k):
            pass

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            return lambda *a, **k: None

    _st = _Strategies("hypothesis.strategies")
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.strategies = _st
    _hyp.assume = lambda *a, **k: True
    _hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

"""Benchmark harness — one function per paper figure/claim.

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark).

| benchmark                | paper artifact                               |
|--------------------------|----------------------------------------------|
| llload_query_*           | Fig 2/3 per-user view (scaling vs rload)     |
| llload_all_2048          | Fig 4 privileged --all -g view               |
| llload_topn_4096         | Fig 5/10 top-N overloaded nodes              |
| snapshot_tsv_2048        | 15-min archive write format (§V-A)           |
| bus_read_{cached,uncached} | TelemetryBus snapshot-query throughput     |
| daemon_snapshot_*        | HTTP /snapshot requests/s, cached vs collect |
| stream_fanout_512n_64w   | /stream delta fan-out bytes vs polling (§14) |
| query_{table,json}_512n  | query engine filter+sort+render (§7)         |
| insights_{replay,incremental} | §V-B advise: streaming engine vs replay |
| experiments_low_duty_8g  | §V-B campaign: fixed vs closed-loop NPPN     |
| sim_{snapshot,tick}_*    | columnar FleetState vs object engine         |
| sim_campaign_100k        | LLSC-scale (102 400-node) runner smoke cell  |
| columnarize_1wk          | vectorized archive columnarization           |
| weekly_analysis_1wk      | Fig 6 weekly node-hours aggregation          |
| jobstore_ingest/report   | §11 job-history tier ingest + report render  |
| monitor_overhead         | "light-weight" claim: train loop +hooks      |
| overloading_nppn_*       | §V-B GPU overloading throughput (measured)   |
| overloading_model_*      | §V-B analytic packing model                  |
| train_step / serve_step  | substrate step costs (CPU, reduced config)   |

Benchmarks that back a CI acceptance floor additionally write a
``BENCH_<name>.json`` artifact at the repo root (``_emit``) — always to
the same path regardless of the working directory, so re-running the
harness regenerates every checked-in artifact in place.  ``main``
accepts benchmark names (``python benchmarks/run.py sim jobstore``) to
run a subset.
"""
from __future__ import annotations

import json
import os
import random
import time

import numpy as np

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _emit(name, payload):
    """Write ``BENCH_<name>.json`` at the repo root and return its path."""
    path = os.path.join(_REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def _timeit(fn, *, repeat=5, warmup=1):
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times) * 1e6  # us


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------- LLload ---

def _sim(n_nodes):
    from repro.cluster.workloads import make_llsc_sim, paper_scenario

    n_gpu = max(4, n_nodes // 8)
    sim = make_llsc_sim(n_cpu=n_nodes - n_gpu, n_gpu=n_gpu)
    paper_scenario(sim, random.Random(0))
    sim.run_until(1800.0)
    return sim


def bench_llload_query():
    from repro.core.formatting import format_user_view
    from repro.core.llload import LLload

    for n in (64, 512, 2048):
        sim = _sim(n)
        snap = sim.snapshot()
        ll = LLload(snap)

        def q():
            blk = ll.user_view("cd67890")
            return format_user_view(snap.cluster, blk, gpu=True)

        us = _timeit(q)
        _row(f"llload_query_{n}n", us, f"nodes_per_s={n / (us / 1e6):.0f}")


def bench_llload_all():
    from repro.core.formatting import format_all_view
    from repro.core.llload import LLload

    sim = _sim(2048)
    snap = sim.snapshot()
    ll = LLload(snap, privileged_users={"admin"})
    us = _timeit(lambda: format_all_view(ll.all_view("admin"), gpu=True))
    _row("llload_all_2048n", us)


def bench_topn():
    from repro.core.llload import LLload

    sim = _sim(4096)
    snap = sim.snapshot()
    ll = LLload(snap)
    us = _timeit(lambda: ll.top_loaded(10))
    _row("llload_topn_4096n", us, f"nodes_per_s={4096 / (us / 1e6):.0f}")


def bench_snapshot_tsv():
    sim = _sim(2048)
    snap = sim.snapshot()
    us = _timeit(snap.to_tsv)
    _row("snapshot_tsv_2048n", us)


def bench_bus_reads():
    """Snapshot-query throughput through the TelemetryBus: a cached read
    (within TTL) vs. a read that must re-collect from the source."""
    from repro.monitor import TelemetryBus

    sim = _sim(512)

    cached = TelemetryBus(ttl_s=1e9)
    cached.register(sim.as_source(name="cached"))
    cached.read("cached")                        # warm the cache
    us_hit = _timeit(lambda: cached.read("cached"), repeat=5, warmup=1)
    st = cached.stats("cached")
    _row("bus_read_cached_512n", us_hit,
         f"reads_per_s={1e6 / us_hit:.0f};collections={st.collections}")

    uncached = TelemetryBus(ttl_s=0.0)           # every read re-collects
    uncached.register(sim.as_source(name="uncached"))
    us_miss = _timeit(lambda: uncached.read("uncached"), repeat=5, warmup=1)
    _row("bus_read_uncached_512n", us_miss,
         f"reads_per_s={1e6 / us_miss:.0f};"
         f"cache_speedup={us_miss / max(us_hit, 1e-9):.0f}x")


def bench_daemon():
    """The daemon's request-serving hot path at 512 simulated nodes:
    requests/s for cached /snapshot (bytes reused within the TTL window)
    vs. a daemon that must re-collect per request.  Emits
    ``BENCH_daemon.json`` for CI / acceptance (cached >= 10x uncached)."""
    import http.client

    from repro.daemon import LLloadDaemon, serve_background

    def rps(ttl_s, n_requests):
        sim = _sim(512)
        daemon = LLloadDaemon(sim.as_source(name="bench"), ttl_s=ttl_s)
        server, _ = serve_background(daemon)
        host, port = server.server_address[:2]
        conn = http.client.HTTPConnection(host, port)
        try:
            conn.request("GET", "/snapshot")   # warm (bind, first collect)
            conn.getresponse().read()
            t0 = time.perf_counter()
            for _ in range(n_requests):
                conn.request("GET", "/snapshot")
                rsp = conn.getresponse()
                body = rsp.read()
                assert rsp.status == 200 and body
            dt = time.perf_counter() - t0
        finally:
            conn.close()
            server.shutdown()
            server.server_close()
            daemon.close()
        return n_requests / dt, dt / n_requests * 1e6

    cached_rps, cached_us = rps(ttl_s=1e9, n_requests=300)
    uncached_rps, uncached_us = rps(ttl_s=0.0, n_requests=30)
    speedup = cached_rps / max(uncached_rps, 1e-9)
    _row("daemon_snapshot_cached_512n", cached_us,
         f"requests_per_s={cached_rps:.0f}")
    _row("daemon_snapshot_uncached_512n", uncached_us,
         f"requests_per_s={uncached_rps:.0f};cache_speedup={speedup:.1f}x")
    _emit("daemon", {
        "nodes": 512,
        "cached_requests_per_s": round(cached_rps, 1),
        "uncached_requests_per_s": round(uncached_rps, 1),
        "cache_speedup_x": round(speedup, 2),
    })


def bench_stream():
    """Push-based streaming fan-out (DESIGN.md §14) at 512 simulated
    nodes, 64 live HTTP watchers, ~5% node churn per tick: bytes on the
    wire for a /stream subscriber (keyframe + deltas) vs the same
    watcher polling full /snapshot bodies every tick.  Emits
    ``BENCH_stream.json`` for CI / acceptance (byte reduction >= 10x)."""
    import dataclasses
    import threading
    import urllib.request

    from repro.core.metrics import ClusterSnapshot
    from repro.daemon import LLloadDaemon, protocol, serve_background

    n_watchers, n_ticks, churn = 64, 64, 0.05
    base = _sim(512).snapshot()
    hosts = list(base.nodes)
    rng = random.Random(0)

    class ChurnSource:
        """~5% of the fleet moves per collection; one job rotates."""
        name = "churn"
        interval_hint = None

        def __init__(self):
            self._snap = base
            self._next_job = max(j.job_id for j in base.jobs) + 1

        def snapshot(self):
            snap = self._snap
            nodes = dict(snap.nodes)
            for h in rng.sample(hosts, int(len(hosts) * churn)):
                n = nodes[h]
                nodes[h] = dataclasses.replace(
                    n, load=round(rng.uniform(0.0, n.cores_total), 3),
                    mem_used_gb=round(rng.uniform(0.0, n.mem_total_gb), 3))
            jobs = list(snap.jobs)[1:]
            jobs.append(dataclasses.replace(snap.jobs[0],
                                            job_id=self._next_job))
            self._next_job += 1
            self._snap = ClusterSnapshot(snap.cluster,
                                         snap.timestamp + 15.0, nodes,
                                         jobs, dict(snap.user_emails))
            return self._snap

    # what one polling watcher would transfer: the full encoded snapshot
    # of every tick (the byte-cache serves exactly these bytes)
    polling_bytes = []
    daemon = LLloadDaemon(ChurnSource(), ttl_s=1e9)
    daemon.bus.subscribe(lambda name, snap: polling_bytes.append(
        len(protocol.dumps(protocol.encode_snapshot(snap)))))
    server, _ = serve_background(daemon)
    host, port = server.server_address[:2]
    url = f"http://{host}:{port}/stream?frames={n_ticks + 1}"

    per_watcher = [0] * n_watchers
    frames_seen = [0] * n_watchers

    def watch(i):
        with urllib.request.urlopen(url, timeout=120) as rsp:
            for line in rsp:
                line = line.strip()
                if line:
                    per_watcher[i] += len(line) + 1   # wire newline
                    frames_seen[i] += 1

    threads = [threading.Thread(target=watch, args=(i,))
               for i in range(n_watchers)]
    try:
        for t in threads:
            t.start()
        deadline = time.monotonic() + 60.0
        while daemon.hub.stats()["subscribers"] < n_watchers:
            assert time.monotonic() < deadline, "watchers failed to join"
            time.sleep(0.005)
        t0 = time.perf_counter()
        for _ in range(n_ticks):
            daemon.bus.poll("churn")   # one encode, 64 enqueues
        publish_dt = time.perf_counter() - t0
        for t in threads:
            t.join(timeout=120)
    finally:
        server.shutdown()
        server.server_close()
        daemon.close()

    assert frames_seen == [n_ticks + 1] * n_watchers
    assert len(set(per_watcher)) == 1     # byte-equal fan-out
    assert len(polling_bytes) == n_ticks + 1
    stream_b, poll_b = per_watcher[0], sum(polling_bytes)
    reduction = poll_b / stream_b
    tick_us = publish_dt / n_ticks * 1e6
    _row("stream_fanout_512n_64w", tick_us,
         f"frames={n_ticks + 1};byte_reduction={reduction:.1f}x")
    _emit("stream", {
        "nodes": 512,
        "watchers": n_watchers,
        "frames_per_watcher": n_ticks + 1,
        "churn_node_frac": churn,
        "stream_bytes_per_watcher": stream_b,
        "polling_bytes_per_watcher": poll_b,
        "byte_reduction_x": round(reduction, 2),
        "publish_us_per_tick": round(tick_us, 1),
    })


def bench_query():
    """The unified query engine at 512 simulated nodes: parse + filter +
    sort + render, table vs json renderer (DESIGN.md §7).  Emits
    ``BENCH_query.json`` for CI / acceptance."""
    from repro.query import Query, get_renderer, run_query

    sim = _sim(512)
    snap = sim.snapshot()
    q = Query.from_params(table="nodes", filter="cores>0 and cpu_load>=0",
                          sort="-norm_load",
                          columns="host,user,cpu_load,norm_load,gpu_load")
    n_rows = len(run_query(snap, q).rows)
    out = {"nodes": 512, "rows": n_rows}
    for fmt in ("table", "json"):
        renderer = get_renderer(fmt)

        def full():
            return renderer.render(run_query(snap, q))

        us = _timeit(full)
        _row(f"query_{fmt}_512n", us,
             f"rows={n_rows};rows_per_s={n_rows / (us / 1e6):.0f}")
        out[f"{fmt}_us_per_query"] = round(us, 1)
        out[f"{fmt}_rows_per_s"] = round(n_rows / (us / 1e6), 1)
    _emit("query", out)


def bench_insights():
    """The §V-B advise surface at 512 nodes x 64 snapshots: answering
    "what should users fix right now?" by full-history replay
    (``characterize_snapshots``, the pre-redesign path — O(snapshots ·
    nodes) per query) vs the incremental InsightEngine (fold the newest
    snapshot, read the active set — O(rules · users) per query).  Emits
    ``BENCH_insights.json`` for CI / acceptance (incremental >= 10x)."""
    from repro.core.advisor import characterize_snapshots
    from repro.insights import InsightEngine

    n_nodes, n_snaps = 512, 64
    sim = _sim(n_nodes)
    src = sim.as_source(name="bench", advance_s=60.0)
    snaps = [src.snapshot() for _ in range(n_snaps)]

    us_replay = _timeit(lambda: characterize_snapshots(snaps), repeat=3)
    n_replay = len(characterize_snapshots(snaps))

    engine = InsightEngine()
    for s in snaps:
        engine.observe(s)              # steady state: history absorbed

    def incremental():
        engine.observe(snaps[-1])
        return engine.active()

    us_inc = _timeit(incremental, repeat=3)
    n_inc = len(incremental())
    speedup = us_replay / max(us_inc, 1e-9)
    _row(f"insights_replay_{n_nodes}n_{n_snaps}s", us_replay,
         f"insights={n_replay}")
    _row(f"insights_incremental_{n_nodes}n_{n_snaps}s", us_inc,
         f"insights={n_inc};speedup={speedup:.1f}x")
    _emit("insights", {
        "nodes": n_nodes,
        "snapshots": n_snaps,
        "replay_us_per_query": round(us_replay, 1),
        "incremental_us_per_query": round(us_inc, 1),
        "speedup_x": round(speedup, 2),
    })


def bench_experiments():
    """The §V-B campaign harness on the example sweep (DESIGN.md §9):
    fixed NPPN=1 vs the controller-closed-loop cell on the low-duty mix,
    8-node fleet.  Emits ``BENCH_experiments.json`` for CI / acceptance
    (closed loop >= 1.2x the fixed NPPN=1 throughput)."""
    from repro.experiments import load_campaign, run_campaign

    path = os.path.join(_REPO_ROOT, "examples", "overload_campaign.toml")
    campaign = load_campaign(path)

    t0 = time.perf_counter()
    result = run_campaign(campaign, cells="low_duty/8g/*")
    us_total = (time.perf_counter() - t0) * 1e6

    fixed = result.cell_row("low_duty/8g/nppn1")
    ctl = result.cell_row("low_duty/8g/controller")
    speedup = ctl["throughput"] / max(fixed["throughput"], 1e-9)
    _row("experiments_low_duty_8g", us_total / len(result.results),
         f"cells={len(result.results)};"
         f"fixed1_tasks_per_hr={fixed['throughput']:.1f};"
         f"controller_tasks_per_hr={ctl['throughput']:.1f};"
         f"closed_loop_speedup={speedup:.2f}x;"
         f"converged_nppn={ctl['nppn']}")
    _emit("experiments", {
        "campaign": campaign.name,
        "mix": "low_duty",
        "fleet": 8,
        "cells": len(result.results),
        "fixed_nppn1_tasks_per_hr": round(fixed["throughput"], 2),
        "controller_tasks_per_hr": round(ctl["throughput"], 2),
        "converged_nppn": ctl["nppn"],
        "closed_loop_speedup_x": round(speedup, 2),
        "us_per_cell": round(us_total / len(result.results), 1),
    })


def bench_sim():
    """Columnar FleetState vs the preserved object engine (DESIGN.md
    §10): snapshots/s and scheduler ticks/s at 512 and 4096 nodes on
    the paper scenario, plus a 100k-node campaign smoke cell through
    the real experiments runner.  Emits ``BENCH_sim.json`` for CI /
    acceptance (snapshot speedup >= 10x in CI, >= 50x target locally;
    512-node ticks must not regress below the object engine)."""
    import dataclasses

    from repro.cluster.baseline import ObjectClusterSim
    from repro.cluster.workloads import (llsc_nodes, ml_training_job,
                                         paper_scenario)
    from repro.experiments.runner import run_cell
    from repro.experiments.spec import Cell, Scenario

    def build(n_nodes, columnar):
        from repro.cluster.simulator import ClusterSim

        n_gpu = max(4, n_nodes // 8)
        nodes = llsc_nodes(n_nodes - n_gpu, n_gpu)
        hosts = [n.hostname for n in nodes]
        shared = hosts[:2] + hosts[n_nodes - n_gpu:n_nodes - n_gpu + 1]
        partitions = {
            "normal": {"hosts": [h for h in hosts if h not in shared],
                       "policy": "whole-node"},
            "jupyter": {"hosts": shared, "policy": "shared"},
            "debug": {"hosts": shared, "policy": "shared"},
        }
        cls = ClusterSim if columnar else ObjectClusterSim
        sim = cls(nodes, cluster="bench", partitions=partitions)
        paper_scenario(sim, random.Random(0))
        sim.run_until(1800.0)
        return sim

    def snap_rate(sim, iters):
        sim.snapshot()                               # warm
        t0 = time.perf_counter()
        for _ in range(iters):
            sim.t += 60.0                            # defeat any caching
            sim.snapshot()
        return iters / (time.perf_counter() - t0)

    def tick_rate(sim, iters):
        # steady job churn: one short training job arrives per tick, so
        # every tick pays dispatch + (eventually) completion compaction
        t0 = time.perf_counter()
        for i in range(iters):
            sim.submit(dataclasses.replace(
                ml_training_job(f"tk{i % 8:02d}", tasks=2),
                duration_s=600.0))
            sim.step(60.0)
        return iters / (time.perf_counter() - t0)

    out = {"cells": {}}
    for n in (512, 4096):
        col, obj = build(n, True), build(n, False)
        s_col = snap_rate(col, 200 if n == 512 else 100)
        s_obj = snap_rate(obj, 20 if n == 512 else 5)
        t_col = tick_rate(col, 100 if n == 512 else 50)
        t_obj = tick_rate(obj, 40 if n == 512 else 10)
        s_x, t_x = s_col / s_obj, t_col / t_obj
        _row(f"sim_snapshot_{n}n", 1e6 / s_col,
             f"snapshots_per_s={s_col:.0f};object={s_obj:.1f};"
             f"speedup={s_x:.1f}x")
        _row(f"sim_tick_{n}n", 1e6 / t_col,
             f"ticks_per_s={t_col:.0f};object={t_obj:.1f};"
             f"speedup={t_x:.1f}x")
        out["cells"][str(n)] = {
            "snapshots_per_s": round(s_col, 1),
            "object_snapshots_per_s": round(s_obj, 2),
            "snapshot_speedup_x": round(s_x, 1),
            "ticks_per_s": round(t_col, 1),
            "object_ticks_per_s": round(t_obj, 2),
            "tick_speedup_x": round(t_x, 1),
        }
        # small fleets must never pay for the columnar engine: the
        # early-exit dispatch path keeps 512-node ticks at least at
        # object-engine speed (it measures ~1.5x on quiet hardware)
        if n == 512:
            assert t_x >= 1.0, (
                f"512-node tick regression: columnar {t_col:.0f} ticks/s "
                f"vs object {t_obj:.0f} ({t_x:.2f}x < 1.0x)")

    # 100k-node campaign smoke: a real runner cell at LLSC scale — the
    # object engine could not finish this in any reasonable time
    n_cpu, n_gpu = 98_304, 4_096                     # 102 400 nodes
    cell = Cell("smoke/100k", Scenario(
        mix="low_duty", n_cpu=n_cpu, n_gpu=n_gpu, duration_s=1800.0,
        dt_s=600.0, n_jobs=64, tasks_per_job=8, arrival_s=30.0,
        task_duration_s=1200.0, seed=0).validate(), mode="fixed", nppn=4)
    t0 = time.perf_counter()
    res = run_cell(cell)
    smoke_s = time.perf_counter() - t0
    _row("sim_campaign_100k", smoke_s * 1e6,
         f"nodes={n_cpu + n_gpu};tasks_done={res.tasks_done};"
         f"wall_s={smoke_s:.1f}")
    out["smoke_100k"] = {
        "nodes": n_cpu + n_gpu,
        "tasks_done": res.tasks_done,
        "throughput_tasks_per_hr": round(res.throughput, 1),
        "wall_s": round(smoke_s, 2),
    }
    _emit("sim", out)


def bench_jobstore():
    """The job-history tier (DESIGN.md §11) at 512 nodes x 1000 jobs:
    ``JobHistoryStore.observe`` ingest throughput (job-samples/s over a
    snapshot carrying 1000 running jobs) and the MPCDF-style job-report
    render rate over a full raw ring.  Emits ``BENCH_jobs.json`` for CI
    / acceptance (ingest >= 20k samples/s, >= 200 reports/s)."""
    import dataclasses

    from repro.core.formatting import job_report_text
    from repro.core.metrics import JobRecord
    from repro.daemon.store import JobHistoryStore

    n_nodes, n_jobs = 512, 1000
    sim = _sim(n_nodes)
    base = sim.snapshot()
    hosts = list(base.nodes)
    jobs = [JobRecord(
        job_id=26200000 + i, username=f"u{i % 97:02d}", name="train.sh",
        nodes=[hosts[i % len(hosts)]], cores_per_node=20, state="R",
        job_type="batch", gpus_per_node=1, gpu_request="volta:1",
        start_time=600.0, partition="normal", mem_per_node_gb=16.0,
        submit_time=60.0 * (i % 10), gpu_duty=(i % 100) / 100.0,
        cpu_load=1.0 + (i % 7), mem_used_gb=32.0 + (i % 11),
        step_time_s=0.25 + 0.01 * (i % 5)) for i in range(n_jobs)]

    store = JobHistoryStore(max_jobs=2 * n_jobs)
    n_obs = 16
    clock = [base.timestamp]

    def ingest():
        # timestamps keep advancing across warmup/repeat calls so the
        # out-of-order drop policy never discards the batch
        for _ in range(n_obs):
            clock[0] += 60.0
            store.observe(dataclasses.replace(
                base, timestamp=clock[0], jobs=jobs))

    us = _timeit(ingest, repeat=3)
    sps = n_jobs * n_obs / (us / 1e6)
    _row(f"jobstore_ingest_{n_nodes}n_{n_jobs}j", us / n_obs,
         f"job_samples_per_s={sps:.0f}")

    jid = jobs[0].job_id
    samples = store.raw_points(jid)
    lifetime = store.lifetime(jid)
    assert samples and lifetime is not None

    def render():
        return job_report_text(base.cluster, samples, lifetime)

    us_r = _timeit(render)
    rps = 1e6 / us_r
    _row(f"jobstore_report_{n_nodes}n", us_r,
         f"reports_per_s={rps:.0f};raw_samples={len(samples)}")
    assert sps >= 20_000, f"job-history ingest too slow: {sps:.0f}/s"
    assert rps >= 200, f"job-report render too slow: {rps:.0f}/s"
    _emit("jobs", {
        "nodes": n_nodes,
        "jobs": n_jobs,
        "ingest_job_samples_per_s": round(sps, 1),
        "report_renders_per_s": round(rps, 1),
        "raw_samples_per_report": len(samples),
        "tracked_jobs": len(store.job_ids()),
    })


def bench_columnarize():
    """Vectorized archive columnarization on a week-scale synthetic
    archive (the per-row loop this replaced ran ~5x slower)."""
    from repro.core.analysis import columnarize

    rng = np.random.default_rng(0)
    users = [f"u{i:03d}" for i in range(200)]
    rows = [{
        "timestamp": 900.0 * s, "cluster": "tx", "hostname": f"n{n}",
        "username": users[rng.integers(len(users))], "jobtype": "batch",
        "cores_total": 48, "cores_used": 48,
        "load": float(rng.uniform(0, 96)),
        "mem_total_gb": 192.0, "mem_used_gb": 50.0,
        "gpus_total": 2, "gpus_used": 2,
        "gpu_load": float(rng.uniform(0, 1)),
        "gpu_mem_total_gb": 64.0, "gpu_mem_used_gb": 2.0}
        for s in range(7 * 24 * 4) for n in range(100)]
    us = _timeit(lambda: columnarize(rows), repeat=3)
    _row("columnarize_1wk", us,
         f"rows={len(rows)};rows_per_s={len(rows) / (us / 1e6):.0f}")


def bench_weekly_analysis():
    from repro.core.analysis import weekly_analysis

    rng = np.random.default_rng(0)
    rows = []
    users = [f"u{i:03d}" for i in range(200)]
    for snap_i in range(7 * 24 * 4):          # one week of 15-min snapshots
        ts = snap_i * 900.0
        for node in range(100):               # 100 owned nodes per snapshot
            rows.append({
                "timestamp": ts, "cluster": "tx", "hostname": f"n{node}",
                "username": users[rng.integers(len(users))],
                "jobtype": "batch", "cores_total": 48,
                "cores_used": 48, "load": float(rng.uniform(0, 96)),
                "mem_total_gb": 192.0, "mem_used_gb": 50.0,
                "gpus_total": 2, "gpus_used": 2,
                "gpu_load": float(rng.uniform(0, 1)),
                "gpu_mem_total_gb": 64.0, "gpu_mem_used_gb": 2.0})
    us = _timeit(lambda: weekly_analysis(rows), repeat=3)
    _row("weekly_analysis_1wk", us,
         f"rows={len(rows)};rows_per_s={len(rows) / (us / 1e6):.0f}")


# ----------------------------------------------------- monitoring overhead --

def bench_monitor_overhead():
    """Hook cost measured directly (a loop A/B on 12 steps is noise-bound)."""
    import time as _t

    from repro.configs import reduced_config
    from repro.core.collector import publish_step_utilization
    from repro.train.trainer import Trainer, TrainerConfig

    # cost of one publish (what the trainer adds per monitored step)
    n = 2000
    t0 = _t.perf_counter()
    for _ in range(n):
        publish_step_utilization("bench", model_flops_per_step=1e9,
                                 step_time_s=0.01, peak_flops=1e12)
    hook_us = (_t.perf_counter() - t0) / n * 1e6

    cfg = reduced_config("llsc-100m")
    t = Trainer(cfg, TrainerConfig(steps=10, batch_size=4, seq_len=64,
                                   log_every=0, monitor_every=1))
    t.run(resume=False)
    step_us = np.median([h["time_s"] for h in t.history[2:]]) * 1e6
    _row("monitor_overhead", hook_us,
         f"hook_us={hook_us:.1f};step_us={step_us:.0f};"
         f"overhead_pct={hook_us / step_us * 100:.3f}")


# ------------------------------------------------------------ overloading --

def bench_overloading():
    """§V-B measured: decode throughput vs concurrent streams (NPPN)."""
    import jax

    from repro.configs import reduced_config
    from repro.models import init_params
    from repro.serve.engine import EngineConfig, Request, ServeEngine

    cfg = reduced_config("llsc-100m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    base = None
    for slots in (1, 2, 4, 8):
        eng = ServeEngine(cfg, params, EngineConfig(
            slots=slots, max_seq_len=64, monitor=False))
        for i in range(16):
            eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 8)
                               .astype(np.int32), max_new_tokens=8))
        stats = eng.run()
        tps = stats["tokens_per_s"]
        if base is None:
            base = tps
        # decode_steps is the structural win: the same tokens in ~1/slots
        # the steps.  tokens/s gains saturate when the host device is
        # already compute-bound (unlike the paper's 0.35-duty GPUs, where
        # the sim + analytic model below show the full effect).
        _row(f"overloading_nppn_{slots}", 1e6 / max(tps, 1e-9),
             f"tokens_per_s={tps:.1f};speedup={tps / base:.2f};"
             f"decode_steps={stats['steps']}")


def bench_overloading_model():
    """§V-B analytic packing model for the paper's Fig-7 job (duty 0.35)."""
    from repro.core.overload import packed_throughput_model

    base = packed_throughput_model(0.35, 1)
    for nppn in (1, 2, 4, 8):
        t = packed_throughput_model(0.35, nppn)
        _row(f"overloading_model_nppn_{nppn}", 0.0,
             f"throughput_x={t / base:.2f}")


# -------------------------------------------------------------- substrate --

def bench_steps():
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models import decode_step, init_cache, init_params
    from repro.train.train_step import (default_opt_cfg, init_train_state,
                                        make_train_step)

    cfg = reduced_config("llsc-100m")
    opt_cfg = default_opt_cfg(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = {"tokens": jnp.zeros((4, 64), jnp.int32),
             "labels": jnp.zeros((4, 64), jnp.int32)}

    def train_once():
        nonlocal state
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])

    us = _timeit(train_once, repeat=5, warmup=2)
    toks = 4 * 64
    _row("train_step_reduced", us, f"tokens_per_s={toks / (us / 1e6):.0f}")

    params = init_params(cfg, jax.random.PRNGKey(1))
    caches = init_cache(cfg, 4, 64)
    token = jnp.zeros((4, 1), jnp.int32)
    dstep = jax.jit(lambda p, t, c, l: decode_step(p, cfg, t, c, l))

    def decode_once():
        out, _ = dstep(params, token, caches, jnp.int32(10))
        jax.block_until_ready(out)

    us = _timeit(decode_once, repeat=5, warmup=2)
    _row("serve_step_reduced", us, f"tokens_per_s={4 / (us / 1e6):.0f}")


def bench_storage():
    """The durable segment storage (DESIGN.md §12): WAL ingest
    throughput over pre-encoded wire snapshots, and cold-start recovery
    of a week of 15-min history (672 snapshots) from a compacted data
    directory.  Emits ``BENCH_storage.json`` for CI / acceptance
    (ingest >= 20k snapshots/s, recovery byte-identical and < 10 s)."""
    import dataclasses
    import shutil
    import tempfile

    from repro.daemon import protocol
    from repro.daemon.store import HistoryStore
    from repro.storage import SegmentLog, open_storage

    sim = _sim(64)
    base = sim.snapshot()
    payload = protocol.dumps(protocol.encode_snapshot(base))

    work = tempfile.mkdtemp(prefix="llload-bench-storage-")
    try:
        log = SegmentLog(os.path.join(work, "wal"), max_records=1024)
        n_batch = 2000
        clock = [base.timestamp]

        def ingest():
            for _ in range(n_batch):
                clock[0] += 1.0
                log.append(clock[0], payload)

        us = _timeit(ingest, repeat=3, warmup=1)
        rps = n_batch / (us / 1e6)
        _row("storage_wal_ingest", us / n_batch,
             f"records_per_s={rps:.0f};payload_b={len(payload)}")
        log.close()

        # a week of 15-min history through the full store + compaction,
        # then a cold restart: recovery must reproduce /trend bytes
        week = 4 * 24 * 7
        data = os.path.join(work, "data")
        rt = open_storage(data, compact_interval_s=1e9)
        store = HistoryStore(backend=rt.history)
        t0 = base.timestamp
        for i in range(week):
            store.append(dataclasses.replace(base,
                                             timestamp=t0 + 900.0 * i))
        rt.compact_once()
        before = protocol.dumps(store.trend_wire("15min"))
        rt.close()

        t_rec0 = time.perf_counter()
        rt2 = open_storage(data, compact_interval_s=1e9)
        store2 = HistoryStore(backend=rt2.history)
        counts = store2.recover()
        recovery_s = time.perf_counter() - t_rec0
        identical = protocol.dumps(store2.trend_wire("15min")) == before
        rt2.close()
        _row("storage_week_recovery", recovery_s * 1e6,
             f"tier_points={counts['tier_points']};"
             f"replayed={counts['replayed']};identical={identical}")

        assert rps >= 20_000, f"storage ingest too slow: {rps:.0f}/s"
        assert identical, "recovered /trend bytes differ"
        assert recovery_s < 10.0, \
            f"week recovery too slow: {recovery_s:.2f}s"
        _emit("storage", {
            "wal_payload_bytes": len(payload),
            "wal_ingest_records_per_s": round(rps, 1),
            "week_snapshots": week,
            "recovery_s": round(recovery_s, 4),
            "recovered_tier_points": counts["tier_points"],
            "recovered_replayed_raw": counts["replayed"],
            "trend_byte_identical": identical,
        })
    finally:
        shutil.rmtree(work, ignore_errors=True)


BENCHES = [
    bench_llload_query,
    bench_llload_all,
    bench_topn,
    bench_snapshot_tsv,
    bench_bus_reads,
    bench_daemon,
    bench_stream,
    bench_query,
    bench_insights,
    bench_experiments,
    bench_sim,
    bench_jobstore,
    bench_storage,
    bench_columnarize,
    bench_weekly_analysis,
    bench_monitor_overhead,
    bench_overloading,
    bench_overloading_model,
    bench_steps,
]


def main(argv=None) -> None:
    """Run every benchmark, or a named subset: ``run.py sim jobstore``
    runs ``bench_sim`` and ``bench_jobstore`` only."""
    import sys

    names = {fn.__name__[len("bench_"):]: fn for fn in BENCHES}
    picked = sys.argv[1:] if argv is None else argv
    unknown = [p for p in picked if p not in names]
    if unknown:
        raise SystemExit(
            f"unknown benchmark(s) {', '.join(unknown)}; "
            f"valid: {', '.join(sorted(names))}")
    print("name,us_per_call,derived")
    for bench in (BENCHES if not picked else [names[p] for p in picked]):
        bench()


if __name__ == "__main__":
    main()

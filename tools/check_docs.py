#!/usr/bin/env python3
"""Execute every fenced ``bash``/``python`` block in markdown docs.

    python tools/check_docs.py README.md docs/*.md

Doc snippets rot silently; this runner makes them executable contracts:
CI runs it over README.md and docs/*.md, so a renamed flag or module
breaks the build, not a reader.

Rules:
  * only blocks fenced as ```` ```bash ```` or ```` ```python ```` run —
    illustrative output belongs in ```` ```text ```` / ```` ```console ````
    fences (never executed);
  * a runnable block whose first line is ``# docs: skip`` is parsed but
    not executed (for snippets that need unavailable infrastructure);
  * every block runs from the repo root with ``PYTHONPATH=src`` in a
    fresh interpreter/shell — blocks must be self-contained (start and
    stop their own daemons, bound their own --watch frames);
  * a non-zero exit or a timeout fails the run, printing file:line and
    the block.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import subprocess
import sys
from typing import List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUNNABLE_LANGS = ("bash", "sh", "python")
SKIP_MARK = "# docs: skip"


@dataclasses.dataclass
class Block:
    """One fenced code block: language tag, body, and source location."""
    path: str
    lineno: int                 # line of the opening fence
    lang: str
    code: str


def extract_blocks(path: str) -> List[Block]:
    """Every fenced block in a markdown file, in document order.

    Args:
        path: the markdown file to scan.

    Returns:
        :class:`Block` records (all languages, runnable or not).
    """
    blocks: List[Block] = []
    lang = None
    body: List[str] = []
    start = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            stripped = line.strip()
            if stripped.startswith("```"):
                if lang is None:
                    lang = stripped[3:].strip() or "text"
                    body = []
                    start = lineno
                else:
                    blocks.append(Block(path, start, lang, "".join(body)))
                    lang = None
            elif lang is not None:
                body.append(line)
    return blocks


def is_runnable(block: Block) -> bool:
    """Should this block execute?  ``bash``/``sh``/``python`` fences run
    unless their first line is the ``# docs: skip`` marker."""
    if block.lang not in RUNNABLE_LANGS:
        return False
    first = block.code.lstrip().splitlines()[:1]
    return not (first and first[0].strip() == SKIP_MARK)


def run_block(block: Block, timeout_s: float = 300.0) -> int:
    """Execute one block from the repo root (PYTHONPATH=src).

    Args:
        block: a runnable block (``bash``/``sh`` via ``bash -euo
            pipefail``, ``python`` via this interpreter).
        timeout_s: per-block wall clock limit.

    Returns:
        The exit status (124 on timeout).
    """
    env = dict(os.environ)
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    if block.lang == "python":
        argv = [sys.executable, "-c", block.code]
    else:
        argv = ["bash", "-euo", "pipefail", "-c", block.code]
    try:
        proc = subprocess.run(argv, cwd=REPO, env=env, timeout=timeout_s,
                              stdout=subprocess.PIPE,
                              stderr=subprocess.STDOUT)
    except subprocess.TimeoutExpired:
        print(f"TIMEOUT after {timeout_s:.0f}s: "
              f"{block.path}:{block.lineno}", flush=True)
        return 124
    sys.stdout.buffer.write(proc.stdout)
    sys.stdout.flush()
    return proc.returncode


def main(argv=None) -> int:
    """Run every runnable block of every named file; 0 iff all pass."""
    ap = argparse.ArgumentParser(
        description="execute fenced bash/python blocks in markdown docs")
    ap.add_argument("files", nargs="+", help="markdown files to check")
    ap.add_argument("--timeout", type=float, default=300.0, metavar="S",
                    help="per-block timeout (seconds)")
    args = ap.parse_args(argv)

    ran = 0
    failures: List[str] = []
    for path in args.files:
        for block in extract_blocks(path):
            if not is_runnable(block):
                continue
            ran += 1
            where = f"{path}:{block.lineno}"
            print(f"--- {where} [{block.lang}] ---", flush=True)
            rc = run_block(block, args.timeout)
            if rc != 0:
                failures.append(f"{where} [{block.lang}] exit {rc}")
                print(f"FAILED (exit {rc}): {where}\n{block.code}",
                      flush=True)
    print(f"doc snippets: {ran} ran, {len(failures)} failed")
    # the per-block output can be thousands of lines; repeat every
    # failing fence's file:line at the very end so the culprit is the
    # last thing in the log, not buried in the middle of it
    for failure in failures:
        print(f"FAILED {failure}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""``python -m llcheck`` — run the invariant checkers over the tree.

Exit codes follow the repo convention LL004 itself enforces: 0 when
clean, 1 when findings exist or the environment is broken (missing
path), 2 for usage errors (argparse).  Default scan set is ``src/`` +
``tools/`` under the repo root, mirroring the CI job.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Optional

import llcheck
from llcheck import core, wire_schema

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), os.pardir, os.pardir))
DEFAULT_LOCK = os.path.join(os.path.dirname(__file__), "schema_lock.json")
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def _default_paths() -> List[str]:
    return [os.path.join(REPO_ROOT, "src"), os.path.join(REPO_ROOT, "tools")]


def _update_schema_lock(lock_path: str) -> int:
    paths = [os.path.join(REPO_ROOT, "src")]
    modules, parse_findings = core.load_modules(paths, REPO_ROOT)
    ctx = core.Context(repo_root=REPO_ROOT, modules=modules,
                       schema_lock_path=lock_path)
    protocol = ctx.module(wire_schema.PROTOCOL_SUFFIX)
    if protocol is None or parse_findings:
        print("llcheck: cannot extract schema (protocol module missing "
              "or unparseable)", file=sys.stderr)
        return 1
    schema = wire_schema.extract_schema(
        protocol, ctx.module(wire_schema.METRICS_SUFFIX))
    previous = None
    if os.path.exists(lock_path):
        with open(lock_path, "r", encoding="utf-8") as fh:
            previous = json.load(fh)
    wire_schema.write_lock(lock_path,
                           wire_schema.build_lock(schema, previous))
    rel = os.path.relpath(lock_path, REPO_ROOT)
    print(f"llcheck: wrote {rel} (wire version {schema['wire_version']}, "
          f"{len(schema['node_fields'])} node fields, "
          f"{len(schema['job_fields'])} job fields)")
    return 0


def _check_lock_regen(lock_path: str) -> bool:
    """True when regenerating the schema lock would be a no-op (the CI
    guarantee that the checked-in lock matches the code)."""
    modules, _ = core.load_modules([os.path.join(REPO_ROOT, "src")],
                                   REPO_ROOT)
    ctx = core.Context(repo_root=REPO_ROOT, modules=modules,
                       schema_lock_path=lock_path)
    protocol = ctx.module(wire_schema.PROTOCOL_SUFFIX)
    if protocol is None or not os.path.exists(lock_path):
        return False
    schema = wire_schema.extract_schema(
        protocol, ctx.module(wire_schema.METRICS_SUFFIX))
    with open(lock_path, "r", encoding="utf-8") as fh:
        current = json.load(fh)
    return wire_schema.build_lock(schema, current) == current


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="llcheck",
        description="AST-based invariant checker: lock discipline "
                    "(LL001), wire-schema drift (LL002), label "
                    "cardinality (LL003), exit-code conventions (LL004)")
    parser.add_argument("paths", nargs="*",
                        help="files or directories (default: src/ tools/)")
    parser.add_argument("--format", choices=("table", "json"),
                        default="table")
    parser.add_argument("--ci", action="store_true",
                        help="CI mode: default paths, verify the schema "
                             "lock regenerates to itself, print timing")
    parser.add_argument("--update-schema-lock", action="store_true",
                        help="regenerate tools/llcheck/schema_lock.json "
                             "from the current code and exit")
    parser.add_argument("--schema-lock", default=DEFAULT_LOCK,
                        help=argparse.SUPPRESS)
    parser.add_argument("--baseline", default=DEFAULT_BASELINE,
                        help="baseline JSON of acknowledged findings")
    args = parser.parse_args(argv)

    if args.update_schema_lock:
        return _update_schema_lock(args.schema_lock)

    paths = args.paths or _default_paths()
    for p in paths:
        if not os.path.exists(p):
            print(f"llcheck: no such path: {p}", file=sys.stderr)
            return 1

    started = time.monotonic()
    findings, n_modules = llcheck.run(paths, REPO_ROOT,
                                      schema_lock_path=args.schema_lock)
    if args.ci and not _check_lock_regen(args.schema_lock):
        findings.append(core.Finding(
            "LL002", os.path.relpath(args.schema_lock, REPO_ROOT), 1,
            "schema_lock.json does not match a fresh regeneration — "
            "run 'python -m llcheck --update-schema-lock' and commit"))
    try:
        baseline = core.load_baseline(args.baseline)
    except (ValueError, json.JSONDecodeError) as exc:
        print(f"llcheck: bad baseline: {exc}", file=sys.stderr)
        return 1
    findings, baselined = core.apply_baseline(findings, baseline)
    elapsed = time.monotonic() - started

    try:
        if args.format == "json":
            print(json.dumps({
                "findings": [f.as_dict() for f in findings],
                "baselined": baselined,
                "modules": n_modules,
                "elapsed_s": round(elapsed, 3),
            }, indent=2))
        else:
            if findings:
                sys.stdout.write(core.render_findings_table(findings))
            summary = (f"llcheck: {len(findings)} finding"
                       f"{'s' if len(findings) != 1 else ''} "
                       f"({baselined} baselined) across {n_modules} "
                       f"modules in {elapsed:.2f}s")
            print(summary)
    except BrokenPipeError:
        return 0
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

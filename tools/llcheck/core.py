"""Core model for llcheck: parsed modules, annotation grammars, findings.

llcheck reads two comment grammars (DESIGN.md §13):

``# guarded-by: <lock>``
    On an attribute assignment: the attribute is mutable shared state
    protected by ``self.<lock>``.  On a ``def`` line: the whole method
    runs with ``self.<lock>`` already held (callers acquire it).

``# llcheck: ignore[LL001] <reason>``
    Suppress the listed finding codes on this line.  The reason is
    mandatory: an ignore without one is itself a finding (LL000), so
    every suppression documents *why* the invariant does not apply.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Set, Tuple

GUARD_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)\s*$")
IGNORE_RE = re.compile(r"#\s*llcheck:\s*ignore\[([A-Za-z0-9,\s]*)\]\s*(.*)$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit: a code, a location, and a human sentence."""
    code: str
    path: str          # repo-relative
    line: int
    message: str

    def key(self) -> Tuple[str, str, int]:
        return (self.code, self.path, self.line)

    def as_dict(self) -> Dict[str, object]:
        return {"code": self.code, "path": self.path,
                "line": self.line, "message": self.message}


class SourceModule:
    """A parsed source file plus its llcheck comment annotations."""

    def __init__(self, path: str, repo_root: str,
                 text: Optional[str] = None):
        self.path = os.path.abspath(path)
        self.rel = os.path.relpath(self.path, repo_root).replace(os.sep, "/")
        if text is None:
            with open(self.path, "r", encoding="utf-8") as fh:
                text = fh.read()
        self.text = text
        self.tree = ast.parse(text, filename=self.path)
        # lineno -> lock attribute name   (# guarded-by: _lock)
        self.guards: Dict[int, str] = {}
        # lineno -> (codes, reason)       (# llcheck: ignore[...] reason)
        self.ignores: Dict[int, Tuple[Set[str], str]] = {}
        self._scan_comments()

    def _scan_comments(self) -> None:
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.text).readline)
            for tok in tokens:
                if tok.type != tokenize.COMMENT:
                    continue
                # a comment alone on its line annotates the NEXT line
                # (trailing form annotates its own line) — long statements
                # cannot always fit a trailing comment
                lineno = tok.start[0]
                if tok.line.strip().startswith("#"):
                    lineno += 1
                m = GUARD_RE.search(tok.string)
                if m:
                    self.guards[lineno] = m.group(1)
                    continue
                m = IGNORE_RE.search(tok.string)
                if m:
                    codes = {c.strip() for c in m.group(1).split(",")
                             if c.strip()}
                    self.ignores[lineno] = (codes, m.group(2).strip())
        except tokenize.TokenError:
            pass  # ast.parse already succeeded; truncated trailing token

    # ------------------------------------------------------------ queries
    def ignored(self, lineno: int, code: str) -> bool:
        """True when ``code`` is suppressed on ``lineno`` *with* a reason
        (reasonless ignores do not suppress — they are LL000 findings)."""
        entry = self.ignores.get(lineno)
        return bool(entry and code in entry[0] and entry[1])

    def span_ignored(self, node: ast.AST, code: str) -> bool:
        """True when any physical line of ``node`` carries a valid ignore."""
        end = getattr(node, "end_lineno", None) or node.lineno
        return any(self.ignored(ln, code)
                   for ln in range(node.lineno, end + 1))

    def guard_on(self, node: ast.AST) -> Optional[str]:
        """The ``# guarded-by:`` lock named on any physical line of
        ``node`` (for a def, its header lines up to the first body stmt)."""
        end = getattr(node, "end_lineno", None) or node.lineno
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            end = node.body[0].lineno - 1 if node.body else end
            end = max(end, node.lineno)
        for ln in range(node.lineno, end + 1):
            if ln in self.guards:
                return self.guards[ln]
        return None


@dataclasses.dataclass
class Context:
    """Everything a checker gets: the module set plus repo paths."""
    repo_root: str
    modules: List[SourceModule]
    schema_lock_path: str = ""

    def module(self, rel_suffix: str) -> Optional[SourceModule]:
        for mod in self.modules:
            if mod.rel.endswith(rel_suffix):
                return mod
        return None


def load_modules(paths: Iterable[str], repo_root: str
                 ) -> Tuple[List[SourceModule], List[Finding]]:
    """Parse every ``.py`` under ``paths`` (files or directories).
    Unparseable files become findings, not crashes."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if not d.startswith(".")
                                     and d != "__pycache__")
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames) if f.endswith(".py"))
        else:
            files.append(p)
    modules, findings = [], []
    for path in files:
        try:
            modules.append(SourceModule(path, repo_root))
        except (SyntaxError, UnicodeDecodeError, OSError) as exc:
            rel = os.path.relpath(path, repo_root).replace(os.sep, "/")
            line = getattr(exc, "lineno", None) or 1
            findings.append(Finding("LL000", rel, line,
                                    f"could not parse: {exc}"))
    return modules, findings


def suppression_findings(modules: Iterable[SourceModule]) -> List[Finding]:
    """LL000: every ``llcheck: ignore`` must name codes and give a reason."""
    out = []
    for mod in modules:
        for lineno, (codes, reason) in sorted(mod.ignores.items()):
            if not codes:
                out.append(Finding(
                    "LL000", mod.rel, lineno,
                    "ignore[] names no finding codes"))
            elif not reason:
                out.append(Finding(
                    "LL000", mod.rel, lineno,
                    "ignore[%s] has no reason; suppressions must say why"
                    % ",".join(sorted(codes))))
    return out


# ----------------------------------------------------------------- baseline

def load_baseline(path: str) -> List[Dict[str, object]]:
    """The baseline file: a JSON list of ``{code, path[, line]}`` entries
    for historical findings that are acknowledged but not yet fixed."""
    if not os.path.exists(path):
        return []
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    if not isinstance(data, list):
        raise ValueError(f"baseline {path}: expected a JSON list")
    return data


def apply_baseline(findings: List[Finding],
                   baseline: List[Dict[str, object]]
                   ) -> Tuple[List[Finding], int]:
    """Split findings into (unbaselined, baselined-count)."""
    def matches(f: Finding, entry: Dict[str, object]) -> bool:
        if entry.get("code") != f.code or entry.get("path") != f.path:
            return False
        return "line" not in entry or entry["line"] == f.line

    fresh, suppressed = [], 0
    for f in findings:
        if any(matches(f, e) for e in baseline):
            suppressed += 1
        else:
            fresh.append(f)
    return fresh, suppressed


# ------------------------------------------------------------------ output

def render_findings_table(findings: List[Finding]) -> str:
    """Findings in the repo's table idiom (query/render.py): left-aligned
    string columns, two-space gutters, an ``(N findings)`` footer."""
    header = ["code", "location", "message"]
    rows = [[f.code, f"{f.path}:{f.line}", f.message] for f in findings]
    widths = [len(h) for h in header]
    for row in rows:
        widths = [max(w, len(c)) for w, c in zip(widths, row)]
    lines = ["  ".join(c.ljust(w) for c, w in zip(header, widths)).rstrip()]
    for row in rows:
        lines.append("  ".join(c.ljust(w)
                               for c, w in zip(row, widths)).rstrip())
    lines.append(f"({len(findings)} finding{'s' if len(findings) != 1 else ''})")
    return "\n".join(lines) + "\n"

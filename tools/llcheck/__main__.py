"""Entry point: ``PYTHONPATH=tools python -m llcheck``."""
import sys

from llcheck.cli import main

if __name__ == "__main__":
    sys.exit(main())

"""LL004: CLI exit-code conventions (pinned by the PR 2–3 tests).

Applies to any module defining a top-level ``main`` function:

* exit codes are 0/1/2 only — 1 for environment errors (unreachable
  daemon, unknown host/job, I/O), 2 for usage errors (argparse raises
  it for us), anything else is a convention break;
* an ``except BrokenPipeError`` path must exit 0: piping a one-shot
  view into ``head`` is success, not failure;
* a handler that reports an environment-error type to stderr (the CLI
  error idiom) and returns an integer must return 1 — returning 0
  swallows the failure (cron jobs and scrapers read the exit code),
  returning 2 lies about whose fault it was.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Set

from llcheck import register
from llcheck.core import Context, Finding, SourceModule

ENV_ERROR_TYPES = frozenset({
    "OSError", "IOError", "FileNotFoundError", "PermissionError",
    "ConnectionError", "TimeoutError", "URLError", "HTTPError",
    "QueryError", "RemoteError", "CampaignError", "WireError",
})
ALLOWED_EXITS = frozenset({0, 1, 2})


def _has_main(mod: SourceModule) -> bool:
    return any(isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
               and node.name == "main" for node in mod.tree.body)


def _handler_type_names(handler: ast.ExceptHandler) -> Set[str]:
    names: Set[str] = set()
    types = handler.type
    if types is None:
        return names
    for node in (types.elts if isinstance(types, ast.Tuple) else [types]):
        if isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Attribute):
            names.add(node.attr)
    return names


def _prints_stderr(body: List[ast.stmt]) -> bool:
    """True when the handler reports to stderr (the CLI error idiom):
    ``print(..., file=sys.stderr)`` or ``sys.stderr.write(...)``."""
    for stmt in body:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id == "print":
                for kw in node.keywords:
                    if kw.arg == "file" and isinstance(kw.value,
                                                       ast.Attribute) \
                            and kw.value.attr == "stderr":
                        return True
            elif (isinstance(fn, ast.Attribute) and fn.attr == "write"
                  and isinstance(fn.value, ast.Attribute)
                  and fn.value.attr == "stderr"):
                return True
    return False


def _int_exits(body: List[ast.stmt], returns: bool = True
               ) -> Iterator[ast.AST]:
    """Yield ``(node, value)`` for every constant-int exit in ``body``:
    ``return N`` (when ``returns``), ``sys.exit(N)``, ``SystemExit(N)``."""
    for stmt in body:
        for node in ast.walk(stmt):
            if (returns and isinstance(node, ast.Return)
                    and isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, int)
                    and not isinstance(node.value.value, bool)):
                yield node, node.value.value
            elif isinstance(node, ast.Call):
                fn = node.func
                name = fn.attr if isinstance(fn, ast.Attribute) else (
                    fn.id if isinstance(fn, ast.Name) else "")
                if name in ("exit", "SystemExit", "_exit") and node.args:
                    arg = node.args[0]
                    if (isinstance(arg, ast.Constant)
                            and isinstance(arg.value, int)
                            and not isinstance(arg.value, bool)):
                        yield node, arg.value


@register("LL004", "cli exit-code conventions")
def check(ctx: Context) -> Iterator[Finding]:
    for mod in ctx.modules:
        if not _has_main(mod):
            continue
        # only exit codes 0/1/2 exist: returns are checked inside main()
        # (helpers may return sentinel ints that are not exit codes);
        # sys.exit()/SystemExit are process exits wherever they appear
        mains = [n for n in mod.tree.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and n.name == "main"]
        exits = [e for m in mains for e in _int_exits(m.body)]
        exits.extend(_int_exits(mod.tree.body, returns=False))
        seen = set()
        for node, value in exits:
            if id(node) in seen:
                continue
            seen.add(id(node))
            if value not in ALLOWED_EXITS and not mod.ignored(
                    node.lineno, "LL004"):
                yield Finding(
                    "LL004", mod.rel, node.lineno,
                    f"exit code {value} is outside the convention "
                    f"(0=ok, 1=environment error, 2=usage error)")
        for handler in (n for n in ast.walk(mod.tree)
                        if isinstance(n, ast.ExceptHandler)):
            names = _handler_type_names(handler)
            if "BrokenPipeError" in names:
                for node, value in _int_exits(handler.body):
                    if value != 0 and not mod.ignored(node.lineno, "LL004"):
                        yield Finding(
                            "LL004", mod.rel, node.lineno,
                            f"BrokenPipeError path exits {value}; a "
                            f"truncated pipe (| head) is success — exit 0")
                continue
            # only handlers that *report* an environment error to stderr
            # are exit-code paths; helpers returning sentinel ints are not
            if names & ENV_ERROR_TYPES and _prints_stderr(handler.body):
                for node, value in _int_exits(handler.body):
                    if value != 1 and not mod.ignored(node.lineno, "LL004"):
                        yield Finding(
                            "LL004", mod.rel, node.lineno,
                            f"environment-error handler "
                            f"({', '.join(sorted(names & ENV_ERROR_TYPES))})"
                            f" exits {value}; environment errors exit 1")

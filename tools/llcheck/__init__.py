"""llcheck: the repo's AST-based invariant checker (DESIGN.md §13).

Four checkers encode invariants the codebase established by convention:

========  ===========================================================
LL001     lock discipline: guarded attributes only touched under lock
LL002     wire-schema drift vs. the checked-in schema lock
LL003     Prometheus label cardinality / no f-string label injection
LL004     CLI exit-code conventions (1=environment, 2=usage, pipe=0)
========  ===========================================================

(LL000 is reserved for meta findings: unparseable files and malformed
``llcheck: ignore`` suppressions.)

Checkers self-register via :func:`register`; each is a generator over
:class:`~llcheck.core.Finding` given a :class:`~llcheck.core.Context`.
Everything is stdlib-only so the analyzer can gate CI and pre-commit
without an environment beyond the interpreter.
"""
from __future__ import annotations

import collections
import dataclasses
import os
from typing import Callable, Iterable, Iterator, List, Tuple

from llcheck.core import (Context, Finding, SourceModule, load_modules,
                          suppression_findings)

CheckerFn = Callable[[Context], Iterator[Finding]]


@dataclasses.dataclass(frozen=True)
class Checker:
    code: str
    title: str
    fn: CheckerFn


CHECKERS: "collections.OrderedDict[str, Checker]" = collections.OrderedDict()


def register(code: str, title: str) -> Callable[[CheckerFn], CheckerFn]:
    """Class decorator-style registration: ``@register("LL001", ...)``."""
    def deco(fn: CheckerFn) -> CheckerFn:
        CHECKERS[code] = Checker(code, title, fn)
        return fn
    return deco


def _load_checkers() -> None:
    # importing the modules runs their @register decorators
    from llcheck import cli_exits       # noqa: F401
    from llcheck import lock_discipline  # noqa: F401
    from llcheck import prom_labels     # noqa: F401
    from llcheck import wire_schema     # noqa: F401


def run(paths: Iterable[str], repo_root: str,
        schema_lock_path: str = "") -> Tuple[List[Finding], int]:
    """Run every registered checker over ``paths``.

    Returns ``(findings, modules_scanned)``; findings are sorted by
    (path, line, code) and already filtered through inline ignores
    (each checker consults them) — baseline filtering is the caller's.
    """
    _load_checkers()
    modules, findings = load_modules(paths, repo_root)
    findings.extend(suppression_findings(modules))
    ctx = Context(repo_root=repo_root, modules=modules,
                  schema_lock_path=schema_lock_path or
                  os.path.join(os.path.dirname(__file__),
                               "schema_lock.json"))
    for checker in CHECKERS.values():
        findings.extend(checker.fn(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.code, f.message))
    return findings, len(modules)

"""LL001: lock discipline for classes holding a ``threading.Lock``.

Scope rules (DESIGN.md §13):

* A class is in scope when any of its methods assigns
  ``self.X = threading.Lock()`` / ``threading.RLock()``.
* Attributes annotated ``# guarded-by: <lock>`` on their assignment are
  *guarded*: any ``self.<attr>`` read or write outside a
  ``with self.<lock>:`` block is a finding.  ``__init__`` is exempt
  (the object is not yet published to other threads).
* A ``# guarded-by: <lock>`` on a ``def`` line declares a
  caller-holds-the-lock helper: its whole body is treated as locked.
* Mutable container attributes created in ``__init__`` of an in-scope
  class must be classified — either ``# guarded-by:`` or an explicit
  ``# llcheck: ignore[LL001] <reason>`` — so new state cannot slip in
  unexamined.
* Only ``self.``-attribute accesses are analyzed: cross-object accesses
  (``other._attr``) and class-level state reached via ``cls.`` are out
  of scope, as are nested functions/lambdas (they run later, so the
  lock held at definition time proves nothing — annotate the def line
  if the closure really does run under the lock).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set

from llcheck import register
from llcheck.core import Context, Finding, SourceModule

_LOCK_FACTORIES = {"Lock", "RLock"}
_MUTABLE_CALLS = {"dict", "list", "set", "bytearray", "deque",
                  "OrderedDict", "defaultdict", "Counter"}


def _is_self(node: ast.AST) -> bool:
    return isinstance(node, ast.Name) and node.id == "self"


def _is_lock_factory(call: ast.AST) -> bool:
    if not isinstance(call, ast.Call):
        return False
    fn = call.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else "")
    return name in _LOCK_FACTORIES


def _is_mutable_container(expr: ast.AST) -> bool:
    if isinstance(expr, (ast.Dict, ast.List, ast.Set,
                         ast.ListComp, ast.SetComp, ast.DictComp)):
        return True
    if isinstance(expr, ast.Call):
        fn = expr.func
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else "")
        return name in _MUTABLE_CALLS
    return False


def _self_attr_assigns(method: ast.AST):
    """Yield ``(stmt, attr_name, value)`` for ``self.X = ...`` statements
    directly inside ``method`` (not inside nested defs)."""
    for node in ast.walk(method):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute) and _is_self(tgt.value):
                    yield node, tgt.attr, node.value
        elif isinstance(node, ast.AnnAssign):
            tgt = node.target
            if isinstance(tgt, ast.Attribute) and _is_self(tgt.value):
                yield node, tgt.attr, node.value


class _ClassAuditor:
    """Audit one lock-holding class."""

    def __init__(self, mod: SourceModule, cls: ast.ClassDef):
        self.mod = mod
        self.cls = cls
        self.methods = [n for n in cls.body
                        if isinstance(n, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))]
        self.locks: Set[str] = set()
        self.guarded: Dict[str, str] = {}   # attr -> lock attr
        self.findings: List[Finding] = []
        self._collect_locks_and_guards()

    def _collect_locks_and_guards(self) -> None:
        for method in self.methods:
            for stmt, attr, value in _self_attr_assigns(method):
                if value is not None and _is_lock_factory(value):
                    self.locks.add(attr)
                lock = self.mod.guard_on(stmt)
                if lock is not None:
                    self.guarded[attr] = lock

    # --------------------------------------------------------------- audit
    def audit(self) -> List[Finding]:
        if not self.locks:
            return []
        for attr, lock in sorted(self.guarded.items()):
            if lock not in self.locks:
                self.findings.append(Finding(
                    "LL001", self.mod.rel, self.cls.lineno,
                    f"{self.cls.name}.{attr} is guarded-by {lock!r} but "
                    f"the class holds no such lock attribute"))
        for method in self.methods:
            if method.name == "__init__":
                self._audit_init(method)
            else:
                held = self._def_holds(method)
                for stmt in method.body:
                    self._visit(stmt, held)
        return self.findings

    def _def_holds(self, fn: ast.AST) -> frozenset:
        lock = self.mod.guard_on(fn)
        return frozenset((lock,)) if lock else frozenset()

    def _audit_init(self, init: ast.FunctionDef) -> None:
        """Completeness: every mutable container attribute must be
        classified (guarded or explicitly ignored with a reason).  Only
        the first assignment of each attribute is audited — classifying
        an attribute once classifies it everywhere."""
        seen: Set[str] = set()
        for stmt, attr, value in _self_attr_assigns(init):
            if attr in seen:
                continue
            seen.add(attr)
            if attr in self.guarded or attr in self.locks:
                continue
            if value is None or not _is_mutable_container(value):
                continue
            if self.mod.span_ignored(stmt, "LL001"):
                continue
            self.findings.append(Finding(
                "LL001", self.mod.rel, stmt.lineno,
                f"{self.cls.name}.{attr} is a mutable container in a "
                f"lock-holding class but is not classified: add "
                f"'# guarded-by: <lock>' or "
                f"'# llcheck: ignore[LL001] <reason>'"))

    # ------------------------------------------------------ access walking
    def _visit(self, node: ast.AST, held: frozenset) -> None:
        if isinstance(node, ast.With):
            acquired = set()
            for item in node.items:
                expr = item.context_expr
                if (isinstance(expr, ast.Attribute) and _is_self(expr.value)
                        and expr.attr in self.locks):
                    acquired.add(expr.attr)
                self._visit(expr, held)
                if item.optional_vars is not None:
                    self._visit(item.optional_vars, held)
            inner = frozenset(held | acquired)
            for stmt in node.body:
                self._visit(stmt, inner)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested function runs later: the lock held where it is
            # *defined* proves nothing about where it is *called*
            inner = self._def_holds(node)
            for stmt in node.body:
                self._visit(stmt, inner)
            return
        if isinstance(node, ast.Lambda):
            self._visit(node.body, frozenset())
            return
        if (isinstance(node, ast.Attribute) and _is_self(node.value)
                and node.attr in self.guarded):
            lock = self.guarded[node.attr]
            if lock not in held and not self.mod.ignored(node.lineno,
                                                         "LL001"):
                verb = ("write to"
                        if isinstance(node.ctx, (ast.Store, ast.Del))
                        else "read of")
                self.findings.append(Finding(
                    "LL001", self.mod.rel, node.lineno,
                    f"{verb} {self.cls.name}.{node.attr} outside "
                    f"'with self.{lock}:'"))
        for child in ast.iter_child_nodes(node):
            self._visit(child, held)


@register("LL001", "lock discipline")
def check(ctx: Context) -> Iterator[Finding]:
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                yield from _ClassAuditor(mod, node).audit()

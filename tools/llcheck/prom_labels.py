"""LL003: Prometheus label cardinality stays bounded at the source.

Applies to the metric-emitting modules (``daemon/promtext.py`` and
``daemon/server.py`` — matched by basename so fixture corpora work):

* every ``.sample(name, labels, ...)`` / ``.header(name, ...)`` call
  must have a metric name that *statically* resolves to ``llload_*``
  strings (through literals, the ``prefix`` parameter default, local
  assignments and loops over module-level literal tables);
* label lists must be literal ``[(key, value), ...]`` displays whose
  keys are string literals drawn from the fixed vocabulary;
* no f-string label injection: a ``FormattedValue`` directly after a
  ``...="`` literal mints one label value per distinct input — the
  cardinality explosion PR 2 bounded with ``JOB_LABEL_BUDGET`` and the
  ``_KNOWN_ENDPOINTS`` fold.  Trusted sinks (the escaped ``_labels``
  formatter, the bounded endpoint counter) carry explicit
  ``llcheck: ignore[LL003]`` reasons.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set

from llcheck import register
from llcheck.core import Context, Finding, SourceModule

SCOPE_BASENAMES = ("promtext.py", "server.py")
LABEL_VOCAB = frozenset(
    {"cluster", "host", "user", "job", "kind", "severity", "endpoint"})
METRIC_PREFIX = "llload_"
_MAX_CHOICES = 256


def _in_scope(mod: SourceModule) -> bool:
    base = mod.rel.rsplit("/", 1)[-1]
    return any(base.endswith(s) for s in SCOPE_BASENAMES)


# ------------------------------------------------------- static resolution

class _Resolver:
    """Resolve an expression to its possible string values, through
    literals, parameter defaults, local assignments, and for-loops over
    module-level tables of literal tuples.  ``None`` = unresolvable."""

    def __init__(self, mod: SourceModule):
        self.tables: Dict[str, List[tuple]] = {}
        self.consts: Dict[str, object] = {}
        for node in mod.tree.body:
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                continue
            name = node.targets[0].id
            if isinstance(node.value, ast.Constant):
                self.consts[name] = node.value.value
            else:
                table = self._literal_table(node.value)
                if table is not None:
                    self.tables[name] = table

    @staticmethod
    def _literal_table(expr: ast.expr) -> Optional[List[tuple]]:
        if not isinstance(expr, (ast.List, ast.Tuple)):
            return None
        rows = []
        for elt in expr.elts:
            if isinstance(elt, ast.Constant):
                rows.append((elt.value,))
            elif isinstance(elt, (ast.Tuple, ast.List)):
                if not all(isinstance(c, ast.Constant) for c in elt.elts):
                    return None
                rows.append(tuple(c.value for c in elt.elts))
            else:
                return None
        return rows

    def function_env(self, fn: ast.AST) -> Dict[str, ast.expr]:
        """name -> defining expression (or a synthetic choice set)."""
        env: Dict[str, object] = {}
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = fn.args
            defaults = args.defaults
            params = args.posonlyargs + args.args
            for param, default in zip(params[len(params) - len(defaults):],
                                      defaults):
                env[param.arg] = default
            for param, default in zip(args.kwonlyargs, args.kw_defaults):
                if default is not None:
                    env[param.arg] = default
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                env[node.targets[0].id] = node.value
            elif isinstance(node, ast.For):
                self._bind_loop(env, node.target, node.iter)
        return env

    def _bind_loop(self, env: Dict[str, object], target: ast.expr,
                   it: ast.expr) -> None:
        if not (isinstance(it, ast.Name) and it.id in self.tables):
            return
        table = self.tables[it.id]
        if isinstance(target, ast.Name):
            env[target.id] = {row[0] for row in table if len(row) == 1}
        elif isinstance(target, (ast.Tuple, ast.List)):
            for i, tgt in enumerate(target.elts):
                if isinstance(tgt, ast.Name):
                    env[tgt.id] = {row[i] for row in table if len(row) > i}

    def resolve(self, expr: ast.expr, env: Dict[str, object],
                _seen: Optional[Set[str]] = None) -> Optional[Set[str]]:
        seen = _seen or set()
        if isinstance(expr, ast.Constant):
            return {expr.value} if isinstance(expr.value, str) else None
        if isinstance(expr, ast.Name):
            if expr.id in seen:
                return None
            bound = env.get(expr.id, self.consts.get(expr.id))
            if isinstance(bound, set):
                return bound if all(isinstance(v, str) for v in bound) \
                    else None
            if isinstance(bound, ast.expr):
                return self.resolve(bound, env, seen | {expr.id})
            if isinstance(bound, str):
                return {bound}
            return None
        if isinstance(expr, ast.JoinedStr):
            choices: Set[str] = {""}
            for part in expr.values:
                if isinstance(part, ast.Constant):
                    piece = {str(part.value)}
                elif isinstance(part, ast.FormattedValue):
                    if part.format_spec is not None:
                        return None
                    piece = self.resolve(part.value, env, seen)
                    if piece is None:
                        return None
                else:
                    return None
                choices = {a + b for a in choices for b in piece}
                if len(choices) > _MAX_CHOICES:
                    return None
            return choices
        if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Add):
            left = self.resolve(expr.left, env, seen)
            right = self.resolve(expr.right, env, seen)
            if left is None or right is None:
                return None
            out = {a + b for a in left for b in right}
            return out if len(out) <= _MAX_CHOICES else None
        return None


# --------------------------------------------------------------- checking

def _has_fstring_value(expr: ast.expr) -> bool:
    return any(isinstance(n, ast.FormattedValue) for n in ast.walk(expr))


def _check_call(mod: SourceModule, resolver: _Resolver,
                env: Dict[str, object], call: ast.Call) -> Iterator[Finding]:
    fn = call.func
    if not (isinstance(fn, ast.Attribute)
            and fn.attr in ("sample", "header")):
        return
    if not call.args:
        return
    name_arg = call.args[0]
    names = resolver.resolve(name_arg, env)
    if names is None:
        if not mod.ignored(name_arg.lineno, "LL003"):
            yield Finding(
                "LL003", mod.rel, name_arg.lineno,
                f".{fn.attr}() metric name is not statically resolvable "
                f"to a fixed string set")
    else:
        bad = sorted(n for n in names if not n.startswith(METRIC_PREFIX))
        if bad and not mod.ignored(name_arg.lineno, "LL003"):
            yield Finding(
                "LL003", mod.rel, name_arg.lineno,
                f".{fn.attr}() metric name may resolve to {bad[0]!r}, "
                f"outside the {METRIC_PREFIX}* family")
    if fn.attr != "sample" or len(call.args) < 2:
        return
    labels = call.args[1]
    if not isinstance(labels, (ast.List, ast.Tuple)):
        if not mod.ignored(labels.lineno, "LL003"):
            yield Finding(
                "LL003", mod.rel, labels.lineno,
                ".sample() labels must be a literal list of "
                "(key, value) pairs so the key set is auditable")
        return
    for pair in labels.elts:
        if not (isinstance(pair, (ast.Tuple, ast.List))
                and len(pair.elts) == 2):
            if not mod.ignored(pair.lineno, "LL003"):
                yield Finding("LL003", mod.rel, pair.lineno,
                              ".sample() label entry is not a "
                              "(key, value) pair literal")
            continue
        key, value = pair.elts
        if not (isinstance(key, ast.Constant) and isinstance(key.value, str)):
            if not mod.ignored(key.lineno, "LL003"):
                yield Finding("LL003", mod.rel, key.lineno,
                              ".sample() label key is not a string "
                              "literal")
        elif key.value not in LABEL_VOCAB:
            if not mod.ignored(key.lineno, "LL003"):
                yield Finding(
                    "LL003", mod.rel, key.lineno,
                    f".sample() label key {key.value!r} is outside the "
                    f"fixed vocabulary {sorted(LABEL_VOCAB)}")
        if _has_fstring_value(value) and not mod.ignored(value.lineno,
                                                         "LL003"):
            yield Finding(
                "LL003", mod.rel, value.lineno,
                ".sample() label value is an f-string — every distinct "
                "input mints a new label value (unbounded cardinality)")


def _check_injection(mod: SourceModule, node: ast.JoinedStr
                     ) -> Iterator[Finding]:
    prev = None
    for part in node.values:
        if (isinstance(part, ast.FormattedValue)
                and isinstance(prev, ast.Constant)
                and isinstance(prev.value, str)
                and prev.value.endswith('="')):
            if not mod.ignored(part.lineno, "LL003"):
                yield Finding(
                    "LL003", mod.rel, part.lineno,
                    'f-string label injection (…="{value}"): label values '
                    "must come from a bounded vocabulary or an escaped, "
                    "budget-folded sink")
        prev = part


@register("LL003", "prometheus label cardinality")
def check(ctx: Context) -> Iterator[Finding]:
    for mod in ctx.modules:
        if not _in_scope(mod):
            continue
        resolver = _Resolver(mod)
        scopes = [(mod.tree, {})]
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scopes.append((node, resolver.function_env(node)))
        # ast.walk is breadth-first, so deeper (more specific) scopes come
        # later; visiting in reverse lets the innermost env claim each call
        emitted = set()
        for scope, env in reversed(scopes):
            for node in ast.walk(scope):
                if isinstance(node, ast.Call) and id(node) not in emitted:
                    emitted.add(id(node))
                    yield from _check_call(mod, resolver, env, node)
                elif (isinstance(node, ast.JoinedStr)
                      and id(node) not in emitted):
                    emitted.add(id(node))
                    yield from _check_injection(mod, node)

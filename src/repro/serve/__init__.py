from repro.serve.engine import (Completion, EngineConfig, Request,
                                ServeEngine, overload_decision)

__all__ = ["Completion", "EngineConfig", "Request", "ServeEngine",
           "overload_decision"]

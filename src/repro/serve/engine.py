"""Batched serving engine with overload-aware admission.

The engine runs fixed-capacity decode *slots* (continuous batching: each
slot has its own cache length; finished slots are refilled from the queue
between steps).  The paper tie-in: slot capacity is the NPPN analog —
the :class:`OverloadController` watches the measured device duty cycle and
steps the number of concurrent streams 1 -> 2 -> 4 -> 8 exactly like LLSC
steps tasks-per-GPU, saturating the device with co-resident low-duty work.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overload import (DeviceObservation, OverloadController,
                                 OverloadDecision)
from repro.monitor import publish_step_utilization
from repro.models import model as model_lib
from repro.roofline import hw


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    submitted_s: float = 0.0


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: List[int]
    prompt_len: int
    latency_s: float = 0.0


@dataclasses.dataclass
class EngineConfig:
    slots: int = 4                # concurrent decode streams (NPPN analog)
    max_seq_len: int = 256
    greedy: bool = True           # False: temperature/top-k sampling
    temperature: float = 1.0
    top_k: int = 0                # 0 = full distribution
    seed: int = 0
    job_name: str = "serve"
    peak_flops: float = 5e10
    monitor: bool = True


class ServeEngine:
    """Single-host engine; slots decode in lockstep with per-slot lengths."""

    def __init__(self, cfg, params, ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.queue: deque = deque()
        self.completions: List[Completion] = []
        self.controller = OverloadController()
        self._decode = jax.jit(
            lambda p, t, c, l: model_lib.decode_step(p, cfg, t, c, l),
            donate_argnums=(2,))
        self._prefill = jax.jit(
            lambda p, t: model_lib.prefill(p, cfg, t))
        self._flops_per_token = model_lib.model_flops(cfg, 1, training=False)

    def submit(self, req: Request):
        req.submitted_s = time.perf_counter()
        self.queue.append(req)

    def _select(self, logits, step: int):
        """Greedy argmax or temperature/top-k sampling. logits [B, V]."""
        ecfg = self.ecfg
        if ecfg.greedy:
            return jnp.argmax(logits, axis=-1)
        key = jax.random.fold_in(jax.random.PRNGKey(ecfg.seed), step)
        scaled = logits / max(ecfg.temperature, 1e-6)
        if ecfg.top_k > 0:
            vals, idx = jax.lax.top_k(scaled, ecfg.top_k)
            choice = jax.random.categorical(key, vals, axis=-1)
            return jnp.take_along_axis(idx, choice[:, None], axis=1)[:, 0]
        return jax.random.categorical(key, scaled, axis=-1)

    # ------------------------------------------------------------------
    def _prefill_one(self, req: Request, caches, slot: int, T: int):
        """Prefill one request, splice its cache rows into slot `slot`.

        Returns (caches, prompt_len, first_token) — the first generated
        token comes from the prefill logits (re-feeding the last prompt
        token through decode would double-update SSM states).
        """
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, new = self._prefill(self.params, tokens)
        first_tok = int(self._select(logits, 10_000_000 + req.request_id)[0])
        S = tokens.shape[1]

        def splice(path, dst, src):
            keys = [str(getattr(p, "key", p)) for p in path]
            name = keys[-1]
            b_ax = 1 if "blocks" in keys[:-1] else 0
            if name in ("k", "v", "ckv", "krope"):
                t_ax = b_ax + 1
                if src.shape[t_ax] < dst.shape[t_ax]:
                    pad = [(0, 0)] * src.ndim
                    pad[t_ax] = (0, dst.shape[t_ax] - src.shape[t_ax])
                    src = jnp.pad(src, pad)
            idx = [slice(None)] * dst.ndim
            idx[b_ax] = slot
            src_idx = [slice(None)] * src.ndim
            src_idx[b_ax] = 0
            return dst.at[tuple(idx)].set(
                src[tuple(src_idx)].astype(dst.dtype))

        caches = jax.tree_util.tree_map_with_path(splice, caches, new)
        return caches, S, first_tok

    # ------------------------------------------------------------------
    def run(self, *, max_steps: int = 10_000) -> dict:
        """Drain the queue.  Returns throughput stats."""
        cfg, ecfg = self.cfg, self.ecfg
        B, T = ecfg.slots, ecfg.max_seq_len
        caches = model_lib.init_cache(cfg, B, T)
        lens = np.zeros(B, np.int32)
        active: List[Optional[Request]] = [None] * B
        outputs: List[List[int]] = [[] for _ in range(B)]
        last = np.zeros(B, np.int32)

        t_start = time.perf_counter()
        tokens_out = 0
        steps = 0
        while (self.queue or any(a is not None for a in active)) \
                and steps < max_steps:
            # refill free slots
            for s in range(B):
                if active[s] is None and self.queue:
                    req = self.queue.popleft()
                    caches, S, first = self._prefill_one(req, caches, s, T)
                    active[s] = req
                    lens[s] = S
                    outputs[s] = [first]
                    last[s] = first
                    tokens_out += 1
                    if len(outputs[s]) >= req.max_new_tokens:
                        self.completions.append(Completion(
                            req.request_id, outputs[s], len(req.prompt),
                            time.perf_counter() - req.submitted_s))
                        active[s] = None
            if not any(a is not None for a in active):
                break

            t0 = time.perf_counter()
            # each slot writes its new token at position lens[s]
            logits, caches = self._decode(
                self.params, jnp.asarray(last[:, None]), caches,
                jnp.asarray(lens))
            nxt = np.asarray(self._select(logits, steps), np.int32)
            dt = time.perf_counter() - t0
            steps += 1

            n_active = sum(a is not None for a in active)
            for s in range(B):
                if active[s] is None:
                    continue
                outputs[s].append(int(nxt[s]))
                last[s] = nxt[s]
                lens[s] += 1
                tokens_out += 1
                req = active[s]
                if len(outputs[s]) >= req.max_new_tokens or lens[s] >= T:
                    self.completions.append(Completion(
                        req.request_id, outputs[s], len(req.prompt),
                        time.perf_counter() - req.submitted_s))
                    active[s] = None

            if ecfg.monitor:
                achieved = self._flops_per_token * n_active
                publish_step_utilization(
                    ecfg.job_name, model_flops_per_step=achieved,
                    step_time_s=dt, peak_flops=ecfg.peak_flops,
                    n_devices=jax.device_count(),
                    hbm_total_gb=hw.HBM_BYTES / 1e9)
                self.controller.observe(DeviceObservation(
                    duty_cycle=min(1.0, achieved / (dt * ecfg.peak_flops)),
                    mem_used_gb=0.1 * n_active, mem_total_gb=16.0))

        wall = time.perf_counter() - t_start
        return {
            "requests": len(self.completions),
            "tokens": tokens_out,
            "steps": steps,
            "wall_s": wall,
            "tokens_per_s": tokens_out / wall if wall > 0 else 0.0,
            "decision": self.controller.decide(ecfg.slots),
        }


def overload_decision(engine: ServeEngine) -> OverloadDecision:
    return engine.controller.decide(engine.ecfg.slots)

"""Append-only segment files: the on-disk record format (DESIGN.md §12).

A segment is a header followed by length-prefixed, checksummed records::

    header:  b"LLSG" | u16 format version | u16 reserved     (8 bytes)
    record:  u32 payload length | u32 crc32 | f64 timestamp | payload

The CRC covers the timestamp and the payload, so a torn write (process
killed mid-record, disk full) is detected on read: scanning stops at the
first frame whose length runs past EOF or whose checksum fails, and
everything before it is intact.  Appends that reopen an existing tail
segment first truncate it back to the last valid frame boundary, so one
torn record can never corrupt the records appended after a restart.

Sealed (finished) segments get a JSON sidecar index (``<name>.idx``)
holding the record count, byte size and min/max record timestamp — a
time-range query can skip whole segments without opening them.  Reads go
through :func:`iter_records`, which maps the file when it is large enough
for ``mmap`` to pay off and walks it strictly sequentially either way.
"""
from __future__ import annotations

import dataclasses
import json
import mmap
import os
import struct
import zlib
from typing import Iterator, List, Optional, Tuple

MAGIC = b"LLSG"
FORMAT_VERSION = 1
HEADER = struct.Struct("<4sHH")          # magic, version, reserved
FRAME = struct.Struct("<IId")            # payload length, crc32, timestamp
_MMAP_MIN_BYTES = 1 << 16                # below this, a plain read is faster

MAX_PAYLOAD_BYTES = 64 << 20             # sanity cap against garbage lengths


class SegmentError(ValueError):
    """A segment file that cannot be opened at all (bad magic, or a
    format version newer than this reader understands)."""


def _crc(t_bytes: bytes, payload: bytes) -> int:
    return zlib.crc32(payload, zlib.crc32(t_bytes)) & 0xFFFFFFFF


def frame_record(t: float, payload: bytes) -> bytes:
    """One record as its on-disk frame bytes."""
    t_bytes = struct.pack("<d", t)
    return FRAME.pack(len(payload), _crc(t_bytes, payload), t) + payload


def header_bytes() -> bytes:
    """The 8-byte segment header every segment file starts with."""
    return HEADER.pack(MAGIC, FORMAT_VERSION, 0)


def check_header(buf: bytes) -> None:
    """Validate a segment header; raises :class:`SegmentError` on a bad
    magic or a format version newer than this reader."""
    if len(buf) < HEADER.size:
        raise SegmentError("segment shorter than its header")
    magic, version, _ = HEADER.unpack_from(buf)
    if magic != MAGIC:
        raise SegmentError(f"bad segment magic {magic!r}")
    if version > FORMAT_VERSION:
        raise SegmentError(
            f"segment format {version} is newer than supported "
            f"({FORMAT_VERSION}); upgrade this reader")


@dataclasses.dataclass
class ScanResult:
    """What a sequential scan of one segment found."""
    records: List[Tuple[float, bytes]]   # (timestamp, payload), file order
    valid_bytes: int                     # offset of the first invalid frame
    torn: bool                           # scan stopped before EOF


def _scan(buf, size: int) -> ScanResult:
    check_header(bytes(buf[:HEADER.size]))
    records: List[Tuple[float, bytes]] = []
    off = HEADER.size
    while off < size:
        if off + FRAME.size > size:
            return ScanResult(records, off, torn=True)
        length, crc, t = FRAME.unpack_from(buf, off)
        end = off + FRAME.size + length
        if length > MAX_PAYLOAD_BYTES or end > size:
            return ScanResult(records, off, torn=True)
        payload = bytes(buf[off + FRAME.size:end])
        if _crc(struct.pack("<d", t), payload) != crc:
            return ScanResult(records, off, torn=True)
        records.append((t, payload))
        off = end
    return ScanResult(records, off, torn=False)


def scan_segment(path: str) -> ScanResult:
    """Read every valid record of ``path`` sequentially, stopping at the
    first torn/corrupt frame (``torn=True``); mmap-backed when the file
    is large enough for the mapping to pay off."""
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        if size >= _MMAP_MIN_BYTES:
            with mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ) as mm:
                return _scan(mm, size)
        return _scan(f.read(), size)


def iter_records(path: str) -> Iterator[Tuple[float, bytes]]:
    """Iterate ``(timestamp, payload)`` over a segment's valid records."""
    return iter(scan_segment(path).records)


# --------------------------------------------------------------------- index


@dataclasses.dataclass
class SegmentIndex:
    """The sealed-segment sidecar: enough to answer "does this segment
    overlap [start, end]" and "how many records" without opening it."""
    count: int
    bytes: int
    t_min: float
    t_max: float

    def to_json(self) -> str:
        return json.dumps({"format": FORMAT_VERSION, "count": self.count,
                           "bytes": self.bytes, "t_min": self.t_min,
                           "t_max": self.t_max})

    @classmethod
    def from_json(cls, text: str) -> "SegmentIndex":
        d = json.loads(text)
        return cls(count=int(d["count"]), bytes=int(d["bytes"]),
                   t_min=float(d["t_min"]), t_max=float(d["t_max"]))

    def overlaps(self, start: Optional[float], end: Optional[float]) -> bool:
        """True when [t_min, t_max] intersects [start, end] (None bounds
        are open)."""
        if start is not None and self.t_max < start:
            return False
        if end is not None and self.t_min > end:
            return False
        return True


def index_path(segment_path: str) -> str:
    """The sidecar index path for a segment file."""
    return segment_path + ".idx"


def write_index(segment_path: str, index: SegmentIndex) -> None:
    """Write the sidecar atomically (tmp + rename) so a crash can never
    leave a half-written index next to a sealed segment."""
    tmp = index_path(segment_path) + ".tmp"
    with open(tmp, "w") as f:
        f.write(index.to_json())
    os.replace(tmp, index_path(segment_path))


def read_index(segment_path: str) -> Optional[SegmentIndex]:
    """The sidecar index, or ``None`` when the segment is unsealed (or
    the sidecar is unreadable — the segment scan is the fallback)."""
    try:
        with open(index_path(segment_path)) as f:
            return SegmentIndex.from_json(f.read())
    except (OSError, ValueError, KeyError):
        return None


class SegmentWriter:
    """Append records to one segment file.

    Opening an existing file scans it and truncates back to the last
    valid frame (``torn_dropped`` counts the discarded frames), so the
    writer always appends at a clean record boundary.
    """

    def __init__(self, path: str):
        self.path = path
        self.torn_dropped = 0
        if os.path.exists(path) and os.path.getsize(path) > 0:
            scan = scan_segment(path)
            self.count = len(scan.records)
            self.t_min = min((t for t, _ in scan.records), default=None)
            self.t_max = max((t for t, _ in scan.records), default=None)
            if scan.torn:
                self.torn_dropped = 1
                with open(path, "r+b") as f:
                    f.truncate(scan.valid_bytes)
            self._f = open(path, "ab")
            self.bytes = scan.valid_bytes
        else:
            self._f = open(path, "wb")
            self._f.write(header_bytes())
            self._f.flush()
            self.count = 0
            self.bytes = HEADER.size
            self.t_min = None
            self.t_max = None

    def append(self, t: float, payload: bytes) -> None:
        """Append one record and flush it to the OS (the WAL discipline:
        a process crash keeps every appended record; only the one being
        written when the power goes can tear, and the reader drops it)."""
        frame = frame_record(t, payload)
        self._f.write(frame)
        self._f.flush()
        self.count += 1
        self.bytes += len(frame)
        self.t_min = t if self.t_min is None else min(self.t_min, t)
        self.t_max = t if self.t_max is None else max(self.t_max, t)

    def seal(self) -> SegmentIndex:
        """Close the file and write its sidecar index."""
        index = SegmentIndex(count=self.count, bytes=self.bytes,
                             t_min=self.t_min if self.t_min is not None
                             else 0.0,
                             t_max=self.t_max if self.t_max is not None
                             else 0.0)
        self.close()
        write_index(self.path, index)
        return index

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()

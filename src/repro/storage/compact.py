"""Background compaction driver.

Compaction itself lives on the backends (:meth:`HistoryBackend.
compact_once`, :meth:`JobHistoryBackend.compact_once`) and touches only
*sealed* segments plus its own checkpoint — it never takes a store lock,
so folding a week of history in the background does not stall `/now`
requests.  This module just schedules it: a daemon thread services every
registered backend once per interval, and :meth:`CompactionDriver.
run_once` gives tests and the recovery path a synchronous handle.
"""
from __future__ import annotations

import threading
from typing import Dict, List


class CompactionDriver:
    """Periodically call ``compact_once()`` on each registered backend."""

    def __init__(self, backends: List, *, interval_s: float = 30.0):
        self.backends = list(backends)
        self.interval_s = max(0.05, float(interval_s))
        self._stop = threading.Event()
        self._thread: threading.Thread = None
        self.cycles = 0
        self.errors = 0

    def run_once(self) -> int:
        """One synchronous compaction pass over every backend; returns
        how much work was done (segments/shards compacted)."""
        done = 0
        for backend in self.backends:
            try:
                done += backend.compact_once()
            except Exception:               # keep the daemon serving even
                self.errors += 1            # if one backend hits bad disk
        self.cycles += 1
        return done

    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(target=self._loop,
                                        name="llload-compactor",
                                        daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.run_once()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def stats(self) -> Dict[str, float]:
        return {"interval_s": self.interval_s, "cycles": self.cycles,
                "errors": self.errors,
                "running": self._thread is not None}

"""Write-ahead segment log: a directory of append-only segments.

One :class:`SegmentLog` owns one directory.  Appends go to the *tail*
segment (``seg-<seq>.log``); when the tail reaches the record or byte
limit it is *sealed* — closed, sidecar-indexed — and a new tail opens at
the next sequence number.  Sealed segments are immutable: compaction
reads them, retention deletes them, nothing ever rewrites them.

Reopening a log after a crash resumes the old tail: the
:class:`~repro.storage.segment.SegmentWriter` truncates a torn final
record back to the last valid frame boundary, so recovery loses at most
the record that was mid-write when the process died.
"""
from __future__ import annotations

import dataclasses
import os
import re
import threading
from typing import Iterator, List, Optional, Tuple

from repro.storage.segment import (SegmentIndex, SegmentWriter, index_path,
                                   read_index, scan_segment)

_SEG_RE = re.compile(r"^seg-(\d{8})\.log$")


def segment_name(seq: int) -> str:
    return f"seg-{seq:08d}.log"


@dataclasses.dataclass
class SegmentInfo:
    """One segment as the log lists it (sealed ones carry their index)."""
    seq: int
    path: str
    sealed: bool
    count: int
    bytes: int
    t_min: Optional[float]
    t_max: Optional[float]


class SegmentLog:
    """Appendable directory of segments; thread-safe for one writer plus
    concurrent listers/readers (sealed segments are immutable)."""

    def __init__(self, root: str, *, max_records: int = 1024,
                 max_bytes: int = 4 << 20):
        self.root = root
        self.max_records = max(1, int(max_records))
        self.max_bytes = int(max_bytes)
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        self._writer: Optional[SegmentWriter] = None  # guarded-by: _lock
        self.sealed_total = 0                    # guarded-by: _lock
        self.appended_total = 0                  # guarded-by: _lock
        self.pruned_total = 0                    # guarded-by: _lock
        self.torn_dropped = 0                    # guarded-by: _lock
        seqs = self._list_seqs()
        # the tail is the newest unsealed segment; older unsealed ones
        # (a crash can leave at most the tail unsealed, but be tolerant)
        # are sealed in place so compaction can consume them
        self._tail_seq = seqs[-1] if seqs else 0  # guarded-by: _lock
        for seq in seqs[:-1]:
            path = os.path.join(root, segment_name(seq))
            if read_index(path) is None:
                w = SegmentWriter(path)
                self.torn_dropped += w.torn_dropped
                w.seal()
                self.sealed_total += 1

    # ------------------------------------------------------------- listing
    def _list_seqs(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            m = _SEG_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def segments(self) -> List[SegmentInfo]:
        """Every segment oldest-first; the unsealed tail (if any) last."""
        with self._lock:
            tail = self._writer
            infos: List[SegmentInfo] = []
            for seq in self._list_seqs():
                path = os.path.join(self.root, segment_name(seq))
                idx = read_index(path)
                if idx is not None:
                    infos.append(SegmentInfo(seq, path, True, idx.count,
                                             idx.bytes, idx.t_min, idx.t_max))
                elif tail is not None and tail.path == path:
                    infos.append(SegmentInfo(seq, path, False, tail.count,
                                             tail.bytes, tail.t_min,
                                             tail.t_max))
                else:
                    scan = scan_segment(path)
                    ts = [t for t, _ in scan.records]
                    infos.append(SegmentInfo(
                        seq, path, False, len(scan.records),
                        scan.valid_bytes, min(ts) if ts else None,
                        max(ts) if ts else None))
            return infos

    def sealed_segments(self) -> List[SegmentInfo]:
        return [s for s in self.segments() if s.sealed]

    # ------------------------------------------------------------- writing
    def _open_tail(self) -> SegmentWriter:       # guarded-by: _lock
        path = os.path.join(self.root, segment_name(self._tail_seq))
        w = SegmentWriter(path)
        self.torn_dropped += w.torn_dropped
        return w

    def append(self, t: float, payload: bytes) -> None:
        """Append one record, sealing and rolling the tail when it is
        full."""
        with self._lock:
            if self._writer is None:
                self._writer = self._open_tail()
            w = self._writer
            if w.count >= self.max_records or \
                    (w.count > 0 and w.bytes >= self.max_bytes):
                w.seal()
                self.sealed_total += 1
                self._tail_seq += 1
                w = self._writer = self._open_tail()
            w.append(t, payload)
            self.appended_total += 1

    def seal_tail(self) -> None:
        """Seal the current tail (if it holds any records); mainly for
        tests and deterministic compaction drills."""
        with self._lock:
            if self._writer is None:
                self._writer = self._open_tail()
            if self._writer.count == 0:
                return
            self._writer.seal()
            self.sealed_total += 1
            self._tail_seq += 1
            self._writer = None

    def close(self) -> None:
        """Flush and close the tail writer (the tail stays unsealed — the
        next open resumes it)."""
        with self._lock:
            if self._writer is not None:
                self._writer.close()
                self._writer = None

    # ------------------------------------------------------------- reading
    def replay(self, *, min_seq: int = 0,
               with_seq: bool = False) -> Iterator:
        """Yield records in append order across segments with
        ``seq >= min_seq``: ``(t, payload)`` tuples, or
        ``(seq, t, payload)`` when ``with_seq``."""
        for info in self.segments():
            if info.seq < min_seq:
                continue
            for t, payload in scan_segment(info.path).records:
                yield (info.seq, t, payload) if with_seq else (t, payload)

    # ----------------------------------------------------------- retention
    def prune(self, seqs) -> int:
        """Delete the given sealed segments (and their sidecars); the
        unsealed tail is never deleted.  Returns how many were removed."""
        removed = 0
        with self._lock:
            tail_path = self._writer.path if self._writer else \
                os.path.join(self.root, segment_name(self._tail_seq))
            for seq in sorted(seqs):
                path = os.path.join(self.root, segment_name(seq))
                if path == tail_path or not os.path.exists(path):
                    continue
                os.unlink(path)
                try:
                    os.unlink(index_path(path))
                except FileNotFoundError:
                    pass
                removed += 1
            self.pruned_total += removed
        return removed

    def prune_before(self, t: float, *, keep_records: int = 0,
                     max_seq: Optional[int] = None) -> int:
        """Delete sealed segments whose newest record is older than
        ``t``, keeping enough trailing segments that at least
        ``keep_records`` records survive (the raw-ring refill guarantee).
        With ``max_seq``, only segments at or below that sequence number
        are candidates (the compaction cursor: never drop raw data the
        checkpoint has not folded yet)."""
        infos = self.segments()
        keep_from = len(infos)
        remaining = 0
        while keep_from > 0 and remaining < keep_records:
            keep_from -= 1
            remaining += infos[keep_from].count
        victims = [s.seq for s in infos[:keep_from]
                   if s.sealed and s.t_max is not None and s.t_max < t
                   and (max_seq is None or s.seq <= max_seq)]
        return self.prune(victims) if victims else 0

    # --------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Occupancy + lifetime counters (the ``/stats`` storage rows)."""
        infos = self.segments()
        with self._lock:
            appended = self.appended_total
            pruned = self.pruned_total
            torn = self.torn_dropped
        return {
            "segments": len(infos),
            "sealed": sum(1 for s in infos if s.sealed),
            "records": sum(s.count for s in infos),
            "bytes": sum(s.bytes for s in infos),
            "appended": appended,
            "pruned_segments": pruned,
            "torn_dropped": torn,
        }

    def record_range(self) -> Tuple[Optional[float], Optional[float]]:
        """(oldest, newest) record timestamp across the whole log."""
        infos = [s for s in self.segments() if s.t_min is not None]
        if not infos:
            return None, None
        return (min(s.t_min for s in infos), max(s.t_max for s in infos))

"""Durable sharded history: append-only segment storage for the daemon.

Enable it with ``llload-daemon --data-dir DIR``; without the flag the
daemon keeps today's in-memory-only behavior.  Layout under ``DIR``::

    MANIFEST.json        format versions + creation parameters
    history/             cluster history (HistoryBackend)
    jobs/                per-job shards (JobHistoryBackend)

See DESIGN.md §12 for the segment format and the compaction state
machine; docs/operator-guide.md §7 for retention flags and disk sizing.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

from repro.storage import codec
from repro.storage.backend import (DEFAULT_RETAIN_RAW_S,
                                   DEFAULT_RETAIN_TIER_S, HistoryBackend,
                                   JobHistoryBackend)
from repro.storage.compact import CompactionDriver
from repro.storage.segment import (FORMAT_VERSION, ScanResult, SegmentError,
                                   SegmentIndex, SegmentWriter, frame_record,
                                   iter_records, scan_segment)
from repro.storage.shards import ShardManager, bucket_of, safe_key, unsafe_key
from repro.storage.wal import SegmentInfo, SegmentLog, segment_name

MANIFEST_NAME = "MANIFEST.json"

__all__ = [
    "CompactionDriver", "HistoryBackend", "JobHistoryBackend",
    "ScanResult", "SegmentError", "SegmentIndex", "SegmentInfo",
    "SegmentLog", "SegmentWriter", "ShardManager", "StorageRuntime",
    "bucket_of", "frame_record", "iter_records", "open_storage",
    "safe_key", "scan_segment", "segment_name", "unsafe_key",
]


@dataclasses.dataclass
class StorageRuntime:
    """One opened data directory: both backends plus their compactor."""
    root: str
    history: HistoryBackend
    jobs: JobHistoryBackend
    driver: CompactionDriver

    def start(self) -> None:
        """Start background compaction (after the stores have recovered)."""
        self.driver.start()

    def compact_once(self) -> int:
        return self.driver.run_once()

    def stats(self) -> Dict[str, object]:
        return {"root": self.root, "history": self.history.stats(),
                "jobs": self.jobs.stats(), "compactor": self.driver.stats()}

    def close(self) -> None:
        self.driver.stop()
        self.history.close()
        self.jobs.close()


def open_storage(data_dir: str, *, segment_records: int = 1024,
                 segment_bytes: int = 4 << 20,
                 retain_raw_s: float = DEFAULT_RETAIN_RAW_S,
                 retain_tier_s: float = DEFAULT_RETAIN_TIER_S,
                 compact_interval_s: float = 30.0) -> StorageRuntime:
    """Open (creating if needed) a daemon data directory.

    The compaction driver is returned stopped; call
    :meth:`StorageRuntime.start` once the stores are attached and
    recovered, so the first background pass sees their tier specs.
    """
    os.makedirs(data_dir, exist_ok=True)
    manifest_path = os.path.join(data_dir, MANIFEST_NAME)
    if not os.path.exists(manifest_path):
        tmp = manifest_path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(codec.dumps({
                "segment_format": FORMAT_VERSION,
                "codec_format": codec.CODEC_VERSION,
                "segment_records": segment_records,
                "segment_bytes": segment_bytes,
            }))
        os.replace(tmp, manifest_path)
    history = HistoryBackend(os.path.join(data_dir, "history"),
                             segment_records=segment_records,
                             segment_bytes=segment_bytes,
                             retain_raw_s=retain_raw_s,
                             retain_tier_s=retain_tier_s)
    jobs = JobHistoryBackend(os.path.join(data_dir, "jobs"),
                             segment_records=max(32, segment_records // 4),
                             segment_bytes=max(1 << 16, segment_bytes // 4),
                             retain_raw_s=retain_raw_s,
                             retain_tier_s=retain_tier_s)
    driver = CompactionDriver([history, jobs],
                              interval_s=compact_interval_s)
    return StorageRuntime(root=data_dir, history=history, jobs=jobs,
                          driver=driver)

"""JSON codecs for persisted fold state (DESIGN.md §12).

The storage subsystem persists two kinds of payload:

  * **raw records** — whole snapshots in the daemon's versioned wire
    schema (:mod:`repro.daemon.protocol`) and per-job samples; and
  * **fold state** — finalized tier buckets, open-bucket checkpoints and
    lifetime aggregates, so recovery can *restore* downsampled history
    instead of re-folding a week of raw snapshots.

Everything round-trips exactly: JSON serializes Python floats via
``repr`` so every bit survives, dict insertion order is preserved, and
the per-user flag tuples are rebuilt as tuples on decode.  That is what
makes a restarted daemon's ``/trend`` and ``/weekly`` responses
byte-identical to the pre-restart ones.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional

from repro.daemon.store import (_AGG_FIELDS, _JOB_AGG_FIELDS, Agg, JobPoint,
                                JobSample, TierPoint)

CODEC_VERSION = 1


def dumps(obj: Any) -> bytes:
    """Compact UTF-8 JSON bytes (the segment payload encoding)."""
    return json.dumps(obj, separators=(",", ":")).encode("utf-8")


def loads(data: bytes) -> Any:
    return json.loads(data.decode("utf-8"))


# ----------------------------------------------------------------- aggregates


def agg_to_dict(agg: Agg) -> Dict[str, float]:
    return {"min": agg.min, "mean": agg.mean, "max": agg.max, "n": agg.n}


def agg_from_dict(d: Dict[str, float]) -> Agg:
    return Agg(min=float(d["min"]), mean=float(d["mean"]),
               max=float(d["max"]), n=int(d["n"]))


# ---------------------------------------------------------------- tier points


def tier_point_to_dict(p: TierPoint) -> Dict[str, Any]:
    """A finalized (or open) cluster-tier bucket, losslessly — including
    the Agg sample counts ``to_wire`` omits and the per-user flags."""
    return {
        "t": p.bucket_start,
        "count": p.count,
        "aggs": {f: agg_to_dict(getattr(p, f)) for f in _AGG_FIELDS},
        "users": {u: list(flags) for u, flags in p.user_flags.items()},
    }


def tier_point_from_dict(d: Dict[str, Any]) -> TierPoint:
    p = TierPoint(bucket_start=float(d["t"]), count=int(d["count"]))
    for f in _AGG_FIELDS:
        setattr(p, f, agg_from_dict(d["aggs"][f]))
    p.user_flags = {u: tuple(int(v) for v in flags)
                    for u, flags in d["users"].items()}
    return p


# ----------------------------------------------------------------- job points


def job_point_to_dict(p: JobPoint) -> Dict[str, Any]:
    return {
        "t": p.bucket_start,
        "count": p.count,
        "aggs": {f: agg_to_dict(getattr(p, f)) for f in _JOB_AGG_FIELDS},
    }


def job_point_from_dict(d: Dict[str, Any]) -> JobPoint:
    p = JobPoint(bucket_start=float(d["t"]), count=int(d["count"]))
    for f in _JOB_AGG_FIELDS:
        setattr(p, f, agg_from_dict(d["aggs"][f]))
    return p


# ---------------------------------------------------------------- job samples

_JOB_SAMPLE_FIELDS = ("t", "job_id", "username", "name", "state", "n_nodes",
                      "gpu_duty", "cpu_load", "mem_used_gb", "mem_total_gb",
                      "gpu_mem_used_gb", "gpu_mem_total_gb", "queue_wait_s",
                      "step_time_s")


def job_sample_to_dict(s: JobSample) -> Dict[str, Any]:
    return {f: getattr(s, f) for f in _JOB_SAMPLE_FIELDS}


def job_sample_from_dict(d: Dict[str, Any]) -> JobSample:
    return JobSample(**{f: d[f] for f in _JOB_SAMPLE_FIELDS})


def optional(codec, value) -> Optional[Any]:
    """Apply ``codec`` unless ``value`` is None (checkpoint open buckets
    and last-samples are nullable)."""
    return None if value is None else codec(value)

"""Durable backends for the daemon's history stores (DESIGN.md §12).

Two backends share the same building blocks (segment files, write-ahead
segment logs, shards, checkpoints):

  * :class:`HistoryBackend` — cluster history.  Every appended snapshot
    is written to a raw WAL in the daemon's versioned wire schema
    (:mod:`repro.daemon.protocol`).  Compaction folds *sealed* raw
    segments through a shadow copy of the store's downsampling tiers,
    persisting finalized 15-min/hourly buckets as tier segments, per-user
    weekly-utilization flags into user-keyed shards, and the open-bucket
    state into an atomic ``CHECKPOINT.json``.  Recovery = load the
    checkpoint + tier segments, then replay only the raw records the
    checkpoint does not cover — so a cold start over a week of history
    re-folds minutes of raw data, not the week.

  * :class:`JobHistoryBackend` — per-job history, one shard directory per
    job id.  Samples append to the job's raw log; per-shard compaction
    persists 15-min buckets, lifetime aggregates and the dedup cursor.
    An evicted (or never-loaded) job reloads from its shard on demand,
    which is what keeps resident memory O(active jobs).

Both folds are deterministic and every float survives the JSON round
trip, so a restarted daemon's ``/trend``, ``/weekly`` and ``/job/{id}``
responses are byte-identical to the pre-restart daemon's.
"""
from __future__ import annotations

import collections
import math
import os
import threading
from typing import Dict, List, Optional, Tuple

from repro.daemon import protocol
from repro.daemon.store import (  # noqa: F401 — _Tier/_JobSeries are the
    DEFAULT_TIERS, TierSpec, _JobSeries, _Tier, summarize)
# shared fold engine: the backend persists and restores their state
from repro.storage import codec
from repro.storage.segment import scan_segment
from repro.storage.shards import ShardManager, bucket_of, safe_key
from repro.storage.wal import SegmentLog

CHECKPOINT_NAME = "CHECKPOINT.json"

DEFAULT_RETAIN_RAW_S = 86400.0               # one day of raw snapshots
DEFAULT_RETAIN_TIER_S = 90 * 86400.0         # one quarter of tier buckets


def _write_json_atomic(path: str, obj) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(codec.dumps(obj))
    os.replace(tmp, path)


def _read_json(path: str):
    try:
        with open(path, "rb") as f:
            return codec.loads(f.read())
    except (OSError, ValueError):
        return None


def _tail_record_t(log: SegmentLog) -> Optional[float]:
    """Timestamp of the newest record in ``log`` (None when empty)."""
    infos = log.segments()
    for info in reversed(infos):
        recs = scan_segment(info.path).records
        if recs:
            return recs[-1][0]
    return None


def _load_points(log: SegmentLog, decode, cutoff: Optional[float],
                 limit: int) -> List:
    """Load finalized bucket records from a tier log in append order,
    dropping duplicates (crash-window re-appends are identical, keep the
    first) and anything at/after ``cutoff`` (the checkpoint's open
    bucket — those buckets are rebuilt by replay).  Returns the last
    ``limit`` points."""
    out: List = []
    last = -math.inf
    for t, payload in log.replay():
        if t <= last:
            continue
        if cutoff is not None and t >= cutoff:
            continue
        out.append(decode(codec.loads(payload)))
        last = t
    return out[-limit:] if limit else out


# ---------------------------------------------------------------------------
# Cluster history
# ---------------------------------------------------------------------------


class HistoryBackend:
    """Durable backing for one :class:`~repro.daemon.store.HistoryStore`.

    Layout under ``root``::

        CHECKPOINT.json          compaction cursor + open-bucket state
        raw/seg-*.log[.idx]      snapshot WAL (wire-schema payloads)
        tiers/<name>/seg-*.log   finalized TierPoint records per tier
        users/<xx>/<user>/seg-*  per-user weekly flag series (user-keyed)
    """

    def __init__(self, root: str, *, segment_records: int = 1024,
                 segment_bytes: int = 4 << 20,
                 retain_raw_s: float = DEFAULT_RETAIN_RAW_S,
                 retain_tier_s: float = DEFAULT_RETAIN_TIER_S):
        self.root = root
        self.segment_records = segment_records
        self.segment_bytes = segment_bytes
        self.retain_raw_s = retain_raw_s
        self.retain_tier_s = retain_tier_s
        self.retain_raw_records = 256        # raised by the attached store
        os.makedirs(root, exist_ok=True)
        self.raw_log = SegmentLog(os.path.join(root, "raw"),
                                  max_records=segment_records,
                                  max_bytes=segment_bytes)
        self.users = ShardManager(os.path.join(root, "users"),
                                  max_records=segment_records,
                                  max_bytes=segment_bytes)
        self._tier_specs: Tuple[TierSpec, ...] = \
            tuple(DEFAULT_TIERS)                 # guarded-by: _lock
        self._low: Optional[float] = None        # guarded-by: _lock
        self._tier_logs: Dict[str, SegmentLog] = {}  # guarded-by: _lock
        self._lock = threading.Lock()
        # shadow fold state (lazy; only the compactor needs it)
        self._shadow: Optional[Dict[str, _Tier]] = None  # guarded-by: _lock
        self._shadow_last_t: Optional[float] = None  # guarded-by: _lock
        self._shadow_appended = 0                # guarded-by: _lock
        self._shadow_ooo = 0                     # guarded-by: _lock
        self._through_seq = -1                   # guarded-by: _lock
        self._last_logged: Dict[str, float] = {}  # guarded-by: _lock
        self.compactions = 0                     # guarded-by: _lock
        self.compacted_records = 0               # guarded-by: _lock

    # ---------------------------------------------------------- attachment
    def configure(self, *, tiers, low_threshold: Optional[float],
                  raw_capacity: int) -> None:
        """Adopt the attached store's tier specs / thresholds, so the
        shadow fold and recovery reproduce its state exactly."""
        with self._lock:
            self._tier_specs = tuple(tiers)
            self._low = low_threshold
            self.retain_raw_records = raw_capacity  # the ring-refill floor
            self._shadow = None              # respecified: rebuild lazily

    def _tier_log(self, name: str) -> SegmentLog:  # guarded-by: _lock
        log = self._tier_logs.get(name)
        if log is None:
            log = self._tier_logs[name] = SegmentLog(
                os.path.join(self.root, "tiers", name),
                max_records=self.segment_records,
                max_bytes=self.segment_bytes)
        return log

    # ------------------------------------------------------------- writing
    def append_snapshot(self, snap) -> None:
        """WAL one appended snapshot (called under the store lock, in
        fold order — WAL order IS replay order)."""
        payload = protocol.dumps(protocol.encode_snapshot(snap))
        self.raw_log.append(snap.timestamp, payload)

    # ---------------------------------------------------------- checkpoint
    def _checkpoint_path(self) -> str:
        return os.path.join(self.root, CHECKPOINT_NAME)

    def _write_checkpoint(self) -> None:         # guarded-by: _lock
        tiers = {}
        for spec in self._tier_specs:
            tier = self._shadow[spec.name]
            tiers[spec.name] = {
                "current": codec.optional(codec.tier_point_to_dict,
                                          tier.current),
                "last_t": tier.last_t,
            }
        _write_json_atomic(self._checkpoint_path(), {
            "format": codec.CODEC_VERSION,
            "through_seq": self._through_seq,
            "last_t": self._shadow_last_t,
            "appended": self._shadow_appended,
            "out_of_order": self._shadow_ooo,
            "tiers": tiers,
        })

    def _read_checkpoint(self):
        return _read_json(self._checkpoint_path())

    # ---------------------------------------------------------- compaction
    def _ensure_shadow(self) -> None:            # guarded-by: _lock
        if self._shadow is not None:
            return
        ckpt = self._read_checkpoint()
        self._shadow = {}
        for spec in self._tier_specs:
            tier = _Tier(spec)
            if ckpt is not None:
                st = ckpt["tiers"].get(spec.name)
                if st is not None:
                    tier.current = codec.optional(
                        codec.tier_point_from_dict, st["current"])
                    tier.last_t = st["last_t"]
            self._shadow[spec.name] = tier
            logged = _tail_record_t(self._tier_log(spec.name))
            self._last_logged[spec.name] = \
                logged if logged is not None else -math.inf
        if ckpt is not None:
            self._through_seq = ckpt["through_seq"]
            self._shadow_last_t = ckpt["last_t"]
            self._shadow_appended = ckpt["appended"]
            self._shadow_ooo = ckpt["out_of_order"]

    def _log_point(self, name: str, point) -> None:  # guarded-by: _lock
        if point.bucket_start <= self._last_logged[name]:
            return                           # crash-window re-append
        self._tier_log(name).append(
            point.bucket_start,
            codec.dumps(codec.tier_point_to_dict(point)))
        self._last_logged[name] = point.bucket_start
        if name == self._tier_specs[0].name:
            # the finest tier carries the weekly per-user flags: shard
            # them user-keyed so multi-year windows answer from disk
            for user, flags in point.user_flags.items():
                self.users.log_for(user).append(
                    point.bucket_start, codec.dumps(list(flags)))

    def _shadow_fold(self, snap) -> None:        # guarded-by: _lock
        summary = summarize(snap, self._low)
        if self._shadow_last_t is not None and \
                snap.timestamp == self._shadow_last_t:
            return                           # WAL never holds exact dups
        self._shadow_last_t = snap.timestamp
        self._shadow_appended += 1
        for spec in self._tier_specs:
            tier = self._shadow[spec.name]
            old = tier.current
            if not tier.fold(summary):
                self._shadow_ooo += 1
                continue
            if old is not None and tier.current is not old:
                self._log_point(spec.name, old)

    def compact_once(self) -> int:
        """Fold sealed raw segments beyond the checkpoint into tier +
        user-shard segments, advance the checkpoint, apply retention.
        Returns the number of raw segments compacted."""
        with self._lock:
            self._ensure_shadow()
            done = 0
            for info in self.raw_log.sealed_segments():
                if info.seq <= self._through_seq:
                    continue
                for _, payload in scan_segment(info.path).records:
                    self._shadow_fold(
                        protocol.decode_snapshot(codec.loads(payload)))
                    self.compacted_records += 1
                self._through_seq = info.seq
                done += 1
            if done:
                self._write_checkpoint()
                self.compactions += 1
            self._apply_retention()
            return done

    def _apply_retention(self) -> None:          # guarded-by: _lock
        newest = self.raw_log.record_range()[1]
        if newest is None:
            return
        self.raw_log.prune_before(
            newest - self.retain_raw_s,
            keep_records=self.retain_raw_records,
            max_seq=self._through_seq)
        horizon = newest - self.retain_tier_s
        for spec in self._tier_specs:
            self._tier_log(spec.name).prune_before(horizon)
        for _, log in self.users.iter_logs():
            log.prune_before(horizon)

    # ------------------------------------------------------------ recovery
    def recover_history(self, store) -> Dict[str, int]:
        """Rebuild ``store``'s tiers, raw ring and counters: checkpointed
        state first, then replay of the raw records the checkpoint does
        not cover (older retained records refill only the ring)."""
        ckpt = self._read_checkpoint()
        through = ckpt["through_seq"] if ckpt is not None else -1
        n_points = 0
        # both locks: _tier_log mutates this backend's log table while the
        # store's tiers/ring/counters are rebuilt.  Ordering is safe: the
        # compactor takes self._lock alone, appenders take store._lock
        # alone — nothing acquires them in the opposite order.
        with self._lock, store._lock:
            for tier in store._tiers:
                spec = tier.spec
                st = (ckpt["tiers"].get(spec.name)
                      if ckpt is not None else None)
                current = codec.optional(codec.tier_point_from_dict,
                                         st["current"]) if st else None
                cutoff = current.bucket_start if current is not None \
                    else None
                pts = (_load_points(self._tier_log(spec.name),
                                    codec.tier_point_from_dict, cutoff,
                                    spec.capacity)
                       if ckpt is not None else [])
                tier.points = collections.deque(pts, maxlen=spec.capacity)
                tier.current = current
                tier.last_t = st["last_t"] if st else None
                n_points += len(pts)
            if ckpt is not None:
                store._appended = ckpt["appended"]
                store._out_of_order = ckpt["out_of_order"]
                store._last_t = ckpt["last_t"]
            n_ring = n_replayed = 0
            for seq, t, payload in self.raw_log.replay(with_seq=True):
                snap = protocol.decode_snapshot(codec.loads(payload))
                if seq <= through:
                    store._raw.append(snap)
                    store._last_t = snap.timestamp
                    n_ring += 1
                else:
                    store._absorb(snap, summarize(snap, store._low),
                                  persist=False)
                    n_replayed += 1
        return {"checkpoint": int(ckpt is not None),
                "tier_points": n_points, "ring_refilled": n_ring,
                "replayed": n_replayed}

    # ----------------------------------------------------------- cold reads
    def weekly_flags(self, start: Optional[float], end: Optional[float]
                     ) -> Dict[float, Dict[str, Tuple[int, int, int]]]:
        """Per-bucket per-user utilization flags from the user-keyed
        shards (the disk path behind ``/weekly?start=`` windows older
        than the in-memory tiers)."""
        buckets: Dict[float, Dict[str, Tuple[int, int, int]]] = {}
        for user, log in self.users.iter_logs():
            last = -math.inf
            for info in log.segments():
                if info.t_min is None:
                    continue
                if start is not None and info.t_max < start:
                    continue
                if end is not None and info.t_min > end:
                    continue
                for t, payload in scan_segment(info.path).records:
                    if t <= last:
                        continue             # crash-window duplicate
                    last = t
                    if start is not None and t < start:
                        continue
                    if end is not None and t > end:
                        continue
                    flags = codec.loads(payload)
                    buckets.setdefault(t, {})[user] = \
                        tuple(int(v) for v in flags)
        return buckets

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, object]:
        with self._lock:
            tier_logs = dict(self._tier_logs)
            compactions = self.compactions
            compacted = self.compacted_records
            through = self._through_seq
        return {
            "raw": self.raw_log.stats(),
            "tiers": {name: log.stats() for name, log in tier_logs.items()},
            "users": self.users.stats(),
            "compactions": compactions,
            "compacted_records": compacted,
            "through_seq": through,
        }

    def flush(self) -> None:
        pass                                 # appends flush per record

    def close(self) -> None:
        self.raw_log.close()
        with self._lock:
            tier_logs = list(self._tier_logs.values())
        for log in tier_logs:
            log.close()
        self.users.close()


# ---------------------------------------------------------------------------
# Job history
# ---------------------------------------------------------------------------


class JobHistoryBackend:
    """Durable backing for one :class:`~repro.daemon.store.JobHistoryStore`.

    Layout under ``root`` (one shard directory per job id)::

        <xx>/<job id>/CHECKPOINT.json   per-shard cursor + fold state
        <xx>/<job id>/raw/seg-*         JobSample records
        <xx>/<job id>/points/seg-*      finalized 15-min JobPoint records
    """

    def __init__(self, root: str, *, segment_records: int = 256,
                 segment_bytes: int = 1 << 20,
                 retain_raw_s: float = DEFAULT_RETAIN_RAW_S,
                 retain_tier_s: float = DEFAULT_RETAIN_TIER_S,
                 max_open: int = 64):
        self.root = root
        self.retain_raw_s = retain_raw_s
        self.retain_tier_s = retain_tier_s
        os.makedirs(root, exist_ok=True)
        self.raw = ShardManager(root, subdir="raw", max_open=max_open,
                                max_records=segment_records,
                                max_bytes=segment_bytes)
        self.points = ShardManager(root, subdir="points", max_open=max_open,
                                   max_records=segment_records,
                                   max_bytes=segment_bytes)
        self.bucket_s = 900.0
        self.raw_per_job = 64
        self.buckets_per_job = 4 * 24 * 7
        self._dirty: set = set()                 # guarded-by: _lock
        # first run compacts all shards
        self._scan_pending = True                # guarded-by: _lock
        self._lock = threading.Lock()
        self.compactions = 0                     # guarded-by: _lock
        self.compacted_records = 0               # guarded-by: _lock

    def configure(self, *, bucket_s: float, raw_per_job: int,
                  buckets_per_job: int) -> None:
        """Adopt the attached store's series parameters."""
        self.bucket_s = bucket_s
        self.raw_per_job = raw_per_job
        self.buckets_per_job = buckets_per_job

    # ------------------------------------------------------------- writing
    def append_sample(self, sample) -> None:
        key = str(sample.job_id)
        self.raw.log_for(key).append(
            sample.t, codec.dumps(codec.job_sample_to_dict(sample)))
        with self._lock:
            self._dirty.add(key)

    # ---------------------------------------------------------- checkpoint
    def _checkpoint_path(self, key: str) -> str:
        return os.path.join(self.root, bucket_of(key), safe_key(key),
                            CHECKPOINT_NAME)

    def _write_checkpoint(self, key: str, through: int,
                          series: _JobSeries) -> None:
        _write_json_atomic(self._checkpoint_path(key), {
            "format": codec.CODEC_VERSION,
            "through_seq": through,
            "current": codec.optional(codec.job_point_to_dict,
                                      series.current),
            "lifetime": {f: codec.agg_to_dict(a)
                         for f, a in series.lifetime.items()},
            "last": codec.optional(codec.job_sample_to_dict, series.last),
        })

    def _seed_series(self, ckpt, raw_capacity: int, bucket_s: float,
                     bucket_capacity: int, *, with_points: bool,
                     key: Optional[str] = None) -> Tuple[_JobSeries, int]:
        """A series holding the checkpointed fold state (no raw replay);
        returns (series, through_seq)."""
        series = _JobSeries(raw_capacity, bucket_s, bucket_capacity)
        if ckpt is None:
            return series, -1
        series.current = codec.optional(codec.job_point_from_dict,
                                        ckpt["current"])
        series.lifetime = {f: codec.agg_from_dict(a)
                           for f, a in ckpt["lifetime"].items()}
        series.last = codec.optional(codec.job_sample_from_dict,
                                     ckpt["last"])
        if with_points and key is not None:
            cutoff = series.current.bucket_start \
                if series.current is not None else None
            pts = _load_points(self.points.log_for(key),
                               codec.job_point_from_dict, cutoff,
                               bucket_capacity)
            series.points = collections.deque(pts, maxlen=bucket_capacity)
        return series, ckpt["through_seq"]

    # ------------------------------------------------------------ recovery
    def has_job(self, job_id: int) -> bool:
        return self.raw.has_shard(str(job_id))

    def load_series(self, job_id: int, raw_capacity: int, bucket_s: float,
                    bucket_capacity: int) -> Optional[_JobSeries]:
        """Rebuild one job's series from its shard (checkpointed state +
        raw replay), or ``None`` when the job has no shard."""
        key = str(job_id)
        if not self.raw.has_shard(key):
            return None
        ckpt = _read_json(self._checkpoint_path(key))
        series, through = self._seed_series(
            ckpt, raw_capacity, bucket_s, bucket_capacity,
            with_points=True, key=key)
        n = 0
        for seq, t, payload in self.raw.log_for(key).replay(with_seq=True):
            sample = codec.job_sample_from_dict(codec.loads(payload))
            if seq <= through:
                series.raw.append(sample)    # ring refill only
            else:
                series.fold(sample)
            n += 1
        if ckpt is None and n == 0:
            return None
        return series

    def recover_ids(self) -> List[Tuple[int, float]]:
        """Every job id on disk with its newest sample time, oldest
        first (the LRS insertion order for a recovering store)."""
        out: List[Tuple[int, float]] = []
        for key in self.raw.keys():
            try:
                job_id = int(key)
            except ValueError:
                continue
            t = _tail_record_t(self.raw.log_for(key))
            if t is None:
                ckpt = _read_json(self._checkpoint_path(key))
                if ckpt and ckpt.get("last"):
                    t = ckpt["last"]["t"]
            out.append((job_id, t if t is not None else -math.inf))
        out.sort(key=lambda it: (it[1], it[0]))
        return out

    # ---------------------------------------------------------- compaction
    def compact_once(self) -> int:
        """Per-shard compaction of every shard touched since the last
        run (all shards on the first run after startup)."""
        with self._lock:
            dirty = self._dirty
            self._dirty = set()
            scan = self._scan_pending
            self._scan_pending = False
        if scan:
            dirty = dirty | set(self.raw.keys())
        compacted = 0
        for key in sorted(dirty):
            if self._compact_shard(key):
                compacted += 1
        return compacted

    def _compact_shard(self, key: str) -> bool:
        log = self.raw.log_for(key)
        ckpt = _read_json(self._checkpoint_path(key))
        through = ckpt["through_seq"] if ckpt is not None else -1
        sealed = [s for s in log.sealed_segments() if s.seq > through]
        if not sealed:
            return False
        shadow, _ = self._seed_series(ckpt, 1, self.bucket_s, 1,
                                      with_points=False)
        pts_log = self.points.log_for(key)
        logged = _tail_record_t(pts_log)
        last_logged = logged if logged is not None else -math.inf
        n_records = 0
        for info in sealed:
            for _, payload in scan_segment(info.path).records:
                sample = codec.job_sample_from_dict(codec.loads(payload))
                old = shadow.current
                if shadow.fold(sample) and old is not None and \
                        shadow.current is not old:
                    if old.bucket_start > last_logged:
                        pts_log.append(
                            old.bucket_start,
                            codec.dumps(codec.job_point_to_dict(old)))
                        last_logged = old.bucket_start
                n_records += 1
            through = info.seq
        self._write_checkpoint(key, through, shadow)
        with self._lock:
            self.compacted_records += n_records
            self.compactions += 1
        newest = shadow.last.t if shadow.last is not None else None
        if newest is not None:
            log.prune_before(newest - self.retain_raw_s,
                             keep_records=self.raw_per_job,
                             max_seq=through)
            pts_log.prune_before(newest - self.retain_tier_s)
        return True

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, object]:
        shard_stats = self.raw.stats()
        with self._lock:
            compactions = self.compactions
            compacted = self.compacted_records
        return {
            "shards": shard_stats,
            "points_shards": self.points.stats(),
            "compactions": compactions,
            "compacted_records": compacted,
        }

    def close(self) -> None:
        self.raw.close()
        self.points.close()

"""Key-based sharding: directory-per-shard, bounded open writers.

A :class:`ShardManager` maps an arbitrary string key (a username, a job
id) to its own segment-log directory under a common root.  Keys are
sanitized for the filesystem (anything outside ``[A-Za-z0-9_-]`` is
percent-hex-escaped, so ``..`` can never traverse), and keys are fanned
out under 256 hash buckets (``<xx>/<key>/``) so a million shards never
land in one directory.

Only a bounded number of shards keep an *open* writer at a time (LRU of
open :class:`~repro.storage.wal.SegmentLog` handles): resident state is
O(active keys) while cold shards stay on disk until touched again.
"""
from __future__ import annotations

import collections
import os
import threading
import zlib
from typing import Dict, Iterator, List, Optional

from repro.storage.wal import SegmentLog

_SAFE = frozenset(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_-")


def safe_key(key: str) -> str:
    """A filesystem-safe, collision-free encoding of ``key``
    (percent-hex over the UTF-8 bytes, so any unicode round-trips)."""
    return "".join(chr(b) if chr(b) in _SAFE else f"%{b:02X}"
                   for b in key.encode("utf-8"))


def unsafe_key(name: str) -> str:
    """Invert :func:`safe_key` (tolerant of malformed escapes: they
    decode literally rather than raising on a tampered directory)."""
    out, i = bytearray(), 0
    while i < len(name):
        if name[i] == "%" and i + 3 <= len(name):
            try:
                out.append(int(name[i + 1:i + 3], 16))
                i += 3
                continue
            except ValueError:
                pass
        out.extend(name[i].encode("utf-8"))
        i += 1
    return out.decode("utf-8", errors="replace")


def bucket_of(key: str) -> str:
    """The 2-hex-digit fanout directory for ``key`` (stable hash)."""
    return f"{zlib.crc32(key.encode('utf-8')) & 0xFF:02x}"


class ShardManager:
    """Per-key segment logs under ``root/<bucket>/<safe key>/[sub]``."""

    def __init__(self, root: str, *, subdir: str = "",
                 max_open: int = 64, max_records: int = 1024,
                 max_bytes: int = 4 << 20):
        self.root = root
        self.subdir = subdir
        self.max_open = max(1, max_open)
        self.max_records = max_records
        self.max_bytes = max_bytes
        os.makedirs(root, exist_ok=True)
        # guarded-by: _lock
        self._open: "collections.OrderedDict[str, SegmentLog]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.opened_total = 0                    # guarded-by: _lock
        self.evicted_total = 0                   # guarded-by: _lock

    # ------------------------------------------------------------- mapping
    def dir_for(self, key: str) -> str:
        safe = safe_key(key)
        path = os.path.join(self.root, bucket_of(key), safe)
        return os.path.join(path, self.subdir) if self.subdir else path

    def log_for(self, key: str) -> SegmentLog:
        """The shard's segment log, opening (and LRU-evicting) as
        needed; an evicted log is flushed and closed, never deleted."""
        with self._lock:
            log = self._open.get(key)
            if log is not None:
                self._open.move_to_end(key)
                return log
            log = SegmentLog(self.dir_for(key),
                             max_records=self.max_records,
                             max_bytes=self.max_bytes)
            self._open[key] = log
            self.opened_total += 1
            while len(self._open) > self.max_open:
                _, cold = self._open.popitem(last=False)
                cold.close()
                self.evicted_total += 1
            return log

    def has_shard(self, key: str) -> bool:
        return os.path.isdir(self.dir_for(key))

    def keys(self) -> List[str]:
        """Every shard key present on disk (decoded), sorted."""
        out = []
        try:
            buckets = os.listdir(self.root)
        except FileNotFoundError:
            return []
        for bucket in buckets:
            bdir = os.path.join(self.root, bucket)
            if not os.path.isdir(bdir):
                continue
            for name in os.listdir(bdir):
                if os.path.isdir(os.path.join(bdir, name)):
                    out.append(unsafe_key(name))
        return sorted(out)

    def iter_logs(self) -> Iterator[tuple]:
        """Yield ``(key, SegmentLog)`` for every shard on disk (cold ones
        are opened through the LRU and may evict others)."""
        for key in self.keys():
            yield key, self.log_for(key)

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        with self._lock:
            for log in self._open.values():
                log.close()
            self._open.clear()

    def stats(self) -> Dict[str, float]:
        with self._lock:
            n_open = len(self._open)
            opened = self.opened_total
            evicted = self.evicted_total
        return {"shards": len(self.keys()), "open": n_open,
                "opened": opened, "evicted": evicted}

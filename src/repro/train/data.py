"""Synthetic deterministic data pipeline.

Generates LM token streams with Zipf-ish marginals and local structure
(repeated n-grams) so losses are non-degenerate, fully deterministic in
(seed, step) — restart-safe, which the fault-tolerance tests rely on.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0


class SyntheticLM:
    """Infinite deterministic batch source; batch(step) is random-access."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf-ish unigram distribution (fixed by seed)
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1)
        probs = 1.0 / ranks ** 1.1
        probs /= probs.sum()
        self._logits = jnp.asarray(np.log(probs), jnp.float32)

    def batch(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step)
        k1, k2 = jax.random.split(key)
        B, S = self.cfg.batch_size, self.cfg.seq_len
        tokens = jax.random.categorical(
            k1, jnp.broadcast_to(self._logits, (B, S + 1, self.cfg.vocab_size)))
        # inject copy structure: second half repeats the first half shifted
        half = (S + 1) // 2
        tokens = tokens.at[:, half:2 * half].set(tokens[:, :half])
        tokens = tokens.astype(jnp.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def frontend(self, step: int, cfg_model) -> jnp.ndarray:
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.cfg.seed + 7919), step)
        if cfg_model.frontend == "patch_stub":
            n = cfg_model.frontend_len
        elif cfg_model.frontend == "audio_stub":
            n = cfg_model.encoder.source_len
        else:
            return None
        return jax.random.normal(
            key, (self.cfg.batch_size, n, cfg_model.d_model),
            jnp.dtype(cfg_model.dtype))

"""Training loop with LLload self-reporting, checkpoint/restart, straggler
hooks — the "user job" side of the paper's pipeline.

Every ``monitor_every`` steps the trainer publishes its measured utilization
(achieved model-FLOP/s over peak => the paper's "GPU load" analog, plus HBM
use) into the in-process LLload registry; an optional PeriodicArchiver
captures snapshots on the 15-minute cadence.  The weekly analysis then sees
this job exactly as LLSC sees a user's GPU job.
"""
from __future__ import annotations

import dataclasses
import socket
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.launch.fault import CrashInjector, StragglerDetector
from repro.monitor import publish_step_utilization
from repro.models import model as model_lib
from repro.roofline import hw
from repro.train import checkpoint as ckpt_lib
from repro.train.data import DataConfig, SyntheticLM
from repro.train.train_step import (TrainState, default_opt_cfg,
                                    init_train_state, make_train_step)


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 128
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    async_ckpt: bool = False      # overlap checkpoint I/O with training
    monitor_every: int = 1
    log_every: int = 10
    seed: int = 0
    job_name: str = "train"
    # peak FLOP/s of the *local* device, for the duty-cycle proxy.  On CPU we
    # calibrate a nominal peak so utilization numbers are meaningful.
    peak_flops: float = 5e10


class Trainer:
    def __init__(self, cfg, tcfg: TrainerConfig, *,
                 crash: Optional[CrashInjector] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt_cfg = default_opt_cfg(cfg, total_steps=tcfg.steps)
        self.data = SyntheticLM(DataConfig(cfg.vocab_size, tcfg.seq_len,
                                           tcfg.batch_size, tcfg.seed))
        self.step_fn = jax.jit(make_train_step(cfg, self.opt_cfg),
                               donate_argnums=(0,))
        self.crash = crash
        self.straggler = StragglerDetector()
        self.host = socket.gethostname()
        self.history: list = []
        # model flops per step (6 N D) for the duty-cycle report
        self._flops_per_step = model_lib.model_flops(
            cfg, tcfg.batch_size * tcfg.seq_len, training=True)

    # ------------------------------------------------------------------
    def _init_state(self) -> TrainState:
        return init_train_state(self.cfg, jax.random.PRNGKey(self.tcfg.seed),
                                self.opt_cfg)

    def _batch(self, step: int) -> dict:
        b = self.data.batch(step)
        fe = self.data.frontend(step, self.cfg)
        if fe is not None:
            b["frontend"] = fe
        return b

    # ------------------------------------------------------------------
    def run(self, resume: bool = True) -> dict:
        tc = self.tcfg
        start_step = 0
        state = None
        if tc.ckpt_dir and resume:
            template = jax.eval_shape(self._init_state)
            from repro.launch.fault import resume_latest

            state, start_step = resume_latest(tc.ckpt_dir, template)
        if state is None:
            state = self._init_state()

        params_bytes = sum(np.prod(x.shape) * x.dtype.itemsize
                           for x in jax.tree.leaves(state))
        losses = []
        for step in range(start_step, tc.steps):
            if self.crash is not None:
                self.crash.maybe_crash(step)
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, self._batch(step))
            loss = float(metrics["loss"])  # blocks until step completes
            dt = time.perf_counter() - t0
            losses.append(loss)
            self.straggler.record(self.host, dt)
            self.history.append({"step": step, "loss": loss, "time_s": dt})

            if tc.monitor_every and step % tc.monitor_every == 0:
                publish_step_utilization(
                    tc.job_name,
                    model_flops_per_step=self._flops_per_step,
                    step_time_s=dt, peak_flops=tc.peak_flops,
                    n_devices=jax.device_count(),
                    hbm_used_gb=params_bytes / 1e9,
                    hbm_total_gb=hw.HBM_BYTES * jax.device_count() / 1e9)
            if tc.log_every and step % tc.log_every == 0:
                print(f"[train:{self.cfg.name}] step {step} "
                      f"loss {loss:.4f} ({dt * 1e3:.0f} ms)")
            if tc.ckpt_dir and tc.ckpt_every and \
                    (step + 1) % tc.ckpt_every == 0:
                if tc.async_ckpt:
                    ckpt_lib.save_checkpoint_async(tc.ckpt_dir, step + 1,
                                                   state)
                else:
                    ckpt_lib.save_checkpoint(tc.ckpt_dir, step + 1, state)
        if tc.ckpt_dir:
            ckpt_lib.wait_pending_checkpoints()
            ckpt_lib.save_checkpoint(tc.ckpt_dir, tc.steps, state)
        return {"final_loss": losses[-1] if losses else float("nan"),
                "losses": losses, "start_step": start_step,
                "state": state}

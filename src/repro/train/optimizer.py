"""AdamW with global-norm clipping and schedules, pure JAX.

Master params stay fp32; moments use ``cfg.opt_dtype`` (bf16 for the 398B
jamba so optimizer state fits pod HBM; fp32 elsewhere).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def init_opt_state(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(F32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _decay_mask(path) -> bool:
    """No weight decay on norms, biases, scalars."""
    name = str(path[-1]) if path else ""
    return not any(s in name for s in ("scale", "norm", "bias", "A_log",
                                       "dt_bias", "'D'"))


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(F32)
    bc2 = 1 - cfg.b2 ** step.astype(F32)
    dt = jnp.dtype(cfg.moment_dtype)

    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    decay_flags = {jax.tree_util.keystr(path): _decay_mask(path)
                   for path, _ in flat_p}

    def upd(path, p, g, m, v):
        g = g.astype(F32) * scale
        m2 = cfg.b1 * m.astype(F32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(F32) + (1 - cfg.b2) * jnp.square(g)
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        if decay_flags.get(jax.tree_util.keystr(path), True):
            update = update + cfg.weight_decay * p.astype(F32)
        p2 = p.astype(F32) - lr * update
        return p2.astype(p.dtype), m2.astype(dt), v2.astype(dt)

    out = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state.m, state.v)
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics

from repro.train.checkpoint import (latest_step, list_checkpoints,
                                    restore_checkpoint, save_checkpoint)
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import AdamWConfig, AdamWState, adamw_update, init_opt_state
from repro.train.train_step import (TrainState, default_opt_cfg,
                                    init_train_state, init_train_state_shape,
                                    make_train_step)
from repro.train.trainer import Trainer, TrainerConfig

__all__ = [
    "latest_step", "list_checkpoints", "restore_checkpoint",
    "save_checkpoint", "DataConfig", "SyntheticLM", "AdamWConfig",
    "AdamWState", "adamw_update", "init_opt_state", "TrainState",
    "default_opt_cfg", "init_train_state", "init_train_state_shape",
    "make_train_step", "Trainer", "TrainerConfig",
]

"""Checkpoint save/restore: atomic, mesh-independent, retention-managed.

Arrays are stored *unsharded* with logical tree paths as npz keys, so a
checkpoint written on one mesh restores onto any other (elastic re-scaling:
the loader re-shards on load).  Writes are atomic (tmp + rename) so a
preempted node never leaves a torn checkpoint — the restart path picks the
latest complete step.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":
            # npz has no bf16 codec; bf16 -> f32 is lossless and the loader
            # casts back to the template dtype.
            arr = arr.astype(np.float32)
        out[jax.tree_util.keystr(path)] = arr
    return out


_async_state = {"thread": None}


def save_checkpoint_async(ckpt_dir: str, step: int, state: Any,
                          extra: Optional[dict] = None, keep: int = 3):
    """Non-blocking checkpoint: the write happens on a background thread so
    the train loop overlaps I/O with the next step (jax arrays are
    immutable, so reading them off-thread is safe).  At most one write is
    in flight; a new save joins the previous one first."""
    import threading

    wait_pending_checkpoints()
    t = threading.Thread(target=save_checkpoint,
                         args=(ckpt_dir, step, state, extra, keep),
                         daemon=True)
    _async_state["thread"] = t
    t.start()
    return t


def wait_pending_checkpoints():
    t = _async_state.get("thread")
    if t is not None and t.is_alive():
        t.join()
    _async_state["thread"] = None


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    extra: Optional[dict] = None, keep: int = 3):
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-step-{step}")
    final = os.path.join(ckpt_dir, f"step-{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(state))
    meta = {"step": step, **(extra or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _apply_retention(ckpt_dir, keep)


def _apply_retention(ckpt_dir: str, keep: int):
    steps = sorted(list_checkpoints(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step-{s:09d}"),
                      ignore_errors=True)


def list_checkpoints(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step-") and not name.startswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
                out.append(int(name.split("-")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, state_template: Any,
                       shardings=None):
    """Restore into the structure of ``state_template``; optionally re-shard
    with a matching tree of NamedShardings (elastic re-meshing)."""
    path = os.path.join(ckpt_dir, f"step-{step:09d}")
    with np.load(os.path.join(path, "arrays.npz")) as zf:
        arrays = {k: zf[k] for k in zf.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)

    flat_t = jax.tree_util.tree_flatten_with_path(state_template)
    leaves = []
    for kpath, leaf in flat_t[0]:
        key = jax.tree_util.keystr(kpath)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    state = jax.tree_util.tree_unflatten(flat_t[1], leaves)
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state, meta

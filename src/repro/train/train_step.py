"""Train step: loss -> grad -> AdamW, with bf16 compute / fp32 master params."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import lm_loss
from repro.train.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                   init_opt_state)


class TrainState(NamedTuple):
    params: dict          # fp32 master
    opt: AdamWState


def init_train_state(cfg, key, opt_cfg: AdamWConfig) -> TrainState:
    from repro.models import init_params

    params = init_params(cfg, key, dtype=jnp.float32)
    return TrainState(params, init_opt_state(params, opt_cfg))


def init_train_state_shape(cfg, opt_cfg: AdamWConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_train_state(cfg, k, opt_cfg), key)


def cast_params(params, dtype):
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda p: p.astype(dt) if p.dtype == jnp.float32 and p.ndim > 1 else p,
        params)


def make_train_step(cfg, opt_cfg: AdamWConfig, *, banded: bool = False,
                    aux_weights=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``aux_weights=(lb, z)`` enables the MoE load-balance / router-z
    auxiliary losses (ST-MoE defaults: (0.01, 1e-3))."""

    def train_step(state: TrainState, batch: dict):
        def loss_fn(params):
            p = cast_params(params, cfg.dtype)
            return lm_loss(p, cfg, batch["tokens"], batch["labels"],
                           batch.get("frontend"), banded=banded,
                           aux_weights=aux_weights)

        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        new_params, new_opt, metrics = adamw_update(
            state.params, grads, state.opt, opt_cfg)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt), metrics

    return train_step


def default_opt_cfg(cfg, total_steps: int = 10_000) -> AdamWConfig:
    return AdamWConfig(moment_dtype=cfg.opt_dtype, total_steps=total_steps)

"""repro.experiments — declarative §V-B overloading campaigns (DESIGN.md §9).

A typed :class:`Scenario` + :class:`Campaign` sweep grid (NPPN ladder ×
workload mix × fleet size, plus closed-loop controller cells) runs the
paper's GPU-overloading experiment end to end: the
:class:`CampaignRunner` drives a fresh cluster sim through the
TelemetryBus, streams snapshots to the insight engine, closes the
diagnose→act loop via ``OverloadController.consume`` + scheduler
resubmission, and folds the window into one ``experiments``-table row
per cell — queryable through every §7 surface (CLI ``--experiment``,
``GET /experiments``, any renderer).
"""
from repro.experiments.library import (JOB_RULE_CAMPAIGNS,
                                       fairness_campaign,
                                       fragmentation_campaign,
                                       job_rule_campaign,
                                       starvation_campaign)
from repro.experiments.runner import (CampaignResult, CampaignRunner,
                                      CellResult, arrival_times,
                                      render_result, run_campaign,
                                      run_cell)
from repro.experiments.spec import (ARRIVAL_PATTERNS, MIXES, Campaign,
                                    CampaignError, Cell, MixJob, Scenario,
                                    campaign_from_dict, load_campaign,
                                    loads_toml, mix_names)

__all__ = [
    "ARRIVAL_PATTERNS", "Campaign", "CampaignError", "CampaignResult",
    "CampaignRunner", "Cell", "CellResult", "JOB_RULE_CAMPAIGNS", "MIXES",
    "MixJob", "Scenario", "arrival_times", "campaign_from_dict",
    "fairness_campaign", "fragmentation_campaign", "job_rule_campaign",
    "load_campaign", "loads_toml", "mix_names", "render_result",
    "run_campaign", "run_cell", "starvation_campaign",
]

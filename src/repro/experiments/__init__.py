"""repro.experiments — declarative §V-B overloading campaigns (DESIGN.md §9).

A typed :class:`Scenario` + :class:`Campaign` sweep grid (NPPN ladder ×
workload mix × fleet size, plus closed-loop controller cells) runs the
paper's GPU-overloading experiment end to end: the
:class:`CampaignRunner` drives a fresh cluster sim through the
TelemetryBus, streams snapshots to the insight engine, closes the
diagnose→act loop via ``OverloadController.consume`` + scheduler
resubmission, and folds the window into one ``experiments``-table row
per cell — queryable through every §7 surface (CLI ``--experiment``,
``GET /experiments``, any renderer).
"""
from repro.experiments.runner import (CampaignResult, CampaignRunner,
                                      CellResult, render_result, run_campaign,
                                      run_cell)
from repro.experiments.spec import (MIXES, Campaign, CampaignError, Cell,
                                    MixJob, Scenario, campaign_from_dict,
                                    load_campaign, loads_toml, mix_names)

__all__ = [
    "Campaign", "CampaignError", "CampaignResult", "CampaignRunner",
    "Cell", "CellResult", "MIXES", "MixJob", "Scenario",
    "campaign_from_dict", "load_campaign", "loads_toml", "mix_names",
    "render_result", "run_campaign", "run_cell",
]

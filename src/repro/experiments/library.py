"""Predefined campaigns — the job-level rule scenarios (DESIGN.md §11).

Each of the three job-level insight rules ships with a trace-driven
campaign that demonstrates it closed-loop: the ``fixed`` ``nppn1`` cell
shows the pathology (the rule fires, throughput suffers), and the
``controller`` cell shows the remediation the insight actuates:

  * ``queue_starvation``       — a diurnal rush of NPPN=1 jobs that
    need the whole fleet each; the closed loop steps the NPPN ladder so
    submissions fit the free capacity and the backlog drains.
  * ``fleet_fragmentation``    — bursts of tiny *exclusive* jobs, each
    pinning a whole node at ~10% core usage; the closed loop
    consolidates them onto shared nodes, freeing the fleet for the
    next burst.
  * ``multi_tenant_fairness``  — one tenant fills the fleet before the
    others arrive; the closed loop applies an
    :class:`~repro.launch.fault.ElasticResizePlan` (shrink + resubmit)
    so waiting tenants can start.

``job_rule_campaign(kind)`` returns the campaign for one rule kind;
:data:`JOB_RULE_CAMPAIGNS` maps every kind to its factory.  The
campaigns are plain :class:`~repro.experiments.spec.Campaign` values —
they run through the same runner, query table, CLI, and daemon
endpoint as any TOML-loaded sweep (``examples/job_rules_campaign.toml``
is the starvation one in file form).
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.experiments.spec import Campaign, Scenario


def starvation_campaign() -> Campaign:
    """``queue_starvation``: diurnal arrivals of fleet-sized jobs.

    At NPPN=1 every job wants 8 GPUs (= the whole 4-node fleet), so the
    two diurnal rushes pile up a pending queue whose oldest job waits
    far past the starvation threshold.  The controller cell steps the
    ladder (starvation *and* the low-duty diagnosis both push it), jobs
    shrink to a fraction of the fleet, and the queue drains.
    """
    return Campaign(
        name="queue-starvation",
        scenario=Scenario(arrival_pattern="diurnal", duration_s=10800.0,
                          dt_s=300.0, n_jobs=12, tasks_per_job=8,
                          arrival_s=300.0, task_duration_s=1800.0),
        mixes=("starved",), nppn=(1,), fleets=(4,),
        controller=True).validate()


def fragmentation_campaign() -> Campaign:
    """``fleet_fragmentation``: bursts of tiny exclusive jobs.

    Each burst of 8 one-task exclusive jobs pins all 8 nodes at 4/40
    busy cores, so the next burst queues behind idle capacity.  The
    controller cell consolidates (drops ``exclusive`` and resubmits);
    the batch then shares a couple of nodes and the fleet is free for
    the following burst.
    """
    return Campaign(
        name="fleet-fragmentation",
        scenario=Scenario(arrival_pattern="bursty", duration_s=10800.0,
                          dt_s=300.0, n_jobs=16, tasks_per_job=1,
                          arrival_s=300.0, task_duration_s=7200.0),
        mixes=("fragmented",), nppn=(1,), fleets=(8,),
        controller=True).validate()


def fairness_campaign() -> Campaign:
    """``multi_tenant_fairness``: one tenant front-runs the fleet.

    The ``hog00`` stream submits everything at the start and occupies
    8 of 10 nodes; ``ten01`` arrives a third into the window and can
    only wait.  The controller cell shrinks the dominant tenant's jobs
    (elastic resize), the waiting tenant dispatches ahead of the
    resubmissions, and both finish inside the window.
    """
    return Campaign(
        name="multi-tenant-fairness",
        scenario=Scenario(arrival_pattern="elastic", duration_s=14400.0,
                          dt_s=300.0, n_jobs=4, tasks_per_job=8,
                          arrival_s=300.0, task_duration_s=7200.0),
        mixes=("tenants",), nppn=(1,), fleets=(10,),
        controller=True).validate()


#: rule kind -> campaign factory, for every job-level rule.
JOB_RULE_CAMPAIGNS: Dict[str, Callable[[], Campaign]] = {
    "queue_starvation": starvation_campaign,
    "fleet_fragmentation": fragmentation_campaign,
    "multi_tenant_fairness": fairness_campaign,
}


def job_rule_campaign(kind: str) -> Campaign:
    """The demonstration campaign for one job-level rule kind.

    Raises:
        KeyError: for kinds without a campaign (the message lists the
            valid ones).
    """
    try:
        return JOB_RULE_CAMPAIGNS[kind]()
    except KeyError:
        raise KeyError(f"no campaign for rule kind {kind!r}; available: "
                       + ", ".join(sorted(JOB_RULE_CAMPAIGNS))) from None

"""Declarative experiment campaigns — the §V-B overloading sweep, typed.

A :class:`Scenario` describes one simulated experiment: the node fleet,
a named workload mix (factories from :mod:`repro.cluster.workloads`),
the arrival pattern, the window, and the seed.  A :class:`Campaign`
sweeps a grid of cells over that scenario — the NPPN ladder × workload
mix × fleet size, plus an optional ``controller`` cell per (mix, fleet)
where the closed loop (InsightEngine → OverloadController → scheduler
resubmission) picks the level live instead of a fixed NPPN.

Campaigns load from a TOML file (``load_campaign``) or a plain dict
(``campaign_from_dict``); :meth:`Campaign.spec_json` is the canonical
JSON form the CLI forwards to a daemon's ``GET /experiments`` so remote
runs are byte-identical to local ones.

Only a small, fully documented TOML subset is parsed (this repo is
dependency-free and the interpreter predates :mod:`tomllib`):
``[section]`` headers and ``key = value`` lines where a value is a
double-quoted string (no escapes), an integer, a float, ``true`` /
``false``, or a one-line array of those scalars.  ``#`` comments are
allowed anywhere outside a string.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Dict, List, Optional, Sequence, Tuple


class CampaignError(ValueError):
    """A campaign file / spec is malformed (bad TOML, unknown mix, ...)."""


# Upper bounds a validated campaign may not exceed.  Campaign specs are
# client-controlled input to the daemon's GET /experiments: without
# ceilings, one request (duration_s=1e12, or fleets=[10**6]) would pin a
# request thread's CPU/memory indefinitely.  The caps are far above any
# sensible experiment (the reference campaign uses 36 steps, 8 nodes,
# 16 cells) yet keep the worst accepted spec bounded.
MAX_STEPS_PER_CELL = 10_000          # duration_s / dt_s
# LLSC-scale ceiling: the columnar FleetState (DESIGN.md §10) keeps a
# 100k-node cell tractable, so the cap is now sized to the largest
# published reference system rather than to the object engine's limits.
MAX_FLEET_NODES = 131_072            # n_cpu + n_gpu per cell
MAX_JOBS = 10_000                    # n_jobs per cell
MAX_TASKS_PER_JOB = 1_024
MAX_NPPN = 64
MAX_CELLS = 256                      # grid size


# ------------------------------------------------------------- workload mixes


@dataclasses.dataclass(frozen=True)
class MixJob:
    """One arrival stream inside a workload mix.

    ``factory`` names a job factory in :mod:`repro.cluster.workloads`
    (called as ``factory(username, tasks=N)``); ``overloadable`` marks
    the stream whose ``tasks_per_gpu`` the sweep / controller drives —
    high-duty streams keep their own NPPN (overloading a saturated job
    is exactly what the paper warns against).
    """
    factory: str
    username: str
    overloadable: bool = False


#: Named workload mixes a scenario can reference.  Arrivals round-robin
#: over the mix's streams in order.
MIXES: Dict[str, Tuple[MixJob, ...]] = {
    # Fig 7's remediation target: low GPU duty (0.35), tiny GPU memory.
    "low_duty": (MixJob("overloaded_gpu_job", "exp00", overloadable=True),),
    # Low-duty stream interleaved with a well-utilized training stream
    # (whole-node policy keeps the two users on disjoint nodes).
    "mixed": (MixJob("overloaded_gpu_job", "exp00", overloadable=True),
              MixJob("ml_training_job", "exp01")),
    # Control: high-duty training only — overloading has nothing to win.
    "high_duty": (MixJob("ml_training_job", "exp01"),),
    # Job-level rule scenarios (DESIGN.md §11) — paired with a
    # non-uniform arrival_pattern so the matching diagnosis fires:
    # a diurnal rush of low-NPPN jobs backs the queue up
    # (queue_starvation; the closed loop raises NPPN so jobs fit),
    "starved": (MixJob("overloaded_gpu_job", "exp10", overloadable=True),),
    # bursts of tiny exclusive jobs pin whole nodes at idle cores
    # (fleet_fragmentation; the closed loop consolidates them),
    "fragmented": (MixJob("fragmented_job", "exp20"),),
    # one tenant fills the fleet before others arrive
    # (multi_tenant_fairness; the closed loop elastically shrinks it).
    "tenants": (MixJob("ml_training_job", "hog00"),
                MixJob("ml_training_job", "ten01")),
}


def mix_names() -> List[str]:
    """Names of the registered workload mixes, sorted."""
    return sorted(MIXES)


# ------------------------------------------------------------------ scenario


#: Supported arrival traces.  ``uniform`` is the classic one-every-
#: ``arrival_s`` stream; the others warp the same job count into the
#: pathological shapes the job-level rules diagnose (DESIGN.md §11).
ARRIVAL_PATTERNS = ("uniform", "diurnal", "bursty", "elastic")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One experiment setup: fleet, workload, arrivals, window, seed.

    ``n_jobs`` jobs of ``tasks_per_job`` tasks arrive one every
    ``arrival_s`` seconds starting at t=0, each task running
    ``task_duration_s``; the sim advances in ``dt_s`` steps for
    ``duration_s`` seconds, snapshotting (through the TelemetryBus)
    once per step.  ``arrival_pattern`` warps the arrival times
    (see :data:`ARRIVAL_PATTERNS` and
    :func:`repro.experiments.runner.arrival_times`); non-uniform
    patterns also surface pending jobs in snapshots so queue-level
    rules can observe the backlog.
    """
    mix: str = "low_duty"
    n_cpu: int = 0                  # CPU-only nodes in the fleet
    n_gpu: int = 8                  # GPU nodes (2 devices each)
    duration_s: float = 10800.0     # simulated window
    dt_s: float = 300.0             # sim step == snapshot cadence
    n_jobs: int = 24
    tasks_per_job: int = 8
    arrival_s: float = 300.0        # one job arrives every arrival_s
    arrival_pattern: str = "uniform"
    task_duration_s: float = 1800.0
    seed: int = 0

    def validate(self) -> "Scenario":
        """Check field ranges and the mix name; returns self.

        Raises:
            CampaignError: on any out-of-range field or unknown mix.
        """
        if self.mix not in MIXES:
            raise CampaignError(f"unknown workload mix {self.mix!r}; "
                                "valid mixes: " + ", ".join(mix_names()))
        if self.arrival_pattern not in ARRIVAL_PATTERNS:
            raise CampaignError(
                f"unknown arrival_pattern {self.arrival_pattern!r}; "
                "valid patterns: " + ", ".join(ARRIVAL_PATTERNS))
        for field in ("duration_s", "dt_s", "arrival_s", "task_duration_s"):
            if getattr(self, field) <= 0:
                raise CampaignError(f"scenario.{field} must be > 0, got "
                                    f"{getattr(self, field)}")
        for field in ("n_gpu", "n_jobs", "tasks_per_job"):
            if getattr(self, field) < 1:
                raise CampaignError(f"scenario.{field} must be >= 1, got "
                                    f"{getattr(self, field)}")
        if self.n_cpu < 0:
            raise CampaignError(f"scenario.n_cpu must be >= 0, got "
                                f"{self.n_cpu}")
        if self.dt_s > self.duration_s:
            raise CampaignError("scenario.dt_s exceeds duration_s: the "
                                "window would contain no snapshots")
        if self.duration_s / self.dt_s > MAX_STEPS_PER_CELL:
            raise CampaignError(
                f"scenario window is {self.duration_s / self.dt_s:.0f} "
                f"steps; the cap is {MAX_STEPS_PER_CELL} (raise dt_s or "
                "shrink duration_s)")
        if self.n_cpu + self.n_gpu > MAX_FLEET_NODES:
            raise CampaignError(
                f"fleet of {self.n_cpu + self.n_gpu} nodes exceeds the "
                f"{MAX_FLEET_NODES}-node cap")
        if self.n_jobs > MAX_JOBS:
            raise CampaignError(
                f"scenario.n_jobs {self.n_jobs} exceeds the cap "
                f"{MAX_JOBS}")
        if self.tasks_per_job > MAX_TASKS_PER_JOB:
            raise CampaignError(
                f"scenario.tasks_per_job {self.tasks_per_job} exceeds "
                f"the cap {MAX_TASKS_PER_JOB}")
        return self


@dataclasses.dataclass(frozen=True)
class Cell:
    """One point of the sweep grid.

    ``mode`` is ``fixed`` (every overloadable arrival uses ``nppn``
    tasks-per-GPU for the whole window) or ``controller`` (arrivals
    start at NPPN=1 and the closed loop steps the level from live
    insights).  ``name`` is ``mix/<fleet>g/nppn<N>`` or
    ``mix/<fleet>g/controller``.
    """
    name: str
    scenario: Scenario
    mode: str = "fixed"             # "fixed" | "controller"
    nppn: int = 1                   # fixed level (controller starts at 1)


# ------------------------------------------------------------------ campaign


@dataclasses.dataclass(frozen=True)
class Campaign:
    """A sweep grid over one scenario: NPPN ladder × mixes × fleets.

    ``controller=True`` adds one closed-loop cell per (mix, fleet) next
    to the fixed-NPPN ladder — the fixed ``nppn=1`` cell is the speedup
    baseline the results table reports against.
    """
    name: str = "campaign"
    scenario: Scenario = Scenario()
    mixes: Tuple[str, ...] = ("low_duty",)
    nppn: Tuple[int, ...] = (1, 2, 4)
    fleets: Tuple[int, ...] = (8,)
    controller: bool = True
    seed: int = 0

    def validate(self) -> "Campaign":
        """Check the sweep axes and every cell's scenario; returns self.

        Raises:
            CampaignError: on empty axes, bad ladder values, or any
                scenario validation failure.
        """
        if not self.name:
            raise CampaignError("campaign.name must be non-empty")
        if not self.mixes:
            raise CampaignError("sweep.mixes must name >= 1 mix")
        if not self.nppn and not self.controller:
            raise CampaignError("sweep needs an nppn ladder and/or "
                                "controller = true")
        for n in self.nppn:
            if not 1 <= n <= MAX_NPPN:
                raise CampaignError(f"sweep.nppn values must be in "
                                    f"1..{MAX_NPPN}, got {n}")
        if not self.fleets:
            raise CampaignError("sweep.fleets must name >= 1 fleet size")
        cells = self.cells()
        if len(cells) > MAX_CELLS:
            raise CampaignError(
                f"sweep grid has {len(cells)} cells; the cap is "
                f"{MAX_CELLS} (select fewer mixes/fleets/nppn levels)")
        for cell in cells:
            cell.scenario.validate()
        return self

    # -------------------------------------------------------------- grid
    def cells(self) -> List[Cell]:
        """Materialize the grid, in deterministic sweep order: for each
        mix, for each fleet, the fixed ladder then the controller cell."""
        out: List[Cell] = []
        for mix in self.mixes:
            for fleet in self.fleets:
                sc = dataclasses.replace(self.scenario, mix=mix,
                                         n_gpu=fleet, seed=self.seed)
                for n in self.nppn:
                    out.append(Cell(f"{mix}/{fleet}g/nppn{n}", sc,
                                    mode="fixed", nppn=n))
                if self.controller:
                    out.append(Cell(f"{mix}/{fleet}g/controller", sc,
                                    mode="controller", nppn=1))
        return out

    def select_cells(self, patterns: Optional[str]) -> List[Cell]:
        """Cells matching a comma-separated glob list (``--cells``).

        Args:
            patterns: e.g. ``"low_duty/*,mixed/8g/controller"``;
                ``None``/empty selects every cell.

        Returns:
            Matching cells in grid order.

        Raises:
            CampaignError: when a pattern matches no cell (the message
                lists the valid cell names).
        """
        cells = self.cells()
        if not patterns or not patterns.strip():
            return cells
        globs = [p.strip() for p in patterns.split(",") if p.strip()]
        selected: List[Cell] = []
        for g in globs:
            hits = [c for c in cells if fnmatch.fnmatchcase(c.name, g)]
            if not hits:
                raise CampaignError(
                    f"--cells pattern {g!r} matches no cell; cells: "
                    + ", ".join(c.name for c in cells))
            for c in hits:
                if c not in selected:
                    selected.append(c)
        selected.sort(key=lambda c: cells.index(c))
        return selected

    # ------------------------------------------------------------- codec
    def to_dict(self) -> dict:
        """The campaign as the same three-section dict the TOML file
        uses (``campaign`` / ``scenario`` / ``sweep``)."""
        sc = dataclasses.asdict(self.scenario)
        sc.pop("mix")               # swept axes live in [sweep]
        sc.pop("n_gpu")
        sc.pop("seed")
        return {
            "campaign": {"name": self.name, "seed": self.seed},
            "scenario": sc,
            "sweep": {"mixes": list(self.mixes), "nppn": list(self.nppn),
                      "fleets": list(self.fleets),
                      "controller": self.controller},
        }

    def spec_json(self) -> str:
        """Canonical JSON of :meth:`to_dict` — sorted keys, no spaces —
        the wire form ``--source remote`` forwards to /experiments."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))


def campaign_from_dict(data: dict) -> Campaign:
    """Build and validate a :class:`Campaign` from the three-section
    dict form (the TOML file's shape, or a decoded :meth:`spec_json`).

    Raises:
        CampaignError: on unknown sections/keys, wrong value types, or
            any validation failure.
    """
    if not isinstance(data, dict):
        raise CampaignError(f"campaign spec must be a table, got "
                            f"{type(data).__name__}")
    unknown = set(data) - {"campaign", "scenario", "sweep"}
    if unknown:
        raise CampaignError("unknown campaign section(s): "
                            + ", ".join(sorted(map(str, unknown)))
                            + " (valid: campaign, scenario, sweep)")

    def section(name: str) -> dict:
        sec = data.get(name, {})
        if not isinstance(sec, dict):
            raise CampaignError(f"[{name}] must be a table")
        return dict(sec)

    def take(sec: dict, secname: str, key: str, kind, default):
        if key not in sec:
            return default
        v = sec.pop(key)
        if kind is float and isinstance(v, int) \
                and not isinstance(v, bool):
            v = float(v)
        if kind is not None and (not isinstance(v, kind)
                                 or isinstance(v, bool) is not
                                 (kind is bool)):
            raise CampaignError(
                f"{secname}.{key} must be {kind.__name__}, got {v!r}")
        return v

    camp = section("campaign")
    name = take(camp, "campaign", "name", str, "campaign")
    seed = take(camp, "campaign", "seed", int, 0)
    if camp:
        raise CampaignError("unknown campaign key(s): "
                            + ", ".join(sorted(camp)))

    scen = section("scenario")
    fields = {}
    for f in dataclasses.fields(Scenario):
        if f.name in ("mix", "n_gpu", "seed"):
            scen.pop(f.name, None)   # swept axes are [sweep]'s business
            continue
        kind = (str if f.type == "str"
                else float if f.type == "float" else int)
        fields[f.name] = take(scen, "scenario", f.name, kind,
                              f.default)
    if scen:
        raise CampaignError("unknown scenario key(s): "
                            + ", ".join(sorted(scen)) + " (valid: "
                            + ", ".join(f.name for f in
                                        dataclasses.fields(Scenario)
                                        if f.name not in
                                        ("mix", "n_gpu", "seed")) + ")")

    sweep = section("sweep")
    mixes = take(sweep, "sweep", "mixes", list, ["low_duty"])
    nppn = take(sweep, "sweep", "nppn", list, [1, 2, 4])
    fleets = take(sweep, "sweep", "fleets", list, [8])
    controller = take(sweep, "sweep", "controller", bool, True)
    if sweep:
        raise CampaignError("unknown sweep key(s): "
                            + ", ".join(sorted(sweep))
                            + " (valid: mixes, nppn, fleets, controller)")
    for label, vals, kind in (("mixes", mixes, str), ("nppn", nppn, int),
                              ("fleets", fleets, int)):
        for v in vals:
            if not isinstance(v, kind) or isinstance(v, bool):
                raise CampaignError(f"sweep.{label} entries must be "
                                    f"{kind.__name__}, got {v!r}")

    return Campaign(name=name, scenario=Scenario(**fields),
                    mixes=tuple(mixes), nppn=tuple(nppn),
                    fleets=tuple(fleets), controller=controller,
                    seed=seed).validate()


def load_campaign(path: str) -> Campaign:
    """Load and validate a campaign from a TOML file.

    Args:
        path: the campaign file (see module docstring for the supported
            TOML subset; ``examples/overload_campaign.toml`` is the
            reference).

    Returns:
        The validated :class:`Campaign`.

    Raises:
        CampaignError: on parse or validation failure.
        OSError: when the file cannot be read.
    """
    with open(path) as f:
        return campaign_from_dict(loads_toml(f.read()))


# --------------------------------------------------------------- TOML subset


def _strip_comment(line: str) -> str:
    out = []
    in_str = False
    for ch in line:
        if ch == '"':
            in_str = not in_str
        if ch == "#" and not in_str:
            break
        out.append(ch)
    return "".join(out)


def _parse_scalar(s: str, lineno: int):
    if s.startswith('"') and s.endswith('"') and len(s) >= 2:
        body = s[1:-1]
        if '"' in body or "\\" in body:
            raise CampaignError(
                f"TOML line {lineno}: escapes are outside the supported "
                f"subset: {s!r}")
        return body
    if s == "true":
        return True
    if s == "false":
        return False
    try:
        return int(s)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError:
        pass
    raise CampaignError(
        f"TOML line {lineno}: cannot parse value {s!r} (supported: "
        '"string", integer, float, true/false, [array of those])')


def _parse_value(s: str, lineno: int):
    if s.startswith("[") and s.endswith("]"):
        body = s[1:-1].strip()
        if not body:
            return []
        return [_parse_scalar(p.strip(), lineno)
                for p in body.split(",") if p.strip()]
    return _parse_scalar(s, lineno)


def loads_toml(text: str) -> dict:
    """Parse the documented TOML subset into nested dicts.

    Args:
        text: TOML source (``[section]`` + ``key = value`` lines).

    Returns:
        ``{section: {key: value}}`` plus any top-level keys.

    Raises:
        CampaignError: on any line outside the subset.
    """
    root: Dict[str, object] = {}
    section: Optional[Dict[str, object]] = None
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = _strip_comment(raw).strip()
        if not line:
            continue
        if line.startswith("["):
            if not line.endswith("]"):
                raise CampaignError(
                    f"TOML line {lineno}: malformed section {raw!r}")
            name = line[1:-1].strip()
            if not name or "[" in name or "]" in name or "." in name:
                raise CampaignError(
                    f"TOML line {lineno}: section names must be plain "
                    f"(no nesting), got {raw!r}")
            existing = root.setdefault(name, {})
            if not isinstance(existing, dict):
                raise CampaignError(
                    f"TOML line {lineno}: {name!r} is both a key and a "
                    "section")
            section = existing
            continue
        if "=" not in line:
            raise CampaignError(
                f"TOML line {lineno}: expected key = value, got {raw!r}")
        key, _, val = line.partition("=")
        key = key.strip()
        val = val.strip()
        if not key or not val:
            raise CampaignError(
                f"TOML line {lineno}: expected key = value, got {raw!r}")
        target = root if section is None else section
        target[key] = _parse_value(val, lineno)
    return root

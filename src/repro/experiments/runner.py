"""Campaign runner — reproduces the paper's §V-B overloading experiment.

Each cell builds a fresh :class:`~repro.cluster.simulator.ClusterSim`
fleet, registers it on a :class:`~repro.monitor.bus.TelemetryBus`, and
steps simulated time; every step one snapshot flows through the bus to
a streaming :class:`~repro.insights.engine.InsightEngine`.  In
``fixed`` mode the cell's NPPN is applied to every overloadable
arrival; in ``controller`` mode the loop closes live — a firing
``low_gpu`` insight feeds :meth:`~repro.core.overload.
OverloadController.consume`, and a level change cancels + resubmits
that user's jobs at the new NPPN (the paper's ladder, 1 → 2 → 4 → 8,
driven by diagnosis instead of by hand).

Snapshots fold into one :class:`CellResult` per cell (throughput in
tasks/hr, mean GPU duty, device-memory headroom, queue wait, active-
insight observations); :class:`CampaignResult.rows` adds the per-cell
speedup against the matching fixed ``nppn1`` baseline and feeds the §7
``experiments`` query table, so every renderer / filter / sort works on
campaign output — locally, in ``--watch`` progress frames, and
server-side via the daemon's ``GET /experiments``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Sequence

from repro.experiments.spec import (MIXES, Campaign, Cell, MixJob,
                                    Scenario)


@dataclasses.dataclass(frozen=True)
class CellResult:
    """Folded measurements for one completed cell (one table row)."""
    cell: str                   # cell name (mix/<fleet>g/nppnN|controller)
    mode: str                   # fixed | controller
    mix: str
    fleet: int                  # GPU nodes
    nppn: int                   # fixed level, or the converged level
    tasks_done: int             # tasks of jobs completed in the window
    throughput: float           # tasks_done per hour
    gpu_duty: float             # mean device duty over in-use GPU nodes
    mem_headroom: float         # mean free device-memory fraction
    queue_wait_s: float         # mean submit->start wait
    insights: int               # active insights summed over snapshots
    seed: int
    #: per-kind breakdown of ``insights`` (observations per rule kind);
    #: not a table column — the goldens pin the row layout — but what
    #: the rule-scenario campaigns assert against.
    kinds: Dict[str, int] = dataclasses.field(default_factory=dict)

    def row(self) -> dict:
        """This result as an ``experiments``-table row (``speedup`` is
        filled in by :meth:`CampaignResult.rows`)."""
        return {
            "cell": self.cell, "mode": self.mode, "mix": self.mix,
            "fleet": self.fleet, "nppn": self.nppn,
            "tasks_done": self.tasks_done, "throughput": self.throughput,
            "speedup": None, "gpu_duty": self.gpu_duty,
            "mem_headroom": self.mem_headroom,
            "queue_wait_s": self.queue_wait_s, "insights": self.insights,
            "seed": self.seed,
        }


@dataclasses.dataclass
class CampaignResult:
    """Results for the cells run so far (possibly a partial campaign
    while ``--watch`` streams progress frames)."""
    campaign: Campaign
    results: List[CellResult]

    def rows(self) -> List[dict]:
        """Table rows in cell order, with ``speedup`` computed against
        the same (mix, fleet) fixed ``nppn1`` cell — ``None`` when that
        baseline is absent (not selected, or not yet run)."""
        base: Dict[tuple, float] = {}
        for r in self.results:
            if r.mode == "fixed" and r.nppn == 1 and r.throughput > 0:
                base[(r.mix, r.fleet)] = r.throughput
        rows = []
        for r in self.results:
            row = r.row()
            b = base.get((r.mix, r.fleet))
            row["speedup"] = (r.throughput / b) if b else None
            rows.append(row)
        return rows

    def cell_row(self, name: str) -> Optional[dict]:
        """The row for one cell name, or ``None`` if it was not run."""
        for row in self.rows():
            if row["cell"] == name:
                return row
        return None


class CampaignRunner:
    """Run a campaign's cells in grid order, one fresh sim per cell."""

    def __init__(self, campaign: Campaign,
                 cells: Optional[Sequence[Cell]] = None):
        """Args:
            campaign: the validated sweep definition.
            cells: subset to run (e.g. from
                :meth:`Campaign.select_cells`); default: every cell.
        """
        self.campaign = campaign
        self.cells = list(cells) if cells is not None else campaign.cells()

    def run_iter(self) -> Iterator[CellResult]:
        """Yield each cell's result as it completes (powers ``--watch``
        progress frames)."""
        for cell in self.cells:
            yield run_cell(cell)

    def run(self) -> CampaignResult:
        """Run every selected cell and return the full result."""
        return CampaignResult(self.campaign, list(self.run_iter()))

    def result(self, done: Sequence[CellResult]) -> CampaignResult:
        """A (partial) :class:`CampaignResult` over ``done`` cells."""
        return CampaignResult(self.campaign, list(done))


# ----------------------------------------------------------------- one cell


#: Jobs per burst for the ``bursty`` arrival pattern; bursts land every
#: ``BURST_SIZE * arrival_s`` seconds, so the mean rate stays the
#: uniform stream's while submissions arrive in platoons.
BURST_SIZE = 8


def arrival_times(sc: Scenario, n_streams: int = 1) -> List[float]:
    """Per-job arrival times (seconds) for the scenario's
    ``arrival_pattern`` — the deterministic traces behind the job-level
    rule scenarios (DESIGN.md §11).

      * ``uniform`` — one job every ``arrival_s`` (the §V-B stream).
      * ``diurnal`` — arrivals follow a ``1 - cos(2πt/P)`` intensity
        with two "days" in the window (``P = duration_s / 2``);
        inverse-CDF placement bunches submissions into two rushes that
        back the queue up (``queue_starvation``'s trace).
      * ``bursty`` — platoons of :data:`BURST_SIZE` simultaneous jobs
        (``fleet_fragmentation``'s trace: each burst pins a rack of
        whole nodes at once).
      * ``elastic`` — stream 0 (the dominant tenant) submits everything
        up front, one job per sim step; the other streams arrive a
        third into the window and find the fleet taken
        (``multi_tenant_fairness``'s trace).

    Times are per job *index*; for ``elastic`` they are not monotonic
    in index (stream 0 front-runs), so the runner submits in
    time-sorted order while keeping each index's mix stream.
    """
    n = sc.n_jobs
    if sc.arrival_pattern == "diurnal":
        period = sc.duration_s / 2.0
        two_pi = 2.0 * math.pi

        def cdf(t: float) -> float:
            # integral of the 1 - cos intensity, normalized over the window
            return (t - (period / two_pi)
                    * math.sin(two_pi * t / period)) / sc.duration_s

        out = []
        for i in range(n):
            target = (i + 0.5) / n
            lo, hi = 0.0, sc.duration_s
            for _ in range(50):          # bisection: |hi-lo| < 1e-10 s
                mid = (lo + hi) / 2.0
                if cdf(mid) < target:
                    lo = mid
                else:
                    hi = mid
            out.append((lo + hi) / 2.0)
        return out
    if sc.arrival_pattern == "bursty":
        return [(i // BURST_SIZE) * BURST_SIZE * sc.arrival_s
                for i in range(n)]
    if sc.arrival_pattern == "elastic":
        streams = max(n_streams, 1)
        return [(i // streams) * sc.dt_s if i % streams == 0
                else sc.duration_s / 3.0 + (i // streams) * sc.arrival_s
                for i in range(n)]
    return [i * sc.arrival_s for i in range(n)]


def _build_spec(mj: MixJob, sc: Scenario, nppn: int):
    """One arrival's JobSpec: the mix factory's job with the scenario's
    task count/duration, at ``nppn`` tasks-per-GPU when overloadable."""
    from repro.cluster import workloads

    spec = getattr(workloads, mj.factory)(mj.username,
                                          tasks=sc.tasks_per_job)
    return dataclasses.replace(
        spec, duration_s=sc.task_duration_s,
        tasks_per_gpu=(nppn if mj.overloadable else spec.tasks_per_gpu))


def _resubmit_user(sim, username: str, nppn: int) -> int:
    """The closed loop's actuator: cancel every pending/running job of
    ``username`` and resubmit its spec at ``nppn`` tasks-per-GPU (work
    done so far is lost, like a real resubmission).  Returns the number
    of jobs requeued."""
    sched = sim.sched
    requeue = [j for j in list(sched.pending) + list(sched.running)
               if j.spec.username == username]
    for job in requeue:
        sched.cancel(job.job_id)
    for job in requeue:
        sim.submit(dataclasses.replace(job.spec, tasks_per_gpu=nppn))
    return len(requeue)


def _consolidate_user(sim, username: str) -> int:
    """``fleet_fragmentation``'s actuator: cancel the user's *exclusive*
    jobs and resubmit them without the flag, so the scheduler packs
    them onto shared whole nodes instead of one node each.  Idempotent
    — returns 0 (and touches nothing) once no exclusive job remains,
    so re-firing insights cause no churn."""
    sched = sim.sched
    requeue = [j for j in list(sched.pending) + list(sched.running)
               if j.spec.username == username and j.spec.exclusive]
    for job in requeue:
        sched.cancel(job.job_id)
    for job in requeue:
        sim.submit(dataclasses.replace(job.spec, exclusive=False))
    return len(requeue)


def _elastic_shrink(sim, plan) -> int:
    """``multi_tenant_fairness``'s actuator: resubmit the dominant
    tenant's jobs at the :class:`~repro.launch.fault.ElasticResizePlan`
    target size (work done so far is lost, like any resubmission).
    Jobs already at or below the target are left alone.  Returns the
    number of jobs resized."""
    sched = sim.sched
    resize = [j for j in list(sched.pending) + list(sched.running)
              if j.spec.username == plan.username
              and plan.shrink(j.spec.n_tasks) < j.spec.n_tasks]
    for job in resize:
        sched.cancel(job.job_id)
    for job in resize:
        sim.submit(dataclasses.replace(
            job.spec, n_tasks=plan.shrink(job.spec.n_tasks)))
    return len(resize)


#: Fleets at or below this size fold GPU duty/headroom through per-node
#: ``NodeSnapshot`` objects, exactly as before the columnar engine —
#: numpy's pairwise summation can differ from the sequential Python fold
#: in the last ulp, and every pre-existing campaign golden lives at
#: ≤ 4096 nodes.  Larger fleets (which have no legacy goldens) use the
#: array fold, still fully deterministic for a given cell + seed.
COLUMNAR_FOLD_MIN_NODES = 4_096


def _gpu_fold(snap):
    """Mean GPU duty and memory headroom over busy GPU nodes for one
    poll; ``(None, None)`` when no GPU node is busy."""
    nodes = snap.nodes
    columns = getattr(nodes, "columns", None)
    if columns is not None and len(nodes) > COLUMNAR_FOLD_MIN_NODES:
        import numpy as np

        busy = (columns.gpus_total > 0) & (columns.gpus_used > 0)
        k = int(busy.sum())
        if not k:
            return None, None
        free = (columns.gpu_mem_total_gb[busy]
                - columns.gpu_mem_used_gb[busy])
        return (float(columns.gpu_load[busy].sum()) / k,
                float(np.sum(free / columns.gpu_mem_total_gb[busy])) / k)
    gpu_nodes = [n for n in nodes.values()
                 if n.gpus_total > 0 and n.gpus_used > 0]
    if not gpu_nodes:
        return None, None
    return (sum(n.gpu_load for n in gpu_nodes) / len(gpu_nodes),
            sum(n.gpu_mem_free_gb / n.gpu_mem_total_gb
                for n in gpu_nodes) / len(gpu_nodes))


def run_cell(cell: Cell) -> CellResult:
    """Run one cell start to finish and fold its measurements.

    The sim is driven *through the bus*: every ``dt_s`` step is one
    ``bus.poll`` (advancing simulated time and ticking the scheduler),
    whose snapshot streams to the insight engine exactly as the daemon's
    sampler would.  Deterministic: same cell + seed ⇒ identical result.
    """
    from repro.cluster.node import make_nodes
    from repro.cluster.simulator import ClusterSim
    from repro.core.overload import OverloadController
    from repro.insights import InsightEngine
    from repro.launch.fault import ElasticResizePlan
    from repro.monitor import TelemetryBus

    sc = cell.scenario
    nodes = (make_nodes("d", sc.n_cpu, cores=48, mem_gb=192.0)
             + make_nodes("c", sc.n_gpu, cores=40, mem_gb=384.0, gpus=2,
                          gpu_mem_gb=32.0))
    # non-uniform arrivals exist to stress the queue: surface pending
    # jobs so the queue-level rules can see the backlog
    sim = ClusterSim(nodes, cluster="exp", seed=sc.seed,
                     show_pending=sc.arrival_pattern != "uniform")
    source = sim.as_source(advance_s=sc.dt_s, name="exp")
    bus = TelemetryBus(ttl_s=0.0, history=8)
    bus.register(source)
    engine = InsightEngine()
    bus.subscribe(engine.subscriber(source.name))

    mix = MIXES[sc.mix]
    levels = {mj.username: (cell.nppn if mj.overloadable else 1)
              for mj in mix}
    controllers = {}
    if cell.mode == "controller":
        controllers = {mj.username: OverloadController()
                       for mj in mix if mj.overloadable}

    times = arrival_times(sc, len(mix))
    order = sorted(range(sc.n_jobs), key=lambda i: (times[i], i))

    duty_sum = head_sum = 0.0
    duty_polls = 0
    insight_obs = 0
    kinds: Dict[str, int] = {}
    submitted = 0
    while True:
        while (submitted < sc.n_jobs
               and times[order[submitted]] <= sim.t + 1e-9):
            idx = order[submitted]
            mj = mix[idx % len(mix)]
            sim.submit(_build_spec(mj, sc, levels[mj.username]),
                       now=times[idx])
            submitted += 1
        if sim.t >= sc.duration_s - 1e-9:
            break
        snap = bus.poll(source.name)
        duty, head = _gpu_fold(snap)
        if duty is not None:
            duty_sum += duty
            head_sum += head
            duty_polls += 1
        active = engine.active()
        insight_obs += len(active)
        for ins in active:
            kinds[ins.kind] = kinds.get(ins.kind, 0) + 1
        if cell.mode != "controller":
            continue
        for ins in active:
            if ins.last_seen < snap.timestamp:
                # hysteresis keeps a clearing insight active for a few
                # frames; only a *firing* diagnosis drives actuation
                continue
            if ins.kind == "low_gpu":
                ctl = controllers.get(ins.username)
                if ctl is None:
                    continue
                cur = levels[ins.username]
                decision = ctl.consume(ins, cur)
                if decision.nppn != cur:
                    levels[ins.username] = decision.nppn
                    _resubmit_user(sim, ins.username, decision.nppn)
            elif ins.kind == "queue_starvation":
                # starvation on an overloadable stream: jobs don't fit
                # the free capacity — step the ladder so they do
                if ins.username not in controllers:
                    continue
                cur = levels[ins.username]
                nxt = min(cur * 2, 8)
                if nxt != cur:
                    levels[ins.username] = nxt
                    _resubmit_user(sim, ins.username, nxt)
            elif ins.kind == "fleet_fragmentation":
                _consolidate_user(sim, ins.username)
            elif ins.kind == "multi_tenant_fairness":
                # shrink while the unfairness persists; bounded — each
                # resize halves the tenant's jobs and the actuator
                # no-ops once every job reaches the plan's floor
                _elastic_shrink(sim, ElasticResizePlan(ins.username))

    completed = sim.sched.completed
    tasks_done = sum(j.spec.n_tasks for j in completed)
    started = [j for j in list(completed) + list(sim.sched.running)
               if j.start_time is not None]
    queue_wait = (sum(j.start_time - j.submit_time for j in started)
                  / len(started)) if started else 0.0
    over_levels = [levels[mj.username] for mj in mix if mj.overloadable]
    return CellResult(
        cell=cell.name, mode=cell.mode, mix=sc.mix, fleet=sc.n_gpu,
        nppn=(max(over_levels) if over_levels else cell.nppn),
        tasks_done=tasks_done,
        throughput=tasks_done / (sc.duration_s / 3600.0),
        gpu_duty=(duty_sum / duty_polls) if duty_polls else 0.0,
        mem_headroom=(head_sum / duty_polls) if duty_polls else 0.0,
        queue_wait_s=queue_wait, insights=insight_obs, seed=sc.seed,
        kinds=kinds)


def run_campaign(campaign: Campaign,
                 cells: Optional[str] = None) -> CampaignResult:
    """One-call convenience: select cells by pattern and run them.

    Args:
        campaign: the sweep definition.
        cells: optional comma-separated cell globs (``--cells`` form).

    Returns:
        The full :class:`CampaignResult`.
    """
    return CampaignRunner(campaign,
                          campaign.select_cells(cells)).run()


def render_result(result: CampaignResult, *,
                  columns: Optional[str] = None,
                  filter: Optional[str] = None,  # noqa: A002 — CLI name
                  sort: Optional[str] = None,
                  group_by: Optional[str] = None,
                  limit: Optional[int] = None,
                  fmt: str = "table") -> str:
    """Render a campaign result through the §7 query engine.

    The one rendering path shared by the CLI and the daemon's
    ``GET /experiments`` — which is what makes ``--source remote``
    output byte-identical to a local run of the same campaign.

    Args:
        result: the (possibly partial) campaign result.
        columns/filter/sort/group_by/limit: the generic query modifiers
            in their CLI string forms.
        fmt: a registry renderer name (``text`` aliases ``table``: the
            experiments table has no legacy paper layout).

    Returns:
        The rendered table, newline-terminated.

    Raises:
        QueryError: on unknown columns/filters/formats.
    """
    from repro.query import Query, get_renderer, run_query

    q = Query.from_params(table="experiments", columns=columns,
                          filter=filter, sort=sort, group_by=group_by,
                          limit=limit)
    renderer = get_renderer("table" if fmt in (None, "", "text") else fmt)
    rs = run_query(None, q, experiments=result)
    rs.cluster = result.campaign.name
    return renderer.render(rs)

"""llsc-100m — the paper's own demo workload.

LLload (the paper) is architecture-agnostic infrastructure; this ~110M dense
LM is the in-repo stand-in for "a user's training job" in the end-to-end
monitoring examples (examples/train_with_monitoring.py) and the overloading
throughput study.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llsc-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=32768,
    tie_embeddings=True,
))

"""qwen1.5-4b — QKV bias [hf:Qwen/Qwen1.5-4B; hf].

40L d_model=2560 20H (MHA kv=20) d_ff=6912 vocab=151936; biases on Q/K/V
projections (Qwen signature).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_head=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=5_000_000.0,
))

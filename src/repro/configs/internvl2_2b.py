"""internvl2-2b — InternViT + InternLM2 [arXiv:2404.16821; hf].

LM backbone only (InternLM2-1.8B-style decoder): 24L d_model=2048 16H
(GQA kv=8) d_ff=8192 vocab=92553.  The InternViT frontend is a STUB per the
assignment: ``input_specs()`` provides 256 precomputed patch embeddings that
are prepended to the token sequence.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    frontend="patch_stub",
    frontend_len=256,
))

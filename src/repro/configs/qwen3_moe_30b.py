"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

48L d_model=2048 32H (GQA kv=4) vocab=151936; every layer MoE with 128
experts, top-8, expert d_ff=768, renormalized top-k routing.
"""
from repro.configs.base import ModelConfig, MoESpec, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=768,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    layer_pattern=("attn",),
    mlp_pattern=("moe",),
    moe=MoESpec(n_experts=128, top_k=8, d_ff_expert=768, norm_topk_prob=True),
))

"""mamba2-370m — SSD (state-space duality) [arXiv:2405.21060; unverified].

48L d_model=1024, attention-free (d_ff=0: no FFN, the Mamba-2 block is the
whole layer), vocab 50280 (GPT-NeoX tokenizer), ssm_state=128.
"""
from repro.configs.base import ModelConfig, SSMSpec, register

CONFIG = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,          # = d_inner / head_dim (SSD heads)
    n_kv_heads=32,
    d_head=64,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=("ssm",),
    mlp_pattern=("mlp",),
    ssm=SSMSpec(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                chunk=256),
    tie_embeddings=True,
))

"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536; MoE (16 experts,
top-2) every other layer; attention every 8th layer (1:7 attn:mamba).
Deviation noted in DESIGN.md: Jamba's SSM layers are Mamba-1; we implement
them in SSD (Mamba-2) form with d_state=16, head_dim=64 — same FLOP/byte
shape, one SSM code path.

Scale notes: 398B params.  Optimizer moments are kept in bf16
(``opt_dtype``) so train state fits 512 chips; the dry-run records the
memory analysis for both meshes.
"""
from repro.configs.base import ModelConfig, MoESpec, SSMSpec, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab_size=65536,
    # period of 8: attention at position 4, mamba elsewhere; MoE on odd slots
    layer_pattern=("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm"),
    mlp_pattern=("mlp", "moe", "mlp", "moe", "mlp", "moe", "mlp", "moe"),
    moe=MoESpec(n_experts=16, top_k=2, d_ff_expert=24576),
    ssm=SSMSpec(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1,
                chunk=256),
    opt_dtype="bfloat16",
))

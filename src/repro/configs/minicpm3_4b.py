"""minicpm3-4b — MLA [hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H d_ff=6400 vocab=73448; multi-head latent attention with
q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32 (per HF config).
Decode uses the absorbed-latent form (cache = compressed c_kv + rope key).
"""
from repro.configs.base import MLASpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=96,           # qk head dim (nope 64 + rope 32)
    d_ff=6400,
    vocab_size=73448,
    mla=MLASpec(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                qk_rope_head_dim=32, v_head_dim=64),
))

"""whisper-base — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

6L encoder + 6L decoder, d_model=512 8H d_ff=2048 vocab=51865.  The conv1d
audio frontend is a STUB per the assignment: ``input_specs()`` provides 1500
precomputed frame embeddings as encoder input.  Deviation (DESIGN.md):
RoPE instead of Whisper's learned absolute positions.
"""
from repro.configs.base import EncoderSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,                  # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_head=64,
    d_ff=2048,
    vocab_size=51865,
    act="gelu",
    encoder=EncoderSpec(n_layers=6, n_heads=8, n_kv_heads=8, d_ff=2048,
                        source_len=1500),
    frontend="audio_stub",
))

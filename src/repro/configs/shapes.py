"""Assigned input shapes and per-(arch, shape) input specs.

``train_*`` shapes lower ``train_step``; ``prefill_*`` lower the serving
prefill; ``decode_*`` / ``long_*`` lower ``serve_step`` (one new token
against a KV cache of ``seq_len``).

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins (weak-type
correct, shardable, zero allocation) — the same pattern the dry-run uses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple:
    """(ok, reason). long_500k only for sub-quadratic families."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 524k-token decode cache "
                       "requires sub-quadratic attention (DESIGN.md §4)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def frontend_spec(cfg: ModelConfig, batch: int):
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "patch_stub":
        return _sds((batch, cfg.frontend_len, cfg.d_model), dt)
    if cfg.frontend == "audio_stub":
        return _sds((batch, cfg.encoder.source_len, cfg.d_model), dt)
    return None


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct inputs for the step function of ``shape.kind``."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    fe = frontend_spec(cfg, B)

    if shape.kind == "train":
        S_text = S - (cfg.frontend_len if cfg.frontend == "patch_stub" else 0)
        specs = {"tokens": _sds((B, S_text), i32),
                 "labels": _sds((B, S_text), i32)}
        if fe is not None:
            specs["frontend"] = fe
        return specs

    if shape.kind == "prefill":
        S_text = S - (cfg.frontend_len if cfg.frontend == "patch_stub" else 0)
        specs = {"tokens": _sds((B, S_text), i32)}
        if fe is not None:
            specs["frontend"] = fe
        return specs

    if shape.kind == "decode":
        from repro.models.model import cache_struct

        return {
            "token": _sds((B, 1), i32),
            "caches": cache_struct(cfg, B, S),
            "cache_len": _sds((), i32),
        }
    raise ValueError(shape.kind)

"""granite-moe-1b-a400m — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L d_model=1024 16H (GQA kv=8) vocab=49155; every layer MoE with 32
experts, top-8, expert d_ff=512.
"""
from repro.configs.base import ModelConfig, MoESpec, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab_size=49155,
    layer_pattern=("attn",),
    mlp_pattern=("moe",),
    moe=MoESpec(n_experts=32, top_k=8, d_ff_expert=512, norm_topk_prob=True),
    tie_embeddings=True,
))

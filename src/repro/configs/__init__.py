from repro.configs.base import (ModelConfig, MoESpec, SSMSpec, MLASpec,
                                EncoderSpec, get_config, list_archs,
                                reduced_config)
from repro.configs.shapes import SHAPES, ShapeSpec, input_specs, shape_applicable

__all__ = [
    "ModelConfig", "MoESpec", "SSMSpec", "MLASpec", "EncoderSpec",
    "get_config", "list_archs", "reduced_config",
    "SHAPES", "ShapeSpec", "input_specs", "shape_applicable",
]

"""gemma3-1b — 5:1 local:global attention [hf:google/gemma-3-1b-pt; unverified].

26L d_model=1152 4H (GQA kv=1, head_dim=256) d_ff=6912 vocab=262144.
Pattern: 5 sliding-window (512) layers then 1 global layer; 26 = 4 periods
of 6 + 2 trailing local layers.  Local layers use rope base 10k, global
layers 1M.  Tied embeddings scaled by sqrt(d_model).
"""
import math

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab_size=262144,
    layer_pattern=("attn_local",) * 5 + ("attn",),
    mlp_pattern=("mlp",) * 6,
    attn_window=512,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    tie_embeddings=True,
    embed_scale=math.sqrt(1152.0),
    act="geglu",
))

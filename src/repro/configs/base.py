"""Model configuration system.

Every assigned architecture is expressed as a :class:`ModelConfig`.  A config
fully determines parameter shapes, the layer pattern (including hybrid
attention/SSM interleaves and local:global attention schedules), the MoE and
MLA sub-specs, and the modality frontend stubs.

Configs are *frozen* dataclasses so they can be used as static args to
``jax.jit`` and hashed into compilation caches.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# --------------------------------------------------------------------------
# Sub-specs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts FFN spec (GShard-style top-k with capacity)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # qwen3-style: softmax over the selected top-k logits (renormalized);
    # if False: softmax over all experts then select (switch-style).
    norm_topk_prob: bool = True
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """Mamba-2 SSD spec."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class MLASpec:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Encoder stack for enc-dec models (whisper).  Bidirectional attention."""

    n_layers: int = 6
    n_heads: int = 8
    n_kv_heads: int = 8
    d_ff: int = 2048
    # Number of (precomputed, stubbed) frontend frames fed to the encoder.
    source_len: int = 1500


# --------------------------------------------------------------------------
# Main config
# --------------------------------------------------------------------------

MIXERS = ("attn", "attn_local", "ssm")
MLPS = ("mlp", "moe")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    # Layer pattern: one *period* of mixer kinds / mlp kinds; the model is
    # ``n_layers // len(pattern)`` scanned periods plus an unrolled remainder
    # of ``pattern[: n_layers % len(pattern)]``.
    layer_pattern: Tuple[str, ...] = ("attn",)
    mlp_pattern: Tuple[str, ...] = ("mlp",)

    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # gemma3: local (sliding-window) layers use a different rope base.
    rope_theta_local: Optional[float] = None
    embed_scale: float = 1.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_window: Optional[int] = None  # window for 'attn_local' layers
    attn_logit_softcap: Optional[float] = None
    act: str = "swiglu"  # swiglu | gelu

    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    mla: Optional[MLASpec] = None
    encoder: Optional[EncoderSpec] = None

    # Modality frontend stub: 'none' | 'patch_stub' (vlm) | 'audio_stub'.
    frontend: str = "none"
    frontend_len: int = 0  # precomputed embeddings prepended to the sequence

    dtype: str = "bfloat16"
    # Cross-entropy is computed in sequence chunks of this size so the full
    # [B, S, V] logits tensor is never materialized (vocab up to 262k).
    loss_chunk: int = 512
    # Query-chunk size for the HLO-level flash attention scan.
    attn_chunk: int = 1024
    # Remat ("activation checkpoint") policy for scanned blocks:
    # 'none' | 'full' | 'dots'.
    remat: str = "full"
    # Optimizer moment dtype ('float32' normally; 'bfloat16' for 398B jamba
    # so optimizer state fits pod HBM).
    opt_dtype: str = "float32"

    # ---------------------------------------------------------------- helpers
    def __post_init__(self):
        assert self.family in ("dense", "ssm", "moe", "hybrid", "vlm", "audio")
        assert len(self.layer_pattern) == len(self.mlp_pattern)
        for m in self.layer_pattern:
            assert m in MIXERS, m
        for m in self.mlp_pattern:
            assert m in MLPS, m
        if "ssm" in self.layer_pattern:
            assert self.ssm is not None
        if "moe" in self.mlp_pattern:
            assert self.moe is not None
        if "attn_local" in self.layer_pattern:
            assert self.attn_window is not None

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def n_remainder(self) -> int:
        return self.n_layers % self.period

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def uses_attention(self) -> bool:
        return any(m.startswith("attn") for m in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run long_500k (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    # -------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Exact parameter count (matches init_params; used for 6ND)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        """Active (per-token) parameters, for MoE 6·N_active·D."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # Import every per-arch config module exactly once.
    import repro.configs.archs  # noqa: F401


# --------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# --------------------------------------------------------------------------


def reduced_config(name_or_cfg) -> ModelConfig:
    """A tiny config of the *same family / layer pattern* for CPU smoke tests.

    Keeps the period structure (so hybrid/local-global/moe code paths are
    exercised) while shrinking widths, depth, vocab, experts.
    """
    cfg = name_or_cfg if isinstance(name_or_cfg, ModelConfig) else get_config(name_or_cfg)
    period = cfg.period
    n_layers = period + min(cfg.n_remainder, 1)
    d_model = 64
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4
    moe = None
    if cfg.moe is not None:
        # capacity_factor = E/k guarantees C >= tokens-per-group, i.e. no
        # capacity drops — keeps smoke tests deterministic w.r.t. grouping.
        moe = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2), d_ff_expert=32,
            capacity_factor=4.0)
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    mla = None
    if cfg.mla is not None:
        mla = MLASpec(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
                      qk_rope_head_dim=8, v_head_dim=8)
    enc = None
    if cfg.encoder is not None:
        enc = EncoderSpec(n_layers=2, n_heads=4, n_kv_heads=4, d_ff=64,
                          source_len=16)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        embed_scale=math.sqrt(d_model) if cfg.embed_scale != 1.0 else 1.0,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        moe=moe,
        ssm=ssm,
        mla=mla,
        encoder=enc,
        attn_window=min(cfg.attn_window, 8) if cfg.attn_window else None,
        frontend_len=8 if cfg.frontend != "none" else 0,
        loss_chunk=32,
        attn_chunk=16,
        dtype="float32",
        remat="none",
    )

"""Import side-effects: registers every architecture config."""
import repro.configs.gemma3_1b       # noqa: F401
import repro.configs.granite_moe_1b  # noqa: F401
import repro.configs.internvl2_2b    # noqa: F401
import repro.configs.jamba15_large   # noqa: F401
import repro.configs.llsc_100m       # noqa: F401
import repro.configs.mamba2_370m     # noqa: F401
import repro.configs.minicpm3_4b     # noqa: F401
import repro.configs.phi3_medium_14b # noqa: F401
import repro.configs.qwen15_4b       # noqa: F401
import repro.configs.qwen3_moe_30b   # noqa: F401
import repro.configs.whisper_base    # noqa: F401

# The 10 assigned architectures (llsc-100m is the paper's own demo extra).
ASSIGNED = (
    "mamba2-370m",
    "internvl2-2b",
    "minicpm3-4b",
    "qwen1.5-4b",
    "phi3-medium-14b",
    "gemma3-1b",
    "jamba-1.5-large-398b",
    "whisper-base",
    "qwen3-moe-30b-a3b",
    "granite-moe-1b-a400m",
)

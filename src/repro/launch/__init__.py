"""Launchers and distribution: mesh, sharding rules, dry-run, fault tolerance.

NOTE: repro.launch.dryrun is intentionally NOT imported here — importing it
sets XLA_FLAGS to 512 host devices, which must only happen for dry-runs.
"""
from repro.launch.fault import (CrashInjector, StragglerDetector,
                                resume_latest)
from repro.launch.mesh import (axis_size, fsdp_axes, make_host_mesh,
                               make_production_mesh, tp_axis)
from repro.launch.sharding import (ShardingOptions, batch_shardings,
                                   cache_shardings, hint_context,
                                   param_shardings)

__all__ = [
    "CrashInjector", "StragglerDetector", "resume_latest", "axis_size",
    "fsdp_axes", "make_host_mesh", "make_production_mesh", "tp_axis",
    "ShardingOptions", "batch_shardings", "cache_shardings", "hint_context",
    "param_shardings",
]

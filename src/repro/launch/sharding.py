"""Sharding rules: parameter / optimizer / cache / batch partition specs.

Strategy (DESIGN.md §3):
  * 2D weight sharding — the "input" dim of every matmul weight shards over
    the FSDP axes (pod+data), the "output"/head/ff dim over the tensor axis
    (`model`) — when divisible; non-divisible dims stay replicated (GQA kv
    heads, odd head counts).
  * MoE expert weights shard experts over `model` (expert parallelism; the
    dispatch buffer hint turns this into an all-to-all), d_model over FSDP.
  * Activations shard batch over FSDP; optional sequence-parallel hint
    shards the sequence dim over `model` between blocks (perf lever).
  * Decode caches shard batch over FSDP when divisible, else the time axis
    (long_500k batch=1 -> context-parallel decode).

Everything degrades gracefully: any dim not divisible by its axis is
replicated, so every (arch x shape x mesh) combination lowers.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, fsdp_axes, tp_axis


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _fits(mesh, dim: int, axes) -> bool:
    return axes is not None and dim % axis_size(mesh, axes) == 0


def _axes_or_none(mesh, dim: int, axes):
    return axes if _fits(mesh, dim, axes) else None


class ShardingOptions:
    """Global toggles used by the perf hillclimb."""
    sequence_parallel: bool = False


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

_IN_OUT = {  # name -> which dim is the "input" (fsdp) dim for 2D weights
    "wq": 0, "wk": 0, "wv": 0, "wq_a": 0, "wq_b": 0, "wkv_a": 0,
    "wkv_b": 0, "w1": 0, "w3": 0, "in_proj": 0, "lm_head": 0,
    "wo": 1, "w2": 1, "out_proj": 1,
}


def _param_spec_leaf(mesh, name: str, shape, stacked: bool):
    fsdp = fsdp_axes(mesh)
    tp = tp_axis(mesh)
    core = shape[1:] if stacked else shape
    spec: list = [None] * len(core)

    from repro.models.perf_flags import current as _perf

    if name == "embed":
        # [V, D]: vocab over model (TP softmax/gather), D over FSDP
        spec = [_axes_or_none(mesh, core[0], tp),
                _axes_or_none(mesh, core[1], fsdp)]
    elif name == "router" and len(core) == 2:
        spec = [_axes_or_none(mesh, core[0], fsdp), None]
    elif len(core) == 3 and name in ("w1", "w3"):
        if _perf().moe_fsdp_tp:
            # experts replicated; 2D-shard (d_model->fsdp, d_ff->tp):
            # the combine gather stays local to each model shard (§Perf)
            spec = [None, _axes_or_none(mesh, core[1], fsdp),
                    _axes_or_none(mesh, core[2], tp)]
        else:
            # MoE experts [E, D, F]: expert-parallel over model
            spec = [_axes_or_none(mesh, core[0], tp),
                    _axes_or_none(mesh, core[1], fsdp), None]
    elif len(core) == 3 and name == "w2":
        if _perf().moe_fsdp_tp:
            spec = [None, _axes_or_none(mesh, core[1], tp),
                    _axes_or_none(mesh, core[2], fsdp)]
        else:
            spec = [_axes_or_none(mesh, core[0], tp), None,
                    _axes_or_none(mesh, core[2], fsdp)]
    elif name == "conv_w":
        spec = [None, _axes_or_none(mesh, core[1], tp)]
    elif len(core) == 2 and name in _IN_OUT:
        in_dim = _IN_OUT[name]
        out_dim = 1 - in_dim
        spec[in_dim] = _axes_or_none(mesh, core[in_dim], fsdp)
        spec[out_dim] = _axes_or_none(mesh, core[out_dim], tp)
    elif len(core) >= 1 and core[-1] > 1024:
        # large 1-D (biases over big ff dims): shard over tp
        spec[-1] = _axes_or_none(mesh, core[-1], tp)

    if stacked:
        spec = [None] + spec  # leading n_periods axis
    return P(*spec)


def param_shardings(mesh, params_tree):
    """Tree of NamedShardings matching a params (or TrainState) tree."""

    def walk(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = str(keys[-1]) if keys else ""
        stacked = any(str(k) in ("blocks", "enc_blocks") for k in keys[:-1])
        spec = _param_spec_leaf(mesh, name, leaf.shape, stacked)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(walk, params_tree)


# --------------------------------------------------------------------------
# batches / caches
# --------------------------------------------------------------------------


def batch_shardings(mesh, batch_tree):
    """tokens/labels [B,S], frontend [B,P,D] -> batch over FSDP axes."""
    fsdp = fsdp_axes(mesh)

    def leaf(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        spec = [None] * x.ndim
        spec[0] = _axes_or_none(mesh, x.shape[0], fsdp)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, batch_tree)


_CACHE_HEAD_DIM = {"k": 2, "v": 2}  # [B,T,Hk,dh] (after batch dim)


def cache_shardings(mesh, cache_tree):
    from repro.models.perf_flags import current as _perf

    fsdp = fsdp_axes(mesh)
    tp = tp_axis(mesh)

    def walk(path, leaf):
        keys = [str(getattr(p, "key", p)) for p in path]
        name = keys[-1]
        stacked = "blocks" in keys[:-1]
        off = 1 if stacked else 0
        shape = leaf.shape
        spec = [None] * len(shape)
        bdim = off
        if _fits(mesh, shape[bdim], fsdp):
            spec[bdim] = fsdp
        elif name in ("k", "v", "ckv", "krope") and len(shape) > bdim + 1 \
                and _fits(mesh, shape[bdim + 1], fsdp):
            spec[bdim + 1] = fsdp  # context-parallel decode (batch=1)
        if name in ("k", "v", "xk", "xv") and len(shape) >= bdim + 4:
            hdim = bdim + 2
            if _fits(mesh, shape[hdim], tp):
                spec[hdim] = tp
            elif _perf().decode_cache_seq_shard and spec[bdim + 1] is None \
                    and _fits(mesh, shape[bdim + 1], tp):
                # heads don't divide the model axis: context-parallel the
                # cache time dim instead (§Perf decode lever)
                spec[bdim + 1] = tp
        if name in ("ckv", "krope") and _perf().decode_cache_seq_shard \
                and len(shape) > bdim + 1 and spec[bdim + 1] is None \
                and _fits(mesh, shape[bdim + 1], tp):
            spec[bdim + 1] = tp
        if name == "ssd" and len(shape) >= bdim + 3:
            # [B, G, HG, P, N]: heads-per-group over tp
            if _fits(mesh, shape[bdim + 2], tp):
                spec[bdim + 2] = tp
        if name == "conv" and _fits(mesh, shape[-1], tp):
            spec[-1] = tp
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(walk, cache_tree)


# --------------------------------------------------------------------------
# activation hints for the model interior
# --------------------------------------------------------------------------


def activation_hints(mesh) -> dict:
    from repro.models.perf_flags import current as _perf

    fsdp = fsdp_axes(mesh)
    tp = tp_axis(mesh)
    seq = tp if (ShardingOptions.sequence_parallel
                 or _perf().sequence_parallel) else None
    moe_expert_axis = None if _perf().moe_fsdp_tp else tp
    return {
        # [B, S, D]
        "activation": P(fsdp, seq, None),
        # [G, E, C, d] MoE dispatch buffer: groups over FSDP; experts over TP
        # only under expert parallelism (baseline)
        "moe_dispatch": P(fsdp, moe_expert_axis, None, None),
        # [G, T, d] MoE combine output (psum lands here under moe_fsdp_tp)
        "moe_out": P(fsdp, None, None),
        # CE-loss head weight resharding (loss_weight_gather lever):
        # untied [D, V]: replicate D, keep V on tp; tied [V, D]: same idea
        "loss_head": P(None, tp),
        "loss_head_tied": P(tp, None),
        # [B, C, V] logits chunk
        "logits": P(fsdp, None, tp),
    }


def hint_context(mesh):
    from repro.models.sharding_hints import hint_context as _ctx

    return _ctx(activation_hints(mesh), mesh)

"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch llsc-100m \
        --steps 200 --batch 8 --seq 256 [--reduced] [--ckpt-dir ckpts/run1]

On this CPU container full-size archs are launched with --reduced (same
family/pattern, tiny dims); on a real pod the same entrypoint builds the
production mesh and shards via repro.launch.sharding.

XLA flags for a real TPU run (latency-hiding overlap of the gradient
collectives with backward compute) are recorded here so the launcher is the
single source of truth:

    --xla_tpu_enable_async_collective_fusion=true
    --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
    --xla_tpu_overlap_compute_collective_tc=true
    --xla_enable_async_all_gather=true
"""
from __future__ import annotations

import argparse

from repro.configs import get_config, reduced_config
from repro.launch.fault import CrashInjector
from repro.train.trainer import Trainer, TrainerConfig

TPU_XLA_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true"
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llsc-100m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU smoke) config of the arch")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a failure at this step (restart demo)")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    tcfg = TrainerConfig(steps=args.steps, batch_size=args.batch,
                         seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         job_name=f"train:{cfg.name}")
    crash = CrashInjector(args.crash_at) if args.crash_at else None
    trainer = Trainer(cfg, tcfg, crash=crash)
    out = trainer.run(resume=not args.no_resume)
    print(f"[launch.train] done: start_step={out['start_step']} "
          f"final_loss={out['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Fault tolerance & elasticity for 1000+-node runs.

Three mechanisms, all LLload-integrated (the paper's monitoring is what
*detects* the conditions; this module *acts* on them):

  * Checkpoint/restart — atomic step checkpoints (train/checkpoint.py),
    ``resume_latest`` picks the newest complete step after any crash or
    preemption.  Checkpoints are mesh-independent, so a restart may use a
    different device count (elastic re-scaling) — params are re-sharded on
    load against the new mesh.
  * Straggler detection — per-host step wall-times are published into the
    LLload registry; a host persistently slower than the fleet median by
    ``slow_factor`` is flagged (on a real pod: trigger checkpoint + evict +
    restart without it).  This is the LLload "-t N" idea pointed at step
    time instead of CPU load.
  * Failure simulation hooks for tests: `CrashInjector` raises at a chosen
    step so the restart path is exercised end-to-end.
  * Elastic resize — :class:`ElasticResizePlan` is the shrink decision a
    ``multi_tenant_fairness`` insight actuates (DESIGN.md §11): a tenant
    holding most of the fleet while others queue gets its jobs
    resubmitted at a reduced task count, the same mesh-independent
    re-scaling the checkpoint layer supports.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerReport:
    host: str
    median_step_s: float
    host_step_s: float
    factor: float


class StragglerDetector:
    """Tracks per-host step times (a real deployment feeds one entry per
    host from its LLload self-report; tests feed synthetic fleets)."""

    def __init__(self, slow_factor: float = 1.5, window: int = 16):
        self.slow_factor = slow_factor
        self.window = window
        self._times: Dict[str, List[float]] = {}

    def record(self, host: str, step_s: float):
        buf = self._times.setdefault(host, [])
        buf.append(step_s)
        if len(buf) > self.window:
            buf.pop(0)

    def stragglers(self) -> List[StragglerReport]:
        if len(self._times) < 2:
            return []
        means = {h: statistics.fmean(v) for h, v in self._times.items()
                 if v}
        med = statistics.median(means.values())
        out = []
        for host, m in means.items():
            if med > 0 and m / med >= self.slow_factor:
                out.append(StragglerReport(host, med, m, m / med))
        return sorted(out, key=lambda r: -r.factor)


@dataclasses.dataclass(frozen=True)
class ElasticResizePlan:
    """A shrink decision for one dominant tenant's jobs.

    ``shrink`` maps a job's current task count to its resized one:
    ``max(min_tasks, int(n_tasks * factor))`` — deterministic, so the
    closed loop (insight → resize → resubmit) replays identically.
    A plan never grows a job (``factor`` is clamped to <= 1.0).
    """
    username: str
    factor: float = 0.5
    min_tasks: int = 1

    def shrink(self, n_tasks: int) -> int:
        """The resized task count for a job of ``n_tasks`` tasks."""
        factor = min(self.factor, 1.0)
        return max(self.min_tasks, int(n_tasks * factor))


class CrashInjector:
    """Deterministic failure injection for restart tests."""

    def __init__(self, crash_at_step: Optional[int] = None):
        self.crash_at_step = crash_at_step
        self.fired = False

    def maybe_crash(self, step: int):
        if (self.crash_at_step is not None and step == self.crash_at_step
                and not self.fired):
            self.fired = True
            raise RuntimeError(f"injected node failure at step {step}")


def resume_latest(ckpt_dir: str, state_template, shardings=None):
    """(state, start_step) — state_template if no checkpoint exists."""
    from repro.train import checkpoint as ckpt  # (lazy: avoids import cycle)

    step = ckpt.latest_step(ckpt_dir)
    if step is None:
        return None, 0
    state, meta = ckpt.restore_checkpoint(ckpt_dir, step, state_template,
                                          shardings)
    return state, int(meta["step"])

"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (CPU smoke runs: 1 device -> 1x1 mesh)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def fsdp_axes(mesh) -> tuple:
    """The axes parameters/batch shard over (FSDP): pod+data when present."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def tp_axis(mesh) -> str:
    return "model"


def axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n

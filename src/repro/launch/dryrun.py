import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and extract memory / cost / collective statistics for the roofline.

The two lines above MUST precede any jax import: jax locks the device count
at first initialization, and the dry-run needs 512 placeholder host devices
to build the (pod=2, data=16, model=16) mesh.  (Smoke tests and benchmarks
never import this module, so they keep seeing 1 device.)

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-4b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (SHAPES, get_config, input_specs,  # noqa: E402
                           shape_applicable)
from repro.configs.archs import ASSIGNED  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.sharding import (batch_shardings, cache_shardings,  # noqa: E402
                                   hint_context, param_shardings)
from repro.models import model as model_lib  # noqa: E402
from repro.roofline.analysis import roofline  # noqa: E402
from repro.train.train_step import (default_opt_cfg,  # noqa: E402
                                    init_train_state_shape, make_train_step)


def _use_mesh(mesh):
    try:
        return jax.sharding.use_mesh(mesh)
    except AttributeError:  # older jax: Mesh as context manager
        return mesh


# --------------------------------------------------------------------------
# Step builders: (fn, example_args, in_shardings, donate_argnums)
# --------------------------------------------------------------------------


def build_cell(cfg, shape, mesh):
    specs = input_specs(cfg, shape)
    dt = jnp.dtype(cfg.dtype)

    if shape.kind == "train":
        opt_cfg = default_opt_cfg(cfg)
        step = make_train_step(cfg, opt_cfg)
        state = init_train_state_shape(cfg, opt_cfg)
        batch = {k: v for k, v in specs.items()}
        args = (state, batch)
        shardings = (param_shardings(mesh, state), batch_shardings(mesh, batch))
        return step, args, shardings, (0,)

    params = model_lib.init_params_shape(cfg, dtype=dt)
    p_sh = param_shardings(mesh, params)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return model_lib.prefill(params, cfg, batch["tokens"],
                                     batch.get("frontend"))
        batch = dict(specs)
        args = (params, batch)
        return prefill_fn, args, (p_sh, batch_shardings(mesh, batch)), ()

    if shape.kind == "decode":
        def serve_step(params, caches, token, cache_len):
            return model_lib.decode_step(params, cfg, token, caches,
                                         cache_len)
        caches = specs["caches"]
        args = (params, caches, specs["token"], specs["cache_len"])
        shardings = (p_sh, cache_shardings(mesh, caches),
                     batch_shardings(mesh, specs["token"]),
                     jax.sharding.NamedSharding(
                         mesh, jax.sharding.PartitionSpec()))
        return serve_step, args, shardings, (1,)

    raise ValueError(shape.kind)


# --------------------------------------------------------------------------
# One cell
# --------------------------------------------------------------------------


def _compile_cell(cfg, shape, mesh, *, unroll: bool):
    from contextlib import nullcontext

    from repro.models.scan_util import unroll_scans

    ctx = unroll_scans() if unroll else nullcontext()
    with _use_mesh(mesh), hint_context(mesh), ctx:
        fn, args, shardings, donate = build_cell(cfg, shape, mesh)
        jfn = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
    return compiled


def _extract_cost(compiled) -> dict:
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    cost = dict(cost) if cost else {}
    from repro.roofline.analysis import parse_collective_bytes

    coll = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective": {k: v for k, v in coll.items() if k != "_op_counts"},
        "op_counts": coll.get("_op_counts"),
    }


def _reduced_depth(cfg, periods: int):
    return dataclasses.replace(
        cfg, name=f"{cfg.name}", n_layers=cfg.period * periods + cfg.n_remainder)


def probe_costs(cfg, shape, mesh) -> dict:
    """Exact per-device cost via two unrolled reduced-depth compiles.

    cost_analysis counts while-loop bodies once, so the scanned full model
    under-reports by ~n_periods.  Costs are affine in the period count
    (identical periods), so cost(P) = c1 + (P-1) * (c2 - c1) is exact.
    """
    P = cfg.n_periods
    if P <= 2:
        c = _extract_cost(_compile_cell(cfg, shape, mesh, unroll=True))
        c["probe"] = f"unrolled-full(P={P})"
        return c
    c1 = _extract_cost(_compile_cell(_reduced_depth(cfg, 1), shape, mesh,
                                     unroll=True))
    c2 = _extract_cost(_compile_cell(_reduced_depth(cfg, 2), shape, mesh,
                                     unroll=True))

    def affine(a, b):
        return a + (P - 1) * (b - a)

    coll = {k: affine(c1["collective"][k], c2["collective"][k])
            for k in c1["collective"]}
    return {
        "flops": affine(c1["flops"], c2["flops"]),
        "bytes": affine(c1["bytes"], c2["bytes"]),
        "collective": coll,
        "op_counts": c2.get("op_counts"),
        "probe": f"two-point(P=1,2 -> {P})",
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True, flags=None) -> dict:
    from contextlib import nullcontext

    from repro.models.perf_flags import PerfFlags, perf_flags

    flags = flags or PerfFlags()
    with perf_flags(flags):
        return _run_cell_inner(arch, shape_name, multi_pod=multi_pod,
                               verbose=verbose, flags=flags)


def _run_cell_inner(arch: str, shape_name: str, *, multi_pod: bool,
                    verbose: bool, flags) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    # 1) full-config compile (scanned): proves lowering + memory analysis
    compiled = _compile_cell(cfg, shape, mesh, unroll=False)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_info = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            mem_info[attr] = getattr(mem, attr, None)

    # 2) cost probes (unrolled, depth-extrapolated): exact FLOPs/bytes/comm
    t1 = time.time()
    cost = probe_costs(cfg, shape, mesh)
    t_probe = time.time() - t1

    n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
    mf = model_lib.model_flops(cfg, n_tokens, training=(shape.kind == "train"))
    hlo_stub = ""  # collective bytes already extracted by the probes
    terms = roofline({"flops": cost["flops"], "bytes accessed": cost["bytes"]},
                     hlo_stub, n_devices=n_dev, model_flops_global=mf)
    # overwrite collective numbers with probe-extrapolated values
    from repro.roofline import hw
    coll_bytes = sum(cost["collective"].values())
    terms.collective_bytes = coll_bytes
    terms.collective_s = coll_bytes / hw.ICI_BW_PER_LINK
    terms.collective_breakdown = {**cost["collective"],
                                  "op_counts": cost.get("op_counts")}
    tmap = {"compute": terms.compute_s, "memory": terms.memory_s,
            "collective": terms.collective_s}
    terms.dominant = max(tmap, key=tmap.get)
    t_lower, t_compile = 0.0, t_compile

    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "mesh": dict(mesh.shape),
        "n_devices": n_dev,
        "perf_flags": flags.active(),
        "compile_s": round(t_compile, 2), "probe_s": round(t_probe, 2),
        "cost_probe": cost.get("probe"),
        "memory_analysis": mem_info,
        "flops_per_device": terms.flops,
        "hbm_bytes_per_device": terms.hbm_bytes,
        "collective_bytes_per_device": terms.collective_bytes,
        "collective_breakdown": terms.collective_breakdown,
        "compute_s": terms.compute_s,
        "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "dominant": terms.dominant,
        "model_flops_global": mf,
        "useful_flops_ratio": terms.useful_ratio,
        "params": model_lib.count_params(cfg),
        "params_active": model_lib.count_params_analytic(cfg, True),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} "
              f"({'multi' if multi_pod else 'single'}-pod {n_dev} chips): "
              f"compile {t_compile:.1f}s probe {t_probe:.1f}s "
              f"[{cost.get('probe')}]")
        print(f"  memory_analysis: {mem_info}")
        print(f"  flops/dev={terms.flops:.3e} hbm/dev={terms.hbm_bytes:.3e} "
              f"coll/dev={terms.collective_bytes:.3e}")
        print(f"  terms: compute={terms.compute_s * 1e3:.2f}ms "
              f"memory={terms.memory_s * 1e3:.2f}ms "
              f"collective={terms.collective_s * 1e3:.2f}ms "
              f"-> dominant={terms.dominant} "
              f"useful={terms.useful_ratio:.2f}")
    return result


def cells(archs=None, shapes=None):
    for arch in (archs or ASSIGNED):
        cfg = get_config(arch)
        for shape_name in (shapes or list(SHAPES)):
            yield arch, shape_name


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--flags", default="",
                    help="comma-separated perf flags (see models/perf_flags)")
    args = ap.parse_args(argv)

    from repro.models.perf_flags import PerfFlags

    flags = PerfFlags.parse(args.flags)
    suffix = ("__" + "+".join(flags.active())) if flags.active() else ""

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else None
    shapes = [args.shape] if args.shape else None
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    failures = []
    for arch, shape_name in cells(archs, shapes):
        for mp in meshes:
            tag = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}{suffix}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[dryrun] {tag}: cached")
                continue
            try:
                res = run_cell(arch, shape_name, multi_pod=mp, flags=flags)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                res = {"arch": arch, "shape": shape_name, "multi_pod": mp,
                       "status": "error", "error": repr(e)}
                failures.append(tag)
            with open(path, "w") as f:
                json.dump(res, f, indent=2, default=str)
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        return 1
    print("[dryrun] all requested cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Serving launcher CLI: batched requests against any arch with LLload
monitoring and overload-aware admission.

    PYTHONPATH=src python -m repro.launch.serve --arch llsc-100m --reduced \
        --requests 16 --slots 4 [--max-new 16]

The engine publishes per-step duty cycle into the LLload registry; at the
end it prints the LLload view of itself plus the controller's NPPN verdict
(the paper's overloading loop applied to this very job).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.collector import JaxJobRegistry
from repro.models import init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llsc-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, EngineConfig(
        slots=args.slots, max_seq_len=args.max_seq,
        job_name=f"serve:{cfg.name}"))

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size,
                                           args.prompt_len).astype(np.int32),
                           max_new_tokens=args.max_new))
    stats = eng.run()
    print(f"[serve:{cfg.name}] {stats['requests']} requests, "
          f"{stats['tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tokens_per_s']:.1f} tok/s, {stats['steps']} steps)")
    agg = JaxJobRegistry.global_registry().aggregate()
    print(f"LLload view: duty={agg.duty_cycle:.3f} "
          f"step={agg.step_time_s * 1e3:.1f}ms")
    d = stats["decision"]
    print(f"Overload controller: slots {args.slots} -> {d.nppn} ({d.reason})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""LLload daemon — the telemetry bus served over HTTP (DESIGN.md §6).

One process collects (through a :class:`~repro.monitor.bus.TelemetryBus`
and any :class:`~repro.monitor.source.MetricSource`), many clients read
over the network::

    python -m repro.daemon --source sim --port 8080
    curl localhost:8080/healthz
    LLload --source remote --url http://localhost:8080 -t 10

Endpoints (all GET):

    /snapshot            versioned wire JSON of the current snapshot
    /query?table=T&...   the unified query engine (DESIGN.md §7):
                         filter/sort/columns/group_by/limit over
                         nodes|users|jobs|history, any registry format
    /view/user?user=U    rendered per-user view (text, ``&gpu=1`` for -g)
    /view/top?n=N        rendered top-N loaded nodes (text)
    /view/nodes?hosts=A,B  rendered node detail (text)
      (all /view/* accept &filter=&sort=&columns=&limit=&format= —
       the CLI's query flags pass through verbatim)
    /insights            the §V-B advise view (DESIGN.md §8), answered
                         from the daemon's incremental InsightEngine —
                         text by default, any registry format via
                         &format=, query params pass through verbatim
    /experiments?spec=J  run a §V-B overloading campaign server-side
                         (DESIGN.md §9) and render its experiments
                         table; deterministic per spec, memoized
    /trend?window=S      downsampled series from the history store
    /weekly              weekly low/over-utilization report from tiers
    /stream?frames=N     chunked JSON-lines frame stream (DESIGN.md §14):
                         a full keyframe on subscribe, deltas after, each
                         with a monotonic seq; ?frames bounds the
                         subscription server-side
    /healthz             liveness + wire version
    /stats               bus / store / request / stream counters (JSON)
    /metrics             Prometheus text exposition

This is the repo's first request-serving hot path: responses for the
cacheable endpoints are encoded **once** per TTL window and the same
bytes are handed to every concurrent reader — N readers cost one
collection *and* one JSON encode (`/stats` shows ``http_cache_hits``
doing the work).  ``/stream`` extends the same amortization to watchers:
one delta encode per collection is fanned out to every subscriber by the
:class:`~repro.daemon.stream.StreamHub`, so steady-state watch traffic
is O(changed nodes), not O(nodes), per interval.
"""
from __future__ import annotations

import argparse
import threading
import time
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple

from repro.core import formatting
from repro.daemon import promtext, protocol
from repro.daemon.store import (HistoryStore, JobHistoryStore,
                                as_snapshots, job_sample)
from repro.daemon.stream import DEFAULT_QUEUE_MAX, StreamHub
from repro.insights import InsightEngine
from repro.monitor import TelemetryBus, build_source
from repro.query import (Query, QueryError, advise_query, apply_modifiers,
                         get_renderer, resolve_format, run_query,
                         view_query)

JSON_CT = "application/json; charset=utf-8"
TEXT_CT = "text/plain; charset=utf-8"

# endpoints whose bytes may be reused within a TTL window (everything
# derived purely from the current snapshot / store state; /experiments
# is deterministic per spec and additionally memoized across windows)
_CACHEABLE = ("/snapshot", "/query", "/view/", "/metrics", "/trend",
              "/weekly", "/insights", "/experiments", "/job/")

# the fixed label vocabulary for the per-endpoint request counter:
# arbitrary client paths must not mint new Prometheus label values (label
# injection + unbounded counter growth), so anything else counts as other
_KNOWN_ENDPOINTS = frozenset([
    "/snapshot", "/query", "/view/user", "/view/top", "/view/nodes",
    "/insights", "/experiments", "/trend", "/weekly", "/healthz",
    "/stats", "/metrics", "/job", "/stream",
])

STREAM_CT = "application/x-ndjson; charset=utf-8"


class HTTPError(Exception):
    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


class LLloadDaemon:
    """The request-handling core, independent of the HTTP plumbing (so
    tests and benchmarks can call :meth:`handle` directly)."""

    def __init__(self, source, *, ttl_s: float = 2.0,
                 store: Optional[HistoryStore] = None,
                 privileged: Optional[set] = None,
                 history: int = 64, storage=None,
                 stream_keyframe_every: int =
                 protocol.STREAM_KEYFRAME_EVERY,
                 stream_queue_max: int = DEFAULT_QUEUE_MAX):
        self.bus = TelemetryBus(ttl_s=ttl_s, history=history)
        self.bus.register(source)
        self.source = source
        # optional durable storage (repro.storage.StorageRuntime): both
        # history stores gain a write-ahead backend and recover their
        # pre-restart state before the sampler delivers anything
        self.storage = storage
        # llcheck: ignore[LL001] written only during __init__ recovery, read-only once serving starts
        self.recovered: Dict[str, Dict[str, int]] = {}
        if store is not None:
            self.store = store
        else:
            self.store = HistoryStore(
                backend=storage.history if storage is not None else None)
        self.bus.subscribe(self.store.subscriber(source.name))
        # the insight engine streams alongside the history store: every
        # collection is folded once, so /insights reads are O(active)
        self.insights = InsightEngine()
        self.bus.subscribe(self.insights.subscriber(source.name))
        # the job-keyed tier streams the same way: one fold per
        # collection, so /job/{id} and the job_history table are O(read)
        self.jobstore = JobHistoryStore(
            backend=storage.jobs if storage is not None else None)
        self.bus.subscribe(self.jobstore.subscriber(source.name))
        # the stream hub subscribes like the stores: every new collection
        # is delta-encoded once and fanned out to /stream subscribers
        self.hub = StreamHub(keyframe_every=stream_keyframe_every,
                             queue_max=stream_queue_max)
        self.bus.subscribe(self.hub.publish)
        if storage is not None:
            self.recovered = {"history": self.store.recover(),
                              "jobs": self.jobstore.recover()}
        self.privileged = privileged if privileged is not None else set()
        self.ttl_s = ttl_s
        self._started = time.monotonic()
        self._lock = threading.Lock()
        self._requests: Dict[str, int] = {}          # guarded-by: _lock
        self._cache_hits = 0                         # guarded-by: _lock
        self._errors = 0                             # guarded-by: _lock
        # endpoint byte-cache: key -> (expires_monotonic, status, ct, body)
        self._cache: Dict[str, Tuple[float, int, str, bytes]] = {}  # guarded-by: _lock
        self._build_locks: Dict[str, threading.Lock] = {}  # guarded-by: _lock
        # campaign results survive TTL expiry: a campaign is seeded and
        # deterministic, so re-running one on every cache window would be
        # pure waste — keyed by (spec JSON, cells), small FIFO, with a
        # per-key run lock (the byte-cache's single-flight keys on the
        # full query string, so format=table and format=csv of the same
        # campaign would otherwise run the sweep twice)
        self._experiment_memo: Dict[Tuple[str, str], object] = {}  # guarded-by: _lock
        # guarded-by: _lock
        self._experiment_locks: Dict[Tuple[str, str], threading.Lock] = {}

    # ----------------------------------------------------------- lifecycle
    def start_sampler(self, interval_s: Optional[float] = None):
        """Start the bus's background sampler (default period: the
        source's interval hint, else the TTL)."""
        self.bus.start(interval_s)

    def backfill(self, archive_or_snaps) -> int:
        """Replay an archive (or any snapshot iterable) into the history
        store AND the insight engine, so a restarted daemon serves
        /trend, /weekly and /insights with real history — persistence
        and first-seen survive the restart instead of starting cold."""
        n = 0
        for snap in as_snapshots(archive_or_snaps):
            self.store.append(snap)
            self.insights.observe(snap)
            self.jobstore.observe(snap)
            n += 1
        return n

    def close(self):
        """Stop the background sampler, wake every /stream subscriber
        with a sentinel (SIGTERM drains cleanly) and, when durable
        storage is attached, stop its compactor + segment writers
        (idempotent)."""
        self.bus.stop()
        self.hub.close()
        if self.storage is not None:
            self.storage.close()

    # -------------------------------------------------------------- stream
    def stream_subscribe(self, *, frames: Optional[int] = None):
        """Register a ``/stream`` subscriber: primes the hub with a
        current snapshot if nothing was ever published (a frozen daemon
        still owes the subscriber one keyframe), then subscribes — the
        first delivered frame is always a keyframe at the current seq."""
        if self.hub.empty():
            try:
                # reading the bus publishes through the subscriber chain
                # when it collects; prime() covers the cached-read case
                self.hub.prime(self.bus.read(self.source.name))
            except Exception as exc:  # noqa: BLE001 — surfaced as HTTP 503
                raise HTTPError(
                    503, f"source collection failed: {exc}") from exc
        return self.hub.subscribe(frames=frames)

    # ------------------------------------------------------------ counters
    def counters(self) -> Dict[str, float]:
        """HTTP + bus counters in Prometheus sample-name form (the
        ``/stats`` payload and ``/metrics`` counter section)."""
        with self._lock:
            # llcheck: ignore[LL003] endpoint labels are bounded: handle() folds unknown paths into "other" via _KNOWN_ENDPOINTS
            out = {f'requests_total{{endpoint="{ep}"}}': float(n)
                   for ep, n in self._requests.items()}
            out["http_cache_hits_total"] = float(self._cache_hits)
            out["http_errors_total"] = float(self._errors)
        st = self.bus.stats(self.source.name)
        out["bus_collections_total"] = float(st.collections)
        out["bus_reads_total"] = float(st.reads)
        hub = self.hub.stats()
        out["stream_frames_sent_total"] = hub["frames_sent"]
        out["stream_evicted_total"] = hub["evicted"]
        out["stream_resyncs_total"] = hub["resyncs"]
        return out

    def count_request(self, endpoint: str) -> None:
        """Count one request against a bounded endpoint label (the
        ``/stream`` handler bypasses :meth:`handle`, so it counts here)."""
        endpoint = endpoint if endpoint in _KNOWN_ENDPOINTS else "other"
        with self._lock:
            self._requests[endpoint] = self._requests.get(endpoint, 0) + 1

    def count_error(self) -> None:
        """Count one HTTP error response (``/stream`` handler path)."""
        with self._lock:
            self._errors += 1

    # ------------------------------------------------------------- handle
    def handle(self, path: str,
               query: Optional[Dict[str, str]] = None
               ) -> Tuple[int, str, bytes]:
        """Serve one request; returns (status, content type, body)."""
        query = query or {}
        # /job/{id} carries an arbitrary id in the path: count it as
        # "/job" so request-counter labels stay bounded
        endpoint = ("/job" if path.startswith("/job/")
                    else path if path in _KNOWN_ENDPOINTS else "other")
        with self._lock:
            self._requests[endpoint] = self._requests.get(endpoint, 0) + 1

        try:
            if path in ("/healthz", "/stats"):     # always fresh
                return self._dispatch(path, query)
            if any(path == p or (p.endswith("/") and path.startswith(p))
                   for p in _CACHEABLE):
                return self._cached(path, query)
            raise HTTPError(404, f"unknown endpoint {path!r}")
        except HTTPError as exc:
            with self._lock:
                self._errors += 1
            body = protocol.dumps(protocol.encode_error(exc.message,
                                                        exc.status))
            return exc.status, JSON_CT, body
        except Exception as exc:  # noqa: BLE001 — never kill the server
            with self._lock:
                self._errors += 1
            body = protocol.dumps(protocol.encode_error(
                f"{type(exc).__name__}: {exc}", 500))
            return 500, JSON_CT, body

    def _cached(self, path: str, query: Dict[str, str]
                ) -> Tuple[int, str, bytes]:
        key = path + "?" + urllib.parse.urlencode(sorted(query.items()))
        now = time.monotonic()
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None and now < hit[0]:
                self._cache_hits += 1
                return hit[1:]
            build_lock = self._build_locks.setdefault(key, threading.Lock())
        with build_lock:
            # single-flight: whoever got here first built it already
            now = time.monotonic()
            with self._lock:
                hit = self._cache.get(key)
                if hit is not None and now < hit[0]:
                    self._cache_hits += 1
                    return hit[1:]
            ok = False
            try:
                status, ct, body = self._dispatch(path, query)
                ok = status == 200
            finally:
                if not ok:
                    # nothing was cached (dispatch raised or errored), so
                    # the build lock would leak one entry per distinct bad
                    # path/query; duplicate rebuilds of an error are cheap
                    with self._lock:
                        self._build_locks.pop(key, None)
            if ok:
                with self._lock:
                    if len(self._cache) >= 512:
                        # bound memory against unbounded distinct query
                        # strings: drop expired entries, then worst case
                        # start over (rebuilding is one TTL window of work)
                        now = time.monotonic()
                        self._cache = {k: v for k, v in self._cache.items()
                                       if now < v[0]}
                        if len(self._cache) >= 512:
                            self._cache.clear()
                        self._build_locks = {
                            k: v for k, v in self._build_locks.items()
                            if k in self._cache}
                    self._cache[key] = (time.monotonic() + self.ttl_s,
                                        status, ct, body)
            return status, ct, body

    # ----------------------------------------------------------- endpoints
    def _dispatch(self, path: str, query: Dict[str, str]
                  ) -> Tuple[int, str, bytes]:
        if path == "/healthz":
            return 200, JSON_CT, protocol.dumps({
                "status": "ok",
                "source": self.source.name,
                "wire_version": protocol.WIRE_VERSION,
                "uptime_s": time.monotonic() - self._started,
                "ttl_s": self.ttl_s})
        if path == "/stats":
            st = self.bus.stats(self.source.name)
            payload = {
                "bus": {"reads": st.reads, "cache_hits": st.cache_hits,
                        "collections": st.collections, "errors": st.errors},
                "store": self.store.sizes(),
                "jobstore": self.jobstore.sizes(),
                "stream": self.hub.stats(),
                "http": self.counters()}
            if self.storage is not None:
                payload["storage"] = self.storage.stats()
            if hasattr(self.source, "stale_children"):
                # fan-in daemon: surface per-child health so an operator
                # sees a severed child as a count, not a frozen merge
                stale = self.source.stale_children()
                payload["fanin"] = {
                    "stale_children": len(stale),
                    "stale": {k: round(v, 3) for k, v in stale.items()},
                    "staleness": {k: round(v, 3) for k, v in
                                  self.source.staleness().items()}}
            return 200, JSON_CT, protocol.dumps(payload)
        if path == "/snapshot":
            snap = self.bus.read(self.source.name)
            return 200, JSON_CT, protocol.dumps(
                protocol.encode_snapshot(snap))
        if path == "/metrics":
            snap = self.bus.read(self.source.name)
            text = promtext.render_prometheus(
                snap, counters=self.counters(),
                insights=self.insights.active(),
                job_samples=[job_sample(snap, j) for j in snap.jobs])
            return 200, promtext.CONTENT_TYPE, text.encode("utf-8")
        if path == "/trend":
            window = _float_q(query, "window")
            tier = query.get("tier")
            if tier is None:
                tier = (self.store.select_tier(window)
                        if window is not None else "raw")
            try:
                wire = self.store.trend_wire(tier, window)
            except KeyError as exc:
                raise HTTPError(400, str(exc)) from exc
            return 200, JSON_CT, protocol.dumps(
                protocol.envelope("trend", wire))
        if path == "/weekly":
            snap = self.bus.read(self.source.name)
            try:
                rep = self.store.weekly_report(
                    emails=snap.user_emails,
                    start=_float_q(query, "start"),
                    end=_float_q(query, "end"))
            except KeyError as exc:
                raise HTTPError(400, str(exc)) from exc
            payload = {"start": rep.start, "end": rep.end}
            for cat in ("low_gpu", "low_cpu", "high_cpu"):
                payload[cat] = [
                    {"username": r.username, "email": r.email,
                     "node_hours": r.node_hours}
                    for r in getattr(rep, cat)]
            return 200, JSON_CT, protocol.dumps(
                protocol.envelope("weekly", payload))
        if path == "/query":
            return self._query(query)
        if path == "/insights":
            return self._insights(query)
        if path == "/experiments":
            return self._experiments(query)
        if path.startswith("/view/"):
            return self._view(path[len("/view/"):], query)
        if path.startswith("/job/"):
            return self._job(path[len("/job/"):])
        raise HTTPError(404, f"unknown endpoint {path!r}")

    def _query(self, query: Dict[str, str]) -> Tuple[int, str, bytes]:
        """The unified query engine over HTTP; same vocabulary, same
        renderers, same JSON schema as the local CLI (DESIGN.md §7)."""
        fmt = query.get("format") or "json"
        try:
            q = Query.from_params(
                table=query.get("table"),
                columns=query.get("columns"),
                filter=query.get("filter"),
                sort=query.get("sort"),
                group_by=query.get("group_by"),
                limit=query.get("limit"))
            renderer = get_renderer(fmt)
            snap = self.bus.read(self.source.name)
            rs = run_query(snap, q, store=self.store,
                           insights=self.insights,
                           jobstore=self.jobstore)
            body = renderer.render(rs)      # prom may reject dup labels
        except QueryError as exc:
            raise HTTPError(400, str(exc)) from exc
        return 200, renderer.content_type, body.encode("utf-8")

    def _insights(self, query: Dict[str, str]) -> Tuple[int, str, bytes]:
        """The advise view (DESIGN.md §8), answered from the streaming
        insight engine; same canned query + modifier overlay as the
        local CLI, so ``--source remote --advise`` is byte-identical."""
        snap = self.bus.read(self.source.name)   # feeds the engine if stale
        try:
            q = apply_modifiers(
                advise_query(),
                columns=query.get("columns"),
                filter=query.get("filter"),
                sort=query.get("sort"),
                group_by=query.get("group_by"),
                limit=_int_q(query, "limit", default=None))
            fmt = resolve_format(query.get("format"),
                                 query.get("columns"),
                                 query.get("group_by"))
            rs = run_query(snap, q, store=self.store,
                           insights=self.insights)
            if fmt != "text":
                renderer = get_renderer(fmt)
                return (200, renderer.content_type,
                        renderer.render(rs).encode("utf-8"))
            text = formatting.advise_view_text(snap, rs.rows)
        except QueryError as exc:
            raise HTTPError(400, str(exc)) from exc
        return 200, TEXT_CT, (text + "\n").encode("utf-8")

    def _experiments(self, query: Dict[str, str]
                     ) -> Tuple[int, str, bytes]:
        """Run (or recall) a §V-B overloading campaign server-side
        (DESIGN.md §9): ``?spec=`` carries the canonical campaign JSON
        the CLI's ``--experiment --source remote`` forwards, ``?cells=``
        the grid subset, and the §7 query params shape the rendered
        ``experiments`` table.  Results are memoized per (spec, cells) —
        campaigns are deterministic — so only the first reader pays for
        the sweep."""
        import json

        from repro.experiments import (CampaignError, CampaignRunner,
                                       campaign_from_dict, render_result)

        spec = query.get("spec")
        if not spec:
            raise HTTPError(400, "/experiments requires ?spec=JSON (the "
                            "canonical campaign the CLI forwards; see "
                            "Campaign.spec_json)")
        cells = query.get("cells") or ""
        key = (spec, cells)
        with self._lock:
            run_lock = self._experiment_locks.setdefault(
                key, threading.Lock())
        with run_lock:
            # single-flight per campaign: whoever got here first ran it
            with self._lock:
                result = self._experiment_memo.get(key)
            if result is None:
                try:
                    campaign = campaign_from_dict(json.loads(spec))
                    selected = campaign.select_cells(cells or None)
                except (CampaignError, json.JSONDecodeError) as exc:
                    with self._lock:
                        self._experiment_locks.pop(key, None)
                    raise HTTPError(400,
                                    f"bad campaign spec: {exc}") from exc
                result = CampaignRunner(campaign, cells=selected).run()
                with self._lock:
                    while len(self._experiment_memo) >= 8:
                        evicted = next(iter(self._experiment_memo))
                        self._experiment_memo.pop(evicted)
                        self._experiment_locks.pop(evicted, None)
                    self._experiment_memo[key] = result
        fmt = query.get("format") or "table"
        try:
            renderer = get_renderer("table" if fmt == "text" else fmt)
            body = render_result(
                result, columns=query.get("columns"),
                filter=query.get("filter"), sort=query.get("sort"),
                group_by=query.get("group_by"),
                limit=_int_q(query, "limit", default=None),
                fmt=renderer.name)
        except QueryError as exc:
            raise HTTPError(400, str(exc)) from exc
        return 200, renderer.content_type, body.encode("utf-8")

    def _job(self, id_part: str) -> Tuple[int, str, bytes]:
        """The MPCDF-style job report (DESIGN.md §11), answered from the
        job-keyed history tier; the same render path the local CLI uses,
        so ``LLload --job ID --source remote`` is byte-identical."""
        try:
            job_id = int(id_part)
        except ValueError as exc:
            raise HTTPError(400, f"/job/{{id}} needs an integer job id, "
                            f"got {id_part!r}") from exc
        snap = self.bus.read(self.source.name)   # feeds the store if stale
        samples = self.jobstore.raw_points(job_id)
        lifetime = self.jobstore.lifetime(job_id)
        if not samples or lifetime is None:
            raise HTTPError(404, f"unknown job {job_id} (not in the "
                            "current snapshot or retained history)")
        text = formatting.job_report_text(snap.cluster, samples, lifetime)
        return 200, TEXT_CT, (text + "\n").encode("utf-8")

    def _view(self, kind: str, query: Dict[str, str]
              ) -> Tuple[int, str, bytes]:
        if kind not in ("user", "top", "nodes"):
            raise HTTPError(404, f"unknown view {kind!r}")
        snap = self.bus.read(self.source.name)
        user = query.get("user")
        gpu = query.get("gpu", "0") not in ("0", "", "false")
        n = _int_q(query, "n", default=10)
        hosts = [h.strip() for h in query.get("hosts", "").split(",")
                 if h.strip()]
        if kind == "user" and not user:
            raise HTTPError(400, "/view/user requires ?user=NAME")
        if kind == "top" and n <= 0:
            raise HTTPError(400, "?n must be > 0")
        if kind == "nodes" and not hosts:
            raise HTTPError(400, "/view/nodes requires ?hosts=A,B")
        try:
            canned = view_query(kind, user=user or "", n=n, hosts=hosts)
            q = apply_modifiers(
                canned,
                columns=query.get("columns"),
                filter=query.get("filter"),
                sort=query.get("sort"),
                group_by=query.get("group_by"),
                limit=_int_q(query, "limit", default=None))
            fmt = resolve_format(query.get("format"),
                                 query.get("columns"),
                                 query.get("group_by"))
            rs = run_query(snap, q, store=self.store)
            if fmt != "text":
                renderer = get_renderer(fmt)
                return (200, renderer.content_type,
                        renderer.render(rs).encode("utf-8"))
            if kind == "user":
                text = formatting.user_view_text(snap, rs.rows, user, gpu)
            elif kind == "top":
                text = formatting.top_view_text(rs.rows, q.limit or n)
            else:
                text = formatting.node_detail_text(snap, rs.rows, hosts)
        except QueryError as exc:
            raise HTTPError(400, str(exc)) from exc
        return 200, TEXT_CT, (text + "\n").encode("utf-8")


def _float_q(query: Dict[str, str], key: str) -> Optional[float]:
    if key not in query:
        return None
    try:
        return float(query[key])
    except ValueError as exc:
        raise HTTPError(400, f"?{key} must be a number") from exc


def _int_q(query: Dict[str, str], key: str, default: int) -> int:
    if key not in query:
        return default
    try:
        return int(query[key])
    except ValueError as exc:
        raise HTTPError(400, f"?{key} must be an integer") from exc


# --------------------------------------------------------------------------
# HTTP plumbing
# --------------------------------------------------------------------------


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"

    def do_GET(self):  # noqa: N802 — http.server API
        parsed = urllib.parse.urlsplit(self.path)
        query = {k: v[-1] for k, v in
                 urllib.parse.parse_qs(parsed.query).items()}
        if parsed.path == "/stream":
            self._do_stream(query)
            return
        status, ctype, body = self.server.daemon.handle(parsed.path, query)
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        try:
            self.wfile.write(body)
        except (BrokenPipeError, ConnectionResetError):
            pass                       # client went away mid-response

    def _do_stream(self, query: Dict[str, str]):
        """Serve ``GET /stream``: subscribe to the hub and relay frames
        as chunked JSON lines until the subscription ends (hub close,
        slow-consumer eviction, ?frames delivered) or the client hangs
        up.  This is the one endpoint that holds its connection open, so
        it bypasses the byte-cache/Content-Length path entirely."""
        daemon = self.server.daemon
        daemon.count_request("/stream")
        try:
            frames = None
            if "frames" in query:
                try:
                    frames = int(query["frames"])
                except ValueError as exc:
                    raise HTTPError(400,
                                    "?frames must be an integer") from exc
                if frames <= 0:
                    raise HTTPError(400, "?frames must be > 0")
            sub = daemon.stream_subscribe(frames=frames)
        except HTTPError as exc:
            daemon.count_error()
            body = protocol.dumps(protocol.encode_error(exc.message,
                                                        exc.status))
            self.send_response(exc.status)
            self.send_header("Content-Type", JSON_CT)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            try:
                self.wfile.write(body)
            except (BrokenPipeError, ConnectionResetError):
                pass
            return
        self.send_response(200)
        self.send_header("Content-Type", STREAM_CT)
        self.send_header("Transfer-Encoding", "chunked")
        self.send_header("Cache-Control", "no-store")
        self.end_headers()
        try:
            while True:
                item = sub.get(timeout=0.5)
                if item is None:
                    break               # sentinel: stream ended cleanly
                if item == b"":
                    if sub.closed or sub.evicted:
                        break           # ended while we were waiting
                    continue            # idle poll: nothing collected yet
                self.wfile.write(b"%X\r\n" % len(item) + item + b"\r\n")
                self.wfile.flush()
            self.wfile.write(b"0\r\n\r\n")
        except (BrokenPipeError, ConnectionResetError):
            pass                        # client went away mid-stream
        finally:
            daemon.hub.unsubscribe(sub)
            self.close_connection = True

    def log_message(self, fmt, *args):  # noqa: D102 — quiet by default
        pass


class DaemonServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, addr, daemon: LLloadDaemon):
        super().__init__(addr, _Handler)
        self.daemon = daemon


def serve(daemon: LLloadDaemon, *, host: str = "127.0.0.1",
          port: int = 0) -> DaemonServer:
    """Bind (port 0 => ephemeral) and return the server; the caller runs
    ``serve_forever()`` (or ``serve_background`` does it on a thread)."""
    return DaemonServer((host, port), daemon)


def serve_background(daemon: LLloadDaemon, *, host: str = "127.0.0.1",
                     port: int = 0) -> Tuple[DaemonServer, threading.Thread]:
    """Bind and serve on a daemon thread; returns (server, thread) so
    tests/benchmarks can shut it down deterministically."""
    server = serve(daemon, host=host, port=port)
    thread = threading.Thread(target=server.serve_forever,
                              name="llload-daemon", daemon=True)
    thread.start()
    return server, thread


# --------------------------------------------------------------------------
# CLI (python -m repro.daemon)
# --------------------------------------------------------------------------


def backfill_sources(path: str):
    """Resolve a ``--backfill`` argument into ``(label, replayable)``
    pairs: a single TSV file, a flat directory of daily TSVs, or an
    archive root holding one subdirectory per cluster."""
    import os

    from repro.core.archive import SnapshotArchive
    from repro.monitor.source import ArchiveSource

    if os.path.isfile(path):
        return [(path, ArchiveSource([path]).frames())]
    subdirs = [os.path.join(path, d) for d in sorted(os.listdir(path))
               if os.path.isdir(os.path.join(path, d))]
    out = []
    for sub in (subdirs or [path]):
        cluster = os.path.basename(sub)
        out.append((sub, SnapshotArchive(os.path.dirname(sub) or ".",
                                         cluster)))
    return out


def main(argv=None) -> int:
    """``python -m repro.daemon``: build the selected source, optionally
    backfill the history store from a TSV archive, start the sampler,
    and serve until SIGTERM/SIGINT."""
    from repro.core.cli import _positive_float
    from repro.monitor import default_registry

    ap = argparse.ArgumentParser(
        prog="python -m repro.daemon",
        description="LLload telemetry daemon: one collector, many "
                    "HTTP readers")
    ap.add_argument("--source", default="sim",
                    choices=default_registry().names())
    ap.add_argument("--cluster", default=None, metavar="NAME[,NAME]",
                    help="cluster selection; several names fan out and "
                         "merge (multi-cluster daemon)")
    ap.add_argument("--archive-dir", default=None,
                    help="TSV archive root for --source archive")
    ap.add_argument("--url", default=None, metavar="URL[,URL]",
                    help="upstream daemon URL(s) for --source remote "
                         "(cluster-of-clusters); children are consumed "
                         "via their /stream push channel, falling back "
                         "to polling against pre-stream daemons")
    ap.add_argument("--max-staleness", type=_positive_float, default=None,
                    metavar="S", help="multi-child fan-in: drop a "
                    "failing child from merges once its last good "
                    "snapshot is older than S seconds (surfaced as "
                    "stale_children in /stats; default: serve the last "
                    "good snapshot indefinitely)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="0 picks an ephemeral port")
    ap.add_argument("--ttl", type=_positive_float, default=2.0,
                    metavar="S", help="snapshot/response cache TTL")
    ap.add_argument("--interval", type=_positive_float, default=None,
                    metavar="S", help="background sampler period "
                                      "(default: source hint or TTL)")
    ap.add_argument("--backfill", default=None, metavar="PATH",
                    help="replay a TSV archive into the history store at "
                         "startup: a single TSV file, a flat directory of "
                         "daily TSVs, or an archive root of per-cluster "
                         "subdirectories (the archive must share the "
                         "source's clock: live snapshots older than the "
                         "newest backfilled bucket are dropped from the "
                         "tiers)")
    ap.add_argument("--data-dir", default=None, metavar="DIR",
                    help="durable storage root: history and job stores "
                         "persist to append-only segment files and a "
                         "restarted daemon recovers them (default: "
                         "in-memory only)")
    ap.add_argument("--retain-raw", type=_positive_float, default=86400.0,
                    metavar="S", help="with --data-dir: keep compacted "
                                      "raw segments this long")
    ap.add_argument("--retain-tiers", type=_positive_float,
                    default=90 * 86400.0, metavar="S",
                    help="with --data-dir: keep downsampled tier / "
                         "per-user / per-job segments this long")
    ap.add_argument("--compact-interval", type=_positive_float,
                    default=30.0, metavar="S",
                    help="with --data-dir: background compaction period")
    ap.add_argument("--segment-records", type=int, default=1024,
                    metavar="N", help="with --data-dir: records per "
                                      "segment before it seals")
    args = ap.parse_args(argv)

    from repro.core.cli import make_source_from_args
    # daemon-over-daemon fan-in is a persistent consumer: subscribe to
    # each child's /stream instead of re-polling full snapshots per tick
    args.stream = True
    source = make_source_from_args(args)

    storage = None
    if args.data_dir:
        from repro.storage import open_storage
        storage = open_storage(args.data_dir,
                               segment_records=max(1, args.segment_records),
                               retain_raw_s=args.retain_raw,
                               retain_tier_s=args.retain_tiers,
                               compact_interval_s=args.compact_interval)

    daemon = LLloadDaemon(source, ttl_s=args.ttl, storage=storage)
    if storage is not None:
        rec = daemon.recovered
        print(f"llload daemon: data dir {args.data_dir} "
              f"(recovered {rec['history'].get('tier_points', 0)} tier "
              f"points, {rec['history'].get('ring_refilled', 0) + rec['history'].get('replayed', 0)} "
              f"raw snapshots, {rec['jobs'].get('jobs', 0)} jobs)",
              flush=True)
    if args.backfill:
        total = 0
        for label, replayable in backfill_sources(args.backfill):
            n = daemon.backfill(replayable)
            print(f"backfilled {n} snapshots from {label}", flush=True)
            total += n
        print(f"backfilled {total} snapshots into the history store",
              flush=True)
    if storage is not None:
        storage.start()
    daemon.start_sampler(args.interval)

    server = serve(daemon, host=args.host, port=args.port)
    host, port = server.server_address[:2]
    print(f"llload daemon: source={source.name} listening on "
          f"http://{host}:{port} (ttl {args.ttl}s)", flush=True)

    import signal

    def _stop(signum, frame):
        threading.Thread(target=server.shutdown, daemon=True).start()

    signal.signal(signal.SIGTERM, _stop)
    signal.signal(signal.SIGINT, _stop)
    try:
        server.serve_forever()
    finally:
        server.server_close()
        daemon.close()
        print("llload daemon: stopped", flush=True)
    return 0

"""Multi-resolution downsampling history store (DESIGN.md §6).

Raw snapshots land in a bounded ring; every append also folds a
per-snapshot *summary* into coarser tiers (15-minute, hourly by default)
that keep min/mean/max aggregates per time bucket.  ``/trend`` and
``/weekly`` answer from the pre-aggregated tiers instead of replaying raw
snapshots, so the cost of a week-window query is the number of *buckets*,
not the number of snapshots — and raw snapshots can age out of the ring
without losing the history the coarse tiers already absorbed.

Per-user utilization flags (the weekly low/over-utilization node counts,
paper §V-A thresholds) are folded into the 15-minute tier from one
representative snapshot per bucket — the same cadence the TSV archive
captures — so :meth:`HistoryStore.weekly_report` reproduces the archive
pipeline's weekly analysis from tiers alone.

An existing :class:`~repro.core.archive.SnapshotArchive` can be replayed
into the store with :meth:`HistoryStore.backfill`, so a freshly started
daemon serves week-deep ``/trend`` and ``/weekly`` immediately.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import math
import threading
from typing import Deque, Dict, Iterable, List, Optional, Tuple

from repro.core.analysis import (SNAPSHOT_INTERVAL_HOURS, WeeklyReport,
                                 weekly_from_buckets)
from repro.core.metrics import ClusterSnapshot, JobRecord


def as_snapshots(archive_or_snaps) -> Iterable[ClusterSnapshot]:
    """Normalize a backfill input: a SnapshotArchive (anything with
    ``as_source``) replays through its frames, any other iterable of
    snapshots passes through (shared by HistoryStore.backfill and
    LLloadDaemon.backfill)."""
    if hasattr(archive_or_snaps, "as_source"):
        return archive_or_snaps.as_source().frames()
    return archive_or_snaps


@dataclasses.dataclass
class Agg:
    """Running min/mean/max over the values folded into one bucket."""
    min: float = math.inf
    mean: float = 0.0
    max: float = -math.inf
    n: int = 0

    def fold(self, v: float):
        """Absorb one value (incremental mean, running min/max)."""
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self.mean += (v - self.mean) / (self.n + 1)
        self.n += 1

    def to_wire(self) -> Dict[str, float]:
        """The aggregate as its ``/trend`` wire dict."""
        return {"min": self.min, "mean": self.mean, "max": self.max}


_AGG_FIELDS = ("norm_load", "gpu_load", "nodes", "cores_used",
               "mem_used_gb", "gpus_used")


@dataclasses.dataclass
class TierPoint:
    """One downsampled bucket: aggregates over every snapshot folded in."""
    bucket_start: float            # snapshot-time bucket boundary
    count: int = 0                 # snapshots folded into this bucket
    norm_load: Agg = dataclasses.field(default_factory=Agg)
    gpu_load: Agg = dataclasses.field(default_factory=Agg)
    nodes: Agg = dataclasses.field(default_factory=Agg)
    cores_used: Agg = dataclasses.field(default_factory=Agg)
    mem_used_gb: Agg = dataclasses.field(default_factory=Agg)
    gpus_used: Agg = dataclasses.field(default_factory=Agg)
    # user -> (low_gpu_nodes, low_cpu_nodes, high_cpu_nodes) from the
    # bucket's representative (first) snapshot — the archive-cadence view
    user_flags: Dict[str, Tuple[int, int, int]] = \
        dataclasses.field(default_factory=dict)

    def fold(self, summary: "_Summary", *, representative: bool):
        """Absorb one snapshot summary; ``representative`` marks the
        bucket's flag-carrying snapshot (the archive-cadence view)."""
        for f in _AGG_FIELDS:
            getattr(self, f).fold(getattr(summary, f))
        if representative or not self.user_flags:
            self.user_flags = summary.user_flags
        self.count += 1

    def to_wire(self) -> Dict[str, object]:
        """The bucket as one ``/trend`` point (``t``, ``count``, plus
        min/mean/max per aggregated field)."""
        out: Dict[str, object] = {"t": self.bucket_start, "count": self.count}
        for f in _AGG_FIELDS:
            out[f] = getattr(self, f).to_wire()
        return out


@dataclasses.dataclass
class _Summary:
    """Cluster-level scalars of one snapshot (computed once per append)."""
    timestamp: float
    norm_load: float
    gpu_load: float
    nodes: float
    cores_used: float
    mem_used_gb: float
    gpus_used: float
    user_flags: Dict[str, Tuple[int, int, int]]


def summarize(snap: ClusterSnapshot,
              low_threshold: Optional[float] = None) -> _Summary:
    """Reduce one snapshot to the cluster-level scalars + per-user
    utilization flags the tiers fold (computed once per append)."""
    from repro.core.analysis import LOW_THRESHOLD

    low = LOW_THRESHOLD if low_threshold is None else low_threshold
    high = 1.0 + (1.0 - low)
    nodes = list(snap.nodes.values())
    gpu_nodes = [n for n in nodes if n.gpus_total > 0]
    mean = lambda vs: sum(vs) / len(vs) if vs else 0.0  # noqa: E731
    # attribute each node to the first running job's owner — the same
    # rule as ClusterSnapshot.to_tsv, so weekly_report reproduces the
    # archive pipeline exactly (no double counting on shared nodes)
    owner: Dict[str, str] = {}
    for job in snap.jobs:
        if job.state != "R":
            continue
        for h in job.nodes:
            owner.setdefault(h, job.username)
    flags: Dict[str, Tuple[int, int, int]] = {}
    for h, user in owner.items():
        n = snap.nodes.get(h)
        if n is None:
            continue
        lg, lc, hc = flags.get(user, (0, 0, 0))
        if n.gpus_total > 0 and n.gpu_load < low:
            lg += 1
        if n.norm_load < low:
            lc += 1
        if n.norm_load > high:
            hc += 1
        flags[user] = (lg, lc, hc)
    return _Summary(
        timestamp=snap.timestamp,
        norm_load=mean([n.norm_load for n in nodes]),
        gpu_load=mean([n.gpu_load for n in gpu_nodes]),
        nodes=float(len(nodes)),
        cores_used=float(sum(n.cores_used for n in nodes)),
        mem_used_gb=float(sum(n.mem_used_gb for n in nodes)),
        gpus_used=float(sum(n.gpus_used for n in nodes)),
        user_flags=flags)


@dataclasses.dataclass
class TierSpec:
    name: str
    bucket_s: float
    capacity: int


DEFAULT_TIERS = (
    TierSpec("15min", 900.0, capacity=4 * 24 * 7),      # one week
    TierSpec("hourly", 3600.0, capacity=24 * 90),       # one quarter
)


class _Tier:
    def __init__(self, spec: TierSpec):
        self.spec = spec
        self.points: Deque[TierPoint] = collections.deque(
            maxlen=spec.capacity)
        self.current: Optional[TierPoint] = None
        self.last_t: Optional[float] = None     # newest folded timestamp

    def fold(self, summary: _Summary) -> bool:
        """Fold one summary; returns False when the snapshot is not
        newer than the last one folded (mixed clocks — e.g. an
        epoch-stamped backfill followed by a sim-clock source — or a
        re-delivered snapshot).  Folding it anyway would corrupt the
        open bucket's aggregates, so it is dropped from this tier (the
        raw ring still holds out-of-order ones) and the caller counts
        it.  The ``<=`` makes the fold restart-tolerant: replaying the
        last folded snapshot after recovery is a no-op, the same policy
        as :meth:`_JobSeries.fold`."""
        if self.last_t is not None and summary.timestamp <= self.last_t:
            return False
        start = math.floor(summary.timestamp / self.spec.bucket_s) \
            * self.spec.bucket_s
        cur = self.current
        if cur is not None and start < cur.bucket_start:
            return False
        if cur is None or start > cur.bucket_start:
            if cur is not None:
                self.points.append(cur)
            cur = self.current = TierPoint(bucket_start=start)
        cur.fold(summary, representative=cur.count == 0)
        self.last_t = summary.timestamp
        return True

    def all_points(self) -> List[TierPoint]:
        """Finalized points plus the in-progress bucket.  Must be called
        under the store lock; finalized points are never mutated again,
        but ``current`` still is — hand out a copy so readers serializing
        it outside the lock cannot see a half-folded update."""
        pts = list(self.points)
        if self.current is not None:
            pts.append(copy.deepcopy(self.current))
        return pts


class HistoryStore:
    """Raw ring + downsampling tiers; thread-safe (bus subscriber on one
    thread, HTTP readers on many)."""

    def __init__(self, *, raw_capacity: int = 256,
                 tiers: Iterable[TierSpec] = DEFAULT_TIERS,
                 low_threshold: Optional[float] = None,
                 backend=None):
        # guarded-by: _lock
        self._raw: Deque[ClusterSnapshot] = collections.deque(
            maxlen=raw_capacity)
        # llcheck: ignore[LL001] fixed after construction; the mutable per-tier state inside is only touched under _lock
        self._tiers = [_Tier(spec) for spec in tiers]
        self._low = low_threshold
        self._appended = 0                      # guarded-by: _lock
        self._out_of_order = 0                  # guarded-by: _lock
        self._duplicates = 0                    # guarded-by: _lock
        # last ring-appended t
        self._last_t: Optional[float] = None    # guarded-by: _lock
        self._lock = threading.Lock()
        # optional durable backend (repro.storage.HistoryBackend shape):
        # every accepted append is write-ahead logged, recover() rebuilds
        # the tiers + ring from disk
        self._backend = backend
        if backend is not None:
            backend.configure(tiers=[t.spec for t in self._tiers],
                              low_threshold=low_threshold,
                              raw_capacity=raw_capacity)

    # ------------------------------------------------------------- writes
    def append(self, snap: ClusterSnapshot):
        """Absorb one snapshot: raw ring + every downsampling tier.
        Out-of-order snapshots are dropped from tiers; an exact repeat of
        the previous timestamp (a re-delivered or frozen-clock snapshot)
        is dropped entirely.  Both are counted in :meth:`sizes`."""
        summary = summarize(snap, self._low)
        with self._lock:
            self._absorb(snap, summary, persist=True)

    def _absorb(self, snap: ClusterSnapshot, summary: _Summary,
                persist: bool):                  # guarded-by: _lock
        """The fold under the lock; recovery replays through this with
        ``persist=False`` so replayed records are not re-logged."""
        if self._last_t is not None and snap.timestamp == self._last_t:
            self._duplicates += 1
            return
        self._raw.append(snap)
        self._appended += 1
        self._last_t = snap.timestamp
        for tier in self._tiers:
            if not tier.fold(summary):
                self._out_of_order += 1
        if persist and self._backend is not None:
            self._backend.append_snapshot(snap)

    def recover(self) -> Dict[str, int]:
        """Rebuild tiers, ring and counters from the durable backend
        (no-op without one).  Returns the backend's recovery counts."""
        if self._backend is None:
            return {}
        return self._backend.recover_history(self)

    def subscriber(self, source_name: Optional[str] = None):
        """A TelemetryBus subscriber feeding this store."""
        def fn(name: str, snap: ClusterSnapshot):
            if source_name is None or name == source_name:
                self.append(snap)
        return fn

    def backfill(self, archive_or_snaps) -> int:
        """Replay an archive (or any snapshot iterable) into the store."""
        n = 0
        for snap in as_snapshots(archive_or_snaps):
            self.append(snap)
            n += 1
        return n

    # -------------------------------------------------------------- reads
    def tier_names(self) -> List[str]:
        """``raw`` plus every downsampling tier name, finest first."""
        return ["raw"] + [t.spec.name for t in self._tiers]

    def sizes(self) -> Dict[str, int]:
        """Occupancy per tier plus append / out-of-order-drop counters
        (the ``/stats`` store section)."""
        with self._lock:
            out = {"raw": len(self._raw), "appended": self._appended,
                   "out_of_order_dropped": self._out_of_order,
                   "duplicate_dropped": self._duplicates}
            for t in self._tiers:
                out[t.spec.name] = len(t.all_points())
            return out

    def raw(self) -> List[ClusterSnapshot]:
        """The raw snapshot ring, oldest first."""
        with self._lock:
            return list(self._raw)

    def points(self, tier: str,
               window_s: Optional[float] = None) -> List[TierPoint]:
        """``tier``'s buckets (optionally only the trailing
        ``window_s``); raises KeyError for unknown tier names."""
        with self._lock:
            for t in self._tiers:
                if t.spec.name == tier:
                    pts = t.all_points()
                    break
            else:
                raise KeyError(
                    f"unknown tier {tier!r}; have {self.tier_names()}")
        if window_s is not None and pts:
            horizon = pts[-1].bucket_start - window_s
            pts = [p for p in pts if p.bucket_start >= horizon]
        return pts

    def select_tier(self, window_s: float) -> str:
        """Finest tier whose retained span covers ``window_s``."""
        with self._lock:
            raw = list(self._raw)
            if len(raw) >= 2 and \
                    raw[-1].timestamp - raw[0].timestamp >= window_s:
                return "raw"
            for t in self._tiers:
                pts = t.all_points()
                if pts and pts[-1].bucket_start - pts[0].bucket_start \
                        >= window_s:
                    return t.spec.name
            return self._tiers[-1].spec.name if self._tiers else "raw"

    def trend_wire(self, tier: str,
                   window_s: Optional[float] = None) -> Dict[str, object]:
        """The ``/trend`` payload for ``tier``: ``{"tier", "points"}``
        (raw snapshots summarize on the fly into one-count points)."""
        if tier == "raw":
            with self._lock:
                raw = list(self._raw)
            if window_s is not None and raw:
                horizon = raw[-1].timestamp - window_s
                raw = [s for s in raw if s.timestamp >= horizon]
            pts = []
            for snap in raw:
                s = summarize(snap, self._low)
                pts.append({"t": s.timestamp, "count": 1,
                            **{f: {"min": getattr(s, f),
                                   "mean": getattr(s, f),
                                   "max": getattr(s, f)}
                               for f in _AGG_FIELDS}})
            return {"tier": "raw", "points": pts}
        return {"tier": tier,
                "points": [p.to_wire() for p in self.points(tier, window_s)]}

    def weekly_report(self, emails: Optional[Dict[str, str]] = None,
                      start: Optional[float] = None,
                      end: Optional[float] = None,
                      tier: Optional[str] = None) -> WeeklyReport:
        """The paper's weekly analysis, answered from a tier's per-user
        utilization flags instead of replaying archive rows.  Default
        tier: the store's finest (closest to the archive cadence)."""
        if tier is None:
            if not self._tiers:
                raise KeyError("store has no downsampling tiers")
            tier = self._tiers[0].spec.name
        pts = self.points(tier)
        interval_hours = next(
            (t.spec.bucket_s / 3600.0 for t in self._tiers
             if t.spec.name == tier), SNAPSHOT_INTERVAL_HOURS)
        buckets = [(p.bucket_start, p.user_flags) for p in pts
                   if (start is None or p.bucket_start >= start)
                   and (end is None or p.bucket_start <= end)]
        # an explicit window reaching past the in-memory tier answers the
        # cold part from the backend's user-keyed flag shards (the finest
        # tier is what compaction persisted, so cadence matches)
        if (self._backend is not None and start is not None
                and self._tiers and tier == self._tiers[0].spec.name):
            first_mem = buckets[0][0] if buckets else None
            if first_mem is None or start < first_mem:
                disk = self._backend.weekly_flags(start, end)
                buckets = sorted(
                    [(t, uf) for t, uf in disk.items()
                     if first_mem is None or t < first_mem] + buckets,
                    key=lambda b: b[0])
        return weekly_from_buckets(buckets, emails=emails,
                                   interval_hours=interval_hours)


# ---------------------------------------------------------------------------
# Job-keyed history tier (DESIGN.md §11)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class JobSample:
    """One job's per-snapshot sample — self-reported wire fields when the
    producer filled them, otherwise derived from the job's nodes."""
    t: float
    job_id: int
    username: str
    name: str
    state: str
    n_nodes: int
    gpu_duty: float
    cpu_load: float
    mem_used_gb: float
    mem_total_gb: float
    gpu_mem_used_gb: float
    gpu_mem_total_gb: float
    queue_wait_s: float
    step_time_s: float


def job_sample(snap: ClusterSnapshot, job: JobRecord) -> JobSample:
    """Sample one job from one snapshot.

    Self-reported per-job wire fields (``gpu_duty``, ``cpu_load``,
    ``mem_used_gb``, ``step_time_s``) win when non-zero; otherwise the
    sample is the mean over the job's nodes — which is exact under the
    paper's whole-node scheduling (the job is the only tenant).  Queue
    wait is ``start - submit`` for started jobs and ``now - submit`` for
    still-pending ones (0.0 when the producer reports no submit time).
    """
    nodes = [n for n in (snap.nodes.get(h) for h in job.nodes)
             if n is not None]
    k = max(len(nodes), 1)
    duty = job.gpu_duty or (sum(n.gpu_load for n in nodes) / k)
    cpu = job.cpu_load or (sum(n.norm_load for n in nodes) / k)
    mem = job.mem_used_gb or (sum(n.mem_used_gb for n in nodes) / k)
    if job.submit_time <= 0.0:
        wait = 0.0
    elif job.state == "PD" or not job.start_time:
        wait = max(0.0, snap.timestamp - job.submit_time)
    else:
        wait = max(0.0, job.start_time - job.submit_time)
    return JobSample(
        t=snap.timestamp, job_id=job.job_id, username=job.username,
        name=job.name, state=job.state, n_nodes=len(job.nodes),
        gpu_duty=duty, cpu_load=cpu, mem_used_gb=mem,
        mem_total_gb=sum(n.mem_total_gb for n in nodes) / k,
        gpu_mem_used_gb=sum(n.gpu_mem_used_gb for n in nodes) / k,
        gpu_mem_total_gb=sum(n.gpu_mem_total_gb for n in nodes) / k,
        queue_wait_s=wait, step_time_s=job.step_time_s)


_JOB_AGG_FIELDS = ("gpu_duty", "cpu_load", "mem_used_gb", "step_time_s")


@dataclasses.dataclass
class JobPoint:
    """One downsampled per-job bucket (15-minute by default)."""
    bucket_start: float
    count: int = 0
    gpu_duty: Agg = dataclasses.field(default_factory=Agg)
    cpu_load: Agg = dataclasses.field(default_factory=Agg)
    mem_used_gb: Agg = dataclasses.field(default_factory=Agg)
    step_time_s: Agg = dataclasses.field(default_factory=Agg)

    def fold(self, sample: JobSample):
        """Fold one sample into every aggregated field."""
        for f in _JOB_AGG_FIELDS:
            getattr(self, f).fold(getattr(sample, f))
        self.count += 1


class _JobSeries:
    """One job's retained history: raw ring, 15-min tier, lifetime
    aggregates (which survive raw/tier aging-out)."""

    def __init__(self, raw_capacity: int, bucket_s: float,
                 bucket_capacity: int):
        self.bucket_s = bucket_s
        self.raw: Deque[JobSample] = collections.deque(maxlen=raw_capacity)
        self.points: Deque[JobPoint] = collections.deque(
            maxlen=bucket_capacity)
        self.current: Optional[JobPoint] = None
        self.last: Optional[JobSample] = None       # newest sample seen
        self.lifetime = {f: Agg() for f in _JOB_AGG_FIELDS}

    def fold(self, sample: JobSample) -> bool:
        """Absorb one sample.  Samples at or before the newest retained
        timestamp are dropped (returns False): the same restart-tolerant
        policy as :meth:`_Tier.fold`, plus duplicate suppression so
        re-observing a cached snapshot (every poll inside a daemon's TTL
        window) cannot skew the aggregates."""
        if self.last is not None and sample.t <= self.last.t:
            return False
        start = math.floor(sample.t / self.bucket_s) * self.bucket_s
        cur = self.current
        if cur is None or start > cur.bucket_start:
            if cur is not None:
                self.points.append(cur)
            cur = self.current = JobPoint(bucket_start=start)
        cur.fold(sample)
        self.raw.append(sample)
        self.last = sample
        for f in _JOB_AGG_FIELDS:
            self.lifetime[f].fold(getattr(sample, f))
        return True

    def all_points(self) -> List[JobPoint]:
        """Finalized buckets plus a copy of the open one (same torn-read
        discipline as :meth:`_Tier.all_points`; call under the lock)."""
        pts = list(self.points)
        if self.current is not None:
            pts.append(copy.deepcopy(self.current))
        return pts


class JobHistoryStore:
    """Job-keyed history: per-job raw ring → 15-min downsampling, with
    bounded per-job retention and a bounded job population (least-
    recently-seen jobs evicted first).  Thread-safe, same reader/writer
    discipline as :class:`HistoryStore`."""

    def __init__(self, *, raw_per_job: int = 64, bucket_s: float = 900.0,
                 buckets_per_job: int = 4 * 24 * 7,
                 max_jobs: int = 4096, backend=None):
        self.raw_per_job = raw_per_job
        self.bucket_s = bucket_s
        self.buckets_per_job = buckets_per_job
        self.max_jobs = max_jobs
        # guarded-by: _lock
        self._jobs: "collections.OrderedDict[int, _JobSeries]" = \
            collections.OrderedDict()
        self._appended = 0                      # guarded-by: _lock
        self._dropped = 0                       # guarded-by: _lock
        self._evicted = 0                       # guarded-by: _lock
        self._reloaded = 0                      # guarded-by: _lock
        self._lock = threading.Lock()
        # optional durable backend (repro.storage.JobHistoryBackend
        # shape): accepted samples are write-ahead logged per job shard,
        # evicted jobs reload from their shard on the next touch
        self._backend = backend
        if backend is not None:
            backend.configure(bucket_s=bucket_s, raw_per_job=raw_per_job,
                              buckets_per_job=buckets_per_job)

    # ------------------------------------------------------------- writes
    def observe(self, snap: ClusterSnapshot):
        """Fold every job of one snapshot (bus-subscriber entry point)."""
        samples = [job_sample(snap, job) for job in snap.jobs]
        with self._lock:
            for s in samples:
                series = self._jobs.get(s.job_id)
                if series is None:
                    series = self._revive(s.job_id)
                if series.fold(s):
                    self._appended += 1
                    if self._backend is not None:
                        self._backend.append_sample(s)
                else:
                    self._dropped += 1
                self._jobs.move_to_end(s.job_id)
            self._evict()

    def _evict(self):                            # guarded-by: _lock
        while len(self._jobs) > self.max_jobs:
            self._jobs.popitem(last=False)
            self._evicted += 1

    def _revive(self, job_id: int) -> _JobSeries:  # guarded-by: _lock
        """A series for a job not in memory: reloaded from the backend
        shard when one exists (evicted or pre-restart jobs come back with
        their history), fresh otherwise.  Call under the lock."""
        series = None
        if self._backend is not None:
            series = self._backend.load_series(
                job_id, self.raw_per_job, self.bucket_s,
                self.buckets_per_job)
            if series is not None:
                self._reloaded += 1
        if series is None:
            series = _JobSeries(self.raw_per_job, self.bucket_s,
                                self.buckets_per_job)
        self._jobs[job_id] = series
        return series

    def _series(self, job_id: int) -> Optional[_JobSeries]:  # guarded-by: _lock
        """Read-path lookup: memory first, then a cold reload from the
        backend shard (which counts toward the LRS population and may
        evict).  Call under the lock."""
        series = self._jobs.get(job_id)
        if series is not None:
            return series
        if self._backend is None or not self._backend.has_job(job_id):
            return None
        series = self._revive(job_id)
        self._evict()
        return series

    def recover(self) -> Dict[str, int]:
        """Load the most recently active jobs (up to ``max_jobs``) from
        the durable backend; no-op without one."""
        if self._backend is None:
            return {}
        ids = self._backend.recover_ids()[-self.max_jobs:]
        n = 0
        with self._lock:
            for job_id, _ in ids:           # oldest first = LRS order
                series = self._backend.load_series(
                    job_id, self.raw_per_job, self.bucket_s,
                    self.buckets_per_job)
                if series is not None:
                    self._jobs[job_id] = series
                    self._reloaded += 1
                    n += 1
        return {"jobs": n}

    def subscriber(self, source_name: Optional[str] = None):
        """A TelemetryBus subscriber feeding this store."""
        def fn(name: str, snap: ClusterSnapshot):
            if source_name is None or name == source_name:
                self.observe(snap)
        return fn

    # -------------------------------------------------------------- reads
    def job_ids(self) -> List[int]:
        """Tracked job ids, least recently seen first."""
        with self._lock:
            return list(self._jobs)

    def sizes(self) -> Dict[str, int]:
        """Occupancy (job count, retained raw samples and buckets across
        every in-memory series) + append/drop/evict/reload counters
        (``/stats``)."""
        with self._lock:
            raw_samples = sum(len(s.raw) for s in self._jobs.values())
            buckets = sum(
                len(s.points) + (1 if s.current is not None else 0)
                for s in self._jobs.values())
            return {"jobs": len(self._jobs),
                    "raw_samples": raw_samples, "buckets": buckets,
                    "appended": self._appended, "dropped": self._dropped,
                    "evicted": self._evicted, "reloaded": self._reloaded}

    def raw_points(self, job_id: int) -> List[JobSample]:
        """``job_id``'s raw ring, oldest first (empty when unknown)."""
        with self._lock:
            series = self._series(job_id)
            return list(series.raw) if series is not None else []

    def points(self, job_id: int) -> List[JobPoint]:
        """``job_id``'s 15-min buckets (empty when unknown)."""
        with self._lock:
            series = self._series(job_id)
            return series.all_points() if series is not None else []

    def lifetime(self, job_id: int) -> Optional[Dict[str, Agg]]:
        """Lifetime min/mean/max per sampled field, or ``None``."""
        with self._lock:
            series = self._series(job_id)
            if series is None:
                return None
            return {f: copy.deepcopy(a)
                    for f, a in series.lifetime.items()}

    def last_sample(self, job_id: int) -> Optional[JobSample]:
        """The newest retained sample of ``job_id``, or ``None``."""
        with self._lock:
            series = self._series(job_id)
            return series.last if series is not None else None

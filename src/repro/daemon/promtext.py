"""Prometheus text exposition (the daemon's ``/metrics`` endpoint).

Renders a :class:`~repro.core.metrics.ClusterSnapshot` plus daemon
counters in the Prometheus text format (version 0.0.4): per-node gauges
carry ``cluster``/``host`` labels, per-user gauges carry ``user``, and
the daemon's own request/cache/collection counters are exposed so a
scraper can watch the cache doing its job.  No client library needed —
the format is lines of ``name{labels} value``.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.metrics import ClusterSnapshot

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# per-job gauges are label-bounded: at most JOB_LABEL_BUDGET jobs (top
# by device duty) get their own ``job``/``user`` labels, everything else
# folds into one ``job="other"`` series — the same hardening as the
# per-endpoint request counter (a 10k-job snapshot must not mint 10k
# label values per scrape)
JOB_LABEL_BUDGET = 8

_JOB_GAUGES = [
    # (metric suffix, help text, JobSample attribute, other-bucket agg)
    ("job_gpu_duty", "device duty cycle (MFU proxy)", "gpu_duty", "mean"),
    ("job_cpu_load", "normalized CPU load", "cpu_load", "mean"),
    ("job_mem_used_gb", "memory used (GB)", "mem_used_gb", "sum"),
    ("job_queue_wait_s", "submit-to-start wait (s)", "queue_wait_s",
     "mean"),
    ("job_nodes", "nodes the job occupies", "n_nodes", "sum"),
]

_NODE_GAUGES = [
    # (metric suffix, help text, NodeSnapshot attribute)
    ("node_cores_total", "CPU cores on the node", "cores_total"),
    ("node_cores_used", "CPU cores allocated", "cores_used"),
    ("node_load", "5-minute load average (absolute)", "load"),
    ("node_norm_load", "load / cores (1.0 == fully loaded)", "norm_load"),
    ("node_mem_total_gb", "system memory (GB)", "mem_total_gb"),
    ("node_mem_used_gb", "system memory used (GB)", "mem_used_gb"),
    ("node_gpus_total", "devices on the node", "gpus_total"),
    ("node_gpus_used", "devices allocated", "gpus_used"),
    ("node_gpu_load", "mean device duty cycle (0..1+)", "gpu_load"),
    ("node_gpu_mem_total_gb", "device memory (GB)", "gpu_mem_total_gb"),
    ("node_gpu_mem_used_gb", "device memory used (GB)", "gpu_mem_used_gb"),
]


def _escape(value: str) -> str:
    return (value.replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _labels(pairs: Iterable[Tuple[str, str]]) -> str:
    # llcheck: ignore[LL003] the one trusted formatting sink: every caller passes vocabulary keys and _escape()d, budget-folded values
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
    return "{" + inner + "}" if inner else ""


def _fmt(v: float) -> str:
    return repr(float(v))


class _Writer:
    def __init__(self):
        self.lines: List[str] = []

    def header(self, name: str, help_text: str, kind: str):
        self.lines.append(f"# HELP {name} {help_text}")
        self.lines.append(f"# TYPE {name} {kind}")

    def sample(self, name: str, labels: Iterable[Tuple[str, str]],
               value: float):
        self.lines.append(f"{name}{_labels(labels)} {_fmt(value)}")

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def render_prometheus(snap: ClusterSnapshot, *,
                      counters: Optional[Dict[str, float]] = None,
                      insights: Optional[List] = None,
                      job_samples: Optional[List] = None,
                      job_label_budget: int = JOB_LABEL_BUDGET,
                      prefix: str = "llload_") -> str:
    """One scrape body: snapshot gauges + optional daemon counters,
    active-insight gauges and bounded per-job gauges.

    ``job_samples`` is a list of :class:`~repro.daemon.store.JobSample`
    (the daemon samples its current snapshot); the ``job_label_budget``
    highest-duty jobs get their own ``job``/``user`` labels, the rest
    aggregate into ``job="other"`` so the metric family stays bounded no
    matter how many jobs the cluster runs (DESIGN.md §11).

    ``counters`` maps ``name`` or ``name{label="v"}``-style keys (already
    flattened by the caller) to monotonic values; they are emitted as
    ``counter`` type under ``<prefix>daemon_<name>``.  ``insights`` is
    the active Insight list (DESIGN.md §8): counts are exposed per
    (kind, severity) as ``<prefix>insights_active`` plus an
    ``<prefix>active_insights`` total, so a scraper can alert on
    ``llload_insights_active{severity="critical"} > 0``.
    """
    w = _Writer()
    cluster = snap.cluster

    w.header(f"{prefix}snapshot_timestamp_seconds",
             "snapshot time (cluster clock)", "gauge")
    w.sample(f"{prefix}snapshot_timestamp_seconds",
             [("cluster", cluster)], snap.timestamp)
    w.header(f"{prefix}cluster_nodes", "nodes in the snapshot", "gauge")
    w.sample(f"{prefix}cluster_nodes", [("cluster", cluster)],
             len(snap.nodes))

    for suffix, help_text, attr in _NODE_GAUGES:
        name = prefix + suffix
        w.header(name, help_text, "gauge")
        for host, node in snap.nodes.items():
            w.sample(name, [("cluster", cluster), ("host", host)],
                     getattr(node, attr))

    by_user = snap.nodes_by_user()
    w.header(f"{prefix}user_nodes", "nodes owned by the user", "gauge")
    for user in sorted(by_user):
        w.sample(f"{prefix}user_nodes",
                 [("cluster", cluster), ("user", user)],
                 len(by_user[user]))
    w.header(f"{prefix}user_gpu_duty",
             "mean device duty cycle across the user's device nodes",
             "gauge")
    for user in sorted(by_user):
        gpu_nodes = [snap.nodes[h] for h in by_user[user]
                     if h in snap.nodes and snap.nodes[h].gpus_total > 0]
        if gpu_nodes:
            duty = sum(n.gpu_load for n in gpu_nodes) / len(gpu_nodes)
            w.sample(f"{prefix}user_gpu_duty",
                     [("cluster", cluster), ("user", user)], duty)

    if job_samples is not None:
        w.header(f"{prefix}jobs_tracked", "jobs in the snapshot", "gauge")
        w.sample(f"{prefix}jobs_tracked", [("cluster", cluster)],
                 len(job_samples))
        ordered = sorted(job_samples,
                         key=lambda s: (-s.gpu_duty, s.job_id))
        top = ordered[:job_label_budget]
        rest = ordered[job_label_budget:]
        for suffix, help_text, attr, agg in _JOB_GAUGES:
            name = prefix + suffix
            w.header(name, help_text + " (top jobs by duty; the rest "
                     "fold into job=\"other\")", "gauge")
            for s in top:
                w.sample(name, [("cluster", cluster),
                                ("job", str(s.job_id)),
                                ("user", s.username)],
                         getattr(s, attr))
            if rest:
                vals = [getattr(s, attr) for s in rest]
                v = sum(vals) if agg == "sum" else sum(vals) / len(vals)
                w.sample(name, [("cluster", cluster), ("job", "other"),
                                ("user", "")], v)

    if insights is not None:
        name = f"{prefix}insights_active"
        w.header(name, "active insights by rule kind and severity",
                 "gauge")
        counts: Dict[Tuple[str, str], int] = {}
        for ins in insights:
            key = (ins.kind, str(ins.severity))
            counts[key] = counts.get(key, 0) + 1
        for kind, sev in sorted(counts):
            w.sample(name, [("cluster", cluster), ("kind", kind),
                            ("severity", sev)], counts[(kind, sev)])
        # no _total suffix: that is reserved for counters, and this is a
        # gauge of the currently-active set (rate() would be meaningless)
        total = f"{prefix}active_insights"
        w.header(total, "active insights (all kinds)", "gauge")
        w.sample(total, [("cluster", cluster)], sum(counts.values()))

    # counter keys may carry flattened labels: 'requests_total{endpoint="/x"}'
    emitted = set()
    for name in sorted(counters or {}):
        base = f"{prefix}daemon_{name.split('{', 1)[0]}"
        if base not in emitted:
            # llcheck: ignore[LL003] counter names come from the server's _KNOWN_ENDPOINTS-folded stats dict, not request data
            w.header(base, "daemon counter", "counter")
            emitted.add(base)
        w.lines.append(f"{prefix}daemon_{name} {_fmt(counters[name])}")
    return w.text()


def parse_prometheus(text: str) -> Dict[str, Dict[str, float]]:
    """Tiny exposition-format parser (for tests and the smoke job):
    returns ``{metric_name: {label_string: value}}``."""
    out: Dict[str, Dict[str, float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        body, _, value = line.rpartition(" ")
        if "{" in body:
            name = body[:body.index("{")]
            labels = body[body.index("{"):]
        else:
            name, labels = body, ""
        out.setdefault(name, {})[labels] = float(value)
    return out

"""Remote source + client for the LLload daemon.

:class:`RemoteSource` implements the :class:`~repro.monitor.source.
MetricSource` protocol over HTTP, so a daemon on another host plugs into
everything the telemetry layer already does: ``LLload --source remote
--url http://host:port`` (one-shot and ``--watch``), bus registration,
archive subscription, weekly analysis — and a daemon can itself serve a
``RemoteSource``, fanning out over other daemons (cluster-of-clusters).

Only stdlib ``urllib`` is used; the wire format is
:mod:`repro.daemon.protocol`.
"""
from __future__ import annotations

import urllib.error
import urllib.parse
import urllib.request
from typing import Any, Dict, Optional

from repro.core.metrics import ClusterSnapshot
from repro.daemon import protocol


class RemoteError(RuntimeError):
    """The daemon was unreachable or answered with an error."""


class RemoteClient:
    """Thin typed wrapper over every daemon endpoint."""

    def __init__(self, url: str, *, timeout_s: float = 10.0):
        self.url = url.rstrip("/")
        self.timeout_s = timeout_s

    # ------------------------------------------------------------ plumbing
    def _get(self, path: str,
             query: Optional[Dict[str, object]] = None) -> bytes:
        url = self.url + path
        if query:
            url += "?" + urllib.parse.urlencode(
                {k: v for k, v in query.items() if v is not None})
        try:
            with urllib.request.urlopen(url, timeout=self.timeout_s) as rsp:
                return rsp.read()
        except urllib.error.HTTPError as exc:
            detail = ""
            try:
                err = protocol.loads(exc.read())
                detail = f": {err.get('error', {}).get('message', '')}"
            except Exception:  # noqa: BLE001 — best-effort error detail
                pass
            raise RemoteError(
                f"GET {url} -> HTTP {exc.code}{detail}") from exc
        except (urllib.error.URLError, OSError) as exc:
            raise RemoteError(f"GET {url} failed: {exc}") from exc

    def _get_json(self, path: str,
                  query: Optional[Dict[str, object]] = None) -> Any:
        return protocol.loads(self._get(path, query))

    # ------------------------------------------------------------ endpoints
    def healthz(self) -> Dict[str, Any]:
        """GET /healthz — liveness, source name, wire version, TTL."""
        return self._get_json("/healthz")

    def stats(self) -> Dict[str, Any]:
        """GET /stats — bus / store / HTTP counters."""
        return self._get_json("/stats")

    def snapshot(self) -> ClusterSnapshot:
        """GET /snapshot, decoded to a typed :class:`ClusterSnapshot`
        (lossless: floats round-trip bit-for-bit)."""
        return protocol.decode_snapshot(self._get_json("/snapshot"))

    def trend(self, *, window_s: Optional[float] = None,
              tier: Optional[str] = None) -> Dict[str, Any]:
        """GET /trend — downsampled min/mean/max series; ``window_s``
        auto-selects the finest covering tier unless ``tier`` is set."""
        obj = self._get_json("/trend", {"window": window_s, "tier": tier})
        return protocol._check_envelope(obj, "trend")

    def weekly(self, *, start: Optional[float] = None,
               end: Optional[float] = None) -> Dict[str, Any]:
        """GET /weekly — the §V-A weekly report from the store tiers."""
        obj = self._get_json("/weekly", {"start": start, "end": end})
        return protocol._check_envelope(obj, "weekly")

    def metrics_text(self) -> str:
        """GET /metrics — the Prometheus text exposition, verbatim."""
        return self._get("/metrics").decode("utf-8")

    def view(self, kind: str, **query) -> str:
        """GET /view/{kind} (user/top/nodes) with the query params
        passed through verbatim; returns the rendered body."""
        return self._get(f"/view/{kind}", query).decode("utf-8")

    def query(self, **params) -> str:
        """GET /query with the params passed through verbatim — the
        unified query engine (DESIGN.md §7), answered server-side."""
        return self._get("/query", params).decode("utf-8")

    def insights(self, **params) -> str:
        """GET /insights with the params passed through verbatim — the
        advise view (DESIGN.md §8), answered from the daemon's
        streaming insight engine."""
        return self._get("/insights", params).decode("utf-8")

    def job(self, job_id: int) -> str:
        """GET /job/{id} — the MPCDF-style job report (DESIGN.md §11),
        rendered server-side from the daemon's job history tier.  An
        old daemon without the endpoint answers 404, which surfaces
        here as a :class:`RemoteError` (graceful ``--job`` failure,
        not a traceback)."""
        return self._get(f"/job/{int(job_id)}").decode("utf-8")

    def experiments(self, **params) -> str:
        """GET /experiments with the params passed through verbatim —
        a §V-B overloading campaign run (and memoized) server-side
        (DESIGN.md §9).  ``spec`` carries the canonical campaign JSON
        (:meth:`repro.experiments.Campaign.spec_json`); ``cells`` and
        the §7 query params shape the rendered table."""
        return self._get("/experiments", params).decode("utf-8")


class RemoteSource:
    """A daemon as a :class:`MetricSource` — collection is a GET.

    ``interval_hint`` stays ``None`` unless the caller sets it: probing
    the daemon for its TTL would add a blocking round-trip to one-shot
    use (and to ``MultiClusterSource`` construction, serially, before
    its failure-isolating thread fan-out can help), while over-polling
    is already harmless — requests inside the daemon's TTL window are
    answered from its byte-cache.
    """

    def __init__(self, url: str, *, name: Optional[str] = None,
                 timeout_s: float = 10.0,
                 interval_hint: Optional[float] = None):
        self.client = RemoteClient(url, timeout_s=timeout_s)
        host = urllib.parse.urlsplit(self.client.url).netloc
        self.name = name or f"remote:{host}"
        self.interval_hint = interval_hint

    def snapshot(self) -> ClusterSnapshot:
        """One collection == one GET /snapshot round trip."""
        return self.client.snapshot()

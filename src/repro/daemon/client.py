"""Remote source + client for the LLload daemon.

:class:`RemoteSource` implements the :class:`~repro.monitor.source.
MetricSource` protocol over HTTP, so a daemon on another host plugs into
everything the telemetry layer already does: ``LLload --source remote
--url http://host:port`` (one-shot and ``--watch``), bus registration,
archive subscription, weekly analysis — and a daemon can itself serve a
``RemoteSource``, fanning out over other daemons (cluster-of-clusters).

Only stdlib ``urllib`` is used; the wire format is
:mod:`repro.daemon.protocol`.
"""
from __future__ import annotations

import threading
import time
import urllib.error
import urllib.parse
import urllib.request
from typing import Any, Dict, Iterator, Optional

from repro.core.metrics import ClusterSnapshot
from repro.daemon import protocol


class RemoteError(RuntimeError):
    """The daemon was unreachable or answered with an error.

    ``status`` carries the HTTP status when the daemon *answered* with an
    error (e.g. 404 from an old daemon without ``/stream`` — the signal
    for the streaming client to fall back to polling permanently), and is
    ``None`` for transport failures.
    """

    def __init__(self, message: str, *, status: Optional[int] = None):
        super().__init__(message)
        self.status = status


class RemoteClient:
    """Thin typed wrapper over every daemon endpoint."""

    def __init__(self, url: str, *, timeout_s: float = 10.0):
        self.url = url.rstrip("/")
        self.timeout_s = timeout_s

    # ------------------------------------------------------------ plumbing
    def _get(self, path: str,
             query: Optional[Dict[str, object]] = None) -> bytes:
        url = self.url + path
        if query:
            url += "?" + urllib.parse.urlencode(
                {k: v for k, v in query.items() if v is not None})
        try:
            with urllib.request.urlopen(url, timeout=self.timeout_s) as rsp:
                return rsp.read()
        except urllib.error.HTTPError as exc:
            detail = ""
            try:
                err = protocol.loads(exc.read())
                detail = f": {err.get('error', {}).get('message', '')}"
            except Exception:  # noqa: BLE001 — best-effort error detail
                pass
            raise RemoteError(f"GET {url} -> HTTP {exc.code}{detail}",
                              status=exc.code) from exc
        except (urllib.error.URLError, OSError) as exc:
            raise RemoteError(f"GET {url} failed: {exc}") from exc

    def _get_json(self, path: str,
                  query: Optional[Dict[str, object]] = None) -> Any:
        return protocol.loads(self._get(path, query))

    # ------------------------------------------------------------ endpoints
    def healthz(self) -> Dict[str, Any]:
        """GET /healthz — liveness, source name, wire version, TTL."""
        return self._get_json("/healthz")

    def stats(self) -> Dict[str, Any]:
        """GET /stats — bus / store / HTTP counters."""
        return self._get_json("/stats")

    def snapshot(self) -> ClusterSnapshot:
        """GET /snapshot, decoded to a typed :class:`ClusterSnapshot`
        (lossless: floats round-trip bit-for-bit)."""
        return protocol.decode_snapshot(self._get_json("/snapshot"))

    def trend(self, *, window_s: Optional[float] = None,
              tier: Optional[str] = None) -> Dict[str, Any]:
        """GET /trend — downsampled min/mean/max series; ``window_s``
        auto-selects the finest covering tier unless ``tier`` is set."""
        obj = self._get_json("/trend", {"window": window_s, "tier": tier})
        return protocol._check_envelope(obj, "trend")

    def weekly(self, *, start: Optional[float] = None,
               end: Optional[float] = None) -> Dict[str, Any]:
        """GET /weekly — the §V-A weekly report from the store tiers."""
        obj = self._get_json("/weekly", {"start": start, "end": end})
        return protocol._check_envelope(obj, "weekly")

    def metrics_text(self) -> str:
        """GET /metrics — the Prometheus text exposition, verbatim."""
        return self._get("/metrics").decode("utf-8")

    def view(self, kind: str, **query) -> str:
        """GET /view/{kind} (user/top/nodes) with the query params
        passed through verbatim; returns the rendered body."""
        return self._get(f"/view/{kind}", query).decode("utf-8")

    def query(self, **params) -> str:
        """GET /query with the params passed through verbatim — the
        unified query engine (DESIGN.md §7), answered server-side."""
        return self._get("/query", params).decode("utf-8")

    def insights(self, **params) -> str:
        """GET /insights with the params passed through verbatim — the
        advise view (DESIGN.md §8), answered from the daemon's
        streaming insight engine."""
        return self._get("/insights", params).decode("utf-8")

    def job(self, job_id: int) -> str:
        """GET /job/{id} — the MPCDF-style job report (DESIGN.md §11),
        rendered server-side from the daemon's job history tier.  An
        old daemon without the endpoint answers 404, which surfaces
        here as a :class:`RemoteError` (graceful ``--job`` failure,
        not a traceback)."""
        return self._get(f"/job/{int(job_id)}").decode("utf-8")

    def stream(self, *, frames: Optional[int] = None,
               timeout_s: Optional[float] = None
               ) -> Iterator[Dict[str, Any]]:
        """GET /stream — yield parsed frame envelopes (DESIGN.md §14)
        until the daemon ends the subscription (``frames=N`` bounds it
        server-side) or the connection drops.  Feed the envelopes to a
        :class:`~repro.daemon.protocol.StreamDecoder`; an old daemon
        without the endpoint raises :class:`RemoteError` with
        ``status=404`` (the polling-fallback signal)."""
        url = self.url + "/stream"
        if frames is not None:
            url += f"?frames={int(frames)}"
        try:
            rsp = urllib.request.urlopen(
                url, timeout=timeout_s if timeout_s is not None
                else self.timeout_s)
        except urllib.error.HTTPError as exc:
            exc.read()
            raise RemoteError(f"GET {url} -> HTTP {exc.code}",
                              status=exc.code) from exc
        except (urllib.error.URLError, OSError) as exc:
            raise RemoteError(f"GET {url} failed: {exc}") from exc
        try:
            with rsp:
                # HTTPResponse undoes the chunked transfer encoding;
                # iteration yields the newline-terminated JSON lines
                for line in rsp:
                    line = line.strip()
                    if line:
                        yield protocol.loads(line)
        except (urllib.error.URLError, OSError, ValueError) as exc:
            raise RemoteError(f"stream from {url} died: {exc}") from exc

    def experiments(self, **params) -> str:
        """GET /experiments with the params passed through verbatim —
        a §V-B overloading campaign run (and memoized) server-side
        (DESIGN.md §9).  ``spec`` carries the canonical campaign JSON
        (:meth:`repro.experiments.Campaign.spec_json`); ``cells`` and
        the §7 query params shape the rendered table."""
        return self._get("/experiments", params).decode("utf-8")


class RemoteSource:
    """A daemon as a :class:`MetricSource` — collection is a GET, or,
    with ``stream=True``, a push subscription.

    ``interval_hint`` stays ``None`` unless the caller sets it: probing
    the daemon for its TTL would add a blocking round-trip to one-shot
    use (and to ``MultiClusterSource`` construction, serially, before
    its failure-isolating thread fan-out can help), while over-polling
    is already harmless — requests inside the daemon's TTL window are
    answered from its byte-cache.

    **Streaming mode** (``stream=True``, what ``--watch`` and
    daemon-over-daemon fan-in use): a background reader consumes
    ``GET /stream`` through a :class:`~repro.daemon.protocol.
    StreamDecoder`, so ``snapshot()`` returns the latest pushed state
    without a per-poll round trip — byte-identical (under
    ``encode_snapshot``) to what polling would have fetched.  A sequence
    gap or torn frame triggers an automatic resubscribe (keyframe
    resync, counted in :attr:`resyncs`); a daemon without ``/stream``
    (HTTP 404) flips the source to polling permanently; and when the
    connection is down *and* the last good frame is older than
    ``stale_after_s``, ``snapshot()`` raises :class:`RemoteError`
    instead of serving an unboundedly stale frame — the caller
    (``MultiClusterSource``) decides what staleness policy to apply,
    never a silently frozen view.
    """

    # reconnect pause after a dropped stream: long enough not to spin
    # against a dead daemon, short enough that a restarted one is
    # re-joined within a frame interval
    RETRY_DELAY_S = 0.2

    def __init__(self, url: str, *, name: Optional[str] = None,
                 timeout_s: float = 10.0,
                 interval_hint: Optional[float] = None,
                 stream: bool = False,
                 stale_after_s: float = 10.0):
        self.client = RemoteClient(url, timeout_s=timeout_s)
        host = urllib.parse.urlsplit(self.client.url).netloc
        self.name = name or f"remote:{host}"
        self.interval_hint = interval_hint
        self.stream = bool(stream)
        self.stale_after_s = stale_after_s
        self._lock = threading.Lock()
        self._reader: Optional[threading.Thread] = None  # guarded-by: _lock
        self._snap: Optional[ClusterSnapshot] = None     # guarded-by: _lock
        self._last_frame_at: Optional[float] = None      # guarded-by: _lock
        self._connected = False                          # guarded-by: _lock
        self._unsupported = False                        # guarded-by: _lock
        self._closed = False                             # guarded-by: _lock
        self._last_stream_error: Optional[Exception] = None  # guarded-by: _lock
        self.resyncs = 0                                 # guarded-by: _lock
        self._first_frame = threading.Event()

    # ------------------------------------------------------------ streaming
    def _ensure_reader(self) -> None:
        with self._lock:
            if self._closed:
                raise RemoteError(f"source {self.name!r} is closed")
            if self._reader is None or not self._reader.is_alive():
                self._reader = threading.Thread(
                    target=self._read_stream,
                    name=f"stream-{self.name}", daemon=True)
                self._reader.start()

    def _read_stream(self) -> None:
        decoder = protocol.StreamDecoder()
        while True:
            with self._lock:
                if self._closed:
                    return
            try:
                for obj in self.client.stream(
                        timeout_s=self.client.timeout_s):
                    try:
                        snap = decoder.feed(obj)
                    except protocol.StreamGapError as exc:
                        # missed at least one delta: resubscribe — the
                        # new subscription starts with a keyframe
                        decoder.reset()
                        with self._lock:
                            self.resyncs += 1
                            self._last_stream_error = exc
                        break
                    with self._lock:
                        if self._closed:
                            return
                        self._connected = True
                        self._snap = snap
                        self._last_frame_at = time.monotonic()
                    self._first_frame.set()
                else:
                    # clean end of subscription (daemon drained on
                    # SIGTERM, or a bounded test subscription): resync
                    decoder.reset()
                    with self._lock:
                        self.resyncs += 1
            except RemoteError as exc:
                decoder.reset()
                with self._lock:
                    self._last_stream_error = exc
                    if exc.status == 404:
                        # old daemon without /stream: poll forever after
                        self._unsupported = True
                        self._first_frame.set()
                        return
            except protocol.WireError as exc:     # torn / garbage frame
                decoder.reset()
                with self._lock:
                    self.resyncs += 1
                    self._last_stream_error = exc
            with self._lock:
                self._connected = False
                if self._closed:
                    return
            time.sleep(self.RETRY_DELAY_S)

    def close(self) -> None:
        """Stop the background stream reader (idempotent; the thread is
        a daemon thread, so this is for deterministic tests)."""
        with self._lock:
            self._closed = True
            self._connected = False
        self._first_frame.set()

    # -------------------------------------------------------------- collect
    def snapshot(self) -> ClusterSnapshot:
        """One collection: a GET /snapshot round trip (polling), or the
        latest pushed frame (streaming)."""
        if not self.stream:
            return self.client.snapshot()
        self._ensure_reader()
        deadline = time.monotonic() + self.client.timeout_s
        while True:
            with self._lock:
                unsupported = self._unsupported
                snap = self._snap
                connected = self._connected
                at = self._last_frame_at
                err = self._last_stream_error
            if unsupported:
                return self.client.snapshot()
            if snap is not None:
                if connected or (time.monotonic() - at
                                 <= self.stale_after_s):
                    return snap
                raise RemoteError(
                    f"stream from {self.client.url} has been down for "
                    f"{time.monotonic() - at:.1f}s (> stale_after_s="
                    f"{self.stale_after_s}); last error: {err}")
            if time.monotonic() >= deadline:
                raise RemoteError(
                    f"no stream frame from {self.client.url} within "
                    f"{self.client.timeout_s}s; last error: {err}")
            self._first_frame.wait(0.05)

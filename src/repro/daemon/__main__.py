"""``python -m repro.daemon`` — run the LLload telemetry daemon."""
from repro.daemon.server import main

if __name__ == "__main__":
    raise SystemExit(main())

"""StreamHub — one delta encode per tick, fanned out to N subscribers.

The hub is a :class:`~repro.monitor.bus.TelemetryBus` subscriber: every
new collection is encoded **once** through a shared
:class:`~repro.daemon.protocol.DeltaCodec` and the resulting frame bytes
are enqueued to every subscriber's bounded queue — N watchers cost one
diff and one JSON encode, the same amortization the daemon's byte-cache
gives one-shot readers (DESIGN.md §14).

Backpressure is eviction, not blocking: a subscriber whose queue is full
(a stalled client, a dead TCP peer the OS has not noticed yet) is
dropped and counted in ``evicted`` — the collection path must never
block on the slowest reader.  An evicted client sees its stream end,
resubscribes, and resyncs from the keyframe every new subscription
starts with (counted in ``resyncs``).

``close()`` pushes a sentinel to every subscriber so handler threads
drain promptly on SIGTERM instead of waiting out their poll timeout.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Optional

from repro.core.metrics import ClusterSnapshot
from repro.daemon import protocol

# per-subscriber queue depth: deep enough to absorb a render hiccup at
# watch cadence, shallow enough that a dead peer is evicted within a few
# keyframe periods instead of buffering unboundedly
DEFAULT_QUEUE_MAX = 256


class StreamSubscription:
    """One subscriber's end of the hub: a bounded FIFO of frame bytes.

    ``get(timeout)`` returns the next newline-terminated frame, ``None``
    when the stream ended (hub closed, eviction, or the requested frame
    limit was delivered).  Only the hub enqueues.
    """

    def __init__(self, maxsize: int, limit: Optional[int]):
        self.queue: "queue.Queue[Optional[bytes]]" = queue.Queue(maxsize)
        self.limit = limit          # frames to deliver; None = unbounded
        self.sent = 0               # guarded-by: the hub's _lock
        self.evicted = False        # guarded-by: the hub's _lock
        self.closed = False         # guarded-by: the hub's _lock

    def get(self, timeout: Optional[float] = None) -> Optional[bytes]:
        """The next frame: bytes, ``b""`` on timeout (poll again), or
        ``None`` when the stream ended."""
        try:
            return self.queue.get(timeout=timeout)
        except queue.Empty:
            return b""              # caller decides: poll again or bail


class StreamHub:
    """Per-daemon fan-out of :class:`DeltaCodec` frames (DESIGN.md §14).

    ``publish`` has the bus subscriber signature ``fn(name, snapshot)``;
    ``subscribe`` returns a :class:`StreamSubscription` whose first frame
    is always a ``full`` keyframe at the codec's current seq, so the
    deltas that follow apply contiguously.
    """

    def __init__(self, *,
                 keyframe_every: int = protocol.STREAM_KEYFRAME_EVERY,
                 queue_max: int = DEFAULT_QUEUE_MAX):
        self._codec = protocol.DeltaCodec(keyframe_every=keyframe_every)
        self._queue_max = max(2, int(queue_max))
        self._lock = threading.Lock()
        self._subs: Dict[int, StreamSubscription] = {}  # guarded-by: _lock
        self._next_id = 0                               # guarded-by: _lock
        self._closed = False                            # guarded-by: _lock
        self._frames_sent = 0                           # guarded-by: _lock
        self._evicted = 0                               # guarded-by: _lock
        self._resyncs = 0                               # guarded-by: _lock
        self._subscribed_total = 0                      # guarded-by: _lock

    # ------------------------------------------------------------ counters
    def stats(self) -> Dict[str, float]:
        """The ``/stats`` stream section (and ``/metrics`` counters)."""
        with self._lock:
            return {
                "subscribers": float(len(self._subs)),
                "subscribed_total": float(self._subscribed_total),
                "frames_sent": float(self._frames_sent),
                "evicted": float(self._evicted),
                "resyncs": float(self._resyncs),
                "seq": float(self._codec.seq),
            }

    def empty(self) -> bool:
        """True until the hub has seen its first snapshot."""
        with self._lock:
            return self._codec.seq == 0

    # ------------------------------------------------------------- publish
    def publish(self, source_name: str, snap: ClusterSnapshot) -> None:
        """Bus subscriber hook: encode once, enqueue everywhere."""
        with self._lock:
            if self._closed:
                return
            data = protocol.dumps(self._codec.encode(snap)) + b"\n"
            for sid in list(self._subs):
                self._offer(sid, data)

    def prime(self, snap: ClusterSnapshot) -> None:
        """Seed the codec with an initial snapshot if nothing has been
        published yet (a frozen daemon whose bus never re-collects still
        owes new subscribers one keyframe)."""
        with self._lock:
            if self._closed or self._codec.seq != 0:
                return
            data = protocol.dumps(self._codec.encode(snap)) + b"\n"
            for sid in list(self._subs):
                self._offer(sid, data)

    def _offer(self, sid: int, data: bytes) -> None:  # guarded-by: _lock
        sub = self._subs[sid]
        try:
            sub.queue.put_nowait(data)
        except queue.Full:
            # slow consumer: evict rather than stall the collection path;
            # drop the oldest queued frame to guarantee sentinel space
            # (we are the only producer and we hold the lock)
            sub.evicted = True
            self._evicted += 1
            del self._subs[sid]
            try:
                sub.queue.get_nowait()
            except queue.Empty:
                pass
            sub.queue.put_nowait(None)
            return
        sub.sent += 1
        self._frames_sent += 1
        if sub.limit is not None and sub.sent >= sub.limit:
            # bounded subscription (?frames=N) delivered in full: end the
            # stream server-side so ledgers reconcile exactly
            del self._subs[sid]
            sub.closed = True
            try:
                sub.queue.put_nowait(None)
            except queue.Full:       # pragma: no cover — maxsize >= 2
                pass

    # ----------------------------------------------------------- subscribe
    def subscribe(self, *, frames: Optional[int] = None
                  ) -> StreamSubscription:
        """Register a subscriber; its first frame is a keyframe at the
        current seq (a *resync point*, counted in ``resyncs``)."""
        if frames is not None and frames <= 0:
            raise ValueError("frames must be > 0")
        with self._lock:
            if self._closed:
                raise RuntimeError("stream hub is closed")
            sub = StreamSubscription(self._queue_max, frames)
            sid = self._next_id
            self._next_id += 1
            self._subscribed_total += 1
            self._subs[sid] = sub
            keyframe = self._codec.keyframe()
            if keyframe is not None:
                self._resyncs += 1
                self._offer(sid, protocol.dumps(keyframe) + b"\n")
            sub._sid = sid
            return sub

    def unsubscribe(self, sub: StreamSubscription) -> None:
        """Detach a subscriber (idempotent; handler cleanup path)."""
        with self._lock:
            sid = getattr(sub, "_sid", None)
            if sid is not None and self._subs.get(sid) is sub:
                del self._subs[sid]
            sub.closed = True

    # --------------------------------------------------------------- close
    def close(self) -> None:
        """Stop publishing and wake every subscriber with a sentinel so
        in-flight ``/stream`` handlers drain promptly (SIGTERM path)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            subs = list(self._subs.values())
            self._subs.clear()
        for sub in subs:
            sub.closed = True
            try:
                sub.queue.put_nowait(None)
            except queue.Full:
                try:
                    sub.queue.get_nowait()
                except queue.Empty:
                    pass
                try:
                    sub.queue.put_nowait(None)
                except queue.Full:   # pragma: no cover — single closer
                    pass

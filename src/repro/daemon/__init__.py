"""repro.daemon — the LLload telemetry service (DESIGN.md §6).

One daemon collects through the telemetry bus; any number of clients
read over HTTP: JSON snapshots on a versioned wire schema, rendered
views, trend/weekly queries answered from a multi-resolution history
store, and Prometheus text exposition.  :class:`RemoteSource` closes the
loop: a daemon is itself a :class:`MetricSource`, so CLIs — and other
daemons — consume it like any local source.
"""
from repro.daemon.client import RemoteClient, RemoteError, RemoteSource
from repro.daemon.promtext import parse_prometheus, render_prometheus
from repro.daemon.protocol import (STREAM_KEYFRAME_EVERY, WIRE_VERSION,
                                   DeltaCodec, StreamDecoder,
                                   StreamGapError, WireError, apply_delta,
                                   decode_snapshot, diff_snapshot,
                                   encode_snapshot)
from repro.daemon.server import (LLloadDaemon, serve, serve_background)
from repro.daemon.store import (DEFAULT_TIERS, HistoryStore,
                                JobHistoryStore, JobPoint, JobSample,
                                TierPoint, TierSpec, job_sample)
from repro.daemon.stream import StreamHub, StreamSubscription

__all__ = [
    "DEFAULT_TIERS", "DeltaCodec", "HistoryStore", "JobHistoryStore",
    "JobPoint", "JobSample", "LLloadDaemon", "RemoteClient",
    "RemoteError", "RemoteSource", "STREAM_KEYFRAME_EVERY",
    "StreamDecoder", "StreamGapError", "StreamHub", "StreamSubscription",
    "TierPoint", "TierSpec", "WIRE_VERSION", "WireError", "apply_delta",
    "decode_snapshot", "diff_snapshot", "encode_snapshot", "job_sample",
    "parse_prometheus", "render_prometheus", "serve", "serve_background",
]

"""Versioned JSON wire schemas for the LLload daemon (DESIGN.md §6).

Every payload travels inside an envelope::

    {"v": <wire version>, "kind": "<payload kind>", <kind>: {...}}

Version policy: the version is bumped when a decoder of the previous
version could *misread* a payload (field removed, meaning changed).
Purely additive fields do NOT bump the version — decoders ignore unknown
keys, so old clients keep working against newer daemons.  A decoder
refuses envelopes newer than :data:`WIRE_VERSION` (it cannot know what
changed) and accepts anything older it still understands.

The snapshot codec is **lossless**: ``decode_snapshot(encode_snapshot(s))``
reproduces every node, job, email and float bit-for-bit (JSON round-trips
Python floats exactly via ``repr``), which is what makes a remote
``LLload`` render byte-identical views.

The streaming layer (DESIGN.md §14) rides on the same envelope as
``kind="frame"``: a ``full`` keyframe carries a whole snapshot payload, a
``delta`` frame carries only the nodes/jobs/emails that changed since the
previous frame, and every frame carries a monotonic ``seq`` so a consumer
detects a dropped frame as a gap and resyncs from the next keyframe.
:class:`DeltaCodec` produces frames (one keyframe every
``keyframe_every`` frames), :class:`StreamDecoder` consumes them; the
contract — property-tested in ``tests/test_stream_delta.py`` — is that
applying a delta reproduces the next snapshot **byte-identically**
(``dumps(encode_snapshot(...))`` equality), so a streaming client renders
the exact bytes a polling client would.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot

WIRE_VERSION = 1

_NODE_FIELDS = [
    "hostname", "cores_total", "cores_used", "load",
    "mem_total_gb", "mem_used_gb",
    "gpus_total", "gpus_used", "gpu_load",
    "gpu_mem_total_gb", "gpu_mem_used_gb",
]

_JOB_FIELDS = [
    "job_id", "username", "name", "nodes", "cores_per_node", "state",
    "job_type", "gpus_per_node", "gpu_request", "start_time", "partition",
    "mem_per_node_gb",
    # per-job samples (additive, v1-compatible: old decoders ignore them,
    # old payloads decode with the JobRecord defaults)
    "submit_time", "gpu_duty", "cpu_load", "mem_used_gb", "step_time_s",
]

# stream frame payload fields (kind="frame"; locked by llcheck LL002).
# A full keyframe carries "snapshot"; a delta carries the *_upsert /
# *_remove sets.  Optional fields are omitted when empty — decoders use
# .get(), so absence and emptiness are indistinguishable (by design:
# omitting empty sets is where the ≤5%-churn byte reduction comes from).
_FRAME_FIELDS = ["type", "seq", "snapshot"]
_DELTA_FIELDS = [
    "type", "seq", "cluster", "timestamp",
    "nodes_upsert", "nodes_remove", "node_order",
    "jobs_upsert", "jobs_remove", "job_order", "emails",
]

# keyframe cadence: a full snapshot every N frames bounds how far a
# resyncing client can lag while keeping the steady state delta-sized
STREAM_KEYFRAME_EVERY = 32


class WireError(ValueError):
    """Malformed or incompatible wire payload."""


class StreamGapError(WireError):
    """A frame arrived out of sequence — the consumer missed at least one
    delta and must resync from a keyframe (resubscribe)."""


# ------------------------------------------------------------------ encode

def envelope(kind: str, payload: Any) -> Dict[str, Any]:
    """Wrap ``payload`` in the versioned ``{"v", "kind", kind: ...}``
    envelope every daemon response travels in."""
    return {"v": WIRE_VERSION, "kind": kind, kind: payload}


def _node_dict(n: NodeSnapshot) -> Dict[str, Any]:
    return {f: getattr(n, f) for f in _NODE_FIELDS}


def _job_dict(j: JobRecord) -> Dict[str, Any]:
    return {f: getattr(j, f) for f in _JOB_FIELDS}


def _snapshot_payload(snap: ClusterSnapshot) -> Dict[str, Any]:
    """The bare snapshot payload (shared by ``kind="snapshot"`` envelopes
    and the ``"snapshot"`` field of full stream keyframes)."""
    return {
        "cluster": snap.cluster,
        "timestamp": snap.timestamp,
        # insertion order is preserved through JSON objects, so node
        # iteration order survives the round trip
        "nodes": [_node_dict(n) for n in snap.nodes.values()],
        "jobs": [_job_dict(j) for j in snap.jobs],
        "user_emails": dict(snap.user_emails),
    }


def encode_snapshot(snap: ClusterSnapshot) -> Dict[str, Any]:
    """A snapshot as its wire envelope (losslessly: every node, job,
    email and float survives the round trip)."""
    return envelope("snapshot", _snapshot_payload(snap))


def encode_error(message: str, status: int = 500) -> Dict[str, Any]:
    """An error payload in its wire envelope (HTTP error bodies)."""
    return envelope("error", {"message": message, "status": status})


def dumps(obj: Any) -> bytes:
    """Compact UTF-8 JSON bytes (the daemon's response encoding)."""
    return json.dumps(obj, separators=(",", ":")).encode("utf-8")


# ------------------------------------------------------------------ decode

def _check_envelope(obj: Any, kind: str) -> Dict[str, Any]:
    if not isinstance(obj, dict) or "v" not in obj:
        raise WireError("not a wire envelope (missing 'v')")
    v = obj["v"]
    if not isinstance(v, int) or v > WIRE_VERSION:
        raise WireError(
            f"wire version {v!r} is newer than supported ({WIRE_VERSION}); "
            "upgrade this client")
    if obj.get("kind") == "error":
        err = obj.get("error") or {}
        raise WireError(f"remote error: {err.get('message', 'unknown')}")
    if obj.get("kind") != kind or kind not in obj:
        raise WireError(f"expected kind {kind!r}, got {obj.get('kind')!r}")
    return obj[kind]


def _decode_node(nd: Dict[str, Any]) -> NodeSnapshot:
    return NodeSnapshot(**{f: nd[f] for f in _NODE_FIELDS})


def _decode_job(jd: Dict[str, Any]) -> JobRecord:
    return JobRecord(**{f: jd[f] for f in _JOB_FIELDS if f in jd})


def _decode_snapshot_payload(payload: Dict[str, Any]) -> ClusterSnapshot:
    try:
        nodes: Dict[str, NodeSnapshot] = {}
        for nd in payload["nodes"]:
            node = _decode_node(nd)
            nodes[node.hostname] = node
        jobs: List[JobRecord] = [_decode_job(jd) for jd in payload["jobs"]]
        return ClusterSnapshot(
            cluster=payload["cluster"],
            timestamp=payload["timestamp"],
            nodes=nodes, jobs=jobs,
            user_emails=dict(payload.get("user_emails", {})))
    except (KeyError, TypeError) as exc:
        raise WireError(f"malformed snapshot payload: {exc}") from exc


def decode_snapshot(obj: Any) -> ClusterSnapshot:
    """Decode a snapshot envelope back to a typed ClusterSnapshot;
    unknown fields are ignored, malformed payloads raise WireError."""
    return _decode_snapshot_payload(_check_envelope(obj, "snapshot"))


def loads(data: bytes) -> Any:
    """Parse response bytes as JSON; raises WireError when not JSON."""
    try:
        return json.loads(data.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise WireError(f"not JSON: {exc}") from exc


# ----------------------------------------------------------------- stream

def _patched_order(prev_keys: List, removed: set,
                   upsert_keys: List) -> List:
    """The key order a delta consumer derives without an explicit order
    list: previous order minus removals, new keys appended in upsert
    order.  The producer emits ``node_order``/``job_order`` only when the
    real order disagrees with this derivation (it almost never does —
    fleets are stable, job ids grow), which keeps deltas small."""
    prev_set = set(prev_keys)
    return ([k for k in prev_keys if k not in removed]
            + [k for k in upsert_keys if k not in prev_set])


def diff_snapshot(prev: ClusterSnapshot,
                  cur: ClusterSnapshot) -> Optional[Dict[str, Any]]:
    """The delta payload fields turning ``prev`` into ``cur`` (without
    ``type``/``seq`` — the codec adds those), or ``None`` when the pair
    is not delta-representable (duplicate job ids: merged multi-cluster
    snapshots may repeat an id, and a keyed upsert would corrupt them —
    the codec falls back to a full keyframe)."""
    prev_job_ids = [j.job_id for j in prev.jobs]
    cur_job_ids = [j.job_id for j in cur.jobs]
    if (len(set(prev_job_ids)) != len(prev_job_ids)
            or len(set(cur_job_ids)) != len(cur_job_ids)):
        return None

    out: Dict[str, Any] = {"cluster": cur.cluster,
                           "timestamp": cur.timestamp}

    nodes_remove = [h for h in prev.nodes if h not in cur.nodes]
    nodes_upsert = [_node_dict(n) for h, n in cur.nodes.items()
                    if h not in prev.nodes or prev.nodes[h] != n]
    if nodes_upsert:
        out["nodes_upsert"] = nodes_upsert
    if nodes_remove:
        out["nodes_remove"] = nodes_remove
    derived = _patched_order(list(prev.nodes), set(nodes_remove),
                             [nd["hostname"] for nd in nodes_upsert])
    if derived != list(cur.nodes):
        out["node_order"] = list(cur.nodes)

    prev_jobs = {j.job_id: j for j in prev.jobs}
    cur_jobs = {j.job_id: j for j in cur.jobs}
    jobs_remove = [i for i in prev_job_ids if i not in cur_jobs]
    jobs_upsert = [_job_dict(j) for j in cur.jobs
                   if j.job_id not in prev_jobs
                   or prev_jobs[j.job_id] != j]
    if jobs_upsert:
        out["jobs_upsert"] = jobs_upsert
    if jobs_remove:
        out["jobs_remove"] = jobs_remove
    derived = _patched_order(prev_job_ids, set(jobs_remove),
                             [jd["job_id"] for jd in jobs_upsert])
    if derived != cur_job_ids:
        out["job_order"] = cur_job_ids

    # emails are small (one entry per user): ship the whole dict when
    # anything — value *or insertion order* — changed, else omit it
    if (list(prev.user_emails.items())
            != list(cur.user_emails.items())):
        out["emails"] = dict(cur.user_emails)
    return out


def apply_delta(prev: ClusterSnapshot,
                delta: Dict[str, Any]) -> ClusterSnapshot:
    """Apply a delta payload to ``prev``; the result is byte-identical
    (under ``dumps(encode_snapshot(...))``) to the snapshot the producer
    diffed against.  Malformed or inapplicable deltas raise WireError."""
    prev_job_ids = [j.job_id for j in prev.jobs]
    if len(set(prev_job_ids)) != len(prev_job_ids):
        raise WireError("cannot apply a delta over duplicate job ids")
    try:
        cluster = delta["cluster"]
        timestamp = delta["timestamp"]
        node_upserts = {nd["hostname"]: _decode_node(nd)
                        for nd in delta.get("nodes_upsert", [])}
        job_upserts = {jd["job_id"]: _decode_job(jd)
                       for jd in delta.get("jobs_upsert", [])}
    except (KeyError, TypeError) as exc:
        raise WireError(f"malformed delta payload: {exc}") from exc

    removed = set(delta.get("nodes_remove", []))
    order = delta.get("node_order")
    if order is None:
        order = _patched_order(list(prev.nodes), removed,
                               list(node_upserts))
    nodes: Dict[str, NodeSnapshot] = {}
    for host in order:
        node = node_upserts.get(host)
        if node is None:
            node = prev.nodes.get(host)
        if node is None or host in removed and host not in node_upserts:
            raise WireError(f"delta references unknown node {host!r}")
        nodes[host] = node

    prev_jobs = {j.job_id: j for j in prev.jobs}
    jremoved = set(delta.get("jobs_remove", []))
    jorder = delta.get("job_order")
    if jorder is None:
        jorder = _patched_order(prev_job_ids, jremoved, list(job_upserts))
    jobs: List[JobRecord] = []
    for job_id in jorder:
        job = job_upserts.get(job_id)
        if job is None:
            job = prev_jobs.get(job_id)
        if job is None or job_id in jremoved and job_id not in job_upserts:
            raise WireError(f"delta references unknown job {job_id!r}")
        jobs.append(job)

    emails = delta.get("emails")
    if emails is None:
        emails = dict(prev.user_emails)
    return ClusterSnapshot(cluster=cluster, timestamp=timestamp,
                           nodes=nodes, jobs=jobs,
                           user_emails=dict(emails))


class DeltaCodec:
    """Stateful frame producer: a ``full`` keyframe first and every
    ``keyframe_every`` frames, ``delta`` frames between, each carrying a
    monotonic ``seq``.  Pairs that are not delta-representable (see
    :func:`diff_snapshot`) fall back to keyframes transparently.

    Not thread-safe: the :class:`~repro.daemon.stream.StreamHub` owns one
    codec and serializes ``encode`` under its lock.
    """

    def __init__(self, *, keyframe_every: int = STREAM_KEYFRAME_EVERY):
        self.keyframe_every = max(1, int(keyframe_every))
        self.seq = 0
        self._prev: Optional[ClusterSnapshot] = None
        self._since_keyframe = 0

    def encode(self, snap: ClusterSnapshot) -> Dict[str, Any]:
        """The next frame envelope for ``snap`` (full or delta)."""
        self.seq += 1
        delta = None
        if (self._prev is not None
                and self._since_keyframe < self.keyframe_every):
            delta = diff_snapshot(self._prev, snap)
        self._prev = snap
        if delta is None:
            self._since_keyframe = 1
            return envelope("frame", {
                "type": "full", "seq": self.seq,
                "snapshot": _snapshot_payload(snap)})
        self._since_keyframe += 1
        payload: Dict[str, Any] = {"type": "delta", "seq": self.seq}
        payload.update(delta)
        return envelope("frame", payload)

    def keyframe(self) -> Optional[Dict[str, Any]]:
        """A full frame at the **current** seq — what a subscriber joining
        (or resyncing after a gap) receives so the deltas that follow
        apply contiguously.  ``None`` before the first ``encode``."""
        if self._prev is None:
            return None
        return envelope("frame", {
            "type": "full", "seq": self.seq,
            "snapshot": _snapshot_payload(self._prev)})


class StreamDecoder:
    """Stateful frame consumer: keyframes (re)set the state, deltas must
    arrive with contiguous ``seq`` — a gap raises
    :class:`StreamGapError`, telling the caller to resubscribe for a
    keyframe instead of silently rendering a corrupted snapshot."""

    def __init__(self):
        self.seq: Optional[int] = None
        self.snapshot: Optional[ClusterSnapshot] = None

    def reset(self) -> None:
        """Forget all state (before resubscribing for a keyframe)."""
        self.seq = None
        self.snapshot = None

    def feed(self, obj: Any) -> ClusterSnapshot:
        """Consume one frame envelope; returns the up-to-date snapshot."""
        payload = _check_envelope(obj, "frame")
        seq = payload.get("seq")
        if not isinstance(seq, int):
            raise WireError(f"frame without integer seq: {seq!r}")
        ftype = payload.get("type")
        if ftype == "full":
            if "snapshot" not in payload:
                raise WireError("full frame without a snapshot payload")
            snap = _decode_snapshot_payload(payload["snapshot"])
            self.seq, self.snapshot = seq, snap
            return snap
        if ftype == "delta":
            if self.snapshot is None or self.seq is None:
                raise StreamGapError(
                    f"delta seq {seq} arrived before any keyframe")
            if seq != self.seq + 1:
                raise StreamGapError(
                    f"sequence gap: have {self.seq}, got {seq}")
            snap = apply_delta(self.snapshot, payload)
            self.seq, self.snapshot = seq, snap
            return snap
        raise WireError(f"unknown frame type {ftype!r}")

"""Versioned JSON wire schemas for the LLload daemon (DESIGN.md §6).

Every payload travels inside an envelope::

    {"v": <wire version>, "kind": "<payload kind>", <kind>: {...}}

Version policy: the version is bumped when a decoder of the previous
version could *misread* a payload (field removed, meaning changed).
Purely additive fields do NOT bump the version — decoders ignore unknown
keys, so old clients keep working against newer daemons.  A decoder
refuses envelopes newer than :data:`WIRE_VERSION` (it cannot know what
changed) and accepts anything older it still understands.

The snapshot codec is **lossless**: ``decode_snapshot(encode_snapshot(s))``
reproduces every node, job, email and float bit-for-bit (JSON round-trips
Python floats exactly via ``repr``), which is what makes a remote
``LLload`` render byte-identical views.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot

WIRE_VERSION = 1

_NODE_FIELDS = [
    "hostname", "cores_total", "cores_used", "load",
    "mem_total_gb", "mem_used_gb",
    "gpus_total", "gpus_used", "gpu_load",
    "gpu_mem_total_gb", "gpu_mem_used_gb",
]

_JOB_FIELDS = [
    "job_id", "username", "name", "nodes", "cores_per_node", "state",
    "job_type", "gpus_per_node", "gpu_request", "start_time", "partition",
    "mem_per_node_gb",
    # per-job samples (additive, v1-compatible: old decoders ignore them,
    # old payloads decode with the JobRecord defaults)
    "submit_time", "gpu_duty", "cpu_load", "mem_used_gb", "step_time_s",
]


class WireError(ValueError):
    """Malformed or incompatible wire payload."""


# ------------------------------------------------------------------ encode

def envelope(kind: str, payload: Any) -> Dict[str, Any]:
    """Wrap ``payload`` in the versioned ``{"v", "kind", kind: ...}``
    envelope every daemon response travels in."""
    return {"v": WIRE_VERSION, "kind": kind, kind: payload}


def encode_snapshot(snap: ClusterSnapshot) -> Dict[str, Any]:
    """A snapshot as its wire envelope (losslessly: every node, job,
    email and float survives the round trip)."""
    payload = {
        "cluster": snap.cluster,
        "timestamp": snap.timestamp,
        # insertion order is preserved through JSON objects, so node
        # iteration order survives the round trip
        "nodes": [{f: getattr(n, f) for f in _NODE_FIELDS}
                  for n in snap.nodes.values()],
        "jobs": [{f: getattr(j, f) for f in _JOB_FIELDS}
                 for j in snap.jobs],
        "user_emails": dict(snap.user_emails),
    }
    return envelope("snapshot", payload)


def encode_error(message: str, status: int = 500) -> Dict[str, Any]:
    """An error payload in its wire envelope (HTTP error bodies)."""
    return envelope("error", {"message": message, "status": status})


def dumps(obj: Any) -> bytes:
    """Compact UTF-8 JSON bytes (the daemon's response encoding)."""
    return json.dumps(obj, separators=(",", ":")).encode("utf-8")


# ------------------------------------------------------------------ decode

def _check_envelope(obj: Any, kind: str) -> Dict[str, Any]:
    if not isinstance(obj, dict) or "v" not in obj:
        raise WireError("not a wire envelope (missing 'v')")
    v = obj["v"]
    if not isinstance(v, int) or v > WIRE_VERSION:
        raise WireError(
            f"wire version {v!r} is newer than supported ({WIRE_VERSION}); "
            "upgrade this client")
    if obj.get("kind") == "error":
        err = obj.get("error") or {}
        raise WireError(f"remote error: {err.get('message', 'unknown')}")
    if obj.get("kind") != kind or kind not in obj:
        raise WireError(f"expected kind {kind!r}, got {obj.get('kind')!r}")
    return obj[kind]


def decode_snapshot(obj: Any) -> ClusterSnapshot:
    """Decode a snapshot envelope back to a typed ClusterSnapshot;
    unknown fields are ignored, malformed payloads raise WireError."""
    payload = _check_envelope(obj, "snapshot")
    try:
        nodes: Dict[str, NodeSnapshot] = {}
        for nd in payload["nodes"]:
            node = NodeSnapshot(**{f: nd[f] for f in _NODE_FIELDS})
            nodes[node.hostname] = node
        jobs: List[JobRecord] = []
        for jd in payload["jobs"]:
            jobs.append(JobRecord(**{f: jd[f] for f in _JOB_FIELDS
                                     if f in jd}))
        return ClusterSnapshot(
            cluster=payload["cluster"],
            timestamp=payload["timestamp"],
            nodes=nodes, jobs=jobs,
            user_emails=dict(payload.get("user_emails", {})))
    except (KeyError, TypeError) as exc:
        raise WireError(f"malformed snapshot payload: {exc}") from exc


def loads(data: bytes) -> Any:
    """Parse response bytes as JSON; raises WireError when not JSON."""
    try:
        return json.loads(data.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise WireError(f"not JSON: {exc}") from exc

"""Three-term roofline from compiled dry-run artifacts (no real hardware).

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory     = HLO_bytes / HBM_bw               (per chip)
    collective = collective_bytes / ICI link bw   (per chip)

``cost_analysis()`` on the SPMD-partitioned executable reports *per-device*
FLOPs/bytes, so per-chip rates apply directly (equivalent to the global
form HLO_FLOPs_total / (chips x peak)).

collective_bytes comes from parsing the partitioned HLO: we sum, per
collective op, the bytes each chip moves over ICI (ring-cost convention:
all-reduce 2x, all-gather/reduce-scatter ~1x payload, all-to-all and
collective-permute 1x).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.roofline import hw

_COLLECTIVE_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_TUPLE_COLLECTIVE_RE = re.compile(
    r"=\s+\((.*?)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * hw.DTYPE_BYTES.get(dtype, 4)


_COST_FACTOR = {
    "all-reduce": 2.0,          # ring: 2(n-1)/n ~= 2
    "all-gather": 1.0,          # receives (n-1)/n of output ~= output
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved per collective kind, summed over ops."""
    out: Dict[str, float] = {k: 0.0 for k in _COST_FACTOR}
    counts: Dict[str, int] = {k: 0 for k in _COST_FACTOR}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            size = _shape_bytes(dtype, dims)
        else:
            mt = _TUPLE_COLLECTIVE_RE.search(line)
            if not mt:
                continue
            kind = mt.group(2)
            size = sum(_shape_bytes(d, s)
                       for d, s in _SHAPE_RE.findall(mt.group(1)))
        out[kind] += size * _COST_FACTOR[kind]
        counts[kind] += 1
    out["_op_counts"] = counts  # type: ignore
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    collective_bytes: float      # per-device ICI bytes (cost-weighted)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0     # 6ND / 2ND convention
    useful_ratio: float = 0.0    # model_flops_per_device / HLO flops
    collective_breakdown: Optional[dict] = None

    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """compute_term / max(all terms): 1.0 == compute-bound at peak."""
        b = self.bound_s()
        return self.compute_s / b if b > 0 else 0.0


def terms_from_monitoring(gpu_duty: float, step_time_s: float,
                          hbm_used_gb: float) -> RooflineTerms:
    """Roofline terms estimated from *monitoring* data (DESIGN.md §11):
    what the job-level observability layer knows about a running job,
    instead of a compiled dry-run artifact.

    ``gpu_duty`` is the MFU proxy (achieved FLOP/s / peak), so the
    per-step achieved flops are ``duty * peak * step``; the memory term
    assumes the job streams its resident HBM footprint once per step —
    the standard working-set bound when no HLO is available.  With no
    step time reported a nominal 1 s step is used (both terms scale
    together, so the verdict is step-time invariant).
    """
    step = step_time_s if step_time_s > 0 else 1.0
    flops = gpu_duty * hw.PEAK_FLOPS_BF16 * step
    hbm_bytes = hbm_used_gb * 2.0 ** 30
    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = hbm_bytes / hw.HBM_BW
    dominant = "compute" if compute_s >= memory_s else "memory"
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm_bytes, collective_bytes=0.0,
        compute_s=compute_s, memory_s=memory_s, collective_s=0.0,
        dominant=dominant)


def verdict_from_monitoring(gpu_duty: float, step_time_s: float,
                            hbm_used_gb: float) -> str:
    """One-line roofline verdict for a job report, e.g.
    ``"memory-bound at 43% of roofline"`` (the MPCDF-report phrasing).

    The percentage is the dominant term's share of the step time — how
    close the job runs to the roof it is under (compute-bound at duty
    1.0 means the devices never idle).  Jobs reporting neither duty nor
    HBM get ``"no device activity"`` rather than a fabricated bound.
    """
    if gpu_duty <= 0.0 and hbm_used_gb <= 0.0:
        return "no device activity"
    terms = terms_from_monitoring(gpu_duty, step_time_s, hbm_used_gb)
    step = step_time_s if step_time_s > 0 else 1.0
    frac = min(terms.bound_s() / step, 1.0)
    if terms.dominant == "compute":
        return f"compute-bound at {frac * 100:.0f}% of roofline"
    return f"memory-bound at {frac * 100:.0f}% of roofline"


def roofline(cost: dict, hlo_text: str, *, n_devices: int,
             model_flops_global: float = 0.0) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = parse_collective_bytes(hlo_text)
    breakdown = {k: v for k, v in coll.items() if k != "_op_counts"}
    coll_bytes = sum(breakdown.values())
    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = hbm / hw.HBM_BW
    coll_s = coll_bytes / hw.ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf_dev = model_flops_global / max(n_devices, 1)
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm, collective_bytes=coll_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant, model_flops=model_flops_global,
        useful_ratio=(mf_dev / flops) if flops else 0.0,
        collective_breakdown={**breakdown,
                              "op_counts": coll.get("_op_counts")},
    )

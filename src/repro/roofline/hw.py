"""Target hardware constants: TPU v5e (per chip)."""

PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW_PER_LINK = 50e9       # bytes/s per ICI link
VMEM_BYTES = 128 * 1024 * 1024
HBM_BYTES = 16 * 1024 ** 3   # 16 GiB

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1, "s4": 1,
    "f4e2m1fn": 1, "f8e8m0fnu": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

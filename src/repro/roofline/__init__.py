from repro.roofline import hw
from repro.roofline.analysis import (RooflineTerms, parse_collective_bytes,
                                     roofline)

__all__ = ["hw", "RooflineTerms", "parse_collective_bytes", "roofline"]

from repro.roofline import hw
from repro.roofline.analysis import (RooflineTerms, parse_collective_bytes,
                                     roofline, terms_from_monitoring,
                                     verdict_from_monitoring)

__all__ = ["hw", "RooflineTerms", "parse_collective_bytes", "roofline",
           "terms_from_monitoring", "verdict_from_monitoring"]

"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs."""
from __future__ import annotations

import glob
import json
import os
from typing import List, Optional


def load_cells(out_dir: str) -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def _fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}B"


def markdown_table(cells: List[dict], *, multi_pod: Optional[bool] = None
                   ) -> str:
    rows = [c for c in cells if c.get("status") == "ok"
            and (multi_pod is None or c.get("multi_pod") == multi_pod)]
    rows.sort(key=lambda c: (c["arch"], c["shape"], c["multi_pod"]))
    lines = [
        "| arch | shape | mesh | compute | memory | collective | dominant "
        "| 6ND/HLO | HLO FLOPs/dev | HBM B/dev | coll B/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in rows:
        mesh = "2x16x16" if c["multi_pod"] else "16x16"
        lines.append(
            f"| {c['arch']} | {c['shape']} | {mesh} "
            f"| {_fmt_s(c['compute_s'])} | {_fmt_s(c['memory_s'])} "
            f"| {_fmt_s(c['collective_s'])} | **{c['dominant']}** "
            f"| {c['useful_flops_ratio']:.2f} "
            f"| {c['flops_per_device']:.2e} "
            f"| {_fmt_b(c['hbm_bytes_per_device'])} "
            f"| {_fmt_b(c['collective_bytes_per_device'])} |")
    return "\n".join(lines)


def skipped_table(cells: List[dict]) -> str:
    rows = [c for c in cells if c.get("status") == "skipped"]
    seen = set()
    lines = ["| arch | shape | reason |", "|---|---|---|"]
    for c in rows:
        key = (c["arch"], c["shape"])
        if key in seen:
            continue
        seen.add(key)
        lines.append(f"| {c['arch']} | {c['shape']} | {c['reason']} |")
    return "\n".join(lines)


def memory_table(cells: List[dict]) -> str:
    rows = [c for c in cells if c.get("status") == "ok"]
    rows.sort(key=lambda c: (c["arch"], c["shape"], c["multi_pod"]))
    lines = [
        "| arch | shape | mesh | args/dev | temps/dev | output/dev "
        "| compile | probe |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in rows:
        mesh = "2x16x16" if c["multi_pod"] else "16x16"
        m = c.get("memory_analysis", {})
        lines.append(
            f"| {c['arch']} | {c['shape']} | {mesh} "
            f"| {_fmt_b(m.get('argument_size_in_bytes') or 0)} "
            f"| {_fmt_b(m.get('temp_size_in_bytes') or 0)} "
            f"| {_fmt_b(m.get('output_size_in_bytes') or 0)} "
            f"| {c.get('compile_s', 0):.0f}s | {c.get('probe_s', 0):.0f}s |")
    return "\n".join(lines)


def summarize(out_dir: str = "results/dryrun") -> str:
    cells = load_cells(out_dir)
    ok = sum(1 for c in cells if c.get("status") == "ok")
    sk = sum(1 for c in cells if c.get("status") == "skipped")
    er = [c for c in cells if c.get("status") == "error"]
    parts = [f"cells: {ok} ok, {sk} skipped, {len(er)} error"]
    for c in er:
        parts.append(f"  ERROR {c['arch']} x {c['shape']} "
                     f"(mp={c['multi_pod']}): {c.get('error')}")
    return "\n".join(parts)


if __name__ == "__main__":
    import sys

    out = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    print(summarize(out))
    print()
    print(markdown_table(load_cells(out), multi_pod=False))

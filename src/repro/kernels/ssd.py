"""Mamba-2 SSD intra-chunk Pallas kernel.

Computes the diagonal (within-chunk) SSD contribution for one chunk tile:

    y[i] = C_i . ( sum_{j<=i} exp(segsum dtA)_{ij} * B_j * dt_j * x_j )

per (batch, chunk, head-group) grid cell, entirely in VMEM:
the [l, l] decay matrix is formed from a cumulative-sum difference (no HBM
round-trip for segsum), then two MXU matmuls produce the output tile.
Head-grouped B/C (G groups of HG heads) are indexed in the BlockSpec maps,
mirroring the grouped layout the pure-jnp path uses.

The inter-chunk recurrence stays in jnp (tiny, bandwidth-trivial scan);
this kernel covers the FLOP-dominant quadratic term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, *, chunk: int):
    # block refs: x [1,l,1,hg,p], dt [1,l,1,hg], a [1,hg], b/c [1,l,1,n]
    x = x_ref[0, :, 0].astype(F32)             # [l, hg, p]
    dt = dt_ref[0, :, 0].astype(F32)           # [l, hg]
    A = a_ref[0].astype(F32)                   # [hg]
    Bm = b_ref[0, :, 0].astype(F32)            # [l, n]
    Cm = c_ref[0, :, 0].astype(F32)            # [l, n]

    dtA = dt * A[None, :]                      # [l, hg]
    cs = jnp.cumsum(dtA, axis=0)               # [l, hg]
    diff = cs[:, None, :] - cs[None, :, :]     # [i, j, hg]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where((ii >= jj)[:, :, None], jnp.exp(diff), 0.0)  # [i,j,hg]

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32)        # [i, j]
    w = cb[:, :, None] * L                                      # [i,j,hg]
    xdt = x * dt[:, :, None]                                    # [j,hg,p]
    # y[i,h,p] = sum_j w[i,j,h] * xdt[j,h,p]
    y = jnp.einsum("ijh,jhp->ihp", w, xdt)
    o_ref[0, :, 0] = y.astype(o_ref.dtype)


def ssd_intra_chunk(x, dt, A, B, C, *, interpret: bool = False):
    """x [b,l,h,p]; dt [b,l,h]; A [h]; B,C [b,l,g,n] -> y_diag [b,l,h,p].

    One chunk per call (l = chunk length); vectorized over batch and head
    groups via the grid.
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    xg = x.reshape(b, l, g, hg, p)

    grid = (b, g)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, 1, hg, p), lambda i, j: (i, 0, j, 0, 0)),
            pl.BlockSpec((1, l, 1, hg), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, hg), lambda i, j: (j, 0)),
            pl.BlockSpec((1, l, 1, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, l, 1, n), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, l, 1, hg, p), lambda i, j: (i, 0, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, g, hg, p), x.dtype),
        interpret=interpret,
    )(xg, dt.reshape(b, l, g, hg), A.reshape(g, hg), B, C)
    return out.reshape(b, l, h, p)

"""Fused RMSNorm (plain + Mamba-2 gated) Pallas kernels.

Row-tiled: each grid step normalizes a [block_rows, D] tile in VMEM with
fp32 statistics.  The gated variant fuses ``silu(z) * y`` into the same
pass (one HBM read of y and z instead of materializing the product).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(F32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def _gated_kernel(y_ref, z_ref, s_ref, o_ref, *, eps: float):
    y = y_ref[...].astype(F32)
    z = z_ref[...].astype(F32)
    h = y * (z * jax.nn.sigmoid(z))          # silu
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    o = h * jax.lax.rsqrt(var + eps) * s_ref[...].astype(F32)[None, :]
    o_ref[...] = o.astype(o_ref.dtype)


def _rows_call(kernel, args, rows, d, dtype, block_rows, interpret):
    n = rows // block_rows
    in_specs = [pl.BlockSpec((block_rows, d), lambda i: (i, 0))
                for _ in range(len(args) - 1)]
    in_specs.append(pl.BlockSpec((d,), lambda i: (0,)))  # scale
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), dtype),
        interpret=interpret,
    )(*args)


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x [..., D]; scale [D]."""
    shape = x.shape
    d = shape[-1]
    rows = math.prod(shape[:-1])
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows -= 1
    out = _rows_call(functools.partial(_rmsnorm_kernel, eps=eps),
                     (x2, scale), rows, d, x.dtype, block_rows, interpret)
    return out.reshape(shape)


def gated_rmsnorm(y, z, scale, *, eps: float = 1e-5, block_rows: int = 256,
                  interpret: bool = False):
    """RMSNorm(y * silu(z)); y,z [..., D]; scale [D]."""
    shape = y.shape
    d = shape[-1]
    rows = math.prod(shape[:-1])
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows -= 1
    out = _rows_call(functools.partial(_gated_kernel, eps=eps),
                     (y.reshape(rows, d), z.reshape(rows, d), scale),
                     rows, d, y.dtype, block_rows, interpret)
    return out.reshape(shape)

"""Pallas TPU kernels for the compute hot spots + pure-jnp oracles.

The paper (LLload) has no kernel-level contribution — these kernels belong
to the serving/training substrate the monitoring system observes: flash
attention (GQA prefill), SSD intra-chunk (Mamba-2), fused (gated) RMSNorm.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]

"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """q [B,H,S,D]; k,v [B,Hk,T,D] (GQA: H = G*Hk).  Full softmax."""
    B, H, S, D = q.shape
    Hk, T = k.shape[1], k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Hk, G, S, D)
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bhgsd,bhtd->bhgst", qg, k,
                   preferred_element_type=F32) * scale
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bhgsd", w.astype(v.dtype), v)
    return o.reshape(B, H, S, D)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    xf = x.astype(F32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(F32)).astype(x.dtype)


def gated_rmsnorm_ref(y, z, scale, eps: float = 1e-5):
    """Mamba-2 gated norm: RMSNorm(y * silu(z))."""
    h = y.astype(F32) * jax.nn.silu(z.astype(F32))
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps) * scale.astype(F32)).astype(y.dtype)


def ssd_intra_chunk_ref(x, dt, A, B, C):
    """Intra-chunk SSD (one chunk, diagonal block only).

    x [b,l,h,p]; dt [b,l,h] (>0); A [h] (<0); B,C [b,l,g,n].
    Returns y_diag [b,l,h,p]: sum_{j<=i} C_i.B_j exp(sum_{j<k<=i} dtA) x_j dt_j.
    """
    b, l, h, p = x.shape
    g = B.shape[2]
    hg = h // g
    dtA = dt.astype(F32) * A.astype(F32)[None, None, :]      # [b,l,h]
    cs = jnp.cumsum(dtA, axis=1)
    diff = cs[:, :, None, :] - cs[:, None, :, :]             # [b,i,j,h]
    idx = jnp.arange(l)
    L = jnp.where((idx[:, None] >= idx[None, :])[None, :, :, None],
                  jnp.exp(diff), 0.0)                        # [b,i,j,h]
    xdt = x.astype(F32) * dt.astype(F32)[..., None]
    Lg = L.reshape(b, l, l, g, hg)
    xg = xdt.reshape(b, l, g, hg, p)
    y = jnp.einsum("bign,bjgn,bijgh,bjghp->bighp",
                   C.astype(F32), B.astype(F32), Lg, xg)
    return y.reshape(b, l, h, p).astype(x.dtype)

"""Flash attention (causal, GQA-aware) as a Pallas TPU kernel.

TPU adaptation of the FlashAttention-2 schedule: the grid iterates
(batch, q-head, q-block) in parallel and the KV-block axis sequentially
(innermost, 'arbitrary' semantics); running max / sum / accumulator live in
VMEM scratch across KV steps and the output block is flushed once at the
last KV step.  Block shapes are BlockSpec'd so each step touches
``q[Bq,D] + k[Bk,D] + v[Bk,D]`` in VMEM (MXU-aligned: Bq,Bk,D multiples of
128 on real TPU; the interpret-mode tests also sweep smaller shapes).

GQA is handled in the index maps: KV blocks are indexed by ``h // group``
so query-head groups share one KV stream — no KV replication in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(F32)                    # [Bq, D]
    k = k_ref[0, 0].astype(F32)                    # [Bk, D]
    v = v_ref[0, 0].astype(F32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale  # [Bq, Bk]
    if causal:
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # guard fully-masked rows (exp(NEG_INF - NEG_INF) would be exp(0)=1)
    safe_m = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    p = jnp.exp(jnp.where(s <= NEG_INF, NEG_INF, s - safe_m[:, None]))
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - safe_m))
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q [B,H,S,D]; k,v [B,Hk,T,D] -> [B,H,S,D].  H must be G*Hk."""
    B, H, S, D = q.shape
    Hk, T = k.shape[1], k.shape[2]
    G = H // Hk
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    nq = S // block_q
    nk = T // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_kv_blocks=nk)

    from jax.experimental.pallas import tpu as pltpu

    # jax renamed TPUCompilerParams -> CompilerParams across releases; accept
    # whichever this jax build provides.
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams")

    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q, D), F32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)

"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python per grid cell, validating kernel logic against the
ref.py oracles.  On TPU the same calls compile to Mosaic.  The model code
can route through these via ``use_pallas=True`` call sites.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd as _ssd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q [B,H,S,D]; k,v [B,Hk,T,D] -> [B,H,S,D].  Forward-only kernel."""
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_on_cpu())


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_diff(q, k, v, causal, block_q, block_k):
    """Differentiable flash attention: Pallas kernel forward, exact
    reference-math backward (recompute; a fused backward kernel is the
    natural TPU follow-up)."""
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k)


def _fad_fwd(q, k, v, causal, block_q, block_k):
    out = flash_attention(q, k, v, causal=causal, block_q=block_q,
                          block_k=block_k)
    return out, (q, k, v)


def _fad_bwd(causal, block_q, block_k, res, g):
    from repro.kernels.ref import attention_ref

    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: attention_ref(q_, k_, v_,
                                                      causal=causal),
                     q, k, v)
    return vjp(g)


flash_attention_diff.defvjp(_fad_fwd, _fad_bwd)


def flash_attention_bshd(q, k, v, *, causal: bool = True, block_q=128,
                         block_k=128):
    """Layout adapter for model code: q [B,S,H,D]; k,v [B,T,Hk,D]."""
    o = flash_attention_diff(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2), causal, block_q, block_k)
    return o.swapaxes(1, 2)


@jax.jit
def rmsnorm(x, scale):
    return _rn.rmsnorm(x, scale, interpret=_on_cpu())


@jax.jit
def gated_rmsnorm(y, z, scale):
    return _rn.gated_rmsnorm(y, z, scale, interpret=_on_cpu())


@jax.jit
def ssd_intra_chunk(x, dt, A, B, C):
    return _ssd.ssd_intra_chunk(x, dt, A, B, C, interpret=_on_cpu())

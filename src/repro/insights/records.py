"""Typed insight records (DESIGN.md §8).

An :class:`Insight` is one diagnosis about one subject (a user's jobs):
what rule fired (``kind``), how urgent it is (``severity``), which nodes
are implicated, the human remediation message, any machine-actionable
suggestion (NPPN / cores-per-task), and the *stream* fields the
incremental engine maintains — persistence, streak, first/last-seen.

:class:`Severity` is a ``str`` subclass whose comparisons follow the
``info < warn < critical`` ladder instead of lexicographic order, so the
query engine's generic filters (``severity >= warn``) and sorts
(``-severity``) work on insight rows without any special casing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

SEVERITIES = ("info", "warn", "critical")
_RANK = {name: rank for rank, name in enumerate(SEVERITIES)}


def severity_rank(value: object) -> int:
    """Rank of a severity-ish value; unknown strings rank below ``info``."""
    return _RANK.get(str(value), -1)


class Severity(str):
    """A severity label ordered ``info < warn < critical`` (not lexically).

    Equality and hashing stay plain-string (``Severity("warn") ==
    "warn"``); only the orderings are rank-based, which is exactly what
    filter comparisons and sort keys use.
    """

    __slots__ = ()

    def __new__(cls, value: str = "info") -> "Severity":
        if str(value) not in _RANK:
            raise ValueError(f"unknown severity {value!r}; valid: "
                             + ", ".join(SEVERITIES))
        return super().__new__(cls, value)

    @property
    def rank(self) -> int:
        return _RANK[str(self)]

    def __lt__(self, other) -> bool:
        return self.rank < severity_rank(other)

    def __le__(self, other) -> bool:
        return self.rank <= severity_rank(other)

    def __gt__(self, other) -> bool:
        return self.rank > severity_rank(other)

    def __ge__(self, other) -> bool:
        return self.rank >= severity_rank(other)


INFO = Severity("info")
WARN = Severity("warn")
CRITICAL = Severity("critical")


@dataclasses.dataclass
class Insight:
    """One active diagnosis for one (rule kind, subject) pair.

    Rules fill the diagnostic fields; the :class:`~repro.insights.engine.
    InsightEngine` maintains the stream fields (``persistence``,
    ``streak``, ``first_seen``, ``last_seen``) across snapshots.
    """
    kind: str                       # low_gpu | missubmission | overload | io_storm
    severity: Severity
    username: str                   # the subject
    hostnames: List[str]
    message: str                    # diagnosis + suggested remediation
    suggested_nppn: Optional[int] = None
    suggested_cores_per_task: Optional[int] = None
    evidence: Dict[str, float] = dataclasses.field(default_factory=dict)
    # ---- stream state (engine-maintained) ------------------------------
    persistence: float = 1.0        # hits / snapshots since first seen
    streak: int = 1                 # consecutive snapshots the rule fired
    first_seen: float = 0.0         # cluster-clock time of the first hit
    last_seen: float = 0.0          # cluster-clock time of the latest hit

    def __post_init__(self):
        # fail at the rule that minted the record, not deep in a render:
        # a custom rule passing severity="notice" gets the vocabulary
        # error here instead of a daemon 500 on the first /insights read
        if not isinstance(self.severity, Severity):
            self.severity = Severity(self.severity)

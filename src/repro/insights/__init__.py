"""repro.insights — the pluggable Insights subsystem (DESIGN.md §8).

The paper's usage-characterization playbook (§V-B), redesigned from
dead-end library functions into a first-class queryable surface: typed
:class:`Insight` records produced by registered :class:`Rule`s,
evaluated incrementally over the telemetry stream by an
:class:`InsightEngine`, and surfaced through every layer — the
``insights`` query table, the CLI ``--advise`` view (one-shot and
``--watch``), the daemon's ``GET /insights``, and Prometheus
active-insight gauges.  The old ``repro.core.advisor`` /
``repro.core.overload`` entry points remain as thin shims over this
package.
"""
from repro.insights.engine import InsightEngine, evaluate_snapshots
from repro.insights.records import (CRITICAL, INFO, SEVERITIES, WARN,
                                    Insight, Severity, severity_rank)
from repro.insights.rules import (IO_STORM_FACTOR, IoStormRule,
                                  LowGpuDutyRule, MissubmissionRule, Rule,
                                  RuleContext, ThreadOverloadRule, contexts,
                                  default_rules, get_rule, recommend_nppn,
                                  register_rule, rule_names)

__all__ = [
    "CRITICAL", "INFO", "IO_STORM_FACTOR", "Insight", "InsightEngine",
    "IoStormRule", "LowGpuDutyRule", "MissubmissionRule", "Rule",
    "RuleContext", "SEVERITIES", "Severity", "ThreadOverloadRule", "WARN",
    "contexts", "default_rules", "evaluate_snapshots", "get_rule",
    "recommend_nppn", "register_rule", "rule_names", "severity_rank",
]

"""Incremental streaming insight engine (DESIGN.md §8).

The legacy advisor answered "what should this user fix?" by replaying
the whole snapshot history through the rule logic on every query —
O(snapshots · nodes) per answer.  :class:`InsightEngine` instead
*streams*: each snapshot is folded once into per-(rule kind, subject)
state — hit counts, consecutive streak/miss counters, first/last-seen —
so an answer is a read of the active set and the per-snapshot cost is
O(rules · users).

Stream semantics:

  * **persistence** — hits / snapshots observed since the (kind,
    subject) pair first fired; one noisy sample reads as 0.5 after the
    next clean one, a chronic problem stays at 1.0.
  * **hysteresis** — an insight activates after ``min_streak``
    consecutive hits and deactivates (state dropped, episode over) only
    after ``clear_after`` consecutive misses, so a flickering diagnosis
    neither spams nor vanishes mid-look.
  * **first_seen / last_seen** — cluster-clock timestamps of the
    episode's first and latest hit.

Wiring: ``engine.subscriber(name)`` is a TelemetryBus subscriber (the
daemon registers one next to the HistoryStore's), ``engine.attach(bus)``
also backfills from the bus ring buffer, and ``evaluate_snapshots`` is
the one-call form for replaying an explicit history.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.insights.records import Insight
from repro.insights.rules import Rule, contexts, default_rules


@dataclasses.dataclass
class _State:
    """Stream state for one (rule kind, subject) pair."""
    insight: Insight
    hits: int = 0
    observed: int = 0              # snapshots since (and incl.) first hit
    streak: int = 0                # consecutive hits
    misses: int = 0                # consecutive misses
    first_seen: float = 0.0
    active: bool = False


class InsightEngine:
    """Stateful incremental evaluator over a stream of snapshots."""

    def __init__(self, rules: Optional[Iterable[Rule]] = None, *,
                 min_streak: int = 1, clear_after: int = 2):
        self.rules: List[Rule] = (list(rules) if rules is not None
                                  else default_rules())
        self.min_streak = max(int(min_streak), 1)
        self.clear_after = max(int(clear_after), 1)
        self.observations = 0                       # guarded-by: _lock
        # guarded-by: _lock
        self._states: Dict[Tuple[str, str], _State] = {}
        self._lock = threading.Lock()

    # -------------------------------------------------------------- stream
    def observe(self, snap) -> None:
        """Fold one snapshot into the per-(kind, subject) state —
        O(rules · users) plus one pass over the job table."""
        found: Dict[Tuple[str, str], Insight] = {}
        for ctx in contexts(snap):
            for rule in self.rules:
                ins = rule.evaluate(ctx)
                if ins is not None:
                    found[(ins.kind, ins.username)] = ins
        with self._lock:
            self.observations += 1
            for key, ins in found.items():
                st = self._states.get(key)
                if st is None:
                    st = _State(insight=ins, first_seen=snap.timestamp)
                    self._states[key] = st
                st.hits += 1
                st.observed += 1
                st.streak += 1
                st.misses = 0
                if st.streak >= self.min_streak:
                    st.active = True
                st.insight = dataclasses.replace(
                    ins, persistence=st.hits / st.observed,
                    streak=st.streak, first_seen=st.first_seen,
                    last_seen=snap.timestamp)
            for key in [k for k in self._states if k not in found]:
                st = self._states[key]
                st.observed += 1
                st.streak = 0
                st.misses += 1
                if st.misses >= self.clear_after:
                    del self._states[key]      # episode over
                else:
                    st.insight = dataclasses.replace(
                        st.insight, persistence=st.hits / st.observed,
                        streak=0)

    # --------------------------------------------------------------- reads
    def active(self) -> List[Insight]:
        """The active insights, ordered (username, kind) for determinism
        (canned views re-sort by severity on top of this)."""
        with self._lock:
            out = [st.insight for st in self._states.values() if st.active]
        out.sort(key=lambda i: (i.username, i.kind))
        return out

    # -------------------------------------------------------------- wiring
    def subscriber(self, source_name: Optional[str] = None
                   ) -> Callable[[str, object], None]:
        """A TelemetryBus subscriber feeding this engine (optionally only
        from ``source_name``)."""
        def fn(name: str, snap) -> None:
            if source_name is None or name == source_name:
                self.observe(snap)
        return fn

    def attach(self, bus, source_name: Optional[str] = None
               ) -> "InsightEngine":
        """Backfill from the bus ring buffer, then subscribe for every
        future collection.  Returns self for chaining."""
        for snap in bus.history_of(source_name):
            self.observe(snap)
        bus.subscribe(self.subscriber(source_name))
        return self


def evaluate_snapshots(snaps: Iterable, *,
                       rules: Optional[Iterable[Rule]] = None,
                       min_streak: int = 1,
                       clear_after: int = 2) -> List[Insight]:
    """One-call replay: stream ``snaps`` through a fresh engine and
    return the active set (the modern replacement for the deprecated
    ``characterize_snapshots``)."""
    engine = InsightEngine(rules, min_streak=min_streak,
                           clear_after=clear_after)
    for snap in snaps:
        engine.observe(snap)
    return engine.active()

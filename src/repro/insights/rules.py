"""Pluggable insight rules — the paper's diagnostic playbook (§V-B).

Each :class:`Rule` looks at one subject's nodes in one snapshot and
returns an :class:`~repro.insights.records.Insight` (or ``None``).  The
four paper rules are registered at import:

  * ``low_gpu``       — Fig 7: persistent low GPU duty with small GPU
                        memory -> bigger batch or GPU overloading; an
                        NPPN value is recommended from load + memory
                        headroom (:func:`recommend_nppn`).
  * ``missubmission`` — Fig 8: cores-per-task so large only one task
                        fits a multi-GPU node -> corrected cores request.
  * ``overload``      — Fig 10: normalized load > high threshold:
                        thread oversubscription.
  * ``io_storm``      — Fig 11: extreme load (>> cores) matching the
                        concurrent-write() file-I/O-storm pathology.

``register_rule`` admits new rules; the
:class:`~repro.insights.engine.InsightEngine` evaluates every registered
rule (or an explicit subset) per subject per snapshot.

This module deliberately imports nothing from :mod:`repro.core` at
module scope (the deprecated advisor/overload shims there import *us*);
the shared utilization thresholds are resolved lazily from
:mod:`repro.core.analysis`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Protocol, Tuple

from repro.insights.records import CRITICAL, INFO, WARN, Insight

# normalized load beyond which we suspect an I/O storm rather than plain
# thread oversubscription (Fig 11's nodes showed ~720/48 = 15x)
IO_STORM_FACTOR = 5.0


def _thresholds() -> Tuple[float, float]:
    # lazy: repro.core.analysis owns the paper's §V-A thresholds, but the
    # core package's deprecation shims import this module
    from repro.core.analysis import HIGH_THRESHOLD, LOW_THRESHOLD
    return LOW_THRESHOLD, HIGH_THRESHOLD


def recommend_nppn(gpu_load: float, gpu_mem_used_gb: float,
                   gpu_mem_total_gb: float, *, target_load: float = 0.9,
                   mem_headroom: float = 0.9, max_nppn: int = 8) -> int:
    """The paper's overloading arithmetic: pack tasks-per-GPU until either
    the summed duty cycle reaches ~target or GPU memory would overflow."""
    if gpu_load <= 0:
        return 1
    by_load = int(target_load / max(gpu_load, 1e-3))
    per_task_mem = max(gpu_mem_used_gb, 1e-3)
    by_mem = int((gpu_mem_total_gb * mem_headroom) / per_task_mem)
    n = max(1, min(by_load, by_mem, max_nppn))
    # round down to the NPPN values LLsub exposes: 1, 2, 4, 8
    for v in (8, 4, 2, 1):
        if n >= v:
            return v
    return 1


@dataclasses.dataclass
class RuleContext:
    """One subject's view of one snapshot (what every rule consumes)."""
    snap: object                     # ClusterSnapshot
    username: str
    nodes: List[object]              # the user's NodeSnapshots
    gpu_nodes: List[object]          # subset with devices
    jobs: List[object] = dataclasses.field(default_factory=list)
    # ^ the user's JobRecords (running AND pending) — what the job-level
    #   rules consume; node-level rules ignore it


def contexts(snap) -> Iterator[RuleContext]:
    """Yield one :class:`RuleContext` per user with nodes *or jobs*,
    sorted by username — the engine's O(users + jobs) iteration for one
    snapshot.  Users whose only presence is a queued (``PD``) job get a
    context with empty node lists, which every node-level rule treats as
    not-applicable."""
    by_user = snap.nodes_by_user()
    jobs_by_user: Dict[str, List[object]] = {}
    for job in snap.jobs:
        jobs_by_user.setdefault(job.username, []).append(job)
    for user in sorted(set(by_user) | set(jobs_by_user)):
        nodes = [snap.nodes[h] for h in by_user.get(user, ())
                 if h in snap.nodes]
        jobs = jobs_by_user.get(user, [])
        if not nodes and not jobs:
            continue
        yield RuleContext(snap, user, nodes,
                          [n for n in nodes if n.gpus_total > 0], jobs)


class Rule(Protocol):
    """One diagnostic: ``evaluate`` returns an Insight or None."""
    name: str
    kind: str

    def evaluate(self, ctx: RuleContext) -> Optional[Insight]:
        """Diagnose one subject in one snapshot; None when the rule does
        not apply (the engine folds the miss into its stream state)."""
        ...


class LowGpuDutyRule:
    """Fig 7: persistent low GPU duty -> larger batch or overloading."""
    name = "low_gpu"
    kind = "low_gpu"

    def evaluate(self, ctx: RuleContext) -> Optional[Insight]:
        """INFO when any of the subject's GPU nodes sit below the low
        duty threshold; evidence carries the measured duty + per-device
        memory the overloading controller consumes."""
        low_threshold, _ = _thresholds()
        low_gpu = [n for n in ctx.gpu_nodes
                   if 0 < n.gpu_load < low_threshold and n.gpus_used > 0]
        if not low_gpu:
            return None
        mean_load = sum(n.gpu_load for n in low_gpu) / len(low_gpu)
        # NPPN numerator and denominator must come from the SAME node:
        # taking max(used) across nodes but total from low_gpu[0] computed
        # a nonsense ratio on heterogeneous nodes
        ref = max(low_gpu,
                  key=lambda n: n.gpu_mem_used_gb / max(n.gpus_used, 1))
        mem_used = ref.gpu_mem_used_gb / max(ref.gpus_used, 1)
        mem_total = ref.gpu_mem_total_gb / max(ref.gpus_total, 1)
        nppn = recommend_nppn(mean_load, mem_used, mem_total)
        msg = (f"GPU load {mean_load:.2f} < {low_threshold} on "
               f"{len(low_gpu)} node(s); GPU memory {mem_used:.0f}GB of "
               f"{mem_total:.0f}GB. Consider a larger batch size, or GPU "
               f"overloading with NPPN={nppn} (LLsub triples mode).")
        return Insight(self.kind, INFO, ctx.username,
                       [n.hostname for n in low_gpu], msg,
                       suggested_nppn=nppn,
                       evidence={"gpu_load": mean_load,
                                 "gpu_mem_used_gb": mem_used,
                                 "gpu_mem_total_gb": mem_total})


class MissubmissionRule:
    """Fig 8: cores request so large only one task fits a GPU node."""
    name = "missubmission"
    kind = "missubmission"

    def evaluate(self, ctx: RuleContext) -> Optional[Insight]:
        """WARN when cores are exhausted but devices idle on multi-GPU
        nodes — suggests the corrected cores-per-task request."""
        low_threshold, _ = _thresholds()
        missub = [n for n in ctx.gpu_nodes
                  if n.gpus_total >= 2 and n.gpus_used < n.gpus_total
                  and n.cores_free < n.cores_total // 4
                  and n.norm_load < low_threshold]
        if not missub:
            return None
        n0 = missub[0]
        fair_cores = n0.cores_total // n0.gpus_total
        msg = (f"{len(missub)} node(s) have all cores allocated but only "
               f"{n0.gpus_used}/{n0.gpus_total} GPUs in use with CPU load "
               f"{n0.norm_load:.2f}. The cores-per-task request is too "
               f"large: request {fair_cores} cores and 1 GPU per task so "
               f"{n0.gpus_total} tasks share each node.")
        return Insight(self.kind, WARN, ctx.username,
                       [n.hostname for n in missub], msg,
                       suggested_cores_per_task=fair_cores,
                       evidence={"norm_load": n0.norm_load})


def _overloaded(ctx: RuleContext):
    """(over nodes, worst node) for the two load-pathology rules."""
    _, high_threshold = _thresholds()
    over = [n for n in ctx.nodes if n.norm_load > high_threshold]
    if not over:
        return [], None
    return over, max(over, key=lambda n: n.norm_load)


class ThreadOverloadRule:
    """Fig 10: load moderately above cores -> thread oversubscription."""
    name = "overload"
    kind = "overload"

    def evaluate(self, ctx: RuleContext) -> Optional[Insight]:
        """WARN on load moderately above cores (the I/O-storm rule owns
        anything beyond ``IO_STORM_FACTOR``x)."""
        over, worst = _overloaded(ctx)
        if worst is None or worst.norm_load > IO_STORM_FACTOR:
            return None                  # nothing, or the storm rule owns it
        msg = (f"CPU load {worst.norm_load:.2f}x cores on "
               f"{len(over)} node(s): tasks spawn more threads than "
               "cores (e.g. Python multiprocessing defaults). Set "
               "thread counts to cores/tasks-per-node.")
        return Insight(self.kind, WARN, ctx.username,
                       [n.hostname for n in over], msg,
                       evidence={"max_norm_load": worst.norm_load})


class IoStormRule:
    """Fig 11: extreme load (>> cores) -> concurrent file-I/O storm."""
    name = "io_storm"
    kind = "io_storm"

    def evaluate(self, ctx: RuleContext) -> Optional[Insight]:
        """CRITICAL on extreme load (> ``IO_STORM_FACTOR``x cores) — the
        concurrent-file-I/O pathology, not mere oversubscription."""
        over, worst = _overloaded(ctx)
        if worst is None or worst.norm_load <= IO_STORM_FACTOR:
            return None
        msg = (f"Extreme CPU load {worst.load:.0f} on "
               f"{worst.cores_total} cores ({worst.norm_load:.1f}x). "
               "Beyond thread oversubscription this pattern matches a "
               "concurrent file-I/O storm (e.g. write() in a hot loop) "
               "overwhelming the filesystem client; reduce concurrent "
               "file I/O and cap worker threads.")
        return Insight(self.kind, CRITICAL, ctx.username,
                       [n.hostname for n in over], msg,
                       evidence={"max_norm_load": worst.norm_load})


# --------------------------------------------------------- job-level rules
# (DESIGN.md §11) — thresholds are set so the rules diagnose the
# arrival-driven pathologies (diurnal backlog, whole-node fragmentation,
# one tenant crowding out the rest) without firing on the steady-state
# §V-B mixes, whose snapshots carry only running jobs.

# pending wait beyond which the queue counts as starving the user
STARVATION_WAIT_S = 1800.0
# a user fragmenting the fleet: many whole-node jobs, mostly idle cores
FRAG_MIN_JOBS = 6
FRAG_CORE_FRACTION = 0.35
# a tenant's share of busy nodes beyond which waiting others is unfair
FAIR_DOMINANT_FRACTION = 0.5


class QueueStarvationRule:
    """Queued jobs waiting far beyond the starvation threshold."""
    name = "queue_starvation"
    kind = "queue_starvation"

    def evaluate(self, ctx: RuleContext) -> Optional[Insight]:
        """WARN when any of the subject's pending jobs has waited longer
        than ``STARVATION_WAIT_S`` (needs producers that report
        ``submit_time`` and surface pending jobs)."""
        snap = ctx.snap
        pend = [j for j in ctx.jobs
                if j.state == "PD" and j.submit_time > 0]
        if not pend:
            return None
        worst = max(max(0.0, snap.timestamp - j.submit_time)
                    for j in pend)
        if worst < STARVATION_WAIT_S:
            return None
        msg = (f"{len(pend)} queued job(s), the oldest waiting "
               f"{worst:.0f}s (> {STARVATION_WAIT_S:.0f}s). The queue is "
               "starving this user's work: request fewer or smaller "
               "nodes, or raise NPPN so submissions fit the free "
               "capacity.")
        return Insight(self.kind, WARN, ctx.username, [], msg,
                       evidence={"max_wait_s": worst,
                                 "pending": float(len(pend))})


class FleetFragmentationRule:
    """Many small whole-node jobs pinning nodes at low core usage."""
    name = "fleet_fragmentation"
    kind = "fleet_fragmentation"

    def evaluate(self, ctx: RuleContext) -> Optional[Insight]:
        """INFO when the subject runs ``FRAG_MIN_JOBS``+ jobs whose nodes
        sit below ``FRAG_CORE_FRACTION`` mean core usage — whole-node
        scheduling is fragmenting the fleet."""
        running = [j for j in ctx.jobs if j.state == "R"]
        if len(running) < FRAG_MIN_JOBS or not ctx.nodes:
            return None
        frac = (sum(n.cores_used for n in ctx.nodes)
                / max(sum(n.cores_total for n in ctx.nodes), 1))
        if frac >= FRAG_CORE_FRACTION:
            return None
        msg = (f"{len(running)} running job(s) spread over "
               f"{len(ctx.nodes)} whole node(s) at {frac * 100:.0f}% "
               "mean core usage: whole-node scheduling is fragmenting "
               "the fleet. Consolidate (more tasks per job, or the "
               "shared partition) to free nodes.")
        return Insight(self.kind, INFO, ctx.username,
                       [n.hostname for n in ctx.nodes], msg,
                       evidence={"jobs": float(len(running)),
                                 "core_fraction": frac})


class MultiTenantFairnessRule:
    """One tenant holding most busy nodes while other users queue."""
    name = "multi_tenant_fairness"
    kind = "multi_tenant_fairness"

    def evaluate(self, ctx: RuleContext) -> Optional[Insight]:
        """WARN when the subject owns ``FAIR_DOMINANT_FRACTION``+ of the
        busy nodes while at least one *other* user's job is pending —
        the elastic-resize (shrink) trigger."""
        snap = ctx.snap
        others_waiting = [j for j in snap.jobs
                          if j.state == "PD" and j.submit_time > 0
                          and j.username != ctx.username]
        if not others_waiting or not ctx.nodes:
            return None
        by_user = snap.nodes_by_user()
        occupied = set()
        for hosts in by_user.values():
            occupied.update(hosts)
        mine = len(by_user.get(ctx.username, ()))
        if not occupied or mine / len(occupied) < FAIR_DOMINANT_FRACTION:
            return None
        share = mine / len(occupied)
        worst = max(max(0.0, snap.timestamp - j.submit_time)
                    for j in others_waiting)
        msg = (f"holds {mine} of {len(occupied)} busy node(s) "
               f"({share * 100:.0f}%) while {len(others_waiting)} "
               f"job(s) from other users wait up to {worst:.0f}s. "
               "Elastic resize: shrink this user's jobs so waiting "
               "tenants can start.")
        return Insight(self.kind, WARN, ctx.username,
                       sorted(by_user.get(ctx.username, ())), msg,
                       evidence={"share": share,
                                 "others_waiting":
                                     float(len(others_waiting)),
                                 "max_wait_s": worst})


# ------------------------------------------------------------------ registry


_REGISTRY: Dict[str, Rule] = {}


def register_rule(rule: Rule) -> Rule:
    """Admit a rule; evaluation order is registration order."""
    if rule.name in _REGISTRY:
        raise ValueError(f"rule {rule.name!r} already registered")
    _REGISTRY[rule.name] = rule
    return rule


def get_rule(name: str) -> Rule:
    """The registered rule called ``name``; raises KeyError (listing
    the registered names) when unknown."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown rule {name!r}; registered: "
                       + ", ".join(rule_names()))
    return _REGISTRY[name]


def rule_names() -> List[str]:
    """Registered rule names, sorted."""
    return sorted(_REGISTRY)


def default_rules() -> List[Rule]:
    """Every registered rule, in registration order (paper order for the
    built-ins, so per-subject insight order matches the legacy advisor)."""
    return list(_REGISTRY.values())


for _rule in (LowGpuDutyRule(), MissubmissionRule(), ThreadOverloadRule(),
              IoStormRule(), QueueStarvationRule(),
              FleetFragmentationRule(), MultiTenantFairnessRule()):
    register_rule(_rule)

"""The query engine's user-error type.

A :class:`QueryError` always means "the query was malformed" (unknown
table/column/renderer, bad filter syntax, non-positive limit) — callers
map it to exit code 1 (CLI) or HTTP 400 (daemon), never to a traceback.
"""
from __future__ import annotations


class QueryError(ValueError):
    """Malformed query: bad column, table, filter, sort, or renderer."""

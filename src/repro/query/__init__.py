"""repro.query — the unified query/render engine (DESIGN.md §7).

One typed :class:`Query` (select / filter / sort / group-by / limit)
answers every surface: interactive CLI views, ``--watch`` frames, and
the daemon's ``GET /query`` — each is a canned query through this
package, rendered by a registry renderer (``table``/``json``/``csv``/
``tsv``/``prom``) or the legacy byte-identical text layouts.
"""
from repro.query.engine import (DEFAULT_COLUMNS, TABLES, Column, Query,
                                ResultSet, column_kinds, experiment_rows,
                                history_rows, insight_rows,
                                job_history_rows, job_rows,
                                node_rows, row_from_node, run_query,
                                user_rows, vocabulary)
from repro.query.errors import QueryError
from repro.query.expr import (Bool, Cmp, Expr, Not, conjoin, in_set,
                              parse_filter)
from repro.query.render import (QUERY_SCHEMA_VERSION, RENDERERS, Renderer,
                                get_renderer, json_payload, parse_delimited,
                                register_renderer, render_csv, render_json,
                                render_prom, render_table, render_tsv,
                                renderer_names)
from repro.query.views import (VIEW_KINDS, advise_query, all_query,
                               apply_modifiers, jupyter_jobs_query,
                               nodes_query, resolve_format,
                               running_jobs_query, top_query, user_query,
                               view_query)

__all__ = [
    "Bool", "Cmp", "Column", "DEFAULT_COLUMNS", "Expr", "Not",
    "QUERY_SCHEMA_VERSION", "Query", "QueryError", "RENDERERS",
    "Renderer", "ResultSet", "TABLES", "VIEW_KINDS", "advise_query",
    "all_query",
    "apply_modifiers", "column_kinds", "conjoin", "experiment_rows",
    "get_renderer",
    "history_rows", "in_set", "insight_rows", "job_history_rows",
    "job_rows", "json_payload",
    "jupyter_jobs_query", "node_rows", "nodes_query", "parse_delimited",
    "parse_filter", "register_renderer", "render_csv", "render_json",
    "render_prom", "render_table", "render_tsv", "renderer_names",
    "resolve_format", "row_from_node", "run_query", "running_jobs_query",
    "top_query",
    "user_query", "user_rows", "view_query", "vocabulary",
]

"""Typed query engine over LLload telemetry (DESIGN.md §7).

One :class:`Query` — select / filter / sort / group-by / limit — runs
against any :class:`~repro.core.metrics.ClusterSnapshot` (and, when a
:class:`~repro.daemon.store.HistoryStore` is supplied, its downsampled
tiers).  Every interactive view, watch frame, and daemon endpoint is a
canned query through this module, so the same vocabulary works from
Python (`Query(...)`), the CLI (``--filter/--sort/--columns/--limit``),
and HTTP (``GET /query?...``).

Tables:

  * ``nodes``   — one row per node; ``user`` is the first-owner
                  attribution (the TSV archive rule), ``users`` the
                  comma-joined set of all running-job owners.
  * ``users``   — one row per user with per-user aggregates (a node
                  shared by k users counts toward each of them, matching
                  the interactive per-user views).
  * ``jobs``    — one row per job in the snapshot's job table.
  * ``history`` — one row per downsampled tier bucket (daemon only:
                  requires a HistoryStore).
  * ``insights`` — one row per active §V-B insight (requires an
                  InsightEngine; the CLI builds one for ``--advise`` /
                  ``--table insights``, the daemon streams its own —
                  DESIGN.md §8).
  * ``experiments`` — one row per campaign cell (requires a
                  CampaignResult from ``LLload --experiment`` or the
                  daemon's ``GET /experiments`` — DESIGN.md §9).
  * ``job_history`` — one row per job per 15-minute bucket (requires a
                  JobHistoryStore; the daemon keeps one per source —
                  DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.metrics import ClusterSnapshot
from repro.insights.records import SEVERITIES, Severity
from repro.query.errors import QueryError
from repro.query.expr import Bool, Cmp, Expr, Not, parse_filter

# --------------------------------------------------------------- vocabulary


@dataclasses.dataclass(frozen=True)
class Column:
    name: str
    kind: str                   # "str" | "int" | "float"
    help: str = ""


_NODE_COLUMNS = [
    Column("host", "str", "hostname"),
    Column("user", "str", "owning user (first-owner rule; '' when idle)"),
    Column("users", "str", "all running-job owners, comma-joined"),
    Column("email", "str", "owning user's email"),
    Column("jobtype", "str", "owning job's type (batch/jupyter/debug)"),
    Column("cores", "int", "CPU cores on the node"),
    Column("cores_used", "int", "CPU cores allocated"),
    Column("cores_free", "int", "CPU cores free"),
    Column("cpu_load", "float", "5-minute load average (absolute)"),
    Column("norm_load", "float", "load / cores (1.0 == fully loaded)"),
    Column("mem", "float", "system memory total (GB)"),
    Column("mem_used", "float", "system memory used (GB)"),
    Column("mem_free", "float", "system memory free (GB)"),
    Column("gpus", "int", "devices on the node"),
    Column("gpus_used", "int", "devices allocated"),
    Column("gpus_free", "int", "devices free"),
    Column("gpu_load", "float", "mean device duty cycle (0..1+)"),
    Column("gpu_mem", "float", "device memory total (GB)"),
    Column("gpu_mem_used", "float", "device memory used (GB)"),
    Column("gpu_mem_free", "float", "device memory free (GB)"),
]

_USER_COLUMNS = [
    Column("user", "str", "username"),
    Column("email", "str", "email"),
    Column("nodes", "int", "nodes the user's running jobs occupy"),
    Column("cores_used", "int", "allocated cores across those nodes"),
    Column("gpus_used", "int", "allocated devices across those nodes"),
    Column("cpu_load", "float", "mean absolute load across those nodes"),
    Column("norm_load", "float", "mean normalized load"),
    Column("gpu_load", "float", "mean device duty over device nodes"),
    Column("mem_used", "float", "memory used across those nodes (GB)"),
    Column("gpu_mem_used", "float", "device memory used (GB)"),
]

_JOB_COLUMNS = [
    Column("job_id", "int", "job id"),
    Column("user", "str", "submitting user"),
    Column("name", "str", "job name"),
    Column("state", "str", "R | PD | CG"),
    Column("jobtype", "str", "batch | jupyter | debug"),
    Column("nodes", "str", "assigned hostnames, comma-joined"),
    Column("nnodes", "int", "number of assigned nodes"),
    Column("cores", "int", "cores per node"),
    Column("gpus", "int", "devices per node"),
    Column("gpu_request", "str", "gres request string"),
    Column("start_time", "float", "start time (cluster clock)"),
    Column("partition", "str", "partition"),
    Column("mem", "float", "memory per node (GB)"),
]

_HISTORY_AGGS = ("norm_load", "gpu_load", "nodes", "cores_used",
                 "mem_used_gb", "gpus_used")

_HISTORY_COLUMNS = [
    Column("tier", "str", "tier name (raw or a downsampling tier)"),
    Column("t", "float", "bucket start (cluster clock)"),
    Column("count", "int", "snapshots folded into the bucket"),
] + [
    Column(f"{f}_{agg}", "float", f"bucket {agg} of {f}")
    for f in _HISTORY_AGGS for agg in ("min", "mean", "max")
]

_JOB_HISTORY_AGGS = ("gpu_duty", "cpu_load", "mem_used_gb", "step_time_s")

_JOB_HISTORY_COLUMNS = [
    Column("job_id", "int", "job id"),
    Column("user", "str", "submitting user"),
    Column("name", "str", "job name"),
    Column("state", "str", "job state at the newest sample"),
    Column("nodes", "int", "nodes the job occupies"),
    Column("queue_wait_s", "float", "submit-to-start wait (s)"),
    Column("t", "float", "bucket start (cluster clock)"),
    Column("count", "int", "samples folded into the bucket"),
] + [
    Column(f"{f}_{agg}", "float", f"bucket {agg} of {f}")
    for f in _JOB_HISTORY_AGGS for agg in ("min", "mean", "max")
]

_EXPERIMENT_COLUMNS = [
    Column("cell", "str", "cell id: <mix>/<fleet>g/nppn<N> or "
                          "<mix>/<fleet>g/controller"),
    Column("mode", "str", "fixed (swept NPPN) | controller (closed loop)"),
    Column("mix", "str", "workload mix name"),
    Column("fleet", "int", "GPU nodes in the cell's fleet"),
    Column("nppn", "int", "tasks-per-GPU (controller: converged level)"),
    Column("tasks_done", "int", "tasks completed within the window"),
    Column("throughput", "float", "completed tasks per hour"),
    Column("speedup", "float",
           "throughput vs the same mix+fleet fixed nppn1 cell"),
    Column("gpu_duty", "float", "mean device duty over in-use GPU nodes"),
    Column("mem_headroom", "float", "mean free device-memory fraction"),
    Column("queue_wait_s", "float", "mean submit-to-start wait (s)"),
    Column("insights", "int", "active insights summed over snapshots"),
    Column("seed", "int", "scenario seed"),
]

_INSIGHT_COLUMNS = [
    Column("severity", "str", "info | warn | critical (ordered: "
                              "severity>=warn keeps warn and critical)"),
    Column("kind", "str", "rule kind (low_gpu | missubmission | overload "
                          "| io_storm | any registered rule)"),
    Column("user", "str", "subject username"),
    Column("email", "str", "subject's email"),
    Column("hosts", "str", "implicated hostnames, comma-joined"),
    Column("nodes", "int", "number of implicated nodes"),
    Column("nppn", "int", "suggested tasks-per-GPU (low_gpu rule)"),
    Column("cores_per_task", "int",
           "suggested cores-per-task (missubmission rule)"),
    Column("persistence", "float",
           "fraction of snapshots the diagnosis held since first seen"),
    Column("streak", "int", "consecutive snapshots the rule fired"),
    Column("first_seen", "float", "first diagnosed (cluster clock)"),
    Column("last_seen", "float", "last confirmed (cluster clock)"),
    Column("message", "str", "diagnosis + suggested remediation"),
]

TABLES: Dict[str, List[Column]] = {
    "nodes": _NODE_COLUMNS,
    "users": _USER_COLUMNS,
    "jobs": _JOB_COLUMNS,
    "history": _HISTORY_COLUMNS,
    "insights": _INSIGHT_COLUMNS,
    "experiments": _EXPERIMENT_COLUMNS,
    "job_history": _JOB_HISTORY_COLUMNS,
}

# the default selection shown by generic renderers when no --columns given
DEFAULT_COLUMNS: Dict[str, Tuple[str, ...]] = {
    "nodes": ("host", "user", "cores", "cores_used", "cpu_load",
              "norm_load", "mem", "mem_used", "gpus", "gpus_used",
              "gpu_load"),
    "users": ("user", "nodes", "cores_used", "gpus_used", "norm_load",
              "gpu_load"),
    "jobs": ("job_id", "user", "name", "state", "jobtype", "nnodes",
             "cores", "gpus", "start_time"),
    "history": ("tier", "t", "count", "norm_load_mean", "gpu_load_mean",
                "nodes_mean", "cores_used_mean"),
    "insights": ("severity", "kind", "user", "nodes", "nppn",
                 "persistence", "message"),
    "experiments": ("cell", "mode", "nppn", "tasks_done", "throughput",
                    "speedup", "gpu_duty", "queue_wait_s", "insights"),
    "job_history": ("job_id", "user", "state", "nodes", "t", "count",
                    "gpu_duty_mean", "cpu_load_mean", "mem_used_gb_mean",
                    "queue_wait_s"),
}


def vocabulary(table: str) -> List[str]:
    """Column names of ``table`` (raises QueryError for unknown tables)."""
    if table not in TABLES:
        raise QueryError(f"unknown table {table!r}; valid tables: "
                         + ", ".join(sorted(TABLES)))
    return [c.name for c in TABLES[table]]


def column_kinds(table: str) -> Dict[str, str]:
    """Column name -> kind (``str``/``int``/``float``) for ``table``."""
    return {c.name: c.kind for c in TABLES[table]}


def _check_columns(table: str, names: Sequence[str], what: str,
                   allow_desc: bool = False) -> None:
    vocab = vocabulary(table)
    for name in names:
        base = name[1:] if allow_desc and name.startswith("-") else name
        if base not in vocab:
            raise QueryError(
                f"unknown column {base!r} in {what}; valid columns for "
                f"table {table!r}: " + ", ".join(vocab))


def _check_expr(table: str, expr: Optional[Expr]) -> None:
    if expr is None:
        return
    if isinstance(expr, Cmp):
        _check_columns(table, [expr.column], "filter")
        if (expr.column == "severity" and table == "insights"
                and expr.op not in ("=~", "has")
                and str(expr.value) not in SEVERITIES):
            # severity compares by rank (info < warn < critical); an
            # unknown level would silently rank below everything
            raise QueryError(
                f"unknown severity {expr.value!r} in filter; valid "
                "levels (ascending): " + ", ".join(SEVERITIES))
    elif isinstance(expr, Not):
        _check_expr(table, expr.child)
    elif isinstance(expr, Bool):
        for child in expr.children:
            _check_expr(table, child)


# -------------------------------------------------------------------- Query


@dataclasses.dataclass(frozen=True)
class Query:
    """One typed query; immutable so canned views can be shared."""
    table: str = "nodes"
    columns: Tuple[str, ...] = ()       # () selects DEFAULT_COLUMNS[table]
    where: Optional[Expr] = None
    sort: Tuple[str, ...] = ()          # "-col" sorts descending
    group_by: Optional[str] = None
    limit: Optional[int] = None         # grouped queries limit groups

    def validate(self) -> "Query":
        """Check every referenced table/column/severity/limit; returns
        self so construction can chain.  Raises QueryError (with the
        valid vocabulary in the message) on the first problem."""
        vocabulary(self.table)          # raises on unknown table
        _check_columns(self.table, self.columns, "--columns")
        _check_columns(self.table, self.sort, "--sort", allow_desc=True)
        if self.group_by is not None:
            _check_columns(self.table, [self.group_by], "--group-by")
        _check_expr(self.table, self.where)
        if self.limit is not None and self.limit <= 0:
            raise QueryError(f"limit must be > 0, got {self.limit}")
        return self

    @classmethod
    def from_params(cls, *, table: Optional[str] = None,
                    columns: Optional[str] = None,
                    filter: Optional[str] = None,   # noqa: A002 — CLI name
                    sort: Optional[str] = None,
                    group_by: Optional[str] = None,
                    limit=None) -> "Query":
        """Build from the string forms the CLI flags / query params use."""
        table = (table or "nodes").strip()
        vocab = vocabulary(table)
        cols = tuple(c.strip() for c in (columns or "").split(",")
                     if c.strip())
        sort_keys = tuple(s.strip() for s in (sort or "").split(",")
                          if s.strip())
        where = parse_filter(filter, vocab) if filter else None
        if limit is not None and not isinstance(limit, int):
            try:
                limit = int(str(limit).strip())
            except ValueError:
                raise QueryError(f"limit must be an integer, got {limit!r}")
        return cls(table=table, columns=cols, where=where,
                   sort=sort_keys, group_by=(group_by or None),
                   limit=limit).validate()

    # conveniences for composing canned views with user flags ------------
    def narrowed(self, extra: Optional[Expr]) -> "Query":
        """AND an extra condition onto this query's filter."""
        if extra is None:
            return self
        from repro.query.expr import conjoin
        return dataclasses.replace(self, where=conjoin(self.where, extra))

    def with_params(self, other: "Query") -> "Query":
        """Overlay the explicitly-set parts of ``other`` (same table)."""
        return dataclasses.replace(
            self,
            columns=other.columns or self.columns,
            where=other.where if other.where is not None else self.where,
            sort=other.sort or self.sort,
            group_by=other.group_by or self.group_by,
            limit=other.limit if other.limit is not None else self.limit,
        )


# ---------------------------------------------------------------- ResultSet


@dataclasses.dataclass
class ResultSet:
    """Rows carry the table's *full* vocabulary (renderers project onto
    ``columns``), so canned text views can reach every field."""
    table: str
    columns: List[str]
    rows: List[dict]
    cluster: str = ""
    timestamp: float = 0.0
    group_by: Optional[str] = None
    groups: Optional[List[Tuple[object, List[dict]]]] = None

    def cells(self, row: dict) -> List[object]:
        """``row``'s values projected onto the selected columns."""
        return [row.get(c) for c in self.columns]


# ------------------------------------------------------------ materializers


def row_from_node(n, *, user: str = "", users: str = "",
                  email: str = "", jobtype: str = "") -> dict:
    """One nodes-table row from a NodeSnapshot (ownership supplied by the
    caller) — also the bridge the legacy typed formatters render through."""
    return {
        "host": n.hostname,
        "user": user,
        "users": users,
        "email": email,
        "jobtype": jobtype,
        "cores": n.cores_total,
        "cores_used": n.cores_used,
        "cores_free": n.cores_free,
        "cpu_load": n.load,
        "norm_load": n.norm_load,
        "mem": n.mem_total_gb,
        "mem_used": n.mem_used_gb,
        "mem_free": n.mem_free_gb,
        "gpus": n.gpus_total,
        "gpus_used": n.gpus_used,
        "gpus_free": n.gpus_free,
        "gpu_load": n.gpu_load,
        "gpu_mem": n.gpu_mem_total_gb,
        "gpu_mem_used": n.gpu_mem_used_gb,
        "gpu_mem_free": n.gpu_mem_free_gb,
    }


def node_rows(snap: ClusterSnapshot) -> List[dict]:
    """One nodes-table row per node, sorted by hostname; ``user`` is the
    first-owner attribution, ``users`` every running-job owner."""
    owner: Dict[str, str] = {}
    jobtype: Dict[str, str] = {}
    owners: Dict[str, set] = {}
    for job in snap.jobs:
        if job.state != "R":
            continue
        for h in job.nodes:
            owner.setdefault(h, job.username)
            jobtype.setdefault(h, job.job_type)
            owners.setdefault(h, set()).add(job.username)
    rows = []
    for host in sorted(snap.nodes):
        n = snap.nodes[host]
        user = owner.get(host, "")
        rows.append(row_from_node(
            n, user=user,
            users=", ".join(sorted(owners.get(host, ()))),
            email=snap.email_of(user) if user else "",
            jobtype=jobtype.get(host, "")))
    return rows


def user_rows(snap: ClusterSnapshot) -> List[dict]:
    """One users-table row per user with per-user aggregates (a node
    shared by k users counts toward each of them)."""
    by_user = snap.nodes_by_user()
    rows = []
    for user in sorted(by_user):
        nodes = [snap.nodes[h] for h in by_user[user] if h in snap.nodes]
        if not nodes:
            continue
        gpu_nodes = [n for n in nodes if n.gpus_total > 0]
        mean = lambda vs: sum(vs) / len(vs) if vs else 0.0  # noqa: E731
        rows.append({
            "user": user,
            "email": snap.email_of(user),
            "nodes": len(nodes),
            "cores_used": sum(n.cores_used for n in nodes),
            "gpus_used": sum(n.gpus_used for n in nodes),
            "cpu_load": mean([n.load for n in nodes]),
            "norm_load": mean([n.norm_load for n in nodes]),
            "gpu_load": mean([n.gpu_load for n in gpu_nodes]),
            "mem_used": sum(n.mem_used_gb for n in nodes),
            "gpu_mem_used": sum(n.gpu_mem_used_gb for n in nodes),
        })
    return rows


def job_rows(snap: ClusterSnapshot) -> List[dict]:
    """One jobs-table row per job record, in snapshot job-table order."""
    return [{
        "job_id": j.job_id,
        "user": j.username,
        "name": j.name,
        "state": j.state,
        "jobtype": j.job_type,
        "nodes": ",".join(j.nodes),
        "nnodes": len(j.nodes),
        "cores": j.cores_per_node,
        "gpus": j.gpus_per_node,
        "gpu_request": j.gpu_request,
        "start_time": j.start_time,
        "partition": j.partition,
        "mem": j.mem_per_node_gb,
    } for j in snap.jobs]


def insight_rows(insights, snap: Optional[ClusterSnapshot] = None
                 ) -> List[dict]:
    """One row per active insight.  ``insights`` is an
    :class:`~repro.insights.engine.InsightEngine` (its ``active()`` set
    is materialized) or any iterable of Insight records; ``snap``
    supplies the subject's email when available."""
    items = insights.active() if hasattr(insights, "active") else insights
    rows = []
    for i in items:
        rows.append({
            "severity": Severity(i.severity),
            "kind": i.kind,
            "user": i.username,
            "email": snap.email_of(i.username) if snap is not None else "",
            "hosts": ",".join(i.hostnames),
            "nodes": len(i.hostnames),
            "nppn": i.suggested_nppn,
            "cores_per_task": i.suggested_cores_per_task,
            "persistence": i.persistence,
            "streak": i.streak,
            "first_seen": i.first_seen,
            "last_seen": i.last_seen,
            "message": i.message,
        })
    return rows


def experiment_rows(experiments) -> List[dict]:
    """One row per campaign cell.  ``experiments`` is a
    :class:`~repro.experiments.runner.CampaignResult` (its ``rows()``
    are materialized, speedups included) or any iterable of row dicts
    already in the table's vocabulary."""
    if hasattr(experiments, "rows"):
        return list(experiments.rows())
    return [dict(r) for r in experiments]


def job_history_rows(jobstore) -> List[dict]:
    """One row per job per 15-minute bucket of a
    :class:`~repro.daemon.store.JobHistoryStore`, jobs in id order,
    buckets oldest first.  Identity columns (user/name/state/nodes/
    queue_wait_s) come from the job's newest retained sample."""
    rows = []
    for job_id in sorted(jobstore.job_ids()):
        last = jobstore.last_sample(job_id)
        if last is None:
            continue
        for p in jobstore.points(job_id):
            row = {
                "job_id": job_id,
                "user": last.username,
                "name": last.name,
                "state": last.state,
                "nodes": last.n_nodes,
                "queue_wait_s": last.queue_wait_s,
                "t": p.bucket_start,
                "count": p.count,
            }
            for f in _JOB_HISTORY_AGGS:
                agg = getattr(p, f)
                row[f"{f}_min"] = agg.min
                row[f"{f}_mean"] = agg.mean
                row[f"{f}_max"] = agg.max
            rows.append(row)
    return rows


def history_rows(store) -> List[dict]:
    """Flatten every tier (raw included) of a HistoryStore into rows."""
    rows = []
    for tier in store.tier_names():
        wire = store.trend_wire(tier)
        for p in wire["points"]:
            row = {"tier": tier, "t": p["t"], "count": p["count"]}
            for f in _HISTORY_AGGS:
                for agg in ("min", "mean", "max"):
                    row[f"{f}_{agg}"] = p[f][agg]
            rows.append(row)
    return rows


# --------------------------------------------------------------- execution


def _sorted_rows(rows: List[dict], sort: Sequence[str]) -> List[dict]:
    out = list(rows)
    # apply keys last-to-first: list.sort is stable, so the first key
    # dominates and ties fall through to later keys (and, ultimately, to
    # the materializer's deterministic base order)
    for key in reversed(list(sort)):
        desc = key.startswith("-")
        col = key[1:] if desc else key

        def sort_key(r, col=col, desc=desc):
            # None cells (e.g. insights.nppn outside the low_gpu rule)
            # are not comparable with values; group them after all
            # values in BOTH directions (the marker flips with desc so
            # reverse=True cannot float Nones to the top)
            v = r.get(col)
            if v is None:
                return (0, 0) if desc else (1, 0)
            return (1, v) if desc else (0, v)

        out.sort(key=sort_key, reverse=desc)
    return out


def _grouped(rows: List[dict], column: str
             ) -> List[Tuple[object, List[dict]]]:
    groups: Dict[object, List[dict]] = {}
    order: List[object] = []
    for row in rows:
        key = row.get(column)
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(row)
    return [(k, groups[k]) for k in order]


def run_query(snap: Optional[ClusterSnapshot], query: Query,
              store=None, insights=None, experiments=None,
              jobstore=None) -> ResultSet:
    """Execute ``query`` against a snapshot (and optional history store
    / insight engine / campaign result / job history store).

    ``snap`` may be None only for the ``history``, ``insights``,
    ``experiments`` and ``job_history`` tables; ``insights`` is an
    InsightEngine or an iterable of Insights; ``experiments`` is a
    CampaignResult or an iterable of experiments-table rows;
    ``jobstore`` is a JobHistoryStore.
    """
    query.validate()
    if query.table == "history":
        if store is None:
            raise QueryError(
                "table 'history' needs a history store — query a daemon "
                "(GET /query) or pass store=HistoryStore(...)")
        rows = history_rows(store)
    elif query.table == "job_history":
        if jobstore is None:
            raise QueryError(
                "table 'job_history' needs a job history store — query "
                "a daemon (GET /query) or pass "
                "jobstore=JobHistoryStore(...)")
        rows = job_history_rows(jobstore)
    elif query.table == "insights":
        if insights is None:
            raise QueryError(
                "table 'insights' needs an insight engine — query a "
                "daemon (GET /insights or GET /query) or pass "
                "insights=InsightEngine(...)")
        rows = insight_rows(insights, snap)
    elif query.table == "experiments":
        if experiments is None:
            raise QueryError(
                "table 'experiments' needs campaign results — run "
                "`LLload --experiment FILE`, query a daemon "
                "(GET /experiments), or pass experiments=CampaignResult")
        rows = experiment_rows(experiments)
    elif snap is None:
        raise QueryError(f"table {query.table!r} needs a snapshot")
    elif query.table == "nodes":
        rows = node_rows(snap)
    elif query.table == "users":
        rows = user_rows(snap)
    else:
        rows = job_rows(snap)

    if query.where is not None:
        rows = [r for r in rows if query.where.evaluate(r)]
    rows = _sorted_rows(rows, query.sort)

    groups = None
    if query.group_by is not None:
        groups = _grouped(rows, query.group_by)
        if query.limit is not None:
            groups = groups[:query.limit]
            rows = [r for _, g in groups for r in g]
    elif query.limit is not None:
        rows = rows[:query.limit]

    columns = list(query.columns or DEFAULT_COLUMNS[query.table])
    return ResultSet(
        table=query.table, columns=columns, rows=rows,
        cluster=snap.cluster if snap is not None else "",
        timestamp=snap.timestamp if snap is not None else 0.0,
        group_by=query.group_by, groups=groups)

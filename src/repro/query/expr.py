"""Filter expression parser for the query engine (DESIGN.md §7).

Grammar (precedence low to high)::

    expr    := or
    or      := and ("or" and)*
    and     := unary ("and" unary)*
    unary   := "not" unary | "(" expr ")" | cmp
    cmp     := IDENT OP literal
    OP      := "<=" | ">=" | "==" | "!=" | "=~" | "<" | ">" | "=" | "has"
    literal := NUMBER | STRING | bareword

``=~`` is a shell-glob match (``fnmatch``) for string columns:
``host =~ "c-1-*"``; ``has`` tests membership in a comma-joined list
column: ``users has ab12345``.  ``=`` is accepted as a spelling of
``==``.
Column names are validated against the queried table's vocabulary at
parse time, so a typo reports the valid columns instead of matching
nothing.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import re
from typing import Iterable, List, Optional, Sequence, Union

from repro.query.errors import QueryError

_TOKEN_RE = re.compile(r"""
    \s*(?:
        (?P<op><=|>=|==|!=|=~|<|>|=)
      | (?P<lparen>\()
      | (?P<rparen>\))
      | (?P<string>"(?:[^"\\]|\\.)*"|'(?:[^'\\]|\\.)*')
      | (?P<number>-?\d+(?:\.\d+)?(?:[eE][-+]?\d+)?)
      | (?P<word>[A-Za-z_][A-Za-z0-9_.:*?\[\]-]*)
    )""", re.VERBOSE)


@dataclasses.dataclass(frozen=True)
class _Tok:
    kind: str
    text: str


def _tokenize(text: str) -> List[_Tok]:
    toks, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None or m.end() == pos:
            rest = text[pos:].strip()
            if not rest:
                break
            raise QueryError(f"filter: cannot parse at {rest[:20]!r}")
        pos = m.end()
        for kind in ("op", "lparen", "rparen", "string", "number", "word"):
            tok = m.group(kind)
            if tok is not None:
                toks.append(_Tok(kind, tok))
                break
    return toks


# ---------------------------------------------------------------- AST nodes


@dataclasses.dataclass(frozen=True)
class Cmp:
    column: str
    op: str                       # < <= > >= == != =~ has
    value: Union[float, str]
    raw: Optional[str] = None     # the literal as written (string contexts)

    def evaluate(self, row: dict) -> bool:
        """Does ``row`` satisfy this comparison?  Missing/None cells
        never match; type-mismatched comparisons match nothing (except
        ``!=``, which stays the negation of ``==``)."""
        have = row.get(self.column)
        if have is None:
            return False
        want = self.value
        if isinstance(have, str) and isinstance(want, float):
            # a numeric literal against a string column compares as
            # written: `users has 42` / `host == 123` must match the
            # text "42"/"123", not the float repr "42.0"
            want = self.raw if self.raw is not None else str(want)
        if self.op == "=~":
            return fnmatch.fnmatchcase(str(have), str(want))
        if self.op == "has":
            parts = [p.strip() for p in str(have).split(",")]
            return str(want) in parts
        if isinstance(want, str) and isinstance(have, (int, float)):
            # string literal against a numeric column: equality is
            # False, inequality its negation (!= stays `not ==`), and
            # orderings are unsatisfiable
            return self.op == "!="
        if self.op == "==":
            return have == want
        if self.op == "!=":
            return have != want
        if self.op == "<":
            return have < want
        if self.op == "<=":
            return have <= want
        if self.op == ">":
            return have > want
        return have >= want

    def __str__(self):
        v = self.value if isinstance(self.value, float) else f'"{self.value}"'
        return f"{self.column} {self.op} {v}"


@dataclasses.dataclass(frozen=True)
class Not:
    """Logical negation of one child expression."""
    child: "Expr"

    def evaluate(self, row: dict) -> bool:
        """True when the child expression does not match ``row``."""
        return not self.child.evaluate(row)

    def __str__(self):
        return f"not ({self.child})"


@dataclasses.dataclass(frozen=True)
class Bool:
    """N-ary conjunction (``and``) or disjunction (``or``)."""
    op: str                       # and | or
    children: tuple

    def evaluate(self, row: dict) -> bool:
        """All (``and``) / any (``or``) of the children match ``row``."""
        if self.op == "and":
            return all(c.evaluate(row) for c in self.children)
        return any(c.evaluate(row) for c in self.children)

    def __str__(self):
        return f" {self.op} ".join(f"({c})" for c in self.children)


Expr = Union[Cmp, Not, Bool]


def conjoin(*exprs: Optional[Expr]) -> Optional[Expr]:
    """AND together the non-None expressions (None = match everything)."""
    parts = tuple(e for e in exprs if e is not None)
    if not parts:
        return None
    if len(parts) == 1:
        return parts[0]
    return Bool("and", parts)


def in_set(column: str, values: Iterable[str]) -> Expr:
    """``column`` equals any of ``values`` (used by canned views)."""
    vals = list(values)
    if len(vals) == 1:
        return Cmp(column, "==", vals[0])
    return Bool("or", tuple(Cmp(column, "==", v) for v in vals))


# ------------------------------------------------------------------ parser


class _Parser:
    def __init__(self, toks: List[_Tok], vocabulary: Sequence[str]):
        self.toks = toks
        self.pos = 0
        self.vocab = list(vocabulary)

    def peek(self) -> Optional[_Tok]:
        return self.toks[self.pos] if self.pos < len(self.toks) else None

    def next(self) -> _Tok:
        tok = self.peek()
        if tok is None:
            raise QueryError("filter: unexpected end of expression")
        self.pos += 1
        return tok

    def parse(self) -> Expr:
        expr = self.parse_or()
        if self.peek() is not None:
            raise QueryError(
                f"filter: trailing input at {self.peek().text!r}")
        return expr

    def parse_or(self) -> Expr:
        parts = [self.parse_and()]
        while self.peek() and self.peek().text == "or":
            self.next()
            parts.append(self.parse_and())
        return parts[0] if len(parts) == 1 else Bool("or", tuple(parts))

    def parse_and(self) -> Expr:
        parts = [self.parse_unary()]
        while self.peek() and self.peek().text == "and":
            self.next()
            parts.append(self.parse_unary())
        return parts[0] if len(parts) == 1 else Bool("and", tuple(parts))

    def parse_unary(self) -> Expr:
        tok = self.peek()
        if tok is None:
            raise QueryError("filter: unexpected end of expression")
        if tok.kind == "word" and tok.text == "not":
            self.next()
            return Not(self.parse_unary())
        if tok.kind == "lparen":
            self.next()
            expr = self.parse_or()
            closing = self.next()
            if closing.kind != "rparen":
                raise QueryError("filter: expected ')'")
            return expr
        return self.parse_cmp()

    def parse_cmp(self) -> Expr:
        col = self.next()
        if col.kind != "word":
            raise QueryError(
                f"filter: expected a column name, got {col.text!r}")
        if col.text not in self.vocab:
            raise QueryError(
                f"unknown column {col.text!r} in filter; valid columns: "
                + ", ".join(self.vocab))
        op = self.next()
        if op.kind != "op" and not (op.kind == "word" and op.text == "has"):
            raise QueryError(
                f"filter: expected a comparison after {col.text!r}, "
                f"got {op.text!r}")
        val = self.next()
        if val.kind == "number":
            value: Union[float, str] = float(val.text)
        elif val.kind == "string":
            body = val.text[1:-1]
            value = re.sub(r"\\(.)", r"\1", body)
        elif val.kind == "word" and val.text not in ("and", "or", "not"):
            value = val.text            # bareword string (host == c-1-1-1)
        else:
            raise QueryError(
                f"filter: expected a value after {op.text!r}, "
                f"got {val.text!r}")
        op_text = "==" if op.text == "=" else op.text
        return Cmp(col.text, op_text, value,
                   raw=val.text if val.kind == "number" else None)


def parse_filter(text: str, vocabulary: Sequence[str]) -> Optional[Expr]:
    """Parse ``--filter``-style text against a column vocabulary.

    Empty/blank text means "match everything" (None).
    """
    toks = _tokenize(text)
    if not toks:
        return None
    return _Parser(toks, vocabulary).parse()

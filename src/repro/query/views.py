"""Canned queries behind the interactive LLload views (DESIGN.md §7).

Each paper view is one :class:`~repro.query.engine.Query` (plus, for
composite views, an auxiliary jobs query); the CLI, watch loop, and
daemon all build their views here, overlay the user's
``--filter/--sort/--columns/--limit`` modifiers with
:func:`apply_modifiers`, and hand the result to a renderer — legacy
text (byte-identical to the paper figures) or any registry format.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.query.engine import Query
from repro.query.errors import QueryError
from repro.query.expr import Cmp, conjoin, in_set

VIEW_KINDS = ("user", "top", "nodes", "all", "advise")


def user_query(username: str) -> Query:
    """Fig 2/3: the nodes a user's running jobs occupy (shared nodes
    included — membership in the ``users`` column, not first-owner)."""
    return Query(table="nodes", where=Cmp("users", "has", username),
                 sort=("host",))


def top_query(n: int) -> Query:
    """Fig 5/10: top-N nodes by normalized CPU load."""
    if n <= 0:
        raise QueryError(f"top view needs n > 0, got {n}")
    return Query(table="nodes", sort=("-norm_load",), limit=n)


def nodes_query(hosts: Sequence[str]) -> Query:
    """Fig 11: detail rows for an explicit host list."""
    hosts = [h for h in hosts if h]
    if not hosts:
        raise QueryError("nodes view needs at least one hostname")
    return Query(table="nodes", where=in_set("host", list(hosts)))


def all_query() -> Query:
    """Fig 4: every owned node, ordered for per-user block rendering."""
    return Query(table="nodes", where=Cmp("users", "!=", ""),
                 sort=("host",))


def advise_query() -> Query:
    """§V-B: every active insight, most severe first (ties: the insight
    engine's deterministic (user, kind) order).  Covers all subjects —
    narrow with ``--filter "user == NAME"`` or ``"severity >= warn"``."""
    return Query(table="insights", sort=("-severity", "user", "kind"))


def jupyter_jobs_query() -> Query:
    """The Fig-4 Jupyter summary's source rows."""
    return Query(table="jobs", where=conjoin(
        Cmp("state", "==", "R"), Cmp("jobtype", "==", "jupyter")))


def running_jobs_query() -> Query:
    """Running jobs (the -n job table's source rows)."""
    return Query(table="jobs", where=Cmp("state", "==", "R"))


def view_query(kind: str, *, user: str = "",
               n: int = 10, hosts: Sequence[str] = ()) -> Query:
    """The canned query for one of :data:`VIEW_KINDS` (``user``/``top``/
    ``nodes``/``all``/``advise``), built from the relevant argument;
    raises QueryError for unknown kinds."""
    if kind == "user":
        return user_query(user)
    if kind == "top":
        return top_query(n)
    if kind == "nodes":
        return nodes_query(hosts)
    if kind == "all":
        return all_query()
    if kind == "advise":
        return advise_query()
    raise QueryError(f"unknown view {kind!r}; valid views: "
                     + ", ".join(VIEW_KINDS))


def apply_modifiers(canned: Query, *,
                    columns: Optional[str] = None,
                    filter: Optional[str] = None,  # noqa: A002 — CLI name
                    sort: Optional[str] = None,
                    group_by: Optional[str] = None,
                    limit: Optional[int] = None) -> Query:
    """Overlay string-form CLI flags / query params onto a canned view:
    ``filter`` ANDs with the view's own scope, the others override.
    String parsing and validation are :meth:`Query.from_params`'s — the
    view path and the raw ``--table``//query path share one discipline."""
    mod = Query.from_params(table=canned.table, columns=columns,
                            filter=filter, sort=sort, group_by=group_by,
                            limit=limit)
    q = canned.narrowed(mod.where)
    return q.with_params(dataclasses.replace(mod, where=None)).validate()


def resolve_format(fmt: Optional[str], columns: Optional[str],
                   group_by: Optional[str] = None) -> str:
    """``text`` (the legacy view layout) has fixed columns and no group
    sections, so an explicit ``--columns`` or ``--group-by``
    auto-upgrades it to the generic table renderer; any registry format
    passes through."""
    fmt = fmt or "text"
    if fmt == "text" and (columns or group_by):
        return "table"
    return fmt

"""Renderer registry for query results (DESIGN.md §7).

Every renderer turns a :class:`~repro.query.engine.ResultSet` into one
string with a stable, machine-readable schema:

  * ``table`` — aligned text columns (human exploration)
  * ``json``  — versioned envelope, rows as arrays in column order
  * ``csv``   — RFC-4180 (quoted delimiters/quotes/newlines, CRLF)
  * ``tsv``   — tab-separated with the same quoting discipline
  * ``prom``  — Prometheus gauges, numeric columns labelled by the
                string columns

The same renderer instance answers a local ``--format json`` and the
daemon's ``GET /query&format=json``, which is what makes local and
remote output byte-identical for the same snapshot.
"""
from __future__ import annotations

import csv
import dataclasses
import io
import json
from typing import Callable, Dict, List, Optional, Tuple

from repro.query.engine import ResultSet, column_kinds
from repro.query.errors import QueryError

QUERY_SCHEMA_VERSION = 1

JSON_CT = "application/json; charset=utf-8"
TEXT_CT = "text/plain; charset=utf-8"
CSV_CT = "text/csv; charset=utf-8"
PROM_CT = "text/plain; version=0.0.4; charset=utf-8"


@dataclasses.dataclass(frozen=True)
class Renderer:
    name: str
    content_type: str
    fn: Callable[[ResultSet], str]

    def render(self, rs: ResultSet) -> str:
        """Render ``rs`` to one newline-terminated string."""
        return self.fn(rs)


def _cell_text(v: object, kind: str) -> str:
    if v is None:
        return ""
    if kind == "float":
        return f"{float(v):.2f}"
    return str(v)


# -------------------------------------------------------------------- table


def render_table(rs: ResultSet) -> str:
    """Aligned text columns (numbers right, strings left), groups as
    ``-- col = key --`` sections, ``(N rows)`` footer; floats to two
    decimals."""
    kinds = column_kinds(rs.table)
    header = list(rs.columns)

    def body(rows: List[dict]) -> List[List[str]]:
        return [[_cell_text(r.get(c), kinds.get(c, "str"))
                 for c in rs.columns] for r in rows]

    sections: List[Tuple[Optional[str], List[List[str]]]] = []
    if rs.groups is not None:
        for key, rows in rs.groups:
            sections.append((f"{rs.group_by} = {key}", body(rows)))
    else:
        sections.append((None, body(rs.rows)))

    widths = [len(h) for h in header]
    for _, rows in sections:
        for row in rows:
            for i, cell in enumerate(row):
                widths[i] = max(widths[i], len(cell))

    def fmt(cells: List[str]) -> str:
        out = []
        for i, cell in enumerate(cells):
            kind = kinds.get(header[i], "str")
            if kind in ("int", "float"):
                out.append(cell.rjust(widths[i]))
            else:
                out.append(cell.ljust(widths[i]))
        return "  ".join(out).rstrip()

    lines = [fmt(header)]
    for title, rows in sections:
        if title is not None:
            lines.append(f"-- {title} --")
        lines.extend(fmt(r) for r in rows)
    n = sum(len(rows) for _, rows in sections)
    lines.append(f"({n} row{'' if n == 1 else 's'})")
    # every renderer ends with a newline, so local stdout and daemon
    # response bodies are byte-identical without caller fix-ups
    return "\n".join(lines) + "\n"


# --------------------------------------------------------------------- json


def json_payload(rs: ResultSet) -> Dict[str, object]:
    """The stable ``query_result`` schema (rows are arrays in column
    order); shared verbatim by the CLI and the daemon's /query."""
    payload: Dict[str, object] = {
        "table": rs.table,
        "cluster": rs.cluster,
        "timestamp": rs.timestamp,
        "columns": list(rs.columns),
    }
    if rs.groups is not None:
        payload["group_by"] = rs.group_by
        payload["groups"] = [
            {"key": key, "rows": [rs.cells(r) for r in rows]}
            for key, rows in rs.groups]
    else:
        payload["rows"] = [rs.cells(r) for r in rs.rows]
    return payload


def render_json(rs: ResultSet) -> str:
    """The versioned ``query_result`` JSON envelope (schema in
    DESIGN.md §7); floats keep full precision."""
    env = {"v": QUERY_SCHEMA_VERSION, "kind": "query_result",
           "query_result": json_payload(rs)}
    return json.dumps(env, separators=(",", ":")) + "\n"


# ----------------------------------------------------------------- csv/tsv


def _render_delimited(rs: ResultSet, *, delimiter: str,
                      lineterminator: str) -> str:
    """Header + one line per row.  Python's csv writer implements the
    RFC-4180 discipline: any cell containing the delimiter, a quote, CR
    or LF is quoted, internal quotes doubled.  Grouped results flatten;
    the group column is part of the vocabulary, so no information is
    lost (select it via --columns to keep it)."""
    buf = io.StringIO()
    w = csv.writer(buf, delimiter=delimiter, quotechar='"',
                   quoting=csv.QUOTE_MINIMAL, lineterminator=lineterminator)
    w.writerow(rs.columns)
    for row in rs.rows:
        w.writerow(["" if v is None else repr(v) if isinstance(v, float)
                    else str(v) for v in rs.cells(row)])
    return buf.getvalue()


def render_csv(rs: ResultSet) -> str:
    """RFC-4180 CSV: header + rows, quoted per ``_render_delimited``."""
    return _render_delimited(rs, delimiter=",", lineterminator="\r\n")


def render_tsv(rs: ResultSet) -> str:
    """Tab-separated with the same RFC-4180 quoting as CSV."""
    # CRLF here too: with a bare-\n terminator the csv writer would NOT
    # quote a lone \r inside a cell, breaking render->parse round-trips
    return _render_delimited(rs, delimiter="\t", lineterminator="\r\n")


def parse_delimited(text: str, fmt: str = "csv") -> List[List[str]]:
    """Inverse of the csv/tsv renderers (header row included) — the
    round-trip partner the property tests exercise."""
    delimiter = "," if fmt == "csv" else "\t"
    return list(csv.reader(io.StringIO(text), delimiter=delimiter,
                           quotechar='"'))


# --------------------------------------------------------------------- prom


def _prom_escape(value: str) -> str:
    return (value.replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def render_prom(rs: ResultSet, prefix: str = "llload_query_") -> str:
    """Numeric selected columns become gauges; string selected columns
    become labels (plus ``cluster``)."""
    kinds = column_kinds(rs.table)
    label_cols = [c for c in rs.columns if kinds.get(c) == "str"]
    value_cols = [c for c in rs.columns if kinds.get(c) in ("int", "float")]
    # two samples with identical labels are invalid exposition format —
    # refuse up front instead of emitting metrics Prometheus rejects
    seen = set()
    for row in rs.rows:
        key = tuple(str(row.get(c, "")) for c in label_cols)
        if key in seen:
            raise QueryError(
                "prom format needs string columns that uniquely identify "
                f"each row (duplicate labels {dict(zip(label_cols, key))}); "
                "add a unique column such as 'host' to the selection")
        seen.add(key)
    lines: List[str] = []
    for col in value_cols:
        name = f"{prefix}{rs.table}_{col}"
        lines.append(f"# TYPE {name} gauge")
        for row in rs.rows:
            pairs = [("cluster", rs.cluster)] if rs.cluster else []
            pairs += [(c, str(row.get(c, ""))) for c in label_cols]
            labels = ",".join(f'{k}="{_prom_escape(v)}"' for k, v in pairs)
            labels = "{" + labels + "}" if labels else ""
            v = row.get(col)
            val = repr(float(v)) if v is not None else "NaN"
            lines.append(f"{name}{labels} {val}")
    return "\n".join(lines) + "\n"


# ----------------------------------------------------------------- registry


RENDERERS: Dict[str, Renderer] = {}


def register_renderer(renderer: Renderer) -> None:
    """Admit (or replace) a renderer under its name."""
    RENDERERS[renderer.name] = renderer


def get_renderer(name: str) -> Renderer:
    """The registered renderer called ``name``; raises QueryError (with
    the valid format list) for unknown names."""
    if name not in RENDERERS:
        raise QueryError(f"unknown format {name!r}; valid formats: "
                         + ", ".join(sorted(RENDERERS)))
    return RENDERERS[name]


def renderer_names() -> List[str]:
    """Registered renderer names, sorted (the CLI's --format choices)."""
    return sorted(RENDERERS)


for _r in (
    Renderer("table", TEXT_CT, render_table),
    Renderer("json", JSON_CT, render_json),
    Renderer("csv", CSV_CT, render_csv),
    Renderer("tsv", CSV_CT, render_tsv),
    Renderer("prom", PROM_CT, render_prom),
):
    register_renderer(_r)

"""Render LLload views in the paper's terminal formats (Figs 2–5, 10, 11).

Every view here is a *canned query* through :mod:`repro.query`: the
query engine materializes/filters/sorts rows, and this module owns only
the paper's text layouts.  Two entry layers coexist:

  * the legacy typed API (``format_user_view(cluster, UserBlock, ...)``
    etc.) — unchanged signatures, now rendering through the same
    row formatters, byte-identical to the pre-engine output;
  * the ResultSet API (``user_view_text``/``top_view_text``/
    ``node_detail_text``/``all_view_text``) — consumed by the CLI,
    the watch loop, and the daemon's ``/view/*`` endpoints, so
    ``--filter/--sort/--limit`` compose with every view.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.core.llload import AllView, NodeDetail, TopNode, UserBlock
from repro.core.metrics import ClusterSnapshot, NodeSnapshot
from repro.query import (jupyter_jobs_query, row_from_node, run_query,
                         running_jobs_query)


def _gb(x: float) -> str:
    return f"{x:.0f}GB"


def _node_row(r: dict, gpu: bool) -> str:
    row = (f"{r['host']:<12} {r['cores']:>4} - {r['cores_used']:>3} = "
           f"{r['cores_free']:<4} {r['cpu_load']:>7.2f}  "
           f"{_gb(r['mem']):>7} - {_gb(r['mem_used']):>6} = "
           f"{_gb(r['mem_free']):<7}")
    if gpu:
        row += (f" | {r['gpus']:>2} - {r['gpus_used']} = {r['gpus_free']:<2} "
                f"{r['gpu_load']:>5.2f}  "
                f"{_gb(r['gpu_mem']):>6} - {_gb(r['gpu_mem_used']):>5}"
                f" = {_gb(r['gpu_mem_free']):<6}")
    return row


def _header(gpu: bool) -> str:
    h = (f"{'HOSTNAME':<12} {'CORES':>5} - {'USED':>4}= {'FREE':<4}"
         f" {'LOAD':>6}  {'MEMORY':>7} - {'USED':>6} = {'FREE':<7}")
    if gpu:
        h += (f" | {'GPUS':>4}- {'USED'} = {'FREE'} {'LOAD':>4} "
              f"{'GPUMEM':>7} - {'USED':>5} = {'FREE':<6}")
    return h


def _user_block_text(cluster: str, username: str, email: str,
                     rows: Sequence[dict], gpu: bool,
                     show_email: bool) -> str:
    lines = [f"Cluster name: {cluster}"]
    who = f"Username: {username}"
    if show_email:
        who += f" ({email})"
    who += f", Nodes used: {len(rows)}"
    lines.append(who)
    lines.append(_header(gpu))
    for r in rows:
        lines.append(_node_row(r, gpu))
    return "\n".join(lines)


def _rows_from_nodes(nodes: Sequence[NodeSnapshot]) -> List[dict]:
    return [row_from_node(n) for n in nodes]


# ------------------------------------------------------------- legacy API


def format_user_view(cluster: str, block: UserBlock, gpu: bool = False,
                     show_email: bool = False) -> str:
    return _user_block_text(cluster, block.username, block.email,
                            _rows_from_nodes(block.nodes), gpu, show_email)


def format_all_view(view: AllView, gpu: bool = False) -> str:
    lines = [f"Cluster name: {view.cluster}", ""]
    if view.jupyter:
        lines.append("Jupyter notebook jobs:")
        lines.append("")
        lines.append(f"{'NodeName':<14} Users(GPU)")
        for e in view.jupyter:
            lines.append(f"[J]-{e.hostname:<12}: " + ", ".join(e.users))
        lines.append("")
    lines.append("Node information for each user:")
    lines.append("")
    for blk in view.users:
        lines.append(format_user_view(view.cluster, blk, gpu,
                                      show_email=True))
        lines.append("")
    return "\n".join(lines)


def _top_row(host: str, avg_load: float, cpus: str, mem_total_mb: int,
             mem_free_mb: int) -> str:
    return (f"{host:<12} {avg_load:>9.5f}  {cpus:>14} "
            f"{mem_total_mb:>18} {mem_free_mb:>9}")


_TOP_COLUMNS = (f"{'HOSTNAMES':<12} {'AVG_LOAD':>9}  {'CPUS(A/I/O/T)':>14} "
                f"{'MEMORY(MB, Total)':>18} {'FREE_MEM':>9}")


def _top_header(n: int) -> List[str]:
    return [f"List {n} of nodes with loads, sorted by descending order",
            _TOP_COLUMNS]


def format_top(rows: List[TopNode], n: int) -> str:
    lines = _top_header(n)
    for r in rows:
        cpus = f"{r.cpus_alloc}/{r.cpus_idle}/{r.cpus_other}/{r.cpus_total}"
        lines.append(_top_row(r.hostname, r.avg_load, cpus,
                              r.mem_total_mb, r.mem_free_mb))
    return "\n".join(lines)


_DETAIL_HEADER = ["Node Information:",
                  f"{'HOSTNAMES':<12} {'CPU_LOAD':>9} {'CPUS(A/I/O/T)':>14} "
                  f"{'MEMORY':>8} {'FREE_MEM':>9} {'GRES_USED':>24} "
                  f"{'USER':>10}"]

_JOB_HEADER = (f"{'JOBID':>9} {'NAME':>20} {'USER':>9} {'START_TIME':>19} "
               f"{'EXEC_HOST':>11} {'CPUS':>5} {'MEM':>6} {'ST':>3}")


def _detail_node_line(r: dict, user: str) -> str:
    cpus = f"{r['cores_used']}/{r['cores_free']}/0/{r['cores']}"
    gres = f"gpu:{r['gpus_used']}" if r['gpus'] else "none"
    return (f"{r['host']:<12} {r['cpu_load']:>9.2f} {cpus:>14} "
            f"{int(r['mem'] * 1000):>8} "
            f"{int(r['mem_free'] * 1000):>9} {gres:>24} {user:>10}")


def _detail_job_line(j: dict) -> str:
    exec_host = ",".join(j["nodes"].split(",")[:2]) if j["nodes"] else ""
    return (f"{j['job_id']:>9} {j['name']:>20} {j['user']:>9} "
            f"{j['start_time']:>19.0f} {exec_host:>11} "
            f"{j['cores']:>5} {int(j['mem'] * 1000):>5}M "
            f"{j['state']:>3}")


def _missing_line(missing: Sequence[str]) -> str:
    return (f"Unknown node(s): {', '.join(missing)} "
            "(no such host in this snapshot)")


def format_node_detail(details: Sequence[NodeDetail],
                       missing: Sequence[str] = ()) -> str:
    if not details and missing:
        return "Node Information:\n" + _missing_line(missing)
    lines = list(_DETAIL_HEADER)
    for d in details:
        user = ", ".join(sorted({j.username for j in d.jobs})) or "-"
        lines.append(_detail_node_line(row_from_node(d.node), user))
    lines.append("")
    lines.append(_JOB_HEADER)
    seen = set()
    for d in details:
        for j in d.jobs:
            if j.job_id in seen:
                continue
            seen.add(j.job_id)
            lines.append(_detail_job_line({
                "job_id": j.job_id, "name": j.name, "user": j.username,
                "start_time": j.start_time, "nodes": ",".join(j.nodes),
                "cores": j.cores_per_node, "mem": j.mem_per_node_gb,
                "state": j.state}))
    if missing:
        lines.append("")
        lines.append(_missing_line(missing))
    return "\n".join(lines)


# ---------------------------------------------------------- ResultSet API


def user_view_text(snap: ClusterSnapshot, rows: Sequence[dict],
                   username: str, gpu: bool = False,
                   show_email: bool = False) -> str:
    """Fig 2/3 from engine rows (the user-view canned query's output)."""
    return _user_block_text(snap.cluster, username, snap.email_of(username),
                            rows, gpu, show_email)


def top_view_text(rows: Sequence[dict], n: int) -> str:
    """Fig 5/10 from engine rows (the top canned query's output)."""
    lines = _top_header(n)
    for r in rows:
        cpus = f"{r['cores_used']}/{r['cores_free']}/0/{r['cores']}"
        lines.append(_top_row(r["host"], r["norm_load"], cpus,
                              int(r["mem"] * 1000), int(r["mem_free"] * 1000)))
    return "\n".join(lines)


def _jobs_by_host(job_rows: Sequence[dict]) -> Dict[str, List[dict]]:
    by_host: Dict[str, List[dict]] = {}
    for j in job_rows:
        for h in j["nodes"].split(","):
            if h:
                by_host.setdefault(h, []).append(j)
    return by_host


def node_detail_text(snap: ClusterSnapshot, rows: Sequence[dict],
                     hosts: Sequence[str]) -> str:
    """Fig 11 from engine rows, in the *requested* host order; the job
    table comes from the running-jobs canned query."""
    by_host_row = {r["host"]: r for r in rows}
    jobs = run_query(snap, running_jobs_query()).rows
    by_host_jobs = _jobs_by_host(jobs)
    found = [h for h in hosts if h in by_host_row]
    # "unknown" means absent from the snapshot — a host a --filter
    # excluded exists, so it is simply omitted, never reported missing
    missing = [h for h in hosts if h not in snap.nodes]
    if not found and missing:
        return "Node Information:\n" + _missing_line(missing)
    lines = list(_DETAIL_HEADER)
    for h in found:
        host_jobs = by_host_jobs.get(h, [])
        user = ", ".join(sorted({j["user"] for j in host_jobs})) or "-"
        lines.append(_detail_node_line(by_host_row[h], user))
    lines.append("")
    lines.append(_JOB_HEADER)
    seen = set()
    for h in found:
        for j in by_host_jobs.get(h, []):
            if j["job_id"] in seen:
                continue
            seen.add(j["job_id"])
            lines.append(_detail_job_line(j))
    if missing:
        lines.append("")
        lines.append(_missing_line(missing))
    return "\n".join(lines)


_SEVERITY_TAGS = {"info": "INFO", "warn": "WARN", "critical": "CRIT"}


def advise_view_text(snap: ClusterSnapshot, rows: Sequence[dict]) -> str:
    """§V-B advise view from engine rows (the advise canned query's
    output): one tagged summary line plus the remediation message per
    active insight, most severe first."""
    lines = [f"Cluster name: {snap.cluster}",
             f"Active insights: {len(rows)}"]
    if rows:
        lines.append("")
    for r in rows:
        tag = _SEVERITY_TAGS.get(str(r["severity"]), "????")
        head = (f"[{tag}] {r['kind']}: user {r['user']}, "
                f"{r['nodes']} node(s)")
        if r.get("nppn"):
            head += f", NPPN->{r['nppn']}"
        if r.get("cores_per_task"):
            head += f", cores/task->{r['cores_per_task']}"
        head += (f", persist {r['persistence']:.0%}, "
                 f"since t={r['first_seen']:.0f}")
        lines.append(head)
        lines.append(f"  {r['message']}")
    return "\n".join(lines)


# ------------------------------------------------------------- job report

#: ASCII sparkline ramp (lowest to highest); ASCII so report bytes are
#: stable across terminal encodings and golden files diff cleanly.
_SPARK_RAMP = " .:-=+*#%@"


def sparkline(values: Sequence[float], lo: float = 0.0,
              hi: float = 1.0) -> str:
    """Values as a fixed-ramp ASCII sparkline (one char per value),
    clamped to ``[lo, hi]`` so duty cycles render on an absolute scale."""
    span = max(hi - lo, 1e-12)
    out = []
    for v in values:
        frac = min(1.0, max(0.0, (v - lo) / span))
        out.append(_SPARK_RAMP[min(int(frac * len(_SPARK_RAMP)),
                                   len(_SPARK_RAMP) - 1)])
    return "".join(out)


def _agg_line(label: str, agg, spark: str = "") -> str:
    line = (f"{label:<9}: min {agg.min:6.2f}  mean {agg.mean:6.2f}  "
            f"max {agg.max:6.2f}")
    if spark:
        line += f"  [{spark}]"
    return line


def _headroom(used: float, total: float) -> str:
    if total <= 0:
        return "n/a"
    return f"{max(0.0, total - used) / total * 100:.0f}%"


def job_report_text(cluster: str, samples: Sequence, lifetime: Dict) -> str:
    """The MPCDF-style per-job performance report (DESIGN.md §11).

    One page per job: identity, queue wait, lifetime duty/load/memory
    statistics with an absolute-scale duty sparkline over the retained
    raw samples, memory/HBM headroom from the newest sample, and a
    roofline verdict from the monitoring-side roofline bridge.  This is
    the single render path shared by the local CLI, the daemon's
    ``GET /job/{id}``, and remote forwarding — which is what makes
    ``--job`` output byte-identical across sources.

    Args:
        cluster: cluster name for the header.
        samples: the job's retained raw ring
            (:class:`repro.daemon.store.JobSample`, oldest first,
            non-empty).
        lifetime: lifetime :class:`repro.daemon.store.Agg` per sampled
            field (``gpu_duty``/``cpu_load``/``mem_used_gb``/
            ``step_time_s``).
    """
    from repro.roofline import verdict_from_monitoring

    last = samples[-1]
    span = last.t - samples[0].t
    lines = [
        f"LLload job report: cluster {cluster}, job {last.job_id}",
        (f"User: {last.username}   Name: {last.name}   "
         f"State: {last.state}   Nodes: {last.n_nodes}"),
        (f"Queue wait: {last.queue_wait_s:.0f}s   "
         f"Samples: {len(samples)} raw spanning {span:.0f}s"),
        "",
        _agg_line("GPU duty", lifetime["gpu_duty"],
                  sparkline([s.gpu_duty for s in samples])),
        _agg_line("CPU load", lifetime["cpu_load"]),
        _agg_line("Mem (GB)", lifetime["mem_used_gb"]),
        (f"Memory   : {last.mem_used_gb:.1f}GB used / "
         f"{last.mem_total_gb:.1f}GB  "
         f"(headroom {_headroom(last.mem_used_gb, last.mem_total_gb)})"),
        (f"HBM      : {last.gpu_mem_used_gb:.1f}GB used / "
         f"{last.gpu_mem_total_gb:.1f}GB  (headroom "
         + _headroom(last.gpu_mem_used_gb, last.gpu_mem_total_gb) + ")"),
    ]
    if lifetime["step_time_s"].max > 0:
        lines.append(f"Step time: {lifetime['step_time_s'].mean:.3f}s mean")
    lines.append("")
    lines.append("Roofline : " + verdict_from_monitoring(
        lifetime["gpu_duty"].mean, lifetime["step_time_s"].mean,
        last.gpu_mem_used_gb))
    return "\n".join(lines)


def all_view_text(snap: ClusterSnapshot, rows: Sequence[dict],
                  requesting_user: str, privileged: bool,
                  gpu: bool = False) -> str:
    """Fig 4 from engine rows.  Non-privileged users are silently scoped
    to their own block, exactly like the legacy all view."""
    # split each row's comma-joined owner list once, not once per user
    row_users = [(r, {u.strip() for u in r["users"].split(",") if u.strip()})
                 for r in rows]

    def member_rows(user: str) -> List[dict]:
        return [r for r, owners in row_users if user in owners]

    lines = [f"Cluster name: {snap.cluster}", ""]
    if privileged:
        jupyter: Dict[str, List[str]] = {}
        for j in run_query(snap, jupyter_jobs_query()).rows:
            tag = j["user"]
            if j["gpu_request"]:
                tag += f"({j['gpu_request']})"
            for h in j["nodes"].split(","):
                if h:
                    jupyter.setdefault(h, []).append(tag)
        if jupyter:
            lines.append("Jupyter notebook jobs:")
            lines.append("")
            lines.append(f"{'NodeName':<14} Users(GPU)")
            for h in sorted(jupyter):
                lines.append(f"[J]-{h:<12}: " + ", ".join(sorted(jupyter[h])))
            lines.append("")
        users = sorted({u for _, owners in row_users for u in owners})
    else:
        users = [requesting_user] if member_rows(requesting_user) else []
    lines.append("Node information for each user:")
    lines.append("")
    for user in users:
        lines.append(_user_block_text(
            snap.cluster, user, snap.email_of(user),
            member_rows(user), gpu, show_email=True))
        lines.append("")
    return "\n".join(lines)

"""Render LLload views in the paper's terminal formats (Figs 2–5, 10, 11)."""
from __future__ import annotations

from typing import List, Sequence

from repro.core.llload import AllView, NodeDetail, TopNode, UserBlock
from repro.core.metrics import NodeSnapshot


def _gb(x: float) -> str:
    return f"{x:.0f}GB"


def _node_row(n: NodeSnapshot, gpu: bool) -> str:
    row = (f"{n.hostname:<12} {n.cores_total:>4} - {n.cores_used:>3} = "
           f"{n.cores_free:<4} {n.load:>7.2f}  "
           f"{_gb(n.mem_total_gb):>7} - {_gb(n.mem_used_gb):>6} = "
           f"{_gb(n.mem_free_gb):<7}")
    if gpu:
        row += (f" | {n.gpus_total:>2} - {n.gpus_used} = {n.gpus_free:<2} "
                f"{n.gpu_load:>5.2f}  "
                f"{_gb(n.gpu_mem_total_gb):>6} - {_gb(n.gpu_mem_used_gb):>5}"
                f" = {_gb(n.gpu_mem_free_gb):<6}")
    return row


def _header(gpu: bool) -> str:
    h = (f"{'HOSTNAME':<12} {'CORES':>5} - {'USED':>4}= {'FREE':<4}"
         f" {'LOAD':>6}  {'MEMORY':>7} - {'USED':>6} = {'FREE':<7}")
    if gpu:
        h += (f" | {'GPUS':>4}- {'USED'} = {'FREE'} {'LOAD':>4} "
              f"{'GPUMEM':>7} - {'USED':>5} = {'FREE':<6}")
    return h


def format_user_view(cluster: str, block: UserBlock, gpu: bool = False,
                     show_email: bool = False) -> str:
    lines = [f"Cluster name: {cluster}"]
    who = f"Username: {block.username}"
    if show_email:
        who += f" ({block.email})"
    who += f", Nodes used: {len(block.nodes)}"
    lines.append(who)
    lines.append(_header(gpu))
    for n in block.nodes:
        lines.append(_node_row(n, gpu))
    return "\n".join(lines)


def format_all_view(view: AllView, gpu: bool = False) -> str:
    lines = [f"Cluster name: {view.cluster}", ""]
    if view.jupyter:
        lines.append("Jupyter notebook jobs:")
        lines.append("")
        lines.append(f"{'NodeName':<14} Users(GPU)")
        for e in view.jupyter:
            lines.append(f"[J]-{e.hostname:<12}: " + ", ".join(e.users))
        lines.append("")
    lines.append("Node information for each user:")
    lines.append("")
    for blk in view.users:
        lines.append(format_user_view(view.cluster, blk, gpu,
                                      show_email=True))
        lines.append("")
    return "\n".join(lines)


def format_top(rows: List[TopNode], n: int) -> str:
    lines = [f"List {n} of nodes with loads, sorted by descending order",
             f"{'HOSTNAMES':<12} {'AVG_LOAD':>9}  {'CPUS(A/I/O/T)':>14} "
             f"{'MEMORY(MB, Total)':>18} {'FREE_MEM':>9}"]
    for r in rows:
        cpus = f"{r.cpus_alloc}/{r.cpus_idle}/{r.cpus_other}/{r.cpus_total}"
        lines.append(f"{r.hostname:<12} {r.avg_load:>9.5f}  {cpus:>14} "
                     f"{r.mem_total_mb:>18} {r.mem_free_mb:>9}")
    return "\n".join(lines)


def format_node_detail(details: Sequence[NodeDetail],
                       missing: Sequence[str] = ()) -> str:
    if not details and missing:
        return ("Node Information:\n"
                f"Unknown node(s): {', '.join(missing)} "
                "(no such host in this snapshot)")
    lines = ["Node Information:",
             f"{'HOSTNAMES':<12} {'CPU_LOAD':>9} {'CPUS(A/I/O/T)':>14} "
             f"{'MEMORY':>8} {'FREE_MEM':>9} {'GRES_USED':>24} {'USER':>10}"]
    for d in details:
        n = d.node
        cpus = f"{n.cores_used}/{n.cores_free}/0/{n.cores_total}"
        gres = f"gpu:{n.gpus_used}" if n.gpus_total else "none"
        user = ", ".join(sorted({j.username for j in d.jobs})) or "-"
        lines.append(f"{n.hostname:<12} {n.load:>9.2f} {cpus:>14} "
                     f"{int(n.mem_total_gb * 1000):>8} "
                     f"{int(n.mem_free_gb * 1000):>9} {gres:>24} {user:>10}")
    lines.append("")
    lines.append(f"{'JOBID':>9} {'NAME':>20} {'USER':>9} {'START_TIME':>19} "
                 f"{'EXEC_HOST':>11} {'CPUS':>5} {'MEM':>6} {'ST':>3}")
    seen = set()
    for d in details:
        for j in d.jobs:
            if j.job_id in seen:
                continue
            seen.add(j.job_id)
            lines.append(
                f"{j.job_id:>9} {j.name:>20} {j.username:>9} "
                f"{j.start_time:>19.0f} {','.join(j.nodes[:2]):>11} "
                f"{j.cores_per_node:>5} {int(j.mem_per_node_gb * 1000):>5}M "
                f"{j.state:>3}")
    if missing:
        lines.append("")
        lines.append(f"Unknown node(s): {', '.join(missing)} "
                     "(no such host in this snapshot)")
    return "\n".join(lines)

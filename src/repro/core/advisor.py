"""Usage characterization + remediation advice (paper §V-B).

Reproduces the LLSC team's diagnostic playbook:

  * Fig 7 — persistent low GPU duty with small GPU memory
            -> suggest bigger batch *or* GPU overloading; recommend an NPPN
            (tasks-per-GPU) value from load + memory headroom.
  * Fig 8 — mis-submission: cores-per-task so large only one task fits a
            multi-GPU node -> suggest the corrected cores request.
  * Fig 10/11 — normalized load > high threshold: thread oversubscription;
            extreme load (>> cores) flags the file-I/O-storm pathology the
            paper traced to concurrent write() calls.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence

from repro.core.analysis import HIGH_THRESHOLD, LOW_THRESHOLD
from repro.core.metrics import ClusterSnapshot, NodeSnapshot

# normalized load beyond which we suspect an I/O storm rather than plain
# thread oversubscription (Fig 11's nodes showed ~720/48 = 15x)
IO_STORM_FACTOR = 5.0


@dataclasses.dataclass
class Advice:
    kind: str                  # low_gpu | missubmission | overload | io_storm
    username: str
    hostnames: List[str]
    message: str
    suggested_nppn: Optional[int] = None
    suggested_cores_per_task: Optional[int] = None
    evidence: dict = dataclasses.field(default_factory=dict)


def recommend_nppn(gpu_load: float, gpu_mem_used_gb: float,
                   gpu_mem_total_gb: float, *, target_load: float = 0.9,
                   mem_headroom: float = 0.9, max_nppn: int = 8) -> int:
    """The paper's overloading arithmetic: pack tasks-per-GPU until either
    the summed duty cycle reaches ~target or GPU memory would overflow."""
    if gpu_load <= 0:
        return 1
    by_load = int(target_load / max(gpu_load, 1e-3))
    per_task_mem = max(gpu_mem_used_gb, 1e-3)
    by_mem = int((gpu_mem_total_gb * mem_headroom) / per_task_mem)
    n = max(1, min(by_load, by_mem, max_nppn))
    # round down to the NPPN values LLsub exposes: 1, 2, 4, 8
    for v in (8, 4, 2, 1):
        if n >= v:
            return v
    return 1


def characterize_user(snap: ClusterSnapshot, username: str) -> List[Advice]:
    hosts = snap.nodes_by_user().get(username, [])
    nodes = [snap.nodes[h] for h in hosts]
    out: List[Advice] = []
    if not nodes:
        return out

    gpu_nodes = [n for n in nodes if n.gpus_total > 0]

    # ---- Fig 7: low GPU duty -------------------------------------------
    low_gpu = [n for n in gpu_nodes if 0 < n.gpu_load < LOW_THRESHOLD
               and n.gpus_used > 0]
    if low_gpu:
        mean_load = sum(n.gpu_load for n in low_gpu) / len(low_gpu)
        mem_used = max(n.gpu_mem_used_gb / max(n.gpus_used, 1)
                       for n in low_gpu)
        mem_total = low_gpu[0].gpu_mem_total_gb / max(low_gpu[0].gpus_total, 1)
        nppn = recommend_nppn(mean_load, mem_used, mem_total)
        msg = (f"GPU load {mean_load:.2f} < {LOW_THRESHOLD} on "
               f"{len(low_gpu)} node(s); GPU memory {mem_used:.0f}GB of "
               f"{mem_total:.0f}GB. Consider a larger batch size, or GPU "
               f"overloading with NPPN={nppn} (LLsub triples mode).")
        out.append(Advice("low_gpu", username, [n.hostname for n in low_gpu],
                          msg, suggested_nppn=nppn,
                          evidence={"gpu_load": mean_load,
                                    "gpu_mem_used_gb": mem_used}))

    # ---- Fig 8: mis-submission -----------------------------------------
    missub = [n for n in gpu_nodes
              if n.gpus_total >= 2 and n.gpus_used < n.gpus_total
              and n.cores_free < n.cores_total // 4
              and n.norm_load < LOW_THRESHOLD]
    if missub:
        n0 = missub[0]
        fair_cores = n0.cores_total // n0.gpus_total
        msg = (f"{len(missub)} node(s) have all cores allocated but only "
               f"{n0.gpus_used}/{n0.gpus_total} GPUs in use with CPU load "
               f"{n0.norm_load:.2f}. The cores-per-task request is too "
               f"large: request {fair_cores} cores and 1 GPU per task so "
               f"{n0.gpus_total} tasks share each node.")
        out.append(Advice("missubmission", username,
                          [n.hostname for n in missub], msg,
                          suggested_cores_per_task=fair_cores,
                          evidence={"norm_load": n0.norm_load}))

    # ---- Fig 10/11: overload / IO storm --------------------------------
    over = [n for n in nodes if n.norm_load > HIGH_THRESHOLD]
    if over:
        worst = max(over, key=lambda n: n.norm_load)
        if worst.norm_load > IO_STORM_FACTOR:
            msg = (f"Extreme CPU load {worst.load:.0f} on "
                   f"{worst.cores_total} cores ({worst.norm_load:.1f}x). "
                   "Beyond thread oversubscription this pattern matches a "
                   "concurrent file-I/O storm (e.g. write() in a hot loop) "
                   "overwhelming the filesystem client; reduce concurrent "
                   "file I/O and cap worker threads.")
            kind = "io_storm"
        else:
            msg = (f"CPU load {worst.norm_load:.2f}x cores on "
                   f"{len(over)} node(s): tasks spawn more threads than "
                   "cores (e.g. Python multiprocessing defaults). Set "
                   "thread counts to cores/tasks-per-node.")
            kind = "overload"
        out.append(Advice(kind, username, [n.hostname for n in over], msg,
                          evidence={"max_norm_load": worst.norm_load}))
    return out


def characterize_all(snap: ClusterSnapshot) -> List[Advice]:
    out = []
    for user in sorted(snap.nodes_by_user()):
        out.extend(characterize_user(snap, user))
    return out


def characterize_snapshots(snaps: Iterable[ClusterSnapshot],
                           username: Optional[str] = None) -> List[Advice]:
    """Characterize from a snapshot *history* (any MetricSource replay or
    the bus ring buffer) instead of a single point in time.

    Advice comes from the latest snapshot; each item gains a
    ``persistence`` evidence field — the fraction of snapshots in which
    the same (kind, user) diagnosis held — so one noisy sample doesn't
    trigger an email.
    """
    snaps = list(snaps)
    if not snaps:
        return []
    latest = snaps[-1]
    advice = (characterize_user(latest, username) if username is not None
              else characterize_all(latest))
    if len(snaps) > 1:
        counts = {}
        for snap in snaps:
            for a in (characterize_user(snap, username)
                      if username is not None else characterize_all(snap)):
                counts[(a.kind, a.username)] = \
                    counts.get((a.kind, a.username), 0) + 1
        for a in advice:
            a.evidence["persistence"] = \
                counts.get((a.kind, a.username), 0) / len(snaps)
    return advice

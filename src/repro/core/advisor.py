"""DEPRECATED shim — usage characterization moved to :mod:`repro.insights`.

The paper-§V-B playbook (Fig 7 low GPU duty, Fig 8 mis-submission,
Fig 10/11 thread overload / I/O storm) now lives as registered
:class:`~repro.insights.rules.Rule`s evaluated by the incremental
:class:`~repro.insights.engine.InsightEngine`, and is surfaced as the
``insights`` query table, the CLI ``--advise`` view, and the daemon's
``GET /insights``.  This module keeps the old entry points working:

  * :func:`characterize_user` / :func:`characterize_all` — single-
    snapshot rule evaluation, returning the legacy :class:`Advice`.
  * :func:`characterize_snapshots` — the old **full-history replay**
    (re-characterizes every snapshot per call).  Prefer
    :func:`repro.insights.evaluate_snapshots`, or a long-lived engine
    for streams; ``benchmarks.run.bench_insights`` measures the gap.
  * :func:`recommend_nppn` — re-exported from
    :mod:`repro.insights.rules` (the canonical home).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional

from repro.insights.records import Insight
from repro.insights.rules import (IO_STORM_FACTOR, RuleContext, contexts,
                                  default_rules, recommend_nppn)

__all__ = ["Advice", "IO_STORM_FACTOR", "characterize_all",
           "characterize_snapshots", "characterize_user", "recommend_nppn"]


@dataclasses.dataclass
class Advice:
    """Legacy advice record (predates :class:`repro.insights.Insight`)."""
    kind: str                  # low_gpu | missubmission | overload | io_storm
    username: str
    hostnames: List[str]
    message: str
    suggested_nppn: Optional[int] = None
    suggested_cores_per_task: Optional[int] = None
    evidence: dict = dataclasses.field(default_factory=dict)


def _advice_from(ins: Insight) -> Advice:
    return Advice(ins.kind, ins.username, list(ins.hostnames), ins.message,
                  suggested_nppn=ins.suggested_nppn,
                  suggested_cores_per_task=ins.suggested_cores_per_task,
                  evidence=dict(ins.evidence))


def characterize_user(snap, username: str) -> List[Advice]:
    """One user's diagnoses from one snapshot, via the registered rules
    (rule registration order, matching the legacy output order)."""
    hosts = snap.nodes_by_user().get(username, [])
    nodes = [snap.nodes[h] for h in hosts if h in snap.nodes]
    if not nodes:
        return []
    ctx = RuleContext(snap, username, nodes,
                      [n for n in nodes if n.gpus_total > 0])
    out = []
    for rule in default_rules():
        ins = rule.evaluate(ctx)
        if ins is not None:
            out.append(_advice_from(ins))
    return out


def characterize_all(snap) -> List[Advice]:
    out = []
    for ctx in contexts(snap):
        for rule in default_rules():
            ins = rule.evaluate(ctx)
            if ins is not None:
                out.append(_advice_from(ins))
    return out


def characterize_snapshots(snaps: Iterable,
                           username: Optional[str] = None) -> List[Advice]:
    """Characterize from a snapshot *history* by full replay — the old
    O(snapshots · nodes)-per-query path, kept as a shim (and as the
    benchmark baseline the incremental engine is measured against).

    Advice comes from the latest snapshot; each item gains a
    ``persistence`` evidence field — the fraction of snapshots in which
    the same (kind, user) diagnosis held — so one noisy sample doesn't
    trigger an email.
    """
    snaps = list(snaps)
    if not snaps:
        return []
    latest = snaps[-1]
    advice = (characterize_user(latest, username) if username is not None
              else characterize_all(latest))
    if len(snaps) > 1:
        counts = {}
        for snap in snaps:
            for a in (characterize_user(snap, username)
                      if username is not None else characterize_all(snap)):
                counts[(a.kind, a.username)] = \
                    counts.get((a.kind, a.username), 0) + 1
        for a in advice:
            a.evidence["persistence"] = \
                counts.get((a.kind, a.username), 0) / len(snaps)
    return advice

"""Weekly report rendering + user notification emails (paper §V, Fig 6)."""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

from repro.core.analysis import ReportRow, WeeklyReport


def _section(title: str, metric: str, rows: List[ReportRow]) -> str:
    lines = [f"Most {title} node-hours:", "===",
             f"{metric:>10} | {'Username':<10} | {'Email':<22}"]
    for r in rows:
        nh = f"{r.node_hours:g}"
        lines.append(f"{nh:>10} | {r.username:<10} | {r.email:<22}")
    return "\n".join(lines)


def format_weekly_report(report: WeeklyReport, anonymize: bool = False) -> str:
    rep = report
    if anonymize:
        rep = _anonymized(report)
    d0 = time.strftime("%m/%d/%Y", time.gmtime(rep.start))
    d1 = time.strftime("%m/%d/%Y", time.gmtime(rep.end))
    parts = [f"This report covers activity between {d0} and {d1}.", ""]
    parts.append(_section("Low GPULOAD", "GPULOAD", rep.low_gpu))
    parts.append("")
    parts.append(_section("Low CORELOAD", "CORELOAD", rep.low_cpu))
    parts.append("")
    parts.append(_section("High CORELOAD", "CORELOAD", rep.high_cpu))
    return "\n".join(parts)


def _anonymized(report: WeeklyReport) -> WeeklyReport:
    # one stable username->alias map across the WHOLE report: the same
    # real user must read as the same pseudonym in every section, and a
    # given pseudonym must never mean two different people
    alias = {}

    def name_for(username: str) -> str:
        if username not in alias:
            alias[username] = f"user{len(alias) + 1:02d}"
        return alias[username]

    def anon(rows):
        return [ReportRow(name_for(r.username),
                          f"{name_for(r.username)}@ll.mit.edu",
                          r.node_hours) for r in rows]
    return WeeklyReport(report.start, report.end, anon(report.low_gpu),
                        anon(report.low_cpu), anon(report.high_cpu))


@dataclasses.dataclass
class Email:
    to: str
    subject: str
    body: str


DOC_LINKS = ("https://supercloud.mit.edu/optimizing-your-jobs "
             "(resource-utilization guide)")


def notification_email(row: ReportRow, category: str,
                       advice: Optional[str] = None) -> Email:
    """The judicious weekly outreach email (paper §V-B)."""
    what = {
        "low_gpu": "low GPU utilization",
        "low_cpu": "low CPU utilization",
        "high_cpu": "sustained CPU overload",
    }[category]
    body = (
        f"Hello {row.username},\n\n"
        f"Our weekly LLload analytics noticed {what} from your jobs: "
        f"{row.node_hours:g} node-hours in the last week.\n\n"
        "How this was generated: LLload snapshots of all running jobs are "
        "taken every 15 minutes; node-hours below/above the utilization "
        "thresholds (0.45 low / 1.55 high, normalized) are aggregated per "
        "user.\n\n")
    if advice:
        body += f"Suggestions:\n{advice}\n\n"
    body += f"Documentation: {DOC_LINKS}\n\n- The LLSC team"
    return Email(to=row.email,
                 subject=f"[LLSC] {what} detected for {row.username}",
                 body=body)

"""LLload data model (paper §IV).

The paper tracks a deliberately small set of metrics per node: CPU core
counts (total/used/free), the 5-minute load average, system memory
(total/used/free), and — on accelerator nodes — device counts, device duty
cycle ("GPU load") and device memory.  A :class:`ClusterSnapshot` is one
point-in-time view of the whole system plus the job table that attributes
each node to (under whole-node scheduling) exactly one user.

TPU adaptation: ``gpu_load`` is the *device duty-cycle proxy* — for JAX jobs
it is measured MFU-style utilization (achieved FLOP/s ÷ peak), self-reported
by the job (see collector.py); ``gpu_mem_*`` is HBM.  Field names keep the
paper's vocabulary.
"""
from __future__ import annotations

import dataclasses
import io
import time
from typing import Dict, Iterator, List, Optional

import numpy as np

TSV_COLUMNS = [
    "timestamp", "cluster", "hostname", "username", "jobtype",
    "cores_total", "cores_used", "load",
    "mem_total_gb", "mem_used_gb",
    "gpus_total", "gpus_used", "gpu_load",
    "gpu_mem_total_gb", "gpu_mem_used_gb",
]


@dataclasses.dataclass
class NodeSnapshot:
    hostname: str
    cores_total: int
    cores_used: int
    load: float                    # 5-min load average (absolute)
    mem_total_gb: float
    mem_used_gb: float
    gpus_total: int = 0
    gpus_used: int = 0
    gpu_load: float = 0.0          # mean duty cycle across devices (0..1+)
    gpu_mem_total_gb: float = 0.0
    gpu_mem_used_gb: float = 0.0

    @property
    def cores_free(self) -> int:
        return self.cores_total - self.cores_used

    @property
    def mem_free_gb(self) -> float:
        return self.mem_total_gb - self.mem_used_gb

    @property
    def gpus_free(self) -> int:
        return self.gpus_total - self.gpus_used

    @property
    def gpu_mem_free_gb(self) -> float:
        return self.gpu_mem_total_gb - self.gpu_mem_used_gb

    @property
    def norm_load(self) -> float:
        """Load normalized by core count — 1.0 means fully loaded (paper §IV)."""
        return self.load / max(self.cores_total, 1)


@dataclasses.dataclass
class NodeColumns:
    """Structure-of-arrays form of a fleet of :class:`NodeSnapshot`s.

    One aligned numpy column per ``NodeSnapshot`` field — the columnar
    construction path large producers (the cluster simulator's
    ``FleetState``) emit in one vectorized pass, and columnar consumers
    (the experiments runner's per-step fold) aggregate without ever
    materializing 100k per-node Python objects.  ``node(i)`` or a
    :class:`ColumnarNodeMap` converts back to the object form on demand.
    """

    hostnames: List[str]
    cores_total: np.ndarray
    cores_used: np.ndarray
    load: np.ndarray
    mem_total_gb: np.ndarray
    mem_used_gb: np.ndarray
    gpus_total: np.ndarray
    gpus_used: np.ndarray
    gpu_load: np.ndarray
    gpu_mem_total_gb: np.ndarray
    gpu_mem_used_gb: np.ndarray
    #: optional shared ``hostname -> row`` index; producers that snapshot
    #: repeatedly over a fixed fleet pass one dict instead of paying an
    #: O(nodes) rebuild per snapshot
    index: Optional[Dict[str, int]] = None

    def __len__(self) -> int:
        return len(self.hostnames)

    def node(self, i: int) -> "NodeSnapshot":
        """Materialize row ``i`` as a :class:`NodeSnapshot` (native
        Python scalars, so downstream JSON/text paths see exactly the
        types the object path produced)."""
        return NodeSnapshot(
            hostname=self.hostnames[i],
            cores_total=int(self.cores_total[i]),
            cores_used=int(self.cores_used[i]),
            load=float(self.load[i]),
            mem_total_gb=float(self.mem_total_gb[i]),
            mem_used_gb=float(self.mem_used_gb[i]),
            gpus_total=int(self.gpus_total[i]),
            gpus_used=int(self.gpus_used[i]),
            gpu_load=float(self.gpu_load[i]),
            gpu_mem_total_gb=float(self.gpu_mem_total_gb[i]),
            gpu_mem_used_gb=float(self.gpu_mem_used_gb[i]),
        )

    def as_map(self) -> "ColumnarNodeMap":
        """This fleet as a lazy hostname -> :class:`NodeSnapshot` map."""
        return ColumnarNodeMap(self)


class ColumnarNodeMap:
    """Lazy ``hostname -> NodeSnapshot`` mapping over :class:`NodeColumns`.

    Drop-in for the ``ClusterSnapshot.nodes`` dict: iteration order is
    the fleet's node order (matching the object path's insertion order),
    and a ``NodeSnapshot`` is only materialized — then cached — when a
    consumer actually touches that host.  This is what lets
    ``ClusterSim.snapshot()`` return in microseconds at 100k nodes while
    dict-shaped consumers keep working unchanged; columnar consumers
    can reach the raw arrays through ``.columns``.
    """

    def __init__(self, columns: NodeColumns):
        self.columns = columns
        self._index: Optional[Dict[str, int]] = columns.index
        self._cache: Dict[str, NodeSnapshot] = {}

    def _host_index(self) -> Dict[str, int]:
        if self._index is None:
            self._index = {h: i for i, h in
                           enumerate(self.columns.hostnames)}
        return self._index

    def __getitem__(self, host: str) -> NodeSnapshot:
        node = self._cache.get(host)
        if node is None:
            node = self.columns.node(self._host_index()[host])
            self._cache[host] = node
        return node

    def get(self, host: str, default=None):
        try:
            return self[host]
        except KeyError:
            return default

    def __contains__(self, host) -> bool:
        return host in self._host_index()

    def __iter__(self) -> Iterator[str]:
        return iter(self.columns.hostnames)

    def __len__(self) -> int:
        return len(self.columns.hostnames)

    def __bool__(self) -> bool:
        return bool(self.columns.hostnames)

    def __eq__(self, other):
        # dict semantics (order-insensitive), so snapshots round-tripped
        # over the wire — whose nodes decode to a plain dict — still
        # compare equal to columnar-backed ones
        if other is self:
            return True
        if isinstance(other, ColumnarNodeMap):
            other = {h: other[h] for h in other}
        if not isinstance(other, dict):
            return NotImplemented
        if len(other) != len(self):
            return False
        try:
            return all(other[h] == self[h]
                       for h in self.columns.hostnames)
        except KeyError:
            return False

    __hash__ = None

    def keys(self):
        return list(self.columns.hostnames)

    def values(self) -> List[NodeSnapshot]:
        return [self[h] for h in self.columns.hostnames]

    def items(self):
        return [(h, self[h]) for h in self.columns.hostnames]


@dataclasses.dataclass
class JobRecord:
    job_id: int
    username: str
    name: str
    nodes: List[str]
    cores_per_node: int
    state: str = "R"               # R | PD | CG
    job_type: str = "batch"        # batch | jupyter | debug
    gpus_per_node: int = 0
    gpu_request: str = ""          # e.g. "gres:gpu:volta:1"
    start_time: float = 0.0
    partition: str = "normal"
    mem_per_node_gb: float = 0.0
    # --- per-job samples (additive wire fields; 0.0 = "not reported",
    # consumers derive from the job's nodes instead — see daemon/store) ---
    submit_time: float = 0.0       # for queue-wait (start - submit)
    gpu_duty: float = 0.0          # self-reported device duty (MFU proxy)
    cpu_load: float = 0.0          # self-reported normalized CPU load
    mem_used_gb: float = 0.0       # self-reported memory footprint
    step_time_s: float = 0.0       # training/serving step time, if any


@dataclasses.dataclass
class ClusterSnapshot:
    cluster: str
    timestamp: float
    nodes: Dict[str, NodeSnapshot]
    jobs: List[JobRecord]
    user_emails: Dict[str, str] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------- queries
    def user_of_node(self, hostname: str) -> Optional[str]:
        for job in self.jobs:
            if job.state == "R" and hostname in job.nodes:
                return job.username
        return None

    def nodes_by_user(self) -> Dict[str, List[str]]:
        # set-based dedup: `h not in lst` was O(hosts) per host, which is
        # quadratic for one user spanning half a 100k-node fleet; output
        # (first-seen order per user) is unchanged
        out: Dict[str, List[str]] = {}
        seen: Dict[str, set] = {}
        for job in self.jobs:
            if job.state != "R":
                continue
            if not job.nodes:
                continue
            s = seen.setdefault(job.username, set())
            lst = out.setdefault(job.username, [])
            for h in job.nodes:
                if h not in s:
                    s.add(h)
                    lst.append(h)
        return out

    def jobs_of_user(self, username: str) -> List[JobRecord]:
        return [j for j in self.jobs if j.username == username]

    def jobs_on_node(self, hostname: str) -> List[JobRecord]:
        return [j for j in self.jobs if j.state == "R" and hostname in j.nodes]

    def email_of(self, username: str) -> str:
        return self.user_emails.get(username, f"{username}@ll.mit.edu")

    # --------------------------------------------------------------- TSV
    def to_tsv(self) -> str:
        """One row per (node, owning user) — the `-q --all --tsv` archive
        format the weekly analysis ingests (paper §V-A)."""
        buf = io.StringIO()
        buf.write("\t".join(TSV_COLUMNS) + "\n")
        owner = {}
        jobtype = {}
        for job in self.jobs:
            if job.state != "R":
                continue
            for h in job.nodes:
                owner.setdefault(h, job.username)
                jobtype.setdefault(h, job.job_type)
        for host in sorted(self.nodes):
            n = self.nodes[host]
            user = owner.get(host, "")
            if not user:
                continue  # idle nodes are not archived (no owning job)
            row = [f"{self.timestamp:.0f}", self.cluster, host, user,
                   jobtype.get(host, "batch"),
                   str(n.cores_total), str(n.cores_used), f"{n.load:.4f}",
                   f"{n.mem_total_gb:.1f}", f"{n.mem_used_gb:.1f}",
                   str(n.gpus_total), str(n.gpus_used), f"{n.gpu_load:.4f}",
                   f"{n.gpu_mem_total_gb:.1f}", f"{n.gpu_mem_used_gb:.1f}"]
            buf.write("\t".join(row) + "\n")
        return buf.getvalue()


def rows_from_tsv(text: str) -> List[dict]:
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        return []
    header = lines[0].split("\t")
    out = []
    for ln in lines[1:]:
        # tolerate duplicate header rows mid-file: cross-process archive
        # writers can both lose the "does the file exist yet" race (the
        # in-process case is locked in SnapshotArchive)
        if ln.startswith(f"{header[0]}\t"):
            continue
        vals = ln.split("\t")
        row = dict(zip(header, vals))
        for k in ("timestamp", "load", "mem_total_gb", "mem_used_gb",
                  "gpu_load", "gpu_mem_total_gb", "gpu_mem_used_gb"):
            row[k] = float(row[k])
        for k in ("cores_total", "cores_used", "gpus_total", "gpus_used"):
            row[k] = int(row[k])
        out.append(row)
    return out


def now() -> float:
    return time.time()

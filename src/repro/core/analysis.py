"""Weekly LLload analysis (paper §V-A, Fig 6).

Thresholds exactly as the paper defines them:
  * low utilization:  average normalized load < ``LOW_THRESHOLD`` (0.45)
  * over-utilization: normalized CPU load > ``1 + (1 - LOW_THRESHOLD)`` (1.55)

Every archived snapshot row contributes ``interval_hours`` *node-hours* to a
(user, category) bucket when it satisfies a condition; the report is the
top-10 users per category.  Implemented columnar (numpy) so a week of
15-minute snapshots across thousands of nodes aggregates in milliseconds
(the D4M role in the paper's pipeline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Union

import numpy as np

from repro.core.metrics import ClusterSnapshot, rows_from_tsv

LOW_THRESHOLD = 0.45
HIGH_THRESHOLD = 1.0 + (1.0 - LOW_THRESHOLD)   # = 1.55
SNAPSHOT_INTERVAL_HOURS = 0.25                 # 15 minutes


@dataclasses.dataclass
class ReportRow:
    username: str
    email: str
    node_hours: float


@dataclasses.dataclass
class WeeklyReport:
    start: float
    end: float
    low_gpu: List[ReportRow]
    low_cpu: List[ReportRow]
    high_cpu: List[ReportRow]


@dataclasses.dataclass
class ColumnarRows:
    """Columnar view of archive rows for vectorized aggregation."""
    usernames: np.ndarray       # [N] unique-coded int
    user_list: List[str]
    norm_cpu: np.ndarray        # [N] float
    gpu_load: np.ndarray        # [N] float
    has_gpu: np.ndarray         # [N] bool
    timestamps: np.ndarray      # [N] float


def rows_from_snapshots(snaps: Iterable[ClusterSnapshot]) -> List[dict]:
    """Flatten snapshots (from any MetricSource / bus history) into the
    archive row schema the weekly analysis aggregates."""
    rows: List[dict] = []
    for snap in snaps:
        rows.extend(rows_from_tsv(snap.to_tsv()))
    return rows


def columnarize(rows: Sequence[dict]) -> ColumnarRows:
    """Vectorized: one list-comprehension pass extracts each column, then
    every derived quantity is a numpy array op (no per-row Python math) —
    ``np.unique`` both sorts the user vocabulary and codes every row."""
    n = len(rows)
    users, codes = np.unique(np.array([r["username"] for r in rows],
                                      dtype=object), return_inverse=True)
    load = np.fromiter((r["load"] for r in rows), np.float64, count=n)
    cores = np.fromiter((r["cores_total"] for r in rows), np.float64,
                        count=n)
    gpu_load = np.fromiter((r["gpu_load"] for r in rows), np.float64,
                           count=n)
    gpus = np.fromiter((r["gpus_total"] for r in rows), np.int64, count=n)
    ts = np.fromiter((r["timestamp"] for r in rows), np.float64, count=n)
    return ColumnarRows(codes.astype(np.int32), [str(u) for u in users],
                        load / np.maximum(cores, 1.0), gpu_load,
                        gpus > 0, ts)


def _top10(node_hours: np.ndarray, users: List[str], emails: Dict[str, str]
           ) -> List[ReportRow]:
    order = np.argsort(-node_hours)
    out = []
    for i in order[:10]:
        if node_hours[i] <= 0:
            break
        u = users[i]
        out.append(ReportRow(u, emails.get(u, f"{u}@ll.mit.edu"),
                             float(node_hours[i])))
    return out


def weekly_from_buckets(buckets: Sequence[tuple],
                        emails: Dict[str, str] = None,
                        interval_hours: float = SNAPSHOT_INTERVAL_HOURS
                        ) -> WeeklyReport:
    """Weekly report from pre-aggregated per-user utilization flags.

    ``buckets`` is a sequence of ``(timestamp, {user: (low_gpu_nodes,
    low_cpu_nodes, high_cpu_nodes)})`` — one entry per archive-cadence
    bucket, as maintained by the daemon's
    :class:`~repro.daemon.store.HistoryStore` tiers.  Each flagged node
    contributes ``interval_hours`` node-hours, exactly like a replayed
    archive row, but the cost is O(buckets · users) instead of
    O(snapshots · nodes).
    """
    emails = emails or {}
    if not buckets:
        return WeeklyReport(0, 0, [], [], [])
    users = sorted({u for _, flags in buckets for u in flags})
    uidx = {u: i for i, u in enumerate(users)}
    hours = np.zeros((3, len(users)), np.float64)
    for _, flags in buckets:
        for user, counts in flags.items():
            for cat in range(3):
                hours[cat, uidx[user]] += counts[cat] * interval_hours
    ts = [t for t, _ in buckets]
    return WeeklyReport(
        start=float(min(ts)), end=float(max(ts)),
        low_gpu=_top10(hours[0], users, emails),
        low_cpu=_top10(hours[1], users, emails),
        high_cpu=_top10(hours[2], users, emails),
    )


def weekly_analysis(rows: Union[Sequence[dict],
                                Iterable[ClusterSnapshot]],
                    emails: Dict[str, str] = None,
                    interval_hours: float = SNAPSHOT_INTERVAL_HOURS,
                    low_threshold: float = LOW_THRESHOLD) -> WeeklyReport:
    """rows: archive rows (one per node-user-snapshot), or an iterable of
    :class:`ClusterSnapshot` from any source / the bus ring buffer."""
    emails = emails or {}
    rows = list(rows)
    if rows and isinstance(rows[0], ClusterSnapshot):
        rows = rows_from_snapshots(rows)
    if not rows:
        return WeeklyReport(0, 0, [], [], [])
    col = columnarize(rows)
    high_threshold = 1.0 + (1.0 - low_threshold)
    nu = len(col.user_list)

    def agg(mask: np.ndarray) -> np.ndarray:
        return np.bincount(col.usernames[mask], minlength=nu) * interval_hours

    low_gpu = agg(col.has_gpu & (col.gpu_load < low_threshold))
    low_cpu = agg(col.norm_cpu < low_threshold)
    high_cpu = agg(col.norm_cpu > high_threshold)

    return WeeklyReport(
        start=float(col.timestamps.min()),
        end=float(col.timestamps.max()),
        low_gpu=_top10(low_gpu, col.user_list, emails),
        low_cpu=_top10(low_cpu, col.user_list, emails),
        high_cpu=_top10(high_cpu, col.user_list, emails),
    )

"""Weekly LLload analysis (paper §V-A, Fig 6).

Thresholds exactly as the paper defines them:
  * low utilization:  average normalized load < ``LOW_THRESHOLD`` (0.45)
  * over-utilization: normalized CPU load > ``1 + (1 - LOW_THRESHOLD)`` (1.55)

Every archived snapshot row contributes ``interval_hours`` *node-hours* to a
(user, category) bucket when it satisfies a condition; the report is the
top-10 users per category.  Implemented columnar (numpy) so a week of
15-minute snapshots across thousands of nodes aggregates in milliseconds
(the D4M role in the paper's pipeline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Union

import numpy as np

from repro.core.metrics import ClusterSnapshot, rows_from_tsv

LOW_THRESHOLD = 0.45
HIGH_THRESHOLD = 1.0 + (1.0 - LOW_THRESHOLD)   # = 1.55
SNAPSHOT_INTERVAL_HOURS = 0.25                 # 15 minutes


@dataclasses.dataclass
class ReportRow:
    username: str
    email: str
    node_hours: float


@dataclasses.dataclass
class WeeklyReport:
    start: float
    end: float
    low_gpu: List[ReportRow]
    low_cpu: List[ReportRow]
    high_cpu: List[ReportRow]


@dataclasses.dataclass
class ColumnarRows:
    """Columnar view of archive rows for vectorized aggregation."""
    usernames: np.ndarray       # [N] unique-coded int
    user_list: List[str]
    norm_cpu: np.ndarray        # [N] float
    gpu_load: np.ndarray        # [N] float
    has_gpu: np.ndarray         # [N] bool
    timestamps: np.ndarray      # [N] float


def rows_from_snapshots(snaps: Iterable[ClusterSnapshot]) -> List[dict]:
    """Flatten snapshots (from any MetricSource / bus history) into the
    archive row schema the weekly analysis aggregates."""
    rows: List[dict] = []
    for snap in snaps:
        rows.extend(rows_from_tsv(snap.to_tsv()))
    return rows


def columnarize(rows: Sequence[dict]) -> ColumnarRows:
    users = sorted({r["username"] for r in rows})
    uidx = {u: i for i, u in enumerate(users)}
    n = len(rows)
    codes = np.empty(n, np.int32)
    norm_cpu = np.empty(n, np.float64)
    gpu_load = np.empty(n, np.float64)
    has_gpu = np.empty(n, bool)
    ts = np.empty(n, np.float64)
    for i, r in enumerate(rows):
        codes[i] = uidx[r["username"]]
        norm_cpu[i] = r["load"] / max(r["cores_total"], 1)
        gpu_load[i] = r["gpu_load"]
        has_gpu[i] = r["gpus_total"] > 0
        ts[i] = r["timestamp"]
    return ColumnarRows(codes, users, norm_cpu, gpu_load, has_gpu, ts)


def _top10(node_hours: np.ndarray, users: List[str], emails: Dict[str, str]
           ) -> List[ReportRow]:
    order = np.argsort(-node_hours)
    out = []
    for i in order[:10]:
        if node_hours[i] <= 0:
            break
        u = users[i]
        out.append(ReportRow(u, emails.get(u, f"{u}@ll.mit.edu"),
                             float(node_hours[i])))
    return out


def weekly_analysis(rows: Union[Sequence[dict],
                                Iterable[ClusterSnapshot]],
                    emails: Dict[str, str] = None,
                    interval_hours: float = SNAPSHOT_INTERVAL_HOURS,
                    low_threshold: float = LOW_THRESHOLD) -> WeeklyReport:
    """rows: archive rows (one per node-user-snapshot), or an iterable of
    :class:`ClusterSnapshot` from any source / the bus ring buffer."""
    emails = emails or {}
    rows = list(rows)
    if rows and isinstance(rows[0], ClusterSnapshot):
        rows = rows_from_snapshots(rows)
    if not rows:
        return WeeklyReport(0, 0, [], [], [])
    col = columnarize(rows)
    high_threshold = 1.0 + (1.0 - low_threshold)
    nu = len(col.user_list)

    def agg(mask: np.ndarray) -> np.ndarray:
        return np.bincount(col.usernames[mask], minlength=nu) * interval_hours

    low_gpu = agg(col.has_gpu & (col.gpu_load < low_threshold))
    low_cpu = agg(col.norm_cpu < low_threshold)
    high_cpu = agg(col.norm_cpu > high_threshold)

    return WeeklyReport(
        start=float(col.timestamps.min()),
        end=float(col.timestamps.max()),
        low_gpu=_top10(low_gpu, col.user_list, emails),
        low_cpu=_top10(low_cpu, col.user_list, emails),
        high_cpu=_top10(high_cpu, col.user_list, emails),
    )

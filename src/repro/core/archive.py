"""15-minute snapshot archive (paper §V-A).

Every SNAPSHOT_INTERVAL a ``LLload -q --all --tsv`` equivalent is appended
to per-day TSV files under an archive directory (the paper stores these on
the central parallel FS; each cluster keeps its own archive).

Two ways to drive capture:

  * :class:`PeriodicArchiver` — the legacy pull loop (caller ticks it).
  * :class:`ArchiveSubscriber` — a :class:`~repro.monitor.bus.TelemetryBus`
    subscriber: register it once and every bus collection that crosses the
    cadence is archived, per source.  Replaying an archive back out is
    :meth:`SnapshotArchive.as_source` (DESIGN.md §5).
"""
from __future__ import annotations

import os
import threading
import time
from typing import Iterable, List, Optional

from repro.core.metrics import ClusterSnapshot, rows_from_tsv

SNAPSHOT_INTERVAL_S = 15 * 60  # paper: every 15 minutes


class SnapshotArchive:
    def __init__(self, root: str, cluster: str = "txgreen"):
        self.root = os.path.join(root, cluster)
        os.makedirs(self.root, exist_ok=True)
        # serializes the header-or-body decision against the append that
        # follows it: two concurrent writers (bus subscriber + periodic
        # archiver, two daemons sharing an archive object) must not both
        # see "file missing" and each write a header row
        self._lock = threading.Lock()

    def _path_for(self, timestamp: float) -> str:
        day = time.strftime("%Y-%m-%d", time.gmtime(timestamp))
        return os.path.join(self.root, f"llload-{day}.tsv")

    def _append_text(self, path: str, tsv_text: str):
        with self._lock:
            # decide header-vs-body *after* opening in append mode: the
            # open itself creates the file, so "did it exist" is judged by
            # the write position, which cannot race with our own creation
            with open(path, "a") as f:
                body = (tsv_text if f.tell() == 0
                        else tsv_text.split("\n", 1)[1])
                f.write(body)

    def append(self, snap: ClusterSnapshot):
        self._append_text(self._path_for(snap.timestamp), snap.to_tsv())

    def append_tsv(self, timestamp: float, tsv_text: str):
        self._append_text(self._path_for(timestamp), tsv_text)

    def files(self) -> List[str]:
        return sorted(os.path.join(self.root, f)
                      for f in os.listdir(self.root) if f.endswith(".tsv"))

    def rows(self, start: Optional[float] = None,
             end: Optional[float] = None) -> List[dict]:
        out = []
        for path in self.files():
            with open(path) as f:
                for row in rows_from_tsv(f.read()):
                    t = row["timestamp"]
                    if start is not None and t < start:
                        continue
                    if end is not None and t > end:
                        continue
                    out.append(row)
        return out

    def as_source(self, *, loop: bool = False):
        """Replay this archive as a :class:`repro.monitor.source.MetricSource`
        (one snapshot per archived timestamp)."""
        from repro.monitor.source import ArchiveSource

        return ArchiveSource(self.files(), loop=loop)


class ArchiveSubscriber:
    """TelemetryBus subscriber that archives on the 15-minute cadence.

        bus.subscribe(ArchiveSubscriber(archive))

    Snapshots arrive from every bus collection; one per ``interval_s`` of
    *snapshot* time is appended (per source, so a multi-source bus keeps
    each cluster's cadence independent).  ``source_name`` restricts the
    subscriber to one source.
    """

    def __init__(self, archive: SnapshotArchive,
                 interval_s: float = SNAPSHOT_INTERVAL_S,
                 source_name: Optional[str] = None):
        self.archive = archive
        self.interval_s = interval_s
        self.source_name = source_name
        self._last: dict = {}

    def __call__(self, source_name: str, snap: ClusterSnapshot) -> bool:
        if self.source_name is not None and source_name != self.source_name:
            return False
        last = self._last.get(source_name)
        if last is not None and snap.timestamp - last < self.interval_s:
            return False
        self.archive.append(snap)
        self._last[source_name] = snap.timestamp
        return True


class PeriodicArchiver:
    """Drives snapshot capture on the 15-minute cadence (sim or wall time)."""

    def __init__(self, archive: SnapshotArchive, source,
                 interval_s: float = SNAPSHOT_INTERVAL_S):
        self.archive = archive
        self.source = source          # object with .snapshot() -> ClusterSnapshot
        self.interval_s = interval_s
        self._last = None

    def maybe_capture(self, now: float) -> bool:
        if self._last is not None and now - self._last < self.interval_s:
            return False
        self.archive.append(self.source.snapshot())
        self._last = now
        return True

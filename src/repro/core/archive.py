"""15-minute snapshot archive (paper §V-A).

Every SNAPSHOT_INTERVAL a ``LLload -q --all --tsv`` equivalent is appended
to per-day TSV files under an archive directory (the paper stores these on
the central parallel FS; each cluster keeps its own archive)."""
from __future__ import annotations

import os
import time
from typing import Iterable, List, Optional

from repro.core.metrics import ClusterSnapshot, rows_from_tsv

SNAPSHOT_INTERVAL_S = 15 * 60  # paper: every 15 minutes


class SnapshotArchive:
    def __init__(self, root: str, cluster: str = "txgreen"):
        self.root = os.path.join(root, cluster)
        os.makedirs(self.root, exist_ok=True)

    def _path_for(self, timestamp: float) -> str:
        day = time.strftime("%Y-%m-%d", time.gmtime(timestamp))
        return os.path.join(self.root, f"llload-{day}.tsv")

    def append(self, snap: ClusterSnapshot):
        path = self._path_for(snap.timestamp)
        text = snap.to_tsv()
        body = text.split("\n", 1)[1] if os.path.exists(path) else text
        with open(path, "a") as f:
            f.write(body)

    def append_tsv(self, timestamp: float, tsv_text: str):
        path = self._path_for(timestamp)
        body = (tsv_text.split("\n", 1)[1] if os.path.exists(path)
                else tsv_text)
        with open(path, "a") as f:
            f.write(body)

    def files(self) -> List[str]:
        return sorted(os.path.join(self.root, f)
                      for f in os.listdir(self.root) if f.endswith(".tsv"))

    def rows(self, start: Optional[float] = None,
             end: Optional[float] = None) -> List[dict]:
        out = []
        for path in self.files():
            with open(path) as f:
                for row in rows_from_tsv(f.read()):
                    t = row["timestamp"]
                    if start is not None and t < start:
                        continue
                    if end is not None and t > end:
                        continue
                    out.append(row)
        return out


class PeriodicArchiver:
    """Drives snapshot capture on the 15-minute cadence (sim or wall time)."""

    def __init__(self, archive: SnapshotArchive, source,
                 interval_s: float = SNAPSHOT_INTERVAL_S):
        self.archive = archive
        self.source = source          # object with .snapshot() -> ClusterSnapshot
        self.interval_s = interval_s
        self._last = None

    def maybe_capture(self, now: float) -> bool:
        if self._last is not None and now - self._last < self.interval_s:
            return False
        self.archive.append(self.source.snapshot())
        self._last = now
        return True

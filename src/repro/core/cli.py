"""The ``LLload`` command (paper Figs 2-5, 10, 11).

Usage (mirrors the paper's flags, plus the streaming extensions):

    python -m repro.core.cli [-g] [--all] [-t N] [-n HOST,HOST] [--tsv] [-q]
                             [--user USER] [--source sim|live|jobs|archive]
                             [--cluster NAME[,NAME]] [--archive-dir DIR]
                             [--watch] [--interval S] [--frames N]

``--source sim`` (default) runs against the simulated LLSC cluster populated
with the paper's workload mixture; ``--source live`` collects from this
host + any in-process JAX jobs; ``--source jobs`` shows only the in-process
JAX job registry; ``--source archive --archive-dir DIR`` replays archived
TSV snapshots.  Sources are built by name through the
:mod:`repro.monitor` registry — ``--cluster a,b`` fans the chosen source
out over several clusters and merges the snapshots.  ``--watch`` streams
the selected view through the TelemetryBus (cached reads between polls).
"""
from __future__ import annotations

import argparse
import sys

from repro.core import formatting
from repro.core.llload import LLload
from repro.monitor import TelemetryBus, build_source, default_registry, watch

PRIVILEGED = {"admin", "root", "hpcteam"}


def build_snapshot(source: str):
    """Back-compat helper: one snapshot from a registry source name."""
    return build_source(source).snapshot()


def render_view(snap, args) -> str:
    """Render the view selected by the parsed flags (shared by the
    one-shot and --watch paths)."""
    ll = LLload(snap, privileged_users=PRIVILEGED)
    if args.tsv:
        return snap.to_tsv()
    if args.t is not None:
        return formatting.format_top(ll.top_loaded(args.t), args.t)
    if args.n is not None:
        hosts = [h.strip() for h in args.n.split(",") if h.strip()]
        rep = ll.node_detail_report(hosts)
        return formatting.format_node_detail(rep.details, rep.missing)
    if args.all_users:
        return formatting.format_all_view(ll.all_view(args.user), args.gpu)
    blk = ll.user_view(args.user)
    return formatting.format_user_view(snap.cluster, blk, args.gpu)


def _make_source(args):
    clusters = [c.strip() for c in (args.cluster or "").split(",")
                if c.strip()]
    kwargs = {}
    if args.source == "archive":
        if not args.archive_dir:
            raise SystemExit("--source archive requires --archive-dir")
        kwargs["root"] = args.archive_dir
    if args.watch and args.source == "sim":
        # advance simulated time on each poll so the stream evolves
        kwargs["advance_s"] = 60.0
    return build_source(args.source, clusters=clusters, **kwargs)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="LLload",
                                 description="HPC utilization snapshot")
    ap.add_argument("-g", action="store_true", dest="gpu",
                    help="include GPU utilization columns")
    ap.add_argument("--all", action="store_true", dest="all_users",
                    help="all users (privileged)")
    ap.add_argument("-t", type=int, default=None, metavar="N",
                    help="top-N nodes by CPU load")
    ap.add_argument("-n", type=str, default=None, metavar="NODELIST",
                    help="comma-separated node detail")
    ap.add_argument("--tsv", action="store_true",
                    help="tab-separated output (archive format)")
    ap.add_argument("-q", action="store_true", help="quiet (no banner)")
    ap.add_argument("--user", default="ab12345")
    ap.add_argument("--source", default="sim",
                    choices=default_registry().names())
    ap.add_argument("--cluster", default=None, metavar="NAME[,NAME]",
                    help="cluster selection; several names fan out and "
                         "merge (multi-cluster view)")
    ap.add_argument("--archive-dir", default=None,
                    help="TSV archive root for --source archive")
    ap.add_argument("--watch", action="store_true",
                    help="stream the view, refreshing every --interval s")
    ap.add_argument("--interval", type=float, default=2.0, metavar="S",
                    help="watch refresh interval (seconds)")
    ap.add_argument("--frames", type=int, default=None, metavar="N",
                    help="stop watch after N frames (default: until ^C)")
    args = ap.parse_args(argv)

    source = _make_source(args)

    if args.watch:
        bus = TelemetryBus(ttl_s=3.0 * args.interval)
        bus.register(source)
        ws = watch(bus, lambda snap: render_view(snap, args),
                   source_name=source.name, interval_s=args.interval,
                   max_frames=args.frames)
        if not args.q:
            try:
                print(f"watch: {ws.frames} frames, {ws.reads} reads, "
                      f"{ws.collections} collections")
            except BrokenPipeError:
                pass      # downstream pager closed mid-stream
        return 0

    snap = source.snapshot()
    if args.tsv:
        sys.stdout.write(render_view(snap, args))
        return 0
    # legacy flag precedence: -t wins over -n (matches render_view/--watch)
    if args.n is not None and args.t is None:
        hosts = [h.strip() for h in args.n.split(",") if h.strip()]
        ll = LLload(snap, privileged_users=PRIVILEGED)
        rep = ll.node_detail_report(hosts)
        print(formatting.format_node_detail(rep.details, rep.missing))
        return 1 if (rep.missing and not rep.details) else 0
    print(render_view(snap, args))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

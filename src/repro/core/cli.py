"""The ``LLload`` command (paper Figs 2-5, 10, 11).

Usage (mirrors the paper's flags):

    python -m repro.core.cli [-g] [--all] [-t N] [-n HOST,HOST] [--tsv] [-q]
                             [--user USER] [--source sim|live]

``--source sim`` (default) runs against the simulated LLSC cluster populated
with the paper's workload mixture; ``--source live`` collects from this
host + any in-process JAX jobs.
"""
from __future__ import annotations

import argparse
import random
import sys

from repro.cluster.workloads import make_llsc_sim, paper_scenario
from repro.core import formatting
from repro.core.collector import LocalHostCollector, SimCollector
from repro.core.llload import LLload

PRIVILEGED = {"admin", "root", "hpcteam"}


def build_snapshot(source: str):
    if source == "live":
        return LocalHostCollector().snapshot()
    sim = make_llsc_sim()
    paper_scenario(sim, random.Random(0))
    sim.run_until(3600.0)
    return SimCollector(sim).snapshot()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="LLload",
                                 description="HPC utilization snapshot")
    ap.add_argument("-g", action="store_true", dest="gpu",
                    help="include GPU utilization columns")
    ap.add_argument("--all", action="store_true", dest="all_users",
                    help="all users (privileged)")
    ap.add_argument("-t", type=int, default=None, metavar="N",
                    help="top-N nodes by CPU load")
    ap.add_argument("-n", type=str, default=None, metavar="NODELIST",
                    help="comma-separated node detail")
    ap.add_argument("--tsv", action="store_true",
                    help="tab-separated output (archive format)")
    ap.add_argument("-q", action="store_true", help="quiet (no banner)")
    ap.add_argument("--user", default="ab12345")
    ap.add_argument("--source", default="sim", choices=["sim", "live"])
    args = ap.parse_args(argv)

    snap = build_snapshot(args.source)
    ll = LLload(snap, privileged_users=PRIVILEGED)

    if args.tsv:
        sys.stdout.write(snap.to_tsv())
        return 0
    if args.t is not None:
        print(formatting.format_top(ll.top_loaded(args.t), args.t))
        return 0
    if args.n is not None:
        hosts = [h.strip() for h in args.n.split(",") if h.strip()]
        print(formatting.format_node_detail(ll.node_detail(hosts)))
        return 0
    if args.all_users:
        print(formatting.format_all_view(ll.all_view(args.user), args.gpu))
        return 0
    blk = ll.user_view(args.user)
    print(formatting.format_user_view(snap.cluster, blk, args.gpu))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""The ``LLload`` command (paper Figs 2-5, 10, 11).

Usage (mirrors the paper's flags, plus the streaming extensions):

    python -m repro.core.cli [-g] [--all] [-t N] [-n HOST,HOST] [--advise]
                             [--tsv] [-q] [--user USER] [--job ID]
                             [--filter EXPR] [--sort SPEC] [--columns LIST]
                             [--limit N] [--format FMT] [--table TABLE]
                             [--group-by COL]
                             [--experiment FILE] [--cells PATTERNS]
                             [--source sim|live|jobs|archive|remote]
                             [--cluster NAME[,NAME]] [--archive-dir DIR]
                             [--url URL[,URL]]
                             [--watch] [--interval S] [--frames N]

``--source sim`` (default) runs against the simulated LLSC cluster populated
with the paper's workload mixture; ``--source live`` collects from this
host + any in-process JAX jobs; ``--source jobs`` shows only the in-process
JAX job registry; ``--source archive --archive-dir DIR`` replays archived
TSV snapshots; ``--source remote --url http://host:port`` reads an LLload
daemon (``python -m repro.daemon``) over HTTP — several URLs fan out and
merge.  Sources are built by name through the
:mod:`repro.monitor` registry — ``--cluster a,b`` fans the chosen source
out over several clusters and merges the snapshots.  ``--watch`` streams
the selected view through the TelemetryBus (cached reads between polls).

Every view is a canned :class:`repro.query.Query` (DESIGN.md §7):
``--filter`` ANDs onto the view's scope, ``--sort``/``--columns``/
``--limit`` override it, and ``--format table|json|csv|tsv|prom`` swaps
the paper's text layout for a machine-readable renderer — one-shot, in
``--watch`` frames, and (``--source remote``) answered server-side by
the daemon's ``/query`` endpoint.  ``--table
nodes|users|jobs|history|insights|job_history`` skips the view scoping
and queries a table directly.

``--job ID`` renders the MPCDF-style single-job report (DESIGN.md
§11): lifetime utilization stats, memory/HBM headroom, and a roofline
verdict.  Locally it spans one snapshot; against ``--source remote``
it is answered server-side by the daemon's ``GET /job/{id}`` from the
job-keyed history tier — byte-identical rendering either way.

``--advise`` renders the §V-B insights view (DESIGN.md §8): every
active diagnosis from the pluggable rule registry, one-shot or
streaming under ``--watch`` (where the insight engine accumulates
persistence/hysteresis across frames); against ``--source remote`` it
is answered server-side by the daemon's ``GET /insights`` from the
daemon's full observation history.

``--experiment FILE`` runs a declarative §V-B overloading campaign
(DESIGN.md §9) — a fixed-NPPN × workload-mix × fleet sweep plus
closed-loop controller cells — and renders the ``experiments`` results
table through the same query flags.  ``--cells`` selects a subset of
the grid by glob, ``--watch`` streams one progress frame per completed
cell, and ``--source remote`` forwards the campaign to the daemon's
``GET /experiments`` so the sweep runs (and caches) server-side with
byte-identical output.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.core import formatting
from repro.monitor import TelemetryBus, build_source, default_registry, watch
from repro.query import (Query, QueryError, apply_modifiers, get_renderer,
                         renderer_names, resolve_format, run_query,
                         view_query)

PRIVILEGED = {"admin", "root", "hpcteam"}


def build_snapshot(source: str):
    """Back-compat helper: one snapshot from a registry source name."""
    return build_source(source).snapshot()


def _hosts_from(args) -> list:
    return [h.strip() for h in (args.n or "").split(",") if h.strip()]


def _view_kind(args) -> str:
    """Flag precedence, matching the legacy CLI: --advise is an explicit
    mode switch, then -t wins over -n."""
    if getattr(args, "advise", False):
        return "advise"
    if args.t is not None:
        return "top"
    if args.n is not None:
        return "nodes"
    if args.all_users:
        return "all"
    return "user"


def _wants_insights(args) -> bool:
    """Does this invocation need an InsightEngine (the advise view or a
    direct insights-table query)?"""
    return (getattr(args, "table", None) == "insights"
            or (not getattr(args, "table", None)
                and _view_kind(args) == "advise"))


def has_query_flags(args) -> bool:
    return bool(getattr(args, "table", None) or args.filter or args.sort
                or args.columns or args.group_by
                or args.limit is not None or args.format != "text")


def build_view_query(args):
    """(query, kind, fmt) for the parsed flags; raises QueryError on any
    malformed filter/sort/columns/table so callers can exit 1 before
    collecting a snapshot or starting a watch stream."""
    fmt = resolve_format(args.format, args.columns, args.group_by)
    if getattr(args, "table", None):
        q = Query.from_params(table=args.table, columns=args.columns,
                              filter=args.filter, sort=args.sort,
                              group_by=args.group_by, limit=args.limit)
        return q, "table", ("table" if fmt == "text" else fmt)
    kind = _view_kind(args)
    canned = view_query(kind, user=args.user, n=args.t or 10,
                        hosts=_hosts_from(args))
    q = apply_modifiers(canned, columns=args.columns, filter=args.filter,
                        sort=args.sort, group_by=args.group_by,
                        limit=args.limit)
    return q, kind, fmt


def render_view(snap, args, prebuilt=None, insights=None,
                jobstore=None) -> str:
    """Render the view selected by the parsed flags (shared by the
    one-shot and --watch paths).  Machine formats end with a newline;
    the legacy text layouts do not (the caller prints them).
    ``prebuilt`` is a ``build_view_query(args)`` result to reuse, so
    watch frames don't re-parse the same filter/sort strings;
    ``insights`` is the InsightEngine backing the advise view /
    insights table; ``jobstore`` the JobHistoryStore backing the
    job_history table."""
    if args.tsv:
        return snap.to_tsv()
    q, kind, fmt = prebuilt if prebuilt is not None \
        else build_view_query(args)
    rs = run_query(snap, q, insights=insights, jobstore=jobstore)
    if fmt != "text":
        return get_renderer(fmt).render(rs)
    if kind == "advise":
        return formatting.advise_view_text(snap, rs.rows)
    if kind == "top":
        return formatting.top_view_text(rs.rows, q.limit or args.t or 10)
    if kind == "nodes":
        return formatting.node_detail_text(snap, rs.rows, _hosts_from(args))
    if kind == "all":
        return formatting.all_view_text(snap, rs.rows, args.user,
                                        args.user in PRIVILEGED, args.gpu)
    return formatting.user_view_text(snap, rs.rows, args.user, args.gpu)


def make_source_from_args(args):
    """Build the MetricSource selected by parsed CLI/daemon flags (shared
    by this CLI and ``python -m repro.daemon``)."""
    clusters = [c.strip() for c in (getattr(args, "cluster", None) or "")
                .split(",") if c.strip()]
    kwargs = {}
    if args.source == "archive":
        if not args.archive_dir:
            raise SystemExit("--source archive requires --archive-dir")
        kwargs["root"] = args.archive_dir
    if args.source == "remote":
        # handled fully here: the generic build_source cluster fan-out
        # would create one RemoteSource per cluster name all pointing at
        # the same URL (every node merged twice) — for remote, fan-out is
        # per *URL*, and --cluster just names the children one-to-one
        urls = [u.strip() for u in (getattr(args, "url", None) or "")
                .split(",") if u.strip()]
        if not urls:
            raise SystemExit("--source remote requires --url")
        if clusters and len(clusters) != len(urls):
            raise SystemExit(
                f"--source remote: --cluster must name each --url "
                f"one-to-one (got {len(clusters)} names for "
                f"{len(urls)} URLs)")
        # persistent consumers subscribe to the daemon's /stream push
        # channel instead of re-polling full snapshots: --watch here,
        # and the daemon's own fan-in (it sets args.stream); one-shots
        # keep polling — a subscription for a single read buys nothing
        stream = bool(getattr(args, "stream",
                              getattr(args, "watch", False)))
        registry = default_registry()
        sources = [registry.create("remote", url=u, cluster=c,
                                   stream=stream)
                   for u, c in zip(urls, clusters or [None] * len(urls))]
        if len(sources) == 1:
            return sources[0]
        from repro.monitor import MultiClusterSource
        return MultiClusterSource(
            sources,
            max_staleness_s=getattr(args, "max_staleness", None))
    if getattr(args, "watch", False) and args.source == "sim":
        # advance simulated time on each poll so the stream evolves
        kwargs["advance_s"] = 60.0
    return build_source(args.source, clusters=clusters, **kwargs)


_make_source = make_source_from_args       # back-compat alias


def _forward_remote(args, url: str, kind: str) -> int:
    """Answer one query server-side: GET the daemon's /query (table
    mode), /insights (advise view), or /view/* with the query params
    passed through verbatim."""
    from repro.daemon.client import RemoteClient, RemoteError
    client = RemoteClient(url)
    fmt = resolve_format(args.format, args.columns, args.group_by)
    params = {"filter": args.filter, "sort": args.sort,
              "columns": args.columns, "group_by": args.group_by,
              "limit": args.limit}
    try:
        if kind == "table":
            body = client.query(table=args.table,
                                format=("table" if fmt == "text" else fmt),
                                **params)
        elif kind == "advise":
            body = client.insights(format=fmt, **params)
        elif kind == "user":
            body = client.view("user", user=args.user,
                               gpu=(1 if args.gpu else None),
                               format=fmt, **params)
        else:                               # top
            body = client.view("top", n=args.t, format=fmt, **params)
        sys.stdout.write(body)
        sys.stdout.flush()
        return 0
    except RemoteError as exc:
        print(f"LLload: {exc}", file=sys.stderr)
        return 1
    except BrokenPipeError:
        return 0


def _squelch_broken_pipe() -> None:
    """Point stdout at /dev/null after a BrokenPipeError so the
    interpreter's exit-time flush of the broken stream cannot print an
    'Exception ignored' traceback."""
    try:
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
    except (OSError, ValueError, AttributeError):
        pass      # stdout is not a real fd (tests, embedding)


def _run_experiment(args) -> int:
    """The ``--experiment`` verb: load the campaign, validate the query
    flags up front, then run locally (one progress frame per cell under
    ``--watch``) or forward the canonical spec to a daemon's
    ``GET /experiments`` (``--source remote``) and print its bytes."""
    from repro.experiments import (CampaignError, CampaignRunner,
                                   load_campaign, render_result)
    from repro.query import Query

    fmt = "table" if args.format == "text" else args.format
    try:
        campaign = load_campaign(args.experiment)
        cells = campaign.select_cells(args.cells)
        # fail on bad query flags before the (expensive) sweep runs
        Query.from_params(table="experiments", columns=args.columns,
                          filter=args.filter, sort=args.sort,
                          group_by=args.group_by, limit=args.limit)
    except (CampaignError, QueryError) as exc:
        print(f"LLload: {exc}", file=sys.stderr)
        return 1
    except OSError as exc:
        print(f"LLload: cannot read campaign {args.experiment!r}: {exc}",
              file=sys.stderr)
        return 1

    if args.source == "remote":
        from repro.daemon.client import RemoteClient, RemoteError
        urls = [u.strip() for u in (args.url or "").split(",")
                if u.strip()]
        if len(urls) != 1:
            print("LLload: --experiment --source remote needs exactly "
                  "one --url (the campaign runs on that daemon)",
                  file=sys.stderr)
            return 1
        try:
            body = RemoteClient(urls[0]).experiments(
                spec=campaign.spec_json(), cells=args.cells, format=fmt,
                filter=args.filter, sort=args.sort, columns=args.columns,
                group_by=args.group_by, limit=args.limit)
            sys.stdout.write(body)
            sys.stdout.flush()
            return 0
        except RemoteError as exc:
            print(f"LLload: {exc}", file=sys.stderr)
            return 1
        except BrokenPipeError:
            _squelch_broken_pipe()
            return 0

    runner = CampaignRunner(campaign, cells=cells)

    def render(partial) -> str:
        return render_result(partial, columns=args.columns,
                             filter=args.filter, sort=args.sort,
                             group_by=args.group_by, limit=args.limit,
                             fmt=fmt)

    try:
        if args.watch:
            done = []
            for res in runner.run_iter():
                done.append(res)
                if not args.q:
                    print(f"=== LLload campaign {campaign.name} | cell "
                          f"{len(done)}/{len(runner.cells)} | "
                          f"{res.cell} ===")
                sys.stdout.write(render(runner.result(done)))
                sys.stdout.flush()
            return 0
        sys.stdout.write(render(runner.run()))
        sys.stdout.flush()
        return 0
    except QueryError as exc:
        print(f"LLload: {exc}", file=sys.stderr)
        return 1
    except BrokenPipeError:
        _squelch_broken_pipe()
        return 0


def _run_job(args) -> int:
    """The ``--job`` verb: render the MPCDF-style job report (DESIGN.md
    §11).  ``--source remote`` forwards to the daemon's ``GET /job/{id}``
    (rendered from its full job history tier); locally a fresh
    JobHistoryStore observes one snapshot — the same render path either
    way, so the bytes match."""
    if args.source == "remote":
        from repro.daemon.client import RemoteClient, RemoteError
        urls = [u.strip() for u in (args.url or "").split(",")
                if u.strip()]
        if len(urls) != 1:
            print("LLload: --job --source remote needs exactly one --url "
                  "(the report renders on that daemon)", file=sys.stderr)
            return 1
        try:
            body = RemoteClient(urls[0]).job(args.job)
            sys.stdout.write(body)
            sys.stdout.flush()
            return 0
        except RemoteError as exc:
            # covers old daemons without /job/{id}: their 404 envelope
            # lands here as a one-line error, not a traceback
            print(f"LLload: {exc}", file=sys.stderr)
            return 1
        except BrokenPipeError:
            _squelch_broken_pipe()
            return 0

    from repro.daemon.store import JobHistoryStore
    source = make_source_from_args(args)
    snap = source.snapshot()
    jobstore = JobHistoryStore()
    jobstore.observe(snap)
    samples = jobstore.raw_points(args.job)
    lifetime = jobstore.lifetime(args.job)
    if not samples or lifetime is None:
        print(f"LLload: unknown job {args.job} (not in the current "
              "snapshot)", file=sys.stderr)
        return 1
    try:
        print(formatting.job_report_text(snap.cluster, samples, lifetime))
        sys.stdout.flush()
        return 0
    except BrokenPipeError:
        _squelch_broken_pipe()
        return 0


def _positive_int(s: str) -> int:
    try:
        v = int(s)
    except ValueError:
        raise argparse.ArgumentTypeError(f"invalid int value: {s!r}")
    if v <= 0:
        raise argparse.ArgumentTypeError(f"must be > 0, got {s!r}")
    return v


def _positive_float(s: str) -> float:
    try:
        v = float(s)
    except ValueError:
        raise argparse.ArgumentTypeError(f"invalid float value: {s!r}")
    if v <= 0:
        raise argparse.ArgumentTypeError(f"must be > 0, got {s!r}")
    return v


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="LLload",
                                 description="HPC utilization snapshot")
    ap.add_argument("-g", action="store_true", dest="gpu",
                    help="include GPU utilization columns")
    ap.add_argument("--all", action="store_true", dest="all_users",
                    help="all users (privileged)")
    ap.add_argument("-t", type=_positive_int, default=None, metavar="N",
                    help="top-N nodes by CPU load")
    ap.add_argument("-n", type=str, default=None, metavar="NODELIST",
                    help="comma-separated node detail")
    ap.add_argument("--advise", action="store_true",
                    help="show active insights (§V-B usage "
                         "characterization) for all users")
    ap.add_argument("--tsv", action="store_true",
                    help="tab-separated output (archive format)")
    ap.add_argument("-q", action="store_true", help="quiet (no banner)")
    ap.add_argument("--user", default="ab12345")
    ap.add_argument("--filter", default=None, metavar="EXPR",
                    help="narrow the view's rows, e.g. "
                         "\"gpu_load<0.2 and gpus>0\"")
    ap.add_argument("--sort", default=None, metavar="COL[,COL]",
                    help="sort keys; prefix - for descending "
                         "(e.g. -gpu_load)")
    ap.add_argument("--columns", default=None, metavar="COL[,COL]",
                    help="columns for machine formats "
                         "(e.g. host,cpu_load,gpu_load)")
    ap.add_argument("--limit", type=_positive_int, default=None,
                    metavar="N", help="keep the first N rows (or groups)")
    ap.add_argument("--format", default="text", dest="format",
                    choices=["text"] + renderer_names(),
                    help="output renderer (text = the paper's layout)")
    ap.add_argument("--table", default=None,
                    choices=["nodes", "users", "jobs", "history",
                             "insights", "job_history"],
                    help="query a table directly instead of a view")
    ap.add_argument("--job", type=int, default=None, metavar="ID",
                    help="render the job report for one job: per-job "
                         "time-series stats, memory headroom, queue "
                         "wait, and a roofline verdict")
    ap.add_argument("--group-by", default=None, dest="group_by",
                    metavar="COL", help="partition rows by a column "
                                        "(machine formats)")
    ap.add_argument("--experiment", default=None, metavar="FILE",
                    help="run a declarative overloading campaign (TOML) "
                         "and render the experiments table")
    ap.add_argument("--cells", default=None, metavar="GLOB[,GLOB]",
                    help="with --experiment: run only matching cells "
                         "(e.g. 'low_duty/*,mixed/8g/controller')")
    ap.add_argument("--source", default="sim",
                    choices=default_registry().names())
    ap.add_argument("--cluster", default=None, metavar="NAME[,NAME]",
                    help="cluster selection; several names fan out and "
                         "merge (multi-cluster view)")
    ap.add_argument("--archive-dir", default=None,
                    help="TSV archive root for --source archive")
    ap.add_argument("--url", default=None, metavar="URL[,URL]",
                    help="LLload daemon URL(s) for --source remote; "
                         "several fan out and merge")
    ap.add_argument("--watch", action="store_true",
                    help="stream the view, refreshing every --interval s")
    ap.add_argument("--interval", type=_positive_float, default=2.0,
                    metavar="S", help="watch refresh interval (seconds)")
    ap.add_argument("--frames", type=_positive_int, default=None,
                    metavar="N",
                    help="stop watch after N frames (default: until ^C)")
    # argparse would reject `--sort -gpu_load` ("-g..." looks like an
    # option); merge the value into `--sort=-gpu_load` form first
    argv = list(sys.argv[1:] if argv is None else argv)
    merged = []
    i = 0
    while i < len(argv):
        a = argv[i]
        if (a in ("--sort", "--filter", "--columns") and i + 1 < len(argv)
                and argv[i + 1].startswith("-")):
            merged.append(f"{a}={argv[i + 1]}")
            i += 2
        else:
            merged.append(a)
            i += 1
    args = ap.parse_args(merged)

    prebuilt = None
    try:
        if args.cells and not args.experiment:
            raise QueryError("--cells selects campaign cells and needs "
                             "--experiment FILE")
        if args.experiment and (args.tsv or args.advise or args.table
                                or args.t is not None
                                or args.n is not None):
            raise QueryError(
                "--experiment renders the campaign's experiments table "
                "and cannot combine with --tsv/--advise/--table/-t/-n "
                "(query flags --filter/--sort/--columns/--limit/"
                "--format/--group-by all apply)")
        if args.experiment and args.watch and args.source == "remote":
            raise QueryError(
                "--experiment --watch streams local progress frames; a "
                "remote campaign (GET /experiments) answers in one shot "
                "— drop --watch or run without --source remote")
        if args.job is not None and (args.experiment or args.tsv
                                     or args.advise or args.table
                                     or args.t is not None
                                     or args.n is not None or args.watch):
            raise QueryError(
                "--job renders one job's report and cannot combine with "
                "--experiment/--tsv/--advise/--table/-t/-n/--watch "
                "(use --table job_history for the queryable series)")
        if args.job is not None:
            return _run_job(args)
        if args.experiment:
            return _run_experiment(args)
        if args.tsv and (has_query_flags(args) or args.advise):
            raise QueryError(
                "--tsv is the raw archive format and ignores query "
                "flags and --advise; use --format tsv for filtered/"
                "sorted output")
        if not args.tsv:
            prebuilt = build_view_query(args)   # validate flags up front
    except QueryError as exc:
        print(f"LLload: {exc}", file=sys.stderr)
        return 1

    # --source remote with query flags: forward the query verbatim so the
    # daemon answers it server-side from pre-aggregated data (one URL;
    # fan-out and --watch still merge snapshots and render locally)
    # "all" has no endpoint and "nodes" owes the legacy all-hosts-unknown
    # exit-1 contract, which a forwarded body can't carry — both render
    # locally from the fetched snapshot (byte-identical either way)
    # "advise" forwards even flagless: the daemon's insight engine has
    # streamed every collection, so it answers with real persistence /
    # first-seen state a one-shot local evaluation cannot have
    if (args.source == "remote" and not args.watch and not args.tsv
            and (has_query_flags(args) or _wants_insights(args))):
        urls = [u.strip() for u in (args.url or "").split(",") if u.strip()]
        kind = "table" if args.table else _view_kind(args)
        if len(urls) == 1 and kind in ("table", "user", "top", "advise"):
            return _forward_remote(args, urls[0], kind)

    source = make_source_from_args(args)

    # the advise view / insights table reads an InsightEngine: one-shot
    # it observes the single snapshot; under --watch it subscribes to the
    # bus and accumulates persistence/hysteresis across frames
    engine = None
    if _wants_insights(args):
        from repro.insights import InsightEngine
        engine = InsightEngine()

    # the job_history table reads a JobHistoryStore the same way: one
    # observation per snapshot, accumulated across --watch frames
    jobstore = None
    if getattr(args, "table", None) == "job_history":
        from repro.daemon.store import JobHistoryStore
        jobstore = JobHistoryStore()

    try:
        if args.watch:
            bus = TelemetryBus(ttl_s=3.0 * args.interval)
            bus.register(source)
            if engine is not None:
                bus.subscribe(engine.subscriber(source.name))
            if jobstore is not None:
                bus.subscribe(jobstore.subscriber(source.name))
            if prebuilt is not None and prebuilt[2] != "text":
                # machine renderers end with a newline and the watch
                # loop adds its own; drop ours so a frame's bytes match
                # the one-shot output exactly (no blank separator line)
                def frame(snap):
                    return render_view(snap, args, prebuilt, engine,
                                       jobstore)[:-1]
            else:
                def frame(snap):
                    return render_view(snap, args, prebuilt, engine,
                                       jobstore)
            ws = watch(bus, frame,
                       source_name=source.name, interval_s=args.interval,
                       max_frames=args.frames)
            if not args.q:
                try:
                    print(f"watch: {ws.frames} frames, {ws.reads} reads, "
                          f"{ws.collections} collections")
                except BrokenPipeError:
                    pass      # downstream pager closed mid-stream
            return 0

        snap = source.snapshot()
        if engine is not None:
            engine.observe(snap)
        if jobstore is not None:
            jobstore.observe(snap)
        # one-shot output can land in a closed pager (`LLload ... | head`):
        # a BrokenPipeError is a normal exit, not a traceback
        out = render_view(snap, args, prebuilt, engine, jobstore)
        machine = bool(args.tsv or args.table
                       or resolve_format(args.format, args.columns,
                                         args.group_by) != "text")
        if machine:
            sys.stdout.write(out if out.endswith("\n") else out + "\n")
        else:
            print(out)
        sys.stdout.flush()
        # legacy -n contract: exit 1 when every requested host is unknown
        # (only when -n actually selected the view: -t, --advise and
        # --table all take precedence and never consult the host list)
        if (args.n is not None and args.t is None and args.table is None
                and not args.advise and not args.tsv):
            hosts = _hosts_from(args)
            if hosts and all(h not in snap.nodes for h in hosts):
                return 1
        return 0
    except QueryError as exc:
        # e.g. --table history against a storeless local source
        print(f"LLload: {exc}", file=sys.stderr)
        return 1
    except BrokenPipeError:
        # keep the interpreter's exit-time stdout flush from tracebacking
        _squelch_broken_pipe()
        return 0


if __name__ == "__main__":
    raise SystemExit(main())

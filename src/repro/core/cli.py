"""The ``LLload`` command (paper Figs 2-5, 10, 11).

Usage (mirrors the paper's flags, plus the streaming extensions):

    python -m repro.core.cli [-g] [--all] [-t N] [-n HOST,HOST] [--tsv] [-q]
                             [--user USER]
                             [--source sim|live|jobs|archive|remote]
                             [--cluster NAME[,NAME]] [--archive-dir DIR]
                             [--url URL[,URL]]
                             [--watch] [--interval S] [--frames N]

``--source sim`` (default) runs against the simulated LLSC cluster populated
with the paper's workload mixture; ``--source live`` collects from this
host + any in-process JAX jobs; ``--source jobs`` shows only the in-process
JAX job registry; ``--source archive --archive-dir DIR`` replays archived
TSV snapshots; ``--source remote --url http://host:port`` reads an LLload
daemon (``python -m repro.daemon``) over HTTP — several URLs fan out and
merge.  Sources are built by name through the
:mod:`repro.monitor` registry — ``--cluster a,b`` fans the chosen source
out over several clusters and merges the snapshots.  ``--watch`` streams
the selected view through the TelemetryBus (cached reads between polls).
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.core import formatting
from repro.core.llload import LLload
from repro.monitor import TelemetryBus, build_source, default_registry, watch

PRIVILEGED = {"admin", "root", "hpcteam"}


def build_snapshot(source: str):
    """Back-compat helper: one snapshot from a registry source name."""
    return build_source(source).snapshot()


def render_view(snap, args) -> str:
    """Render the view selected by the parsed flags (shared by the
    one-shot and --watch paths)."""
    ll = LLload(snap, privileged_users=PRIVILEGED)
    if args.tsv:
        return snap.to_tsv()
    if args.t is not None:
        return formatting.format_top(ll.top_loaded(args.t), args.t)
    if args.n is not None:
        hosts = [h.strip() for h in args.n.split(",") if h.strip()]
        rep = ll.node_detail_report(hosts)
        return formatting.format_node_detail(rep.details, rep.missing)
    if args.all_users:
        return formatting.format_all_view(ll.all_view(args.user), args.gpu)
    blk = ll.user_view(args.user)
    return formatting.format_user_view(snap.cluster, blk, args.gpu)


def make_source_from_args(args):
    """Build the MetricSource selected by parsed CLI/daemon flags (shared
    by this CLI and ``python -m repro.daemon``)."""
    clusters = [c.strip() for c in (getattr(args, "cluster", None) or "")
                .split(",") if c.strip()]
    kwargs = {}
    if args.source == "archive":
        if not args.archive_dir:
            raise SystemExit("--source archive requires --archive-dir")
        kwargs["root"] = args.archive_dir
    if args.source == "remote":
        # handled fully here: the generic build_source cluster fan-out
        # would create one RemoteSource per cluster name all pointing at
        # the same URL (every node merged twice) — for remote, fan-out is
        # per *URL*, and --cluster just names the children one-to-one
        urls = [u.strip() for u in (getattr(args, "url", None) or "")
                .split(",") if u.strip()]
        if not urls:
            raise SystemExit("--source remote requires --url")
        if clusters and len(clusters) != len(urls):
            raise SystemExit(
                f"--source remote: --cluster must name each --url "
                f"one-to-one (got {len(clusters)} names for "
                f"{len(urls)} URLs)")
        registry = default_registry()
        sources = [registry.create("remote", url=u, cluster=c)
                   for u, c in zip(urls, clusters or [None] * len(urls))]
        if len(sources) == 1:
            return sources[0]
        from repro.monitor import MultiClusterSource
        return MultiClusterSource(sources)
    if getattr(args, "watch", False) and args.source == "sim":
        # advance simulated time on each poll so the stream evolves
        kwargs["advance_s"] = 60.0
    return build_source(args.source, clusters=clusters, **kwargs)


_make_source = make_source_from_args       # back-compat alias


def _positive_int(s: str) -> int:
    try:
        v = int(s)
    except ValueError:
        raise argparse.ArgumentTypeError(f"invalid int value: {s!r}")
    if v <= 0:
        raise argparse.ArgumentTypeError(f"must be > 0, got {s!r}")
    return v


def _positive_float(s: str) -> float:
    try:
        v = float(s)
    except ValueError:
        raise argparse.ArgumentTypeError(f"invalid float value: {s!r}")
    if v <= 0:
        raise argparse.ArgumentTypeError(f"must be > 0, got {s!r}")
    return v


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="LLload",
                                 description="HPC utilization snapshot")
    ap.add_argument("-g", action="store_true", dest="gpu",
                    help="include GPU utilization columns")
    ap.add_argument("--all", action="store_true", dest="all_users",
                    help="all users (privileged)")
    ap.add_argument("-t", type=_positive_int, default=None, metavar="N",
                    help="top-N nodes by CPU load")
    ap.add_argument("-n", type=str, default=None, metavar="NODELIST",
                    help="comma-separated node detail")
    ap.add_argument("--tsv", action="store_true",
                    help="tab-separated output (archive format)")
    ap.add_argument("-q", action="store_true", help="quiet (no banner)")
    ap.add_argument("--user", default="ab12345")
    ap.add_argument("--source", default="sim",
                    choices=default_registry().names())
    ap.add_argument("--cluster", default=None, metavar="NAME[,NAME]",
                    help="cluster selection; several names fan out and "
                         "merge (multi-cluster view)")
    ap.add_argument("--archive-dir", default=None,
                    help="TSV archive root for --source archive")
    ap.add_argument("--url", default=None, metavar="URL[,URL]",
                    help="LLload daemon URL(s) for --source remote; "
                         "several fan out and merge")
    ap.add_argument("--watch", action="store_true",
                    help="stream the view, refreshing every --interval s")
    ap.add_argument("--interval", type=_positive_float, default=2.0,
                    metavar="S", help="watch refresh interval (seconds)")
    ap.add_argument("--frames", type=_positive_int, default=None,
                    metavar="N",
                    help="stop watch after N frames (default: until ^C)")
    args = ap.parse_args(argv)

    source = make_source_from_args(args)

    if args.watch:
        bus = TelemetryBus(ttl_s=3.0 * args.interval)
        bus.register(source)
        ws = watch(bus, lambda snap: render_view(snap, args),
                   source_name=source.name, interval_s=args.interval,
                   max_frames=args.frames)
        if not args.q:
            try:
                print(f"watch: {ws.frames} frames, {ws.reads} reads, "
                      f"{ws.collections} collections")
            except BrokenPipeError:
                pass      # downstream pager closed mid-stream
        return 0

    snap = source.snapshot()
    # one-shot output can land in a closed pager (`LLload ... | head`):
    # a BrokenPipeError is a normal exit, not a traceback
    try:
        if args.tsv:
            sys.stdout.write(render_view(snap, args))
            sys.stdout.flush()
            return 0
        # legacy flag precedence: -t wins over -n (matches
        # render_view/--watch)
        if args.n is not None and args.t is None:
            hosts = [h.strip() for h in args.n.split(",") if h.strip()]
            ll = LLload(snap, privileged_users=PRIVILEGED)
            rep = ll.node_detail_report(hosts)
            print(formatting.format_node_detail(rep.details, rep.missing))
            sys.stdout.flush()
            return 1 if (rep.missing and not rep.details) else 0
        print(render_view(snap, args))
        sys.stdout.flush()
        return 0
    except BrokenPipeError:
        # keep the interpreter's exit-time stdout flush from tracebacking
        try:
            os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        except (OSError, ValueError, AttributeError):
            pass      # stdout is not a real fd (tests, embedding)
        return 0


if __name__ == "__main__":
    raise SystemExit(main())

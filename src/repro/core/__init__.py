"""repro.core — the paper's contribution: the LLload utilization system.

Snapshot model, query engine/CLI, 15-minute archive, weekly node-hours
analysis, usage characterization (advisor) and the overloading (NPPN)
controller.  See DESIGN.md §1 for the paper-to-module map; the pluggable
source/bus layer that feeds all of it is :mod:`repro.monitor`
(DESIGN.md §5).
"""
from repro.core.analysis import (HIGH_THRESHOLD, LOW_THRESHOLD, WeeklyReport,
                                 rows_from_snapshots, weekly_analysis)
from repro.core.advisor import (Advice, characterize_all,
                                characterize_snapshots, characterize_user,
                                recommend_nppn)
from repro.core.archive import (ArchiveSubscriber, PeriodicArchiver,
                                SnapshotArchive)
from repro.core.collector import (DeviceUtilization, JaxJobRegistry,
                                  LocalHostCollector, SimCollector,
                                  publish_step_utilization)
from repro.core.llload import LLload, NodeDetailReport
from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot
from repro.core.overload import (NPPN_LEVELS, OverloadController,
                                 OverloadDecision, packed_throughput_model)

__all__ = [
    "HIGH_THRESHOLD", "LOW_THRESHOLD", "WeeklyReport", "weekly_analysis",
    "rows_from_snapshots", "Advice", "characterize_all",
    "characterize_snapshots", "characterize_user", "recommend_nppn",
    "ArchiveSubscriber", "SnapshotArchive", "PeriodicArchiver",
    "DeviceUtilization", "JaxJobRegistry", "LocalHostCollector",
    "SimCollector", "publish_step_utilization", "LLload",
    "NodeDetailReport", "ClusterSnapshot", "JobRecord", "NodeSnapshot",
    "NPPN_LEVELS", "OverloadController", "OverloadDecision",
    "packed_throughput_model",
]

"""repro.core — the paper's contribution: the LLload utilization system.

Snapshot model, query engine/CLI, 15-minute archive, weekly node-hours
analysis, usage characterization (advisor) and the overloading (NPPN)
controller.  See DESIGN.md §1 for the paper-to-module map.
"""
from repro.core.analysis import (HIGH_THRESHOLD, LOW_THRESHOLD, WeeklyReport,
                                 weekly_analysis)
from repro.core.advisor import (Advice, characterize_all, characterize_user,
                                recommend_nppn)
from repro.core.archive import PeriodicArchiver, SnapshotArchive
from repro.core.collector import (DeviceUtilization, JaxJobRegistry,
                                  LocalHostCollector, SimCollector,
                                  publish_step_utilization)
from repro.core.llload import LLload
from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot
from repro.core.overload import (NPPN_LEVELS, OverloadController,
                                 OverloadDecision, packed_throughput_model)

__all__ = [
    "HIGH_THRESHOLD", "LOW_THRESHOLD", "WeeklyReport", "weekly_analysis",
    "Advice", "characterize_all", "characterize_user", "recommend_nppn",
    "SnapshotArchive", "PeriodicArchiver", "DeviceUtilization",
    "JaxJobRegistry", "LocalHostCollector", "SimCollector",
    "publish_step_utilization", "LLload", "ClusterSnapshot", "JobRecord",
    "NodeSnapshot", "NPPN_LEVELS", "OverloadController", "OverloadDecision",
    "packed_throughput_model",
]

"""LLload query engine (paper §IV).

Operates on a :class:`ClusterSnapshot` regardless of source (simulator,
archive TSV, or live collectors).  Implements every paper view:

  * default        — per-user node table (Fig 2)
  * ``-g``         — adds GPU columns (Fig 3)
  * ``--all``      — privileged: Jupyter summary + all users with emails
                     (Fig 4); regular users are silently scoped to self
  * ``-t N``       — top-N nodes by normalized CPU load (Figs 5, 10)
  * ``-n LIST``    — node detail + job table (Fig 11)
  * ``--tsv``      — machine-readable output for the 15-min archive
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot


@dataclasses.dataclass
class UserBlock:
    username: str
    email: str
    nodes: List[NodeSnapshot]


@dataclasses.dataclass
class JupyterEntry:
    hostname: str
    users: List[str]           # "user" or "user(gres:gpu:volta:1)"


@dataclasses.dataclass
class AllView:
    cluster: str
    jupyter: List[JupyterEntry]
    users: List[UserBlock]


@dataclasses.dataclass
class TopNode:
    hostname: str
    avg_load: float            # normalized (load / cores): >1 == overloaded
    cpus_alloc: int
    cpus_idle: int
    cpus_other: int
    cpus_total: int
    mem_total_mb: int
    mem_free_mb: int


@dataclasses.dataclass
class NodeDetail:
    node: NodeSnapshot
    norm_load: float
    jobs: List[JobRecord]


@dataclasses.dataclass
class NodeDetailReport:
    """``-n LIST`` result: found details plus the hostnames that matched
    nothing — misses are reported, never silently dropped."""
    details: List[NodeDetail]
    missing: List[str]


class PermissionError_(Exception):
    pass


class LLload:
    def __init__(self, snapshot: ClusterSnapshot,
                 privileged_users: Optional[set] = None):
        self.snap = snapshot
        self.privileged = privileged_users or set()

    # ------------------------------------------------------------ default
    def user_view(self, username: str) -> UserBlock:
        hosts = self.snap.nodes_by_user().get(username, [])
        nodes = [self.snap.nodes[h] for h in sorted(hosts)]
        return UserBlock(username, self.snap.email_of(username), nodes)

    # -------------------------------------------------------------- --all
    def all_view(self, requesting_user: str) -> AllView:
        """Privileged full-system view; non-privileged users get only their
        own block (the paper scopes --all silently, not with an error)."""
        by_user = self.snap.nodes_by_user()
        if requesting_user not in self.privileged:
            blk = self.user_view(requesting_user)
            return AllView(self.snap.cluster, [], [blk] if blk.nodes else [])

        jupyter: Dict[str, List[str]] = {}
        for job in self.snap.jobs:
            if job.state == "R" and job.job_type == "jupyter":
                for h in job.nodes:
                    tag = job.username
                    if job.gpu_request:
                        tag += f"({job.gpu_request})"
                    jupyter.setdefault(h, []).append(tag)
        jup = [JupyterEntry(h, sorted(us)) for h, us in sorted(jupyter.items())]

        blocks = []
        for user in sorted(by_user):
            nodes = [self.snap.nodes[h] for h in sorted(by_user[user])]
            blocks.append(UserBlock(user, self.snap.email_of(user), nodes))
        return AllView(self.snap.cluster, jup, blocks)

    # ---------------------------------------------------------------- -t N
    def top_loaded(self, n: int) -> List[TopNode]:
        rows = []
        for host in self.snap.nodes:
            node = self.snap.nodes[host]
            alloc = node.cores_used
            rows.append(TopNode(
                hostname=host,
                avg_load=node.norm_load,
                cpus_alloc=alloc,
                cpus_idle=node.cores_total - alloc,
                cpus_other=0,
                cpus_total=node.cores_total,
                mem_total_mb=int(node.mem_total_gb * 1000),
                mem_free_mb=int(node.mem_free_gb * 1000),
            ))
        rows.sort(key=lambda r: -r.avg_load)
        return rows[:n]

    # ----------------------------------------------------------- -n LIST
    def node_detail(self, nodelist: Sequence[str]) -> List[NodeDetail]:
        """Details for the known hosts only (legacy shape); use
        :meth:`node_detail_report` to also learn which hosts missed."""
        return self.node_detail_report(nodelist).details

    def node_detail_report(self, nodelist: Sequence[str]) -> NodeDetailReport:
        details, missing = [], []
        for host in nodelist:
            if host not in self.snap.nodes:
                missing.append(host)
                continue
            node = self.snap.nodes[host]
            details.append(NodeDetail(node, node.norm_load,
                                      self.snap.jobs_on_node(host)))
        return NodeDetailReport(details, missing)

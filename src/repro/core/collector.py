"""Snapshot collectors.

Three sources, one schema (:class:`ClusterSnapshot`):

  * :class:`SimCollector` — the cluster simulator (Slurm stand-in).
  * :class:`LocalHostCollector` — this host via /proc + psutil (the paper's
    sinfo/load-average path).
  * :class:`JaxJobRegistry` / publish hooks — *self-reported* device
    utilization from running JAX jobs.  This replaces the paper's
    privileged ssh+nvidia-smi fan-out (and its latency, which the paper
    calls out): each training/serving step publishes achieved-FLOP/s and
    HBM occupancy; the collector turns that into the `gpu_load` /
    `gpu_mem_*` fields.  See DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
import os
import socket
import threading
import time
from typing import Dict, List, Optional

from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot

try:
    import psutil
except ImportError:  # pragma: no cover
    psutil = None


# --------------------------------------------------------------------------
# Simulator source
# --------------------------------------------------------------------------


class SimCollector:
    def __init__(self, sim):
        self.sim = sim

    def snapshot(self) -> ClusterSnapshot:
        return self.sim.snapshot()


# --------------------------------------------------------------------------
# Live local host
# --------------------------------------------------------------------------


class LocalHostCollector:
    """CPU/memory metrics for the current host (one-node 'cluster')."""

    def __init__(self, username: Optional[str] = None,
                 cluster: str = "local"):
        self.username = username or os.environ.get("USER", "user")
        self.cluster = cluster
        self.hostname = socket.gethostname()

    def node_snapshot(self, device: Optional["DeviceUtilization"] = None
                      ) -> NodeSnapshot:
        cores = os.cpu_count() or 1
        load1, load5, _ = os.getloadavg()
        if psutil is not None:
            vm = psutil.virtual_memory()
            mem_total = vm.total / 1e9
            mem_used = (vm.total - vm.available) / 1e9
            cores_used = min(cores, int(round(psutil.cpu_percent(None)
                                              / 100.0 * cores)))
        else:  # pragma: no cover
            mem_total, mem_used, cores_used = 0.0, 0.0, 0
        gpu = device or DeviceUtilization()
        return NodeSnapshot(
            hostname=self.hostname, cores_total=cores, cores_used=cores_used,
            load=load5, mem_total_gb=mem_total, mem_used_gb=mem_used,
            gpus_total=gpu.n_devices, gpus_used=gpu.n_active,
            gpu_load=gpu.duty_cycle, gpu_mem_total_gb=gpu.hbm_total_gb,
            gpu_mem_used_gb=gpu.hbm_used_gb)

    def snapshot(self) -> ClusterSnapshot:
        dev = JaxJobRegistry.global_registry().aggregate()
        node = self.node_snapshot(dev)
        job = JobRecord(job_id=os.getpid(), username=self.username,
                        name="local", nodes=[self.hostname],
                        cores_per_node=node.cores_total,
                        gpus_per_node=dev.n_devices if dev else 0,
                        start_time=_PROC_START)
        return ClusterSnapshot(self.cluster, time.time(),
                               {self.hostname: node}, [job],
                               {self.username: f"{self.username}@local"})


_PROC_START = time.time()


# --------------------------------------------------------------------------
# JAX self-reporting
# --------------------------------------------------------------------------


@dataclasses.dataclass
class DeviceUtilization:
    """What a JAX job knows about its own devices."""
    n_devices: int = 0
    n_active: int = 0
    duty_cycle: float = 0.0     # achieved FLOP/s / peak FLOP/s (MFU proxy)
    hbm_total_gb: float = 0.0
    hbm_used_gb: float = 0.0
    step_time_s: float = 0.0
    achieved_flops: float = 0.0


class JaxJobRegistry:
    """In-process registry JAX jobs publish to; collectors read from it.

    Thread-safe; keyed by job name so several engines (trainer + server)
    in one process are visible individually and in aggregate.
    """

    _global = None
    _global_lock = threading.Lock()

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[str, DeviceUtilization] = {}

    @classmethod
    def global_registry(cls) -> "JaxJobRegistry":
        with cls._global_lock:
            if cls._global is None:
                cls._global = cls()
            return cls._global

    def publish(self, job_name: str, util: DeviceUtilization):
        with self._lock:
            self._entries[job_name] = util

    def remove(self, job_name: str):
        with self._lock:
            self._entries.pop(job_name, None)

    def entries(self) -> Dict[str, DeviceUtilization]:
        with self._lock:
            return dict(self._entries)

    def aggregate(self) -> DeviceUtilization:
        with self._lock:
            entries = list(self._entries.values())
        if not entries:
            return DeviceUtilization()
        n = max(e.n_devices for e in entries)
        return DeviceUtilization(
            n_devices=n,
            n_active=max(e.n_active for e in entries),
            duty_cycle=min(1.5, sum(e.duty_cycle for e in entries)),
            hbm_total_gb=max(e.hbm_total_gb for e in entries),
            hbm_used_gb=sum(e.hbm_used_gb for e in entries),
            step_time_s=max(e.step_time_s for e in entries),
            achieved_flops=sum(e.achieved_flops for e in entries),
        )


def publish_step_utilization(job_name: str, *, model_flops_per_step: float,
                             step_time_s: float, peak_flops: float,
                             n_devices: int = 1, hbm_used_gb: float = 0.0,
                             hbm_total_gb: float = 0.0):
    """Hook called by the trainer/server after each (timed) step."""
    duty = 0.0
    if step_time_s > 0 and peak_flops > 0:
        duty = model_flops_per_step / step_time_s / (peak_flops * n_devices)
    JaxJobRegistry.global_registry().publish(job_name, DeviceUtilization(
        n_devices=n_devices, n_active=n_devices, duty_cycle=duty,
        hbm_total_gb=hbm_total_gb, hbm_used_gb=hbm_used_gb,
        step_time_s=step_time_s,
        achieved_flops=model_flops_per_step / max(step_time_s, 1e-9)))

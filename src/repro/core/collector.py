"""Snapshot collectors.

Three collectors, one schema (:class:`ClusterSnapshot`):

  * :class:`SimCollector` — the cluster simulator (Slurm stand-in).
  * :class:`LocalHostCollector` — this host via /proc + psutil (the paper's
    sinfo/load-average path).
  * :class:`JaxJobRegistry` / publish hooks — *self-reported* device
    utilization from running JAX jobs.  This replaces the paper's
    privileged ssh+nvidia-smi fan-out (and its latency, which the paper
    calls out): each training/serving step publishes achieved-FLOP/s and
    HBM occupancy; the collector turns that into the `gpu_load` /
    `gpu_mem_*` fields.  See DESIGN.md §2.

The uniform source layer lives in :mod:`repro.monitor` (DESIGN.md §5):
these collectors are wrapped as ``MetricSource``s there, and new
consumers should go through the :class:`~repro.monitor.bus.TelemetryBus`
rather than wiring collectors together by hand.  This module keeps the
original names as thin shims for backward compatibility.
"""
from __future__ import annotations

import dataclasses
import os
import socket
import threading
import time
from typing import Dict, List, Optional

from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot

try:
    import psutil
except ImportError:  # pragma: no cover
    psutil = None


# --------------------------------------------------------------------------
# Simulator source
# --------------------------------------------------------------------------


class SimCollector:
    def __init__(self, sim):
        self.sim = sim

    def snapshot(self) -> ClusterSnapshot:
        return self.sim.snapshot()


# --------------------------------------------------------------------------
# Live local host
# --------------------------------------------------------------------------


class LocalHostCollector:
    """CPU/memory metrics for the current host (one-node 'cluster')."""

    def __init__(self, username: Optional[str] = None,
                 cluster: str = "local"):
        self.username = username or os.environ.get("USER", "user")
        self.cluster = cluster
        self.hostname = socket.gethostname()

    def node_snapshot(self, device: Optional["DeviceUtilization"] = None
                      ) -> NodeSnapshot:
        cores = os.cpu_count() or 1
        load1, load5, _ = os.getloadavg()
        if psutil is not None:
            vm = psutil.virtual_memory()
            mem_total = vm.total / 1e9
            mem_used = (vm.total - vm.available) / 1e9
            cores_used = min(cores, int(round(psutil.cpu_percent(None)
                                              / 100.0 * cores)))
        else:  # pragma: no cover
            mem_total, mem_used, cores_used = 0.0, 0.0, 0
        gpu = device or DeviceUtilization()
        return NodeSnapshot(
            hostname=self.hostname, cores_total=cores, cores_used=cores_used,
            load=load5, mem_total_gb=mem_total, mem_used_gb=mem_used,
            gpus_total=gpu.n_devices, gpus_used=gpu.n_active,
            gpu_load=gpu.duty_cycle, gpu_mem_total_gb=gpu.hbm_total_gb,
            gpu_mem_used_gb=gpu.hbm_used_gb)

    def snapshot(self) -> ClusterSnapshot:
        dev = JaxJobRegistry.global_registry().aggregate()
        node = self.node_snapshot(dev)
        job = JobRecord(job_id=os.getpid(), username=self.username,
                        name="local", nodes=[self.hostname],
                        cores_per_node=node.cores_total,
                        gpus_per_node=dev.n_devices if dev else 0,
                        start_time=_PROC_START)
        return ClusterSnapshot(self.cluster, time.time(),
                               {self.hostname: node}, [job],
                               {self.username: f"{self.username}@local"})


_PROC_START = time.time()


# --------------------------------------------------------------------------
# JAX self-reporting
# --------------------------------------------------------------------------


@dataclasses.dataclass
class DeviceUtilization:
    """What a JAX job knows about its own devices."""
    n_devices: int = 0
    n_active: int = 0
    duty_cycle: float = 0.0     # achieved FLOP/s / peak FLOP/s (MFU proxy)
    hbm_total_gb: float = 0.0
    hbm_used_gb: float = 0.0
    step_time_s: float = 0.0
    achieved_flops: float = 0.0


class JaxJobRegistry:
    """In-process registry JAX jobs publish to; collectors read from it.

    Thread-safe; keyed by job name so several engines (trainer + server)
    in one process are visible individually and in aggregate.
    """

    _global = None
    _global_lock = threading.Lock()

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[str, DeviceUtilization] = {}  # guarded-by: _lock

    @classmethod
    def global_registry(cls) -> "JaxJobRegistry":
        with cls._global_lock:
            if cls._global is None:
                cls._global = cls()
            return cls._global

    def publish(self, job_name: str, util: DeviceUtilization):
        with self._lock:
            self._entries[job_name] = util

    def remove(self, job_name: str):
        with self._lock:
            self._entries.pop(job_name, None)

    def entries(self) -> Dict[str, DeviceUtilization]:
        with self._lock:
            return dict(self._entries)

    def aggregate(self) -> DeviceUtilization:
        """Combine all co-resident jobs into one per-device view.

        Jobs in one process share the same physical devices (that is the
        whole point of overloading), so their duty cycles *add* per
        device.  The combined duty is the device-weighted sum normalized
        by the device count::

            duty = sum_j(duty_j * n_devices_j) / max_j(n_devices_j)

        i.e. total achieved FLOP/s over the peak of the devices actually
        present.  It is capped at the true oversubscription bound — the
        number of co-resident jobs ``k`` — because each job can at most
        saturate every device (duty_j <= 1 per device); anything beyond
        ``k`` is self-report noise (e.g. a miscalibrated peak), not load.
        """
        with self._lock:
            entries = list(self._entries.values())
        if not entries:
            return DeviceUtilization()
        n = max(e.n_devices for e in entries)
        weighted = sum(e.duty_cycle * max(e.n_devices, 1)
                       for e in entries) / max(n, 1)
        return DeviceUtilization(
            n_devices=n,
            n_active=max(e.n_active for e in entries),
            duty_cycle=min(float(len(entries)), weighted),
            hbm_total_gb=max(e.hbm_total_gb for e in entries),
            hbm_used_gb=sum(e.hbm_used_gb for e in entries),
            step_time_s=max(e.step_time_s for e in entries),
            achieved_flops=sum(e.achieved_flops for e in entries),
        )


def publish_step_utilization(job_name: str, **kwargs):
    """Backward-compatible shim: the canonical publish hook now lives on
    the telemetry bus (:func:`repro.monitor.publish_step_utilization`)."""
    from repro.monitor.bus import publish_step_utilization as _publish

    return _publish(job_name, **kwargs)

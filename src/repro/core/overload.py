"""Overloading (oversubscription) controller — the paper's §V-B mechanism,
generalized into a closed-loop policy this framework applies to its own
serving/training jobs.

Paper: "GPU overloading involves launching a parent job process ... the
parent process round-robin assigns one of the available GPUs to each of the
child tasks" with NPPN raised 2 -> 4 -> 8 while load and memory allow.

TPU adaptation: the "device" is a TPU chip (or slice); `duty_cycle` is the
measured MFU-proxy from the JAX collector; packing happens either by
co-scheduling micro-jobs on a slice (training) or by admitting more
concurrent request streams into the batcher (serving).  The *policy* below
is identical to the paper's.

Since the Insights redesign (DESIGN.md §8) the controller is also a
*rule consumer*: :meth:`OverloadController.consume` turns an active
``low_gpu`` :class:`~repro.insights.records.Insight` — the Fig-7 rule's
output — into a device observation and a next-NPPN decision, closing
the loop from diagnosis to overloading action.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.insights.rules import recommend_nppn

NPPN_LEVELS = (1, 2, 4, 8)


def nearest_level(nppn: int, *, max_nppn: int = 8) -> int:
    """Clamp an arbitrary tasks-per-GPU count onto the LLsub levels:
    the largest level <= ``nppn`` (and <= ``max_nppn``), floor 1.

    Jobs arrive at NPPN values LLsub never minted (3 from a manual
    launch, 16 from another site's config); ``NPPN_LEVELS.index()`` on
    raw input raised ValueError for every one of them.
    """
    n = min(max(nppn, 1), max(max_nppn, 1))
    for v in reversed(NPPN_LEVELS):
        if v <= n:
            return v
    return NPPN_LEVELS[0]


@dataclasses.dataclass
class DeviceObservation:
    duty_cycle: float          # 0..1 utilization of the device
    mem_used_gb: float         # per co-resident task
    mem_total_gb: float
    throughput: float = 0.0    # task-level items/s (optional)


@dataclasses.dataclass
class OverloadDecision:
    nppn: int
    reason: str


class OverloadController:
    """Hysteresis-free step controller over NPPN levels.

    ``observe`` accumulates device observations; ``decide`` proposes the
    next NPPN.  Raising is allowed only when the *projected* duty cycle and
    memory stay under the caps; lowering triggers when the device saturates
    (duty > saturate_load) — the paper's "the limiting factor is the GPU
    load" case.
    """

    def __init__(self, *, target_load: float = 0.9,
                 saturate_load: float = 0.98, mem_headroom: float = 0.9,
                 max_nppn: int = 8):
        self.target_load = target_load
        self.saturate_load = saturate_load
        self.mem_headroom = mem_headroom
        self.max_nppn = max_nppn
        self.history: List[DeviceObservation] = []

    def observe(self, obs: DeviceObservation):
        self.history.append(obs)

    def consume(self, insight, current_nppn: int = 1) -> OverloadDecision:
        """Consume an insight (rule-engine output): a ``low_gpu`` insight
        carries measured duty and per-task memory in its evidence, which
        becomes a device observation feeding :meth:`decide`; any other
        kind leaves the level unchanged."""
        if getattr(insight, "kind", None) != "low_gpu":
            return OverloadDecision(
                nearest_level(current_nppn, max_nppn=self.max_nppn),
                f"insight kind {getattr(insight, 'kind', None)!r} does not "
                "drive overloading")
        ev = insight.evidence
        self.observe(DeviceObservation(
            duty_cycle=float(ev.get("gpu_load", 0.0)),
            mem_used_gb=float(ev.get("gpu_mem_used_gb", 0.0)),
            mem_total_gb=float(ev.get("gpu_mem_total_gb", 0.0))))
        return self.decide(current_nppn)

    def decide(self, current_nppn: int) -> OverloadDecision:
        # clamp off-ladder inputs (3, 16, ...) onto the nearest level so
        # stepping logic never indexes NPPN_LEVELS with a foreign value
        level = nearest_level(current_nppn, max_nppn=self.max_nppn)
        if not self.history:
            return OverloadDecision(level, "no observations")
        window = self.history[-8:]
        duty = sum(o.duty_cycle for o in window) / len(window)
        obs = window[-1]
        per_task_duty = duty / max(current_nppn, 1)
        per_task_mem = obs.mem_used_gb / max(current_nppn, 1)

        if duty >= self.saturate_load and level > 1:
            if level < current_nppn:
                nxt = level        # clamping already stepped down (3 -> 2)
            else:
                nxt = NPPN_LEVELS[max(NPPN_LEVELS.index(level) - 1, 0)]
            return OverloadDecision(
                nxt, f"device saturated (duty {duty:.2f}); backing off")

        best = recommend_nppn(per_task_duty, per_task_mem, obs.mem_total_gb,
                              target_load=self.target_load,
                              mem_headroom=self.mem_headroom,
                              max_nppn=self.max_nppn)
        if best > current_nppn:
            # step one level at a time (2 -> 4 -> 8), as deployed at LLSC
            idx = NPPN_LEVELS.index(level)
            nxt = NPPN_LEVELS[min(idx + 1, len(NPPN_LEVELS) - 1)]
            return OverloadDecision(
                nxt, f"duty/task {per_task_duty:.2f}, mem/task "
                     f"{per_task_mem:.1f}GB -> headroom for NPPN={best}")
        if best < current_nppn:
            return OverloadDecision(best, "memory or load headroom shrank")
        return OverloadDecision(level, "at recommended level")


def packed_throughput_model(per_task_duty: float, nppn: int,
                            interference: float = 0.03) -> float:
    """Analytic throughput multiple for NPPN tasks sharing one device.

    Tasks time-share: aggregate duty saturates at 1.0; each co-resident
    task adds a small interference tax (context switching / memory traffic).
    Used as the napkin model for the Fig 7 -> NPPN sweep benchmark; the
    measured counterpart is benchmarks/bench_overloading.py.
    """
    raw = min(1.0, per_task_duty * nppn)
    return raw * (1.0 - interference * (nppn - 1))

from repro.models.model import (count_params, count_params_analytic,
                                decode_step, forward_hidden, init_cache,
                                init_params, init_params_shape, lm_loss,
                                model_flops, prefill)

__all__ = [
    "count_params", "count_params_analytic", "decode_step", "forward_hidden",
    "init_cache", "init_params", "init_params_shape", "lm_loss",
    "model_flops", "prefill",
]

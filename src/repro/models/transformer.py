"""Model assembly: periodic layer stacks, scans, prefill/decode, loss.

A model is ``n_periods`` copies of a *period* (``cfg.layer_pattern`` /
``cfg.mlp_pattern``) scanned with ``lax.scan`` (small HLO, fast compiles,
native remat), plus an unrolled remainder of ``n_layers % period`` layers.
Hybrid (jamba 1:7 attn:ssm), local:global (gemma3 5:1) and MoE-every-k
patterns all reduce to this scheme.

Caches: a dict ``{"blocks": {str(pos): tree[n_periods, ...]},
"rem": {str(i): tree}, "enc": ...}`` — scan-compatible because every leaf of
``blocks`` carries the period axis in front.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (embed, init_embed, init_mlp, init_rmsnorm,
                                 mlp, rmsnorm, truncated_normal)
from repro.models.scan_util import scan as _scan
from repro.models.sharding_hints import shard_hint

F32 = jnp.float32


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ==========================================================================
# Init
# ==========================================================================


def init_block(key, cfg, mixer_kind: str, mlp_kind: str, *, cross: bool,
               dtype):
    ks = jax.random.split(key, 4)
    p = {"ln1": init_rmsnorm(cfg.d_model, dtype),
         "ln2": init_rmsnorm(cfg.d_model, dtype)}
    if mixer_kind == "ssm":
        p["mixer"] = ssm_mod.init_mamba2(ks[0], cfg.d_model, cfg.ssm, dtype)
    elif cfg.mla is not None:
        p["mixer"] = attn_mod.init_mla(ks[0], cfg.d_model, cfg.n_heads,
                                       cfg.mla, dtype)
    else:
        p["mixer"] = attn_mod.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
            cfg.qkv_bias, dtype)
    if cross:
        p["ln_x"] = init_rmsnorm(cfg.d_model, dtype)
        p["xattn"] = attn_mod.init_attention(
            ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
            False, dtype)
    if mlp_kind == "moe":
        p["mlp"] = moe_mod.init_moe(ks[2], cfg.d_model, cfg.moe, dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    else:
        p["mlp"] = {}  # attention-free SSM blocks (mamba2) have no FFN
    return p


def _init_enc_block(key, cfg, dtype):
    enc = cfg.encoder
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mixer": attn_mod.init_attention(
            ks[0], cfg.d_model, enc.n_heads, enc.n_kv_heads, cfg.d_model // enc.n_heads,
            False, dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, enc.d_ff, "gelu", dtype),
    }


def init_params(cfg, key, dtype=None):
    """Full parameter tree.  Works under jax.eval_shape (no allocation)."""
    dtype = dtype or _dtype(cfg)
    keys = jax.random.split(key, 8)
    cross = cfg.is_encdec
    params = {"embed": init_embed(keys[0], cfg.vocab_size, cfg.d_model, dtype)}

    blocks = {}
    for p_idx in range(cfg.period):
        mixer_kind = cfg.layer_pattern[p_idx]
        mlp_kind = cfg.mlp_pattern[p_idx]
        pkeys = jax.random.split(jax.random.fold_in(keys[1], p_idx),
                                 cfg.n_periods)
        blocks[str(p_idx)] = jax.vmap(
            lambda k: init_block(k, cfg, mixer_kind, mlp_kind, cross=cross,
                                 dtype=dtype))(pkeys)
    params["blocks"] = blocks

    rem = {}
    for i in range(cfg.n_remainder):
        mixer_kind = cfg.layer_pattern[i]
        mlp_kind = cfg.mlp_pattern[i]
        rem[str(i)] = init_block(jax.random.fold_in(keys[2], i), cfg,
                                 mixer_kind, mlp_kind, cross=cross,
                                 dtype=dtype)
    params["rem"] = rem
    params["final_norm"] = init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = truncated_normal(
            keys[3], (cfg.d_model, cfg.vocab_size), cfg.d_model ** -0.5, dtype)
    if cfg.is_encdec:
        enc = cfg.encoder
        ekeys = jax.random.split(keys[4], enc.n_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_enc_block(k, cfg, dtype))(ekeys)
        params["enc_norm"] = init_rmsnorm(cfg.d_model, dtype)
    return params


# ==========================================================================
# Block application
# ==========================================================================


def _apply_mixer_full(bp, x, cfg, kind, positions, *, want_cache, banded):
    h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
    cache = {}
    if kind == "ssm":
        y, (conv_tail, state) = ssm_mod.mamba2_forward(bp["mixer"], h, cfg)
        if want_cache:
            cache = {"conv": conv_tail.astype(_dtype(cfg)),
                     "ssd": state.astype(F32)}
    elif cfg.mla is not None:
        y, (ckv, krope) = attn_mod.mla_attention(bp["mixer"], h, cfg,
                                                 positions=positions)
        if want_cache:
            cache = {"ckv": ckv.astype(_dtype(cfg)),
                     "krope": krope.astype(_dtype(cfg))}
    else:
        local = kind == "attn_local"
        from repro.models.perf_flags import current as _perf
        banded = banded or (_perf().banded_local and local)
        y, (k, v) = attn_mod.gqa_attention(bp["mixer"], h, cfg, local=local,
                                           positions=positions, banded=banded)
        if want_cache:
            cache = {"k": k.astype(_dtype(cfg)), "v": v.astype(_dtype(cfg))}
    return x + y, cache


def _apply_cross_full(bp, x, cfg, enc_out, *, want_cache):
    h = rmsnorm(bp["ln_x"], x, cfg.norm_eps)
    enc = cfg.encoder
    d_head = cfg.d_model // enc.n_heads
    k, v = attn_mod.cross_kv(bp["xattn"], enc_out, enc.n_kv_heads, d_head)
    y = attn_mod.cross_attention(bp["xattn"], h, k, v, cfg)
    cache = {"xk": k, "xv": v} if want_cache else {}
    return x + y, cache


def _apply_mlp(bp, x, cfg, mlp_kind, *, want_aux=False):
    """Returns (x, aux) where aux = [load_balance, z] router losses."""
    zero = jnp.zeros((2,), F32)
    if mlp_kind != "moe" and not bp["mlp"]:
        return x, zero  # no FFN (mamba2)
    h = rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if mlp_kind == "moe":
        y = moe_mod.moe_ffn(bp["mlp"], h, cfg.moe, cfg.act)
        aux = zero
        if want_aux:
            lb, z = moe_mod.moe_aux_losses(bp["mlp"], h, cfg.moe)
            aux = jnp.stack([lb, z])
        return x + y, aux
    return x + mlp(bp["mlp"], h, cfg.act), zero


@jax.custom_vjp
def _bf16_cotangent(x):
    return x


def _bf16_ct_fwd(x):
    return x, None


def _bf16_ct_bwd(_, g):
    # compress the activation gradient crossing this boundary: the TP/FSDP
    # backward collectives then move bf16 instead of f32 (§Perf lever)
    return (g.astype(jnp.bfloat16).astype(g.dtype),)


_bf16_cotangent.defvjp(_bf16_ct_fwd, _bf16_ct_bwd)


def apply_block_full(bp, x, cfg, mixer_kind, mlp_kind, positions,
                     enc_out=None, *, want_cache=False, banded=False,
                     want_aux=False):
    x, cache = _apply_mixer_full(bp, x, cfg, mixer_kind, positions,
                                 want_cache=want_cache, banded=banded)
    if cfg.is_encdec:
        x, xcache = _apply_cross_full(bp, x, cfg, enc_out,
                                      want_cache=want_cache)
        cache.update(xcache)
    x, aux = _apply_mlp(bp, x, cfg, mlp_kind, want_aux=want_aux)
    x = shard_hint(x, "activation")
    from repro.models.perf_flags import current as _perf
    if _perf().bf16_grads:
        x = _bf16_cotangent(x)
    return x, cache, aux


def apply_block_decode(bp, x, cfg, mixer_kind, mlp_kind, cache, cache_len):
    h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if mixer_kind == "ssm":
        y, conv_state, ssd_state = ssm_mod.mamba2_decode(
            bp["mixer"], h, cfg, cache["conv"], cache["ssd"])
        new_cache["conv"], new_cache["ssd"] = (
            conv_state.astype(cache["conv"].dtype), ssd_state.astype(F32))
    elif cfg.mla is not None:
        y, ckv, krope = attn_mod.mla_decode(
            bp["mixer"], h, cfg, cache["ckv"], cache["krope"], cache_len)
        new_cache["ckv"], new_cache["krope"] = ckv, krope
    else:
        local = mixer_kind == "attn_local"
        y, ck, cv = attn_mod.gqa_decode(
            bp["mixer"], h, cfg, cache["k"], cache["v"], cache_len,
            local=local)
        new_cache["k"], new_cache["v"] = ck, cv
    x = x + y
    if cfg.is_encdec:
        hx = rmsnorm(bp["ln_x"], x, cfg.norm_eps)
        y = attn_mod.cross_attention(bp["xattn"], hx, cache["xk"],
                                     cache["xv"], cfg)
        x = x + y
    x, _ = _apply_mlp(bp, x, cfg, mlp_kind)
    return x, new_cache


# ==========================================================================
# Encoder (enc-dec models)
# ==========================================================================


def encode(params, cfg, enc_embeds):
    """enc_embeds [B, S_enc, d] (stub frontend output) -> encoder hidden."""
    enc = cfg.encoder
    positions = jnp.arange(enc_embeds.shape[1])

    def body(x, bp):
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        d_head = cfg.d_model // enc.n_heads
        q, k, v = attn_mod.gqa_project_qkv(bp["mixer"], h, enc.n_heads,
                                           enc.n_kv_heads, d_head)
        from repro.models.attention import chunked_attention
        o = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
        x = x + o.reshape(x.shape[0], x.shape[1], -1) @ bp["mixer"]["wo"]
        h = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        x = x + mlp(bp["mlp"], h, "gelu")
        return x, None

    x, _ = _scan(body, enc_embeds, params["enc_blocks"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ==========================================================================
# Full-sequence forward (train / prefill)
# ==========================================================================


def _remat(fn, cfg):
    from repro.models.perf_flags import current as _perf

    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots" or _perf().remat_dots:
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def input_embeddings(params, cfg, tokens, frontend_embeds=None):
    x = embed(params["embed"], tokens, cfg.embed_scale)
    if cfg.frontend == "patch_stub" and frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return x


def forward_hidden(params, cfg, tokens, frontend_embeds=None, *,
                   want_cache=False, banded=False, want_aux=False):
    """Returns (hidden [B,S,d], caches-or-None) — or, with ``want_aux``,
    (hidden, caches, aux [2]) where aux sums MoE (load-balance, z) losses.

    For encdec models ``frontend_embeds`` is the encoder (stub) input; for
    vlm it is prepended patch embeddings.
    """
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, frontend_embeds)
        x = embed(params["embed"], tokens, cfg.embed_scale)
    else:
        x = input_embeddings(params, cfg, tokens, frontend_embeds)
    x = shard_hint(x, "activation")
    S = x.shape[1]
    positions = jnp.arange(S)

    def period_fn(carry, pparams):
        x, aux = carry
        caches = {}
        for p_idx in range(cfg.period):
            x, c, a = apply_block_full(
                pparams[str(p_idx)], x, cfg, cfg.layer_pattern[p_idx],
                cfg.mlp_pattern[p_idx], positions, enc_out,
                want_cache=want_cache, banded=banded, want_aux=want_aux)
            caches[str(p_idx)] = c
            aux = aux + a
        return (x, aux), caches

    aux0 = jnp.zeros((2,), F32)
    (x, aux), block_caches = _scan(_remat(period_fn, cfg), (x, aux0),
                                   params["blocks"])

    rem_caches = {}
    for i in range(cfg.n_remainder):
        x, c, a = apply_block_full(
            params["rem"][str(i)], x, cfg, cfg.layer_pattern[i],
            cfg.mlp_pattern[i], positions, enc_out,
            want_cache=want_cache, banded=banded, want_aux=want_aux)
        rem_caches[str(i)] = c
        aux = aux + a

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    caches = None
    if want_cache:
        caches = {"blocks": block_caches, "rem": rem_caches}
    if want_aux:
        return x, caches, aux / max(cfg.n_layers, 1)
    return x, caches


# ==========================================================================
# Logits / loss
# ==========================================================================


def _head(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"], True
    return params["lm_head"], False


def logits_last(params, cfg, hidden):
    """Logits for the final position only. hidden [B,S,d] -> [B,V] fp32."""
    h = hidden[:, -1]
    w, tied = _head(params, cfg)
    if tied:
        return jnp.einsum("bd,vd->bv", h, w, preferred_element_type=F32)
    return jnp.einsum("bd,dv->bv", h, w, preferred_element_type=F32)


def chunked_ce_loss(params, cfg, hidden, labels):
    """Mean CE over labels >= 0 without materializing [B,S,V] logits.

    hidden [B,S,d]; labels [B,S] int32 (-1 = ignore).  Computed in sequence
    chunks of cfg.loss_chunk; each chunk is rematerialized in backward.
    """
    B, S, d = hidden.shape
    w, tied = _head(params, cfg)
    C = min(cfg.loss_chunk, S)
    pad = (-S) % C
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nch = hidden.shape[1] // C
    h_chunks = jnp.moveaxis(hidden.reshape(B, nch, C, d), 1, 0)
    l_chunks = jnp.moveaxis(labels.reshape(B, nch, C), 1, 0)

    from repro.models.perf_flags import current as _perf

    if _perf().loss_weight_gather:
        # Replicate the head weight's d_model shards before the loss einsum:
        # GSPMD then gathers the (small) weight over the FSDP axis instead of
        # all-reducing [B, C, V]-sized partial logits (§Perf lever).
        w = shard_hint(w, "loss_head_tied" if tied else "loss_head")

    @jax.checkpoint
    def chunk_fn(carry, xs):
        hc, lc = xs
        if tied:
            logits = jnp.einsum("bcd,vd->bcv", hc, w,
                                preferred_element_type=F32)
        else:
            logits = jnp.einsum("bcd,dv->bcv", hc, w,
                                preferred_element_type=F32)
        logits = shard_hint(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via masked reduction (shards cleanly over a split vocab,
        # unlike take_along_axis)
        vocab_idx = jnp.arange(logits.shape[-1])
        sel = vocab_idx[None, None, :] == jnp.clip(lc, 0)[..., None]
        gold = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
        valid = lc >= 0
        loss_sum, count = carry
        loss_sum = loss_sum + jnp.sum(jnp.where(valid, lse - gold, 0.0))
        count = count + jnp.sum(valid)
        return (loss_sum, count), None

    (loss_sum, count), _ = _scan(
        chunk_fn, (jnp.zeros((), F32), jnp.zeros((), jnp.int32)),
        (h_chunks, l_chunks))
    return loss_sum / jnp.maximum(count, 1)


def lm_loss(params, cfg, tokens, labels, frontend_embeds=None, *,
            banded=False, aux_weights=None):
    """CE loss (+ optional MoE auxiliary losses).

    ``aux_weights=(lb_w, z_w)``: adds lb_w * load_balance + z_w * z_loss
    (per-MoE-layer means).  Ignored for non-MoE configs.
    """
    want_aux = aux_weights is not None and cfg.moe is not None
    if want_aux:
        hidden, _, aux = forward_hidden(params, cfg, tokens, frontend_embeds,
                                        banded=banded, want_aux=True)
    else:
        hidden, _ = forward_hidden(params, cfg, tokens, frontend_embeds,
                                   banded=banded)
    if cfg.frontend == "patch_stub" and frontend_embeds is not None:
        P = frontend_embeds.shape[1]
        pad_labels = jnp.full(
            (labels.shape[0], P), -1, labels.dtype)
        labels = jnp.concatenate([pad_labels, labels], axis=1)
    loss = chunked_ce_loss(params, cfg, hidden, labels)
    if want_aux:
        loss = loss + aux_weights[0] * aux[0] + aux_weights[1] * aux[1]
    return loss


# ==========================================================================
# Prefill / decode (serving)
# ==========================================================================


def prefill(params, cfg, tokens, frontend_embeds=None):
    """Returns (last-token logits [B,V], caches)."""
    hidden, caches = forward_hidden(params, cfg, tokens, frontend_embeds,
                                    want_cache=True)
    return logits_last(params, cfg, hidden), caches


def decode_step(params, cfg, token, caches, cache_len):
    """One decode step.  token [B,1] int32; cache_len: current length.

    Returns (logits [B,V] fp32, new caches).
    """
    x = embed(params["embed"], token, cfg.embed_scale)

    def period_fn(x, xs):
        pparams, pcache = xs
        new_caches = {}
        for p_idx in range(cfg.period):
            x, nc = apply_block_decode(
                pparams[str(p_idx)], x, cfg, cfg.layer_pattern[p_idx],
                cfg.mlp_pattern[p_idx], pcache[str(p_idx)], cache_len)
            new_caches[str(p_idx)] = nc
        return x, new_caches

    x, new_block_caches = _scan(
        period_fn, x, (params["blocks"], caches["blocks"]))

    new_rem = {}
    for i in range(cfg.n_remainder):
        x, nc = apply_block_decode(
            params["rem"][str(i)], x, cfg, cfg.layer_pattern[i],
            cfg.mlp_pattern[i], caches["rem"][str(i)], cache_len)
        new_rem[str(i)] = nc

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_last(params, cfg, x)
    return logits, {"blocks": new_block_caches, "rem": new_rem}


# ==========================================================================
# Cache allocation (for serving and for decode dry-run cells)
# ==========================================================================


def _block_cache_struct(cfg, mixer_kind, B, T):
    dt = _dtype(cfg)
    c = {}
    if mixer_kind == "ssm":
        spec = cfg.ssm
        ch = spec.d_inner(cfg.d_model) + 2 * spec.n_groups * spec.d_state
        H = spec.n_heads(cfg.d_model)
        c["conv"] = jnp.zeros((B, spec.d_conv - 1, ch), dt)
        c["ssd"] = jnp.zeros((B, spec.n_groups, H // spec.n_groups,
                              spec.head_dim, spec.d_state), F32)
    elif cfg.mla is not None:
        c["ckv"] = jnp.zeros((B, T, cfg.mla.kv_lora_rank), dt)
        c["krope"] = jnp.zeros((B, T, cfg.mla.qk_rope_head_dim), dt)
    else:
        c["k"] = jnp.zeros((B, T, cfg.n_kv_heads, cfg.d_head), dt)
        c["v"] = jnp.zeros((B, T, cfg.n_kv_heads, cfg.d_head), dt)
    if cfg.is_encdec:
        enc = cfg.encoder
        d_head = cfg.d_model // enc.n_heads
        c["xk"] = jnp.zeros((B, enc.source_len, enc.n_kv_heads, d_head), dt)
        c["xv"] = jnp.zeros((B, enc.source_len, enc.n_kv_heads, d_head), dt)
    return c


def init_cache(cfg, B: int, T: int):
    """Zero caches with capacity T (use under eval_shape for specs)."""
    blocks = {}
    for p_idx in range(cfg.period):
        kind = cfg.layer_pattern[p_idx]
        one = _block_cache_struct(cfg, kind, B, T)
        blocks[str(p_idx)] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape),
            one)
    rem = {str(i): _block_cache_struct(cfg, cfg.layer_pattern[i], B, T)
           for i in range(cfg.n_remainder)}
    return {"blocks": blocks, "rem": rem}

"""Sharding hints: model code stays mesh-agnostic.

``repro.launch.sharding`` installs a hint table (name -> PartitionSpec) for
the active mesh; model code calls :func:`shard_hint` at the few places where
GSPMD needs help (MoE dispatch buffers, block boundaries).  Outside a mesh
context the hints are no-ops, so tests/smoke runs on one CPU device are
unaffected.
"""
from __future__ import annotations

import contextlib
import threading

import jax

_state = threading.local()


def current_hints():
    return getattr(_state, "hints", None)


@contextlib.contextmanager
def hint_context(hints: dict, mesh=None):
    """hints: name -> PartitionSpec; with `mesh`, constraints bind to it."""
    prev = current_hints()
    _state.hints = (mesh, hints)
    try:
        yield
    finally:
        _state.hints = prev


def shard_hint(x, name: str):
    state = current_hints()
    if state is None:
        return x
    mesh, hints = state
    if not hints or name not in hints:
        return x
    spec = hints[name]
    # Trim the spec to the array rank (specs are written for full-rank views).
    if len(spec) > x.ndim:
        spec = jax.sharding.PartitionSpec(*tuple(spec)[: x.ndim])
    target = (jax.sharding.NamedSharding(mesh, spec) if mesh is not None
              else spec)
    return jax.lax.with_sharding_constraint(x, target)

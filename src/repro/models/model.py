"""Top-level model API: init / apply / counting, dispatched on ModelConfig."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer


def init_params(cfg, key, dtype=None):
    return transformer.init_params(cfg, key, dtype)


def init_params_shape(cfg, dtype=None):
    """Parameter ShapeDtypeStructs without allocating (for dry-run)."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(transformer.init_params, cfg,
                                            dtype=dtype), key)


forward_hidden = transformer.forward_hidden
lm_loss = transformer.lm_loss
prefill = transformer.prefill
decode_step = transformer.decode_step
init_cache = transformer.init_cache


def cache_struct(cfg, B: int, T: int):
    """ShapeDtypeStructs for a decode cache (for dry-run input specs)."""
    return jax.eval_shape(functools.partial(transformer.init_cache, cfg, B, T))


def count_params(cfg) -> int:
    tree = init_params_shape(cfg)
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def _moe_block_count(cfg) -> int:
    n = cfg.n_periods * sum(1 for m in cfg.mlp_pattern if m == "moe")
    n += sum(1 for m in cfg.mlp_pattern[: cfg.n_remainder] if m == "moe")
    return n


def count_params_analytic(cfg, active_only: bool = False) -> int:
    """Total params; with active_only, MoE experts count only top_k/E."""
    total = count_params(cfg)
    if not active_only or cfg.moe is None:
        return total
    spec = cfg.moe
    per_block_expert = 3 * cfg.d_model * spec.d_ff_expert  # w1,w3,w2
    if cfg.act != "swiglu":
        per_block_expert = 2 * cfg.d_model * spec.d_ff_expert
    n_moe = _moe_block_count(cfg)
    inactive = n_moe * (spec.n_experts - spec.top_k) * per_block_expert
    return total - inactive


def model_flops(cfg, n_tokens: int, *, training: bool) -> float:
    """MODEL_FLOPS: 6·N·D (train) or 2·N·D (inference), N = active params."""
    n = count_params_analytic(cfg, active_only=True)
    # embeddings participate once per token; keep the standard 6ND convention
    return (6.0 if training else 2.0) * n * n_tokens

"""Attention mixers: GQA (global / sliding-window), QKV-bias, MLA.

The core primitive is :func:`chunked_attention` — a ``lax.scan`` over query
chunks so the score tensor never exceeds ``[B, Hkv, G, chunk, Skv]``.  This is
"flash attention at the HLO level": exact softmax per chunk, bounded memory,
and the same loop structure the Pallas kernel (repro.kernels.flash_attention)
implements per-block in VMEM on TPU.

Local (sliding-window) layers have two code paths:
  * masked   — full-length scores with a band mask (baseline; wastes FLOPs)
  * banded   — per-chunk KV slice of width (chunk + window) (optimized; exact
               for window <= attn_window).  Selected by ``banded=True``;
               this is one of the §Perf hillclimb levers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope_bshd, rmsnorm, truncated_normal
from repro.models.scan_util import scan as _scan

F32 = jnp.float32
NEG_INF = -1e30


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------


def init_attention(key, d_model, n_heads, n_kv_heads, d_head, qkv_bias=False,
                   dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    std = d_model ** -0.5
    std_o = (n_heads * d_head) ** -0.5
    p = {
        "wq": truncated_normal(ks[0], (d_model, n_heads * d_head), std, dtype),
        "wk": truncated_normal(ks[1], (d_model, n_kv_heads * d_head), std, dtype),
        "wv": truncated_normal(ks[2], (d_model, n_kv_heads * d_head), std, dtype),
        "wo": truncated_normal(ks[3], (n_heads * d_head, d_model), std_o, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * d_head,), dtype)
    return p


def init_mla(key, d_model, n_heads, spec, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    std = d_model ** -0.5
    qk = spec.qk_head_dim
    p = {
        "wq_a": truncated_normal(ks[0], (d_model, spec.q_lora_rank), std, dtype),
        "q_norm": jnp.ones((spec.q_lora_rank,), dtype),
        "wq_b": truncated_normal(
            ks[1], (spec.q_lora_rank, n_heads * qk), spec.q_lora_rank ** -0.5, dtype),
        "wkv_a": truncated_normal(
            ks[2], (d_model, spec.kv_lora_rank + spec.qk_rope_head_dim), std, dtype),
        "kv_norm": jnp.ones((spec.kv_lora_rank,), dtype),
        "wkv_b": truncated_normal(
            ks[3], (spec.kv_lora_rank,
                    n_heads * (spec.qk_nope_head_dim + spec.v_head_dim)),
            spec.kv_lora_rank ** -0.5, dtype),
        "wo": truncated_normal(
            ks[4], (n_heads * spec.v_head_dim, d_model),
            (n_heads * spec.v_head_dim) ** -0.5, dtype),
    }
    return p


# --------------------------------------------------------------------------
# Core chunked attention
# --------------------------------------------------------------------------


def _attend_block(qc, k, v, q_pos, kv_pos, *, causal, window, kv_valid_len,
                  softcap, scale):
    """qc [B,C,Hk,G,D]; k,v [B,T,Hk,D]; q_pos [C] or [B,C]; kv_pos [T];
    kv_valid_len scalar or [B].  Returns [B,C,Hk,G,Dv]."""
    scores = jnp.einsum("bchgd,bthd->bhgct", qc, k,
                        preferred_element_type=F32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = jnp.asarray(q_pos)
    if q_pos.ndim == 1:
        q_pos = q_pos[None]                       # [1, C]
    mask = (kv_pos >= 0)[None, None, :]           # banded path pads kv_pos<0
    mask = jnp.broadcast_to(mask,
                            (q_pos.shape[0], q_pos.shape[1], kv_pos.shape[0]))
    if causal:
        mask &= kv_pos[None, None, :] <= q_pos[:, :, None]
    if window is not None:
        mask &= (q_pos[:, :, None] - kv_pos[None, None, :]) < window
    if kv_valid_len is not None:
        kvl = jnp.asarray(kv_valid_len)
        if kvl.ndim == 0:
            kvl = kvl[None]
        mask &= kv_pos[None, None, :] < kvl[:, None, None]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgct,bthd->bchgd", weights, v)


def chunked_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                      kv_valid_len=None, softcap=None, chunk=1024,
                      banded=False):
    """q [B,Sq,H,D]; k,v [B,Skv,Hkv,D] -> [B,Sq,H,D].

    ``q_offset``: position of q[0] within the kv sequence (decode: cache_len).
    ``kv_valid_len``: positions >= this are masked (ragged decode caches).
    ``banded``: for windowed layers, slice KV to the band instead of masking.
    """
    B, Sq, H, D = q.shape
    Hk = k.shape[2]
    G = H // Hk
    Dv = v.shape[-1]  # MLA: value head dim != qk head dim
    scale = D ** -0.5
    qg = q.reshape(B, Sq, Hk, G, D)
    Skv = k.shape[1]

    q_off = jnp.asarray(q_offset)
    if Sq <= chunk:
        q_pos = (q_off[:, None] + jnp.arange(Sq) if q_off.ndim == 1
                 else q_off + jnp.arange(Sq))
        kv_pos = jnp.arange(Skv)
        out = _attend_block(qg, k, v, q_pos, kv_pos, causal=causal,
                            window=window, kv_valid_len=kv_valid_len,
                            softcap=softcap, scale=scale)
        return out.reshape(B, Sq, H, Dv)

    pad = (-Sq) % chunk
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    nq = qg.shape[1] // chunk
    q_chunks = jnp.moveaxis(qg.reshape(B, nq, chunk, Hk, G, D), 1, 0)

    use_band = banded and window is not None and not (
        kv_valid_len is not None)
    if use_band:
        # Band width: a q chunk at offset c attends to kv in
        # [c - window + 1, c + chunk); slice width W = chunk + window rounded
        # to a multiple of chunk for static shapes.
        Wb = chunk + ((window + chunk - 1) // chunk) * chunk
        k_pad = jnp.pad(k, ((0, 0), (Wb - chunk, pad), (0, 0), (0, 0)))
        v_pad = jnp.pad(v, ((0, 0), (Wb - chunk, pad), (0, 0), (0, 0)))

        def body(_, inp):
            i, qc = inp
            start = i * chunk  # start of band in padded kv coords
            kc = jax.lax.dynamic_slice_in_dim(k_pad, start, Wb, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v_pad, start, Wb, axis=1)
            q_pos = q_offset + i * chunk + jnp.arange(chunk)
            # padded kv position of band element j is start + j - (Wb - chunk)
            kv_pos = start + jnp.arange(Wb) - (Wb - chunk)
            out = _attend_block(qc, kc, vc, q_pos, kv_pos, causal=causal,
                                window=window, kv_valid_len=None,
                                softcap=softcap, scale=scale)
            # kv_pos < 0 entries are padding; they are masked by the window
            # term only if window <= Wb-chunk; enforce via explicit mask:
            return None, out
    else:
        kv_pos_full = jnp.arange(Skv)

        def body(_, inp):
            i, qc = inp
            q_pos = q_offset + i * chunk + jnp.arange(chunk)
            out = _attend_block(qc, k, v, q_pos, kv_pos_full, causal=causal,
                                window=window, kv_valid_len=kv_valid_len,
                                softcap=softcap, scale=scale)
            return None, out

    _, outs = _scan(body, None, (jnp.arange(nq), q_chunks))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * chunk, Hk, G, Dv)
    if pad:
        out = out[:, :Sq]
    return out.reshape(B, Sq, H, Dv)


# --------------------------------------------------------------------------
# GQA mixer (train/prefill and decode)
# --------------------------------------------------------------------------


def gqa_project_qkv(params, x, n_heads, n_kv_heads, d_head):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (q.reshape(B, S, n_heads, d_head),
            k.reshape(B, S, n_kv_heads, d_head),
            v.reshape(B, S, n_kv_heads, d_head))


def _flash_applicable(cfg, local: bool, S: int) -> bool:
    from repro.models.perf_flags import current as _perf

    if not _perf().flash_kernel or local or cfg.attn_logit_softcap:
        return False
    block = min(128, S)
    return S % block == 0


def gqa_attention(params, x, cfg, *, local: bool, positions, chunk=None,
                  banded=False):
    """Full-sequence (train / prefill) GQA attention. x [B,S,D] -> [B,S,D]."""
    q, k, v = gqa_project_qkv(params, x, cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    theta = cfg.rope_theta_local if (local and cfg.rope_theta_local) \
        else cfg.rope_theta
    q = apply_rope_bshd(q, positions, theta)
    k = apply_rope_bshd(k, positions, theta)
    window = cfg.attn_window if local else None
    B, S, _, _ = q.shape
    if _flash_applicable(cfg, local, S):
        from repro.kernels.ops import flash_attention_bshd

        block = min(128, S)
        out = flash_attention_bshd(q, k, v, causal=True, block_q=block,
                                   block_k=block)
    else:
        out = chunked_attention(
            q, k, v, causal=True, window=window,
            softcap=cfg.attn_logit_softcap, chunk=chunk or cfg.attn_chunk,
            banded=banded)
    return out.reshape(B, S, -1) @ params["wo"], (k, v)


def _cache_write(cache, new, cache_len):
    """Write new [B,1,...] at position cache_len (scalar or per-row [B])."""
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), cache_len, axis=1)
    return jax.vmap(
        lambda c, n, l: jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), l, axis=0))(cache, new, cache_len)


def _decode_positions(cache_len):
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        return jnp.full((1,), cache_len, dtype=jnp.int32)      # [S=1]
    return cache_len[:, None].astype(jnp.int32)                # [B,1]


def gqa_decode(params, x, cfg, cache_k, cache_v, cache_len, *, local: bool):
    """Single-token decode. x [B,1,D]; cache_[kv] [B,T,Hk,D] -> out, caches.

    ``cache_len`` is a scalar (synchronous batch) or per-row [B] vector
    (continuous batching with ragged slot lengths)."""
    q, k, v = gqa_project_qkv(params, x, cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    theta = cfg.rope_theta_local if (local and cfg.rope_theta_local) \
        else cfg.rope_theta
    pos = _decode_positions(cache_len)
    q = apply_rope_bshd(q, pos, theta)
    k = apply_rope_bshd(k, pos, theta)
    cache_k = _cache_write(cache_k, k, cache_len)
    cache_v = _cache_write(cache_v, v, cache_len)
    window = cfg.attn_window if local else None
    out = chunked_attention(
        q, cache_k, cache_v, causal=True, window=window, q_offset=cache_len,
        kv_valid_len=jnp.asarray(cache_len) + 1,
        softcap=cfg.attn_logit_softcap)
    B = x.shape[0]
    return out.reshape(B, 1, -1) @ params["wo"], cache_k, cache_v


# --------------------------------------------------------------------------
# Cross attention (enc-dec)
# --------------------------------------------------------------------------


def cross_attention(params, x, enc_k, enc_v, cfg):
    """x [B,S,D] attends (non-causal) over precomputed encoder K/V."""
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
    out = chunked_attention(q, enc_k, enc_v, causal=False, chunk=cfg.attn_chunk)
    return out.reshape(B, S, -1) @ params["wo"]


def cross_kv(params, enc_out, n_kv_heads, d_head):
    B, S, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(B, S, n_kv_heads, d_head)
    v = (enc_out @ params["wv"]).reshape(B, S, n_kv_heads, d_head)
    return k, v


# --------------------------------------------------------------------------
# MLA (multi-head latent attention)
# --------------------------------------------------------------------------


def _mla_qkv_full(params, x, cfg):
    """Naive MLA path (train/prefill): materialize per-head K and V."""
    spec = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rmsnorm({"scale": params["q_norm"]}, x @ params["wq_a"], cfg.norm_eps)
    q = (cq @ params["wq_b"]).reshape(B, S, H, spec.qk_head_dim)
    q_nope, q_rope = jnp.split(q, [spec.qk_nope_head_dim], axis=-1)

    ckv_full = x @ params["wkv_a"]
    ckv, k_rope = jnp.split(ckv_full, [spec.kv_lora_rank], axis=-1)
    ckv = rmsnorm({"scale": params["kv_norm"]}, ckv, cfg.norm_eps)
    kv = (ckv @ params["wkv_b"]).reshape(
        B, S, H, spec.qk_nope_head_dim + spec.v_head_dim)
    k_nope, v = jnp.split(kv, [spec.qk_nope_head_dim], axis=-1)
    return q_nope, q_rope, k_nope, k_rope[:, :, None, :], v, ckv


def mla_attention(params, x, cfg, *, positions):
    """MLA for train/prefill. Returns (out, (ckv, k_rope)) for the cache."""
    spec = cfg.mla
    B, S, _ = x.shape
    q_nope, q_rope, k_nope, k_rope, v, ckv = _mla_qkv_full(params, x, cfg)
    q_rope = apply_rope_bshd(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope_bshd(k_rope, positions, cfg.rope_theta)  # [B,S,1,r]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (spec.qk_rope_head_dim,))],
        axis=-1)
    out = chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    out = out.reshape(B, S, -1) @ params["wo"]
    return out, (ckv, k_rope[:, :, 0, :])


def mla_decode(params, x, cfg, cache_ckv, cache_krope, cache_len):
    """Absorbed MLA decode: attend in the latent space (DeepSeek-V2 trick).

    cache_ckv [B,T,rank]; cache_krope [B,T,rope_dim].
    """
    spec = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    cq = rmsnorm({"scale": params["q_norm"]}, x @ params["wq_a"], cfg.norm_eps)
    q = (cq @ params["wq_b"]).reshape(B, 1, H, spec.qk_head_dim)
    q_nope, q_rope = jnp.split(q, [spec.qk_nope_head_dim], axis=-1)
    pos = _decode_positions(cache_len)
    q_rope = apply_rope_bshd(q_rope, pos, cfg.rope_theta)

    ckv_full = x @ params["wkv_a"]
    ckv_new, krope_new = jnp.split(ckv_full, [spec.kv_lora_rank], axis=-1)
    ckv_new = rmsnorm({"scale": params["kv_norm"]}, ckv_new, cfg.norm_eps)
    krope_new = apply_rope_bshd(krope_new[:, :, None, :], pos,
                                cfg.rope_theta)[:, :, 0, :]
    cache_ckv = _cache_write(cache_ckv, ckv_new, cache_len)
    cache_krope = _cache_write(cache_krope, krope_new, cache_len)

    # Absorb W_uk into q: wkv_b [rank, H*(nope+v)]
    wkv_b = params["wkv_b"].reshape(
        spec.kv_lora_rank, H, spec.qk_nope_head_dim + spec.v_head_dim)
    w_uk = wkv_b[:, :, : spec.qk_nope_head_dim]   # [rank, H, nope]
    w_uv = wkv_b[:, :, spec.qk_nope_head_dim:]    # [rank, H, v]
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)

    scale = spec.qk_head_dim ** -0.5
    scores = (jnp.einsum("bqhr,btr->bhqt", q_lat, cache_ckv,
                         preferred_element_type=F32)
              + jnp.einsum("bqhe,bte->bhqt", q_rope, cache_krope,
                           preferred_element_type=F32)) * scale
    kv_pos = jnp.arange(cache_ckv.shape[1])
    cl = jnp.asarray(cache_len)
    if cl.ndim == 0:
        valid = (kv_pos <= cl)[None, None, None, :]
    else:
        valid = (kv_pos[None, :] <= cl[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1).astype(cache_ckv.dtype)
    out_lat = jnp.einsum("bhqt,btr->bqhr", weights, cache_ckv)
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat, w_uv)
    out = out.reshape(B, 1, -1) @ params["wo"]
    return out, cache_ckv, cache_krope

"""Mamba-2 SSD (state-space duality) mixer.

Implements the chunked SSD algorithm [arXiv:2405.21060]: within a chunk the
quadratic "attention-like" form, across chunks a linear state recurrence
(``lax.scan``).  Decode is the O(1) recurrent step.  All decay math is fp32.

Shapes (grouped heads): x [B,S,H,P], dt [B,S,H], A [H], B/C [B,S,G,N] with
H = G * HG heads per group.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import truncated_normal
from repro.models.scan_util import scan as _scan

F32 = jnp.float32


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def init_mamba2(key, d_model: int, spec, dtype=jnp.float32):
    d_in = spec.d_inner(d_model)
    H = spec.n_heads(d_model)
    G, N, K = spec.n_groups, spec.d_state, spec.d_conv
    conv_ch = d_in + 2 * G * N
    d_proj = 2 * d_in + 2 * G * N + H
    ks = jax.random.split(key, 4)
    std = d_model ** -0.5
    return {
        "in_proj": truncated_normal(ks[0], (d_model, d_proj), std, dtype),
        "conv_w": truncated_normal(ks[1], (K, conv_ch), K ** -0.5, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=F32)),
        "D": jnp.ones((H,), F32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, F32))),  # softplus^-1
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": truncated_normal(ks[2], (d_in, d_model), d_in ** -0.5, dtype),
    }


# --------------------------------------------------------------------------
# SSD core
# --------------------------------------------------------------------------


def _segsum(x):
    """x [..., L] -> lower-triangular pairwise cumulative sums [..., L, L]."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(L)
    return jnp.where(idx[:, None] >= idx[None, :], diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, *, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x [b,s,h,p]; dt [b,s,h] (>0, fp32); A [h] (<0, fp32); B,C [b,s,g,n].
    Returns (y [b,s,h,p], final_state [b,g,hg,p,n]).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    l = chunk

    # chunked views; heads arranged as (g, hg)
    xc = x.reshape(b, nc, l, g, hg, p)
    dtc = dt.reshape(b, nc, l, g, hg).astype(F32)
    Bc = B.reshape(b, nc, l, g, n)
    Cc = C.reshape(b, nc, l, g, n)
    Ah = A.reshape(g, hg).astype(F32)

    dtA = dtc * Ah[None, None, None]                       # [b,nc,l,g,hg]
    dtA_t = jnp.moveaxis(dtA, 2, -1)                       # [b,nc,g,hg,l]
    Lmat = jnp.exp(_segsum(dtA_t))                         # [b,nc,g,hg,l,l]
    xdt = xc * dtc[..., None]                              # x * dt

    # Intra-chunk (quadratic within chunk)
    y_diag = jnp.einsum("bclgn,bcsgn,bcghls,bcsghp->bclghp",
                        Cc, Bc, Lmat, xdt, preferred_element_type=F32)

    # Per-chunk final states
    A_cum = jnp.cumsum(dtA, axis=2)                        # [b,nc,l,g,hg]
    A_last = A_cum[:, :, -1]                               # [b,nc,g,hg]
    decay_to_end = jnp.exp(A_last[:, :, None] - A_cum)     # [b,nc,l,g,hg]
    chunk_states = jnp.einsum("bclgn,bclgh,bclghp->bcghpn",
                              Bc, decay_to_end, xdt,
                              preferred_element_type=F32)

    # Inter-chunk recurrence
    if initial_state is None:
        init = jnp.zeros((b, g, hg, p, n), F32)
    else:
        init = initial_state.astype(F32)
    chunk_decay = jnp.exp(A_last)                          # [b,nc,g,hg]

    def step(state, inp):
        dec, new = inp                                     # [b,g,hg], [b,g,hg,p,n]
        prev = state
        state = state * dec[..., None, None] + new
        return state, prev

    final_state, prev_states = _scan(
        step, init, (jnp.moveaxis(chunk_decay, 1, 0),
                     jnp.moveaxis(chunk_states, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # [b,nc,g,hg,p,n]

    # Inter-chunk contribution
    state_decay = jnp.exp(A_cum)                           # [b,nc,l,g,hg]
    y_off = jnp.einsum("bclgn,bcghpn,bclgh->bclghp",
                       Cc, prev_states, state_decay,
                       preferred_element_type=F32)

    y = (y_diag + y_off).reshape(b, sp, h, p)
    if pad:
        y = y[:, :s]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt, A, B, C):
    """One-token recurrence. state [b,g,hg,p,n]; x [b,h,p]; dt [b,h];
    B,C [b,g,n].  Returns (y [b,h,p], new_state)."""
    b, h, p = x.shape
    g = B.shape[1]
    hg = h // g
    xg = x.reshape(b, g, hg, p)
    dtg = dt.reshape(b, g, hg).astype(F32)
    Ag = A.reshape(g, hg).astype(F32)
    decay = jnp.exp(dtg * Ag[None])                        # [b,g,hg]
    add = jnp.einsum("bgn,bghp,bgh->bghpn", B, xg, dtg,
                     preferred_element_type=F32)
    state = state.astype(F32) * decay[..., None, None] + add
    y = jnp.einsum("bgn,bghpn->bghp", C, state,
                   preferred_element_type=F32)
    return y.reshape(b, h, p).astype(x.dtype), state


# --------------------------------------------------------------------------
# Depthwise causal conv
# --------------------------------------------------------------------------


def causal_conv(x, w, b):
    """x [B,S,C]; w [K,C]; depthwise causal conv + bias."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return y + b


def conv_decode_step(conv_state, x_new, w, b):
    """conv_state [B,K-1,C]; x_new [B,C] -> (y [B,C], new_state)."""
    K = w.shape[0]
    full = jnp.concatenate([conv_state, x_new[:, None]], axis=1)  # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", full, w) + b
    return y, full[:, 1:]


# --------------------------------------------------------------------------
# Mamba-2 block (mixer)
# --------------------------------------------------------------------------


def _gated_norm(scale, y, z, eps):
    """RMSNorm(y * silu(z)) — Mamba-2 gated norm."""
    h = y.astype(F32) * jax.nn.silu(z.astype(F32))
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps) * scale.astype(F32)).astype(y.dtype)


def _split_proj(proj, d_in, G, N, H):
    z = proj[..., :d_in]
    xBC = proj[..., d_in : 2 * d_in + 2 * G * N]
    dt_raw = proj[..., 2 * d_in + 2 * G * N :]
    return z, xBC, dt_raw


def mamba2_forward(params, x, cfg, *, initial_state=None):
    """Full-sequence Mamba-2 mixer.  x [B,S,D] -> (y, (conv_tail, ssd_state))."""
    spec = cfg.ssm
    d_in = spec.d_inner(cfg.d_model)
    H = spec.n_heads(cfg.d_model)
    G, N, K, P = spec.n_groups, spec.d_state, spec.d_conv, spec.head_dim
    Bsz, S, _ = x.shape

    proj = x @ params["in_proj"]
    z, xBC, dt_raw = _split_proj(proj, d_in, G, N, H)
    conv_tail = xBC[:, max(S - (K - 1), 0):]
    if S < K - 1:  # (never in practice; guard for tiny smoke shapes)
        conv_tail = jnp.pad(xBC, ((0, 0), (K - 1 - S, 0), (0, 0)))
    xBC = jax.nn.silu(causal_conv(xBC, params["conv_w"], params["conv_b"]))
    xs = xBC[..., :d_in].reshape(Bsz, S, H, P)
    Bmat = xBC[..., d_in : d_in + G * N].reshape(Bsz, S, G, N)
    Cmat = xBC[..., d_in + G * N :].reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(F32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y, final_state = ssd_chunked(xs, dt, A, Bmat, Cmat, chunk=spec.chunk,
                                 initial_state=initial_state)
    y = y + xs * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, d_in)
    y = _gated_norm(params["norm"], y, z, cfg.norm_eps)
    return y @ params["out_proj"], (conv_tail, final_state)


def mamba2_decode(params, x, cfg, conv_state, ssd_state):
    """One-token Mamba-2 step.  x [B,1,D] -> (y [B,1,D], new states)."""
    spec = cfg.ssm
    d_in = spec.d_inner(cfg.d_model)
    H = spec.n_heads(cfg.d_model)
    G, N, P = spec.n_groups, spec.d_state, spec.head_dim
    Bsz = x.shape[0]

    proj = (x[:, 0] @ params["in_proj"])
    z, xBC, dt_raw = _split_proj(proj, d_in, G, N, H)
    xBC_c, conv_state = conv_decode_step(conv_state, xBC, params["conv_w"],
                                         params["conv_b"])
    xBC_c = jax.nn.silu(xBC_c)
    xs = xBC_c[..., :d_in].reshape(Bsz, H, P)
    Bmat = xBC_c[..., d_in : d_in + G * N].reshape(Bsz, G, N)
    Cmat = xBC_c[..., d_in + G * N :].reshape(Bsz, G, N)
    dt = jax.nn.softplus(dt_raw.astype(F32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y, ssd_state = ssd_decode_step(ssd_state, xs, dt, A, Bmat, Cmat)
    y = y + xs * params["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(Bsz, d_in)
    y = _gated_norm(params["norm"], y[:, None], z[:, None], cfg.norm_eps)[:, 0]
    return (y @ params["out_proj"])[:, None], conv_state, ssd_state


def ssd_reference(x, dt, A, B, C, *, initial_state=None):
    """O(S^2)-free *sequential* oracle for tests: plain per-step recurrence."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    state = (jnp.zeros((b, g, h // g, p, n), F32) if initial_state is None
             else initial_state.astype(F32))

    def step(state, inp):
        xt, dtt, Bt, Ct = inp
        y, state = ssd_decode_step(state, xt, dtt, A, Bt, Ct)
        return state, y

    state, ys = _scan(
        step, state,
        (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), state

"""Shared layer primitives: norms, RoPE, MLPs, embeddings, initializers.

All modules are functional: ``init_*`` returns a pytree of arrays and
``apply``-style functions take ``(params, x, ...)``.  Compute dtype follows
the input; statistics (norm variance, softmax) accumulate in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


def truncated_normal(key, shape, std, dtype=jnp.float32):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    xf = x.astype(F32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(F32)).astype(dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_sincos(positions, dim: int, theta: float):
    """positions [...,] int -> (sin, cos) each [..., dim/2] float32."""
    assert dim % 2 == 0
    inv_freq = 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    angles = positions.astype(F32)[..., None] * inv_freq  # [..., dim/2]
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope_bshd(x, positions, theta: float):
    """Apply RoPE to x [B, S, H, D] at integer positions [S] or [B, S]."""
    sin, cos = rope_sincos(positions, x.shape[-1], theta)  # [(B,)S, D/2]
    dtype = x.dtype
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2].astype(F32), x[..., d2:].astype(F32)
    if sin.ndim == 2:        # positions [S]
        sin, cos = sin[None, :, None, :], cos[None, :, None, :]
    else:                    # positions [B, S]
        sin, cos = sin[:, :, None, :], cos[:, :, None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(dtype)


# --------------------------------------------------------------------------
# MLP (dense FFN)
# --------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    std_in = d_model ** -0.5
    std_out = d_ff ** -0.5
    p = {
        "w1": truncated_normal(k1, (d_model, d_ff), std_in, dtype),
        "w2": truncated_normal(k2, (d_ff, d_model), std_out, dtype),
    }
    if act in ("swiglu", "geglu"):
        p["w3"] = truncated_normal(k3, (d_model, d_ff), std_in, dtype)
    return p


def mlp(params, x, act: str):
    h = x @ params["w1"]
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ params["w3"])
    elif act == "geglu":
        h = jax.nn.gelu(h) * (x @ params["w3"])
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return h @ params["w2"]


# --------------------------------------------------------------------------
# Embedding / logits
# --------------------------------------------------------------------------


def init_embed(key, vocab: int, d_model: int, dtype=jnp.float32):
    # d^-0.5 keeps tied-head logits O(1); gemma-style embed_scale=sqrt(d)
    # restores unit per-dim RMS on the residual stream.
    return truncated_normal(key, (vocab, d_model), d_model ** -0.5, dtype)


def embed(table, tokens, scale: float = 1.0):
    x = jnp.take(table, tokens, axis=0)
    if scale != 1.0:
        x = (x.astype(F32) * scale).astype(x.dtype)
    return x


def logits_from_hidden(x, table_or_head, transpose: bool):
    """x [B,S,D] @ head; transpose=True when using the tied embedding table."""
    w = table_or_head
    if transpose:
        return jnp.einsum("bsd,vd->bsv", x, w)
    return jnp.einsum("bsd,dv->bsv", x, w)

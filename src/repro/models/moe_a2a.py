"""Expert-parallel MoE with explicit all-to-all (shard_map) — §Perf lever.

Why: under GSPMD, the sort/scatter combine gathers rows from the
expert-sharded capacity buffer ``[E -> model, C, d]``; the partitioner
lowers that cross-shard gather to token-buffer-sized all-reduce /
all-gather pairs per layer (measured: ~17 GB/layer/device for
qwen3-moe train_4k — the dominant collective of the whole step).

Fix (MegaBlocks/DeepSpeed-MoE schedule, TPU-native): shard tokens over the
model axis too, route locally, and move *only the routed token rows* to the
shard that owns their expert with ``lax.all_to_all``, compute the expert
GEMMs locally, and all-to-all the outputs back.  Comm per device per layer
drops to ~2 * T_local * k * d bytes (~134 MB for qwen3) instead of ~17 GB.

Semantics: capacity-dropped tokens (two capacity stages: per-destination
send buffers and per-expert receive buffers) contribute zero, matching the
GSPMD path's capacity semantics.  With ample capacity the result equals
``moe_ffn_dense_reference`` (subprocess-tested on an 8-device host mesh).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.moe import _route, capacity

F32 = jnp.float32


def _sortable_dispatch(ids, n_buckets: int, cap: int):
    """Bucket row indices by `ids` (invalid = negative -> dropped).

    Returns (bucket, pos, order) so rows can be scattered into
    ``[n_buckets, cap, ...]`` buffers with mode='drop'.
    """
    n = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    ids_sorted = ids[order]
    valid = ids_sorted >= 0
    safe = jnp.where(valid, ids_sorted, 0)
    counts = jnp.zeros((n_buckets,), jnp.int32).at[safe].add(
        valid.astype(jnp.int32))
    starts = jnp.cumsum(counts) - counts
    # invalid ids sort first; valid entry j's bucket-relative position is its
    # sorted index minus the invalid prefix minus its bucket's start offset
    n_invalid = jnp.sum(~valid).astype(jnp.int32)
    pos = jnp.arange(n, dtype=jnp.int32) - n_invalid - starts[safe]
    pos = jnp.where(valid, pos, cap)  # out of bounds -> dropped
    return ids_sorted, pos, order


def _moe_block(x_blk, router, w1, w3, w2, *, spec, act, tp_size, e_loc,
               axis_name):
    """Per-device block under shard_map.

    x_blk [B_loc, S_loc, d]; router [d, E]; w1/w3 [E_loc, d, f];
    w2 [E_loc, f, d].
    """
    B_loc, S_loc, d = x_blk.shape
    T = B_loc * S_loc
    k = spec.top_k
    E = spec.n_experts
    xf = x_blk.reshape(T, d)
    shard = jax.lax.axis_index(axis_name)

    # ---- local routing ---------------------------------------------------
    logits = xf.astype(F32) @ router.astype(F32)          # [T, E]
    weights, idx = _route(logits, spec)                   # [T, k]
    e_flat = idx.reshape(-1)                              # [T*k]
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    w_flat = weights.reshape(-1)

    # ---- pack per destination shard --------------------------------------
    cs = max(1, math.ceil(T * k * spec.capacity_factor / tp_size))
    dest = e_flat // e_loc
    dest_sorted, pos, order = _sortable_dispatch(dest, tp_size, cs)
    send_x = jnp.zeros((tp_size, cs, d), x_blk.dtype)
    send_e = jnp.full((tp_size, cs), -1, jnp.int32)
    send_x = send_x.at[dest_sorted, pos].set(xf[t_flat[order]], mode="drop")
    send_e = send_e.at[dest_sorted, pos].set(e_flat[order], mode="drop")

    # ---- all-to-all: rows travel to their expert's shard ------------------
    recv_x = jax.lax.all_to_all(send_x, axis_name, 0, 0, tiled=True)
    recv_e = jax.lax.all_to_all(send_e, axis_name, 0, 0, tiled=True)

    # ---- local dispatch to experts ----------------------------------------
    n_recv = tp_size * cs
    rx = recv_x.reshape(n_recv, d)
    re = recv_e.reshape(n_recv)
    le = jnp.where(re >= 0, re - shard * e_loc, -1)       # local expert id
    c2 = max(1, math.ceil(n_recv / e_loc))
    le_sorted, pos2, order2 = _sortable_dispatch(le, e_loc, c2)
    buf = jnp.zeros((e_loc, c2, d), x_blk.dtype)
    buf = buf.at[le_sorted, pos2].set(rx[order2], mode="drop")

    # ---- expert FFN --------------------------------------------------------
    h1 = jnp.einsum("ecd,edf->ecf", buf, w1)
    if act == "swiglu":
        h = jax.nn.silu(h1) * jnp.einsum("ecd,edf->ecf", buf, w3)
    elif act == "geglu":
        h = jax.nn.gelu(h1) * jnp.einsum("ecd,edf->ecf", buf, w3)
    else:
        h = jax.nn.gelu(h1)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w2)

    # ---- local combine back into recv slot order --------------------------
    keep2 = (pos2 < c2) & (le_sorted >= 0)
    rows2 = out_buf[jnp.clip(le_sorted, 0, e_loc - 1),
                    jnp.clip(pos2, 0, c2 - 1)]
    rows2 = rows2 * keep2[:, None].astype(rows2.dtype)
    back = jnp.zeros((n_recv, d), x_blk.dtype).at[order2].set(rows2)
    back = back.reshape(tp_size, cs, d)

    # ---- all-to-all return trip + weighted combine ------------------------
    ret = jax.lax.all_to_all(back, axis_name, 0, 0, tiled=True)
    keep = pos < cs
    rows = ret[jnp.clip(dest_sorted, 0, tp_size - 1), jnp.clip(pos, 0, cs - 1)]
    scale = jnp.where(keep, w_flat[order], 0.0).astype(rows.dtype)
    rows = rows * scale[:, None]
    y = jnp.zeros((T, d), x_blk.dtype).at[t_flat[order]].add(rows)
    return y.reshape(B_loc, S_loc, d)


def moe_ffn_a2a(params, x, spec, act, mesh, *, fsdp_axes, tp_axis="model"):
    """x [B, S, d] -> [B, S, d] with explicit expert-parallel all-to-all.

    Requires S % tp == 0, E % tp == 0, B % fsdp == 0; the caller falls back
    to the GSPMD path otherwise.
    """
    from jax.experimental.shard_map import shard_map

    tp_size = mesh.shape[tp_axis]
    e_loc = spec.n_experts // tp_size
    blk = partial(_moe_block, spec=spec, act=act, tp_size=tp_size,
                  e_loc=e_loc, axis_name=tp_axis)
    fn = shard_map(
        blk, mesh=mesh,
        in_specs=(P(fsdp_axes, tp_axis, None),   # x: tokens over fsdp x tp
                  P(None, None),                 # router (replicated)
                  P(tp_axis, None, None),        # w1 [E->tp, d, f]
                  P(tp_axis, None, None),        # w3
                  P(tp_axis, None, None)),       # w2
        out_specs=P(fsdp_axes, tp_axis, None),
        check_rep=False)
    return fn(x, params["router"].astype(x.dtype), params["w1"],
              params["w3"], params["w2"])


def a2a_applicable(x_shape, spec, mesh, tp_axis="model") -> bool:
    if mesh is None:
        return False
    tp = mesh.shape.get(tp_axis, 1) if hasattr(mesh.shape, "get") else \
        dict(mesh.shape).get(tp_axis, 1)
    if tp <= 1:
        return False
    B, S, _ = x_shape
    return (S % tp == 0 and spec.n_experts % tp == 0
            and spec.n_experts >= tp)

"""Mixture-of-experts FFN with sort/scatter dispatch.

Design notes (TPU adaptation):
  * No ``[T, E, C]`` one-hot dispatch einsum (GShard style) — at 1M tokens,
    128 experts and capacity ~5k that tensor is ~10^13 elements.  Instead we
    argsort token-expert assignments and *scatter* rows into per-expert
    capacity buffers ``[E, C, d]``, then run a grouped einsum over experts.
  * Tokens are processed in groups (leading ``G`` axis) so the dispatch is
    local to a data shard; the ``[G, E, C, d]`` buffer carries a sharding
    hint (G -> data, E -> model) so GSPMD lowers expert parallelism to an
    all-to-all instead of replicating expert weights.
  * Over-capacity tokens are dropped (standard capacity-factor semantics);
    with a large enough factor the output equals the dense reference
    (property-tested in tests/test_moe.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import truncated_normal
from repro.models.sharding_hints import shard_hint

F32 = jnp.float32


def init_moe(key, d_model: int, spec, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    E, f = spec.n_experts, spec.d_ff_expert
    std_in = d_model ** -0.5
    return {
        "router": truncated_normal(ks[0], (d_model, E), std_in, F32),
        "w1": truncated_normal(ks[1], (E, d_model, f), std_in, dtype),
        "w3": truncated_normal(ks[2], (E, d_model, f), std_in, dtype),
        "w2": truncated_normal(ks[3], (E, f, d_model), f ** -0.5, dtype),
    }


def _route(logits, spec):
    """logits [T, E] fp32 -> (weights [T,k], idx [T,k])."""
    if spec.norm_topk_prob:
        vals, idx = jax.lax.top_k(logits, spec.top_k)
        weights = jax.nn.softmax(vals, axis=-1)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        weights, idx = jax.lax.top_k(probs, spec.top_k)
    return weights, idx


def _dispatch_group(x, idx, weights, E: int, C: int):
    """One token group. x [T,d]; idx/weights [T,k].

    Returns (buf [E,C,d], combine info) where combine info lets the caller
    scatter expert outputs back to tokens.
    """
    T, k = idx.shape
    e_flat = idx.reshape(-1)                       # [T*k]
    t_flat = jnp.repeat(jnp.arange(T), k)          # token id per assignment
    w_flat = weights.reshape(-1)

    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    t_sorted = t_flat[order]
    w_sorted = w_flat[order]

    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[e_sorted]

    # Out-of-capacity writes fall outside [0, C) and are dropped by XLA
    # scatter semantics (mode="drop").
    buf = jnp.zeros((E, C) + x.shape[1:], x.dtype)
    buf = buf.at[e_sorted, pos].set(x[t_sorted], mode="drop")
    return buf, (e_sorted, pos, t_sorted, w_sorted)


def _combine_group(out_buf, combine, T: int, C: int):
    e_sorted, pos, t_sorted, w_sorted = combine
    keep = (pos < C).astype(out_buf.dtype)
    rows = out_buf[e_sorted, jnp.clip(pos, 0, C - 1)]  # [T*k, d]
    rows = rows * (keep * w_sorted.astype(out_buf.dtype))[:, None]
    y = jnp.zeros((T,) + out_buf.shape[2:], out_buf.dtype)
    return y.at[t_sorted].add(rows)


def capacity(tokens_per_group: int, spec) -> int:
    return max(1, math.ceil(tokens_per_group * spec.top_k
                            * spec.capacity_factor / spec.n_experts))


def _pick_groups(B: int, S: int) -> int:
    if S > 1:
        return B  # one group per batch row (shards over the data axis)
    # decode: group tokens so the group axis still shards over data
    for g in (16, 8, 4, 2, 1):
        if B % g == 0 and B // g >= 1:
            return min(g, B)
    return 1


def moe_aux_losses(params, x, spec):
    """(load_balance, z) router losses for x [B,S,d] (fp32 scalars)."""
    xf = x.reshape(-1, x.shape[-1]).astype(F32)
    logits = xf @ params["router"].astype(F32)
    _, idx = _route(logits, spec)
    return load_balance_loss(logits, idx, spec), router_z_loss(logits)


def moe_ffn(params, x, spec, act: str = "swiglu", n_groups=None):
    """x [B, S, d] -> [B, S, d]."""
    from repro.models.perf_flags import current as _perf

    if _perf().moe_a2a:
        from repro.models.moe_a2a import a2a_applicable, moe_ffn_a2a
        from repro.models.sharding_hints import current_hints

        state = current_hints()
        mesh = state[0] if state else None
        if mesh is not None and a2a_applicable(x.shape, spec, mesh):
            fsdp = (("pod", "data") if "pod" in mesh.axis_names
                    else ("data",))
            return moe_ffn_a2a(params, x, spec, act, mesh, fsdp_axes=fsdp)

    B, S, d = x.shape
    G = n_groups or _pick_groups(B, S)
    T = (B * S) // G
    E = spec.n_experts
    C = capacity(T, spec)
    xg = x.reshape(G, T, d)

    logits = jnp.einsum("gtd,de->gte", xg.astype(F32),
                        params["router"].astype(F32))
    weights, idx = jax.vmap(lambda l: _route(l, spec))(logits)

    buf, combine = jax.vmap(lambda xs, i, w: _dispatch_group(xs, i, w, E, C))(
        xg, idx, weights)
    buf = shard_hint(buf, "moe_dispatch")          # [G, E, C, d]

    h1 = jnp.einsum("gecd,edf->gecf", buf, params["w1"])
    if act == "swiglu":
        h = jax.nn.silu(h1) * jnp.einsum("gecd,edf->gecf", buf, params["w3"])
    else:
        h = jax.nn.gelu(h1)
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w2"])
    out_buf = shard_hint(out_buf, "moe_dispatch")

    y = jax.vmap(lambda ob, cmb: _combine_group(ob, cmb, T, C))(out_buf, combine)
    y = shard_hint(y, "moe_out")                   # [G, T, d]
    return y.reshape(B, S, d)


def moe_ffn_dense_reference(params, x, spec, act: str = "swiglu"):
    """Oracle: every token through its top-k experts, no capacity drops."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(F32) @ params["router"].astype(F32)
    weights, idx = _route(logits, spec)
    # all experts densely: [T, E, d_out]
    h1 = jnp.einsum("td,edf->tef", xf, params["w1"])
    if act == "swiglu":
        h = jax.nn.silu(h1) * jnp.einsum("td,edf->tef", xf, params["w3"])
    else:
        h = jax.nn.gelu(h1)
    all_out = jnp.einsum("tef,efd->ted", h, params["w2"])
    sel = jnp.take_along_axis(all_out, idx[..., None], axis=1)  # [T,k,d]
    y = jnp.sum(sel * weights[..., None].astype(sel.dtype), axis=1)
    return y.reshape(B, S, d)


def load_balance_loss(logits, idx, spec):
    """Switch-style auxiliary load-balancing loss (fraction * probability)."""
    E = spec.n_experts
    probs = jax.nn.softmax(logits.astype(F32), axis=-1)  # [T, E]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(idx[..., 0], E)             # top-1 assignment
    ce = jnp.mean(one_hot, axis=0)
    return E * jnp.sum(me * ce)


def router_z_loss(logits):
    """ST-MoE router z-loss: penalizes large router logits (stability)."""
    z = jax.nn.logsumexp(logits.astype(F32), axis=-1)
    return jnp.mean(jnp.square(z))

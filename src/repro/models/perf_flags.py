"""Perf-iteration toggles (EXPERIMENTS.md §Perf).

Each flag is one hillclimb lever with an explicit hypothesis; the dry-run
records which flags were active so before/after roofline terms are
attributable.  Defaults = paper-faithful baseline (all off).

  loss_weight_gather    Force the CE-loss head weight to gather its FSDP
                        shards (replicate the contraction dim) instead of
                        letting GSPMD all-reduce [B,C,V]-sized partial
                        logits over the data axis.  Hypothesis: collective
                        bytes drop by ~tokens*vocab*4B per step for
                        vocab-heavy archs (gemma3, qwen*, internvl2).
  banded_local          Sliding-window layers slice KV to the band instead
                        of masking full-length scores.  Hypothesis: local-
                        attention FLOPs/bytes drop ~S/(chunk+window)x
                        (gemma3 5/6 layers at S=32k: ~10x on those layers).
  decode_cache_seq_shard  Shard decode KV caches over the model axis on the
                        *time* dim (context-parallel decode) when heads
                        don't divide.  Hypothesis: per-device cache bytes
                        (and the decode memory term) drop ~16x for GQA
                        archs with kv_heads < 16 (phi3: kv=10).
  moe_fsdp_tp           MoE experts replicated on the expert dim, 2D-
                        sharded on (d_model->fsdp, d_ff->tp) instead of
                        expert-parallel.  The combine gather becomes local;
                        collective cost becomes FSDP weight gathers +
                        an output psum GSPMD can defer through the combine.
                        Hypothesis: MoE collective bytes drop >5x
                        (qwen3-moe train: 2.25TB/dev baseline).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class PerfFlags:
    loss_weight_gather: bool = False
    banded_local: bool = False
    decode_cache_seq_shard: bool = False
    moe_fsdp_tp: bool = False
    # Expert-parallel MoE with explicit shard_map all-to-all (moe_a2a.py).
    # Hypothesis: replaces the per-layer buffer-sized all-reduce/all-gather
    # pairs of the GSPMD combine with ~2*T_loc*k*d-byte all-to-alls.
    moe_a2a: bool = False
    # Megatron-style sequence parallelism: activations between blocks are
    # sharded [B->fsdp, S->model, D].  Hypothesis: the TP backward dx
    # all-reduces (f32 [B_loc,S,D] per matmul) become all-gather +
    # reduce-scatter pairs and norms/elementwise run on S/16 tokens.
    sequence_parallel: bool = False
    # Gradient compression: force block-boundary cotangents to bf16
    # (identity forward, cast backward).  The HLO ranking shows f32
    # [B_loc, S, D] activation-gradient collectives; hypothesis: those
    # halve, cutting the remaining train collective term up to ~2x.
    bf16_grads: bool = False
    # Route global causal attention through the Pallas flash kernel
    # (kernels/flash_attention.py) — the TPU deployment path for the
    # memory-bound prefill cells (on CPU it runs in interpret mode; the
    # model-level equivalence test uses small shapes).
    flash_kernel: bool = False
    # Remat policy override: save matmul outputs (checkpoint_dots) instead
    # of full recompute.  Hypothesis: backward recompute FLOPs (~1/4 of the
    # train step) disappear at the cost of storing matmul activations.
    remat_dots: bool = False

    @classmethod
    def parse(cls, csv: str) -> "PerfFlags":
        names = [s.strip() for s in csv.split(",") if s.strip()]
        known = {f.name for f in dataclasses.fields(cls)}
        bad = set(names) - known
        if bad:
            raise ValueError(f"unknown perf flags {bad}; known: {known}")
        return cls(**{n: True for n in names})

    def active(self) -> list:
        return [f.name for f in dataclasses.fields(self)
                if getattr(self, f.name)]


def current() -> PerfFlags:
    return getattr(_state, "flags", None) or PerfFlags()


@contextlib.contextmanager
def perf_flags(flags: PerfFlags):
    prev = getattr(_state, "flags", None)
    _state.flags = flags
    try:
        yield
    finally:
        _state.flags = prev

"""Scan wrapper that can unroll into a Python loop.

Why: ``compiled.cost_analysis()`` counts a while-loop body ONCE, not
times-trip-count, so any FLOP/byte/collective statistics extracted from a
scanned model are wrong by ~n_layers.  The dry-run therefore lowers *cost
probes* with all scans unrolled (UNROLL flag), while the production path
keeps ``lax.scan`` (small HLO, fast compiles, native remat).

Use ``repro.models.scan_util.scan`` everywhere a model loops.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

_state = threading.local()


def unrolling() -> bool:
    return getattr(_state, "unroll", False)


@contextlib.contextmanager
def unroll_scans(enable: bool = True):
    prev = unrolling()
    _state.unroll = enable
    try:
        yield
    finally:
        _state.unroll = prev


def scan(f, init, xs, length=None):
    """Drop-in for jax.lax.scan(f, init, xs) honoring the unroll flag."""
    if not unrolling():
        return jax.lax.scan(f, init, xs, length=length)
    if xs is None:
        n = length
        slices = [None] * n
    else:
        leaves = jax.tree.leaves(xs)
        n = leaves[0].shape[0] if leaves else length
        slices = [jax.tree.map(lambda a: a[i], xs) for i in range(n)]
    carry = init
    ys = []
    for i in range(n):
        carry, y = f(carry, slices[i])
        ys.append(y)
    if ys and ys[0] is not None:
        ys_stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys_stacked = None
    return carry, ys_stacked
